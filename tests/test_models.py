"""Per-architecture smoke + cache-consistency tests.

For every assigned architecture (reduced config): one train step on CPU
asserting finite loss and gradient flow, and prefill+decode logits
matching the teacher-forced forward exactly (f32).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import build_model
from repro.optim.adamw import OptConfig
from repro.training import step as training_step

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _inputs(cfg, key, batch=B, seq=S):
    toks = jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)
    kw = {}
    if cfg.frontend == "vision_patches":
        kw["frontend_embeds"] = jax.random.normal(
            key, (batch, cfg.frontend_tokens, cfg.d_model), jnp.float32
        )
    if cfg.is_encoder_decoder:
        kw["enc_embeds"] = jax.random.normal(
            key, (batch, seq, cfg.d_model), jnp.float32
        )
    return toks, kw


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_loss_and_cache_consistency(arch):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = model.init(KEY)

    # --- forward & loss: shapes + finiteness ---
    toks, kw = _inputs(cfg, KEY)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, axis=1)}
    if "frontend_embeds" in kw:
        batch["patch_embeds"] = kw["frontend_embeds"]
    if "enc_embeds" in kw:
        batch["enc_embeds"] = kw["enc_embeds"]
    loss, metrics = jax.jit(lambda p, b: model.loss(p, b))(params, batch)
    assert np.isfinite(float(loss)), (arch, loss)
    assert float(loss) > 0

    logits, _ = model.forward(params, toks, frontend_embeds=kw.get("frontend_embeds"),
                              enc_embeds=kw.get("enc_embeds"), dtype=jnp.float32)
    F = cfg.frontend_tokens if cfg.frontend == "vision_patches" else 0
    assert logits.shape == (B, S + F, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    # --- prefill + decode == teacher-forced forward (f32 exact) ---
    pre = S - 2
    last, cache = model.prefill(
        params, toks[:, :pre], kv_len=S + 4, dtype=jnp.float32,
        frontend_embeds=kw.get("frontend_embeds"), enc_embeds=kw.get("enc_embeds"),
    )
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(logits[:, F + pre - 1]), atol=2e-3, rtol=1e-3
    )
    for i in range(2):
        step_logits, cache = model.decode_step(
            params, cache, toks[:, pre + i : pre + i + 1], dtype=jnp.float32
        )
        np.testing.assert_allclose(
            np.asarray(step_logits),
            np.asarray(logits[:, F + pre + i]),
            atol=2e-3,
            rtol=1e-3,
        )


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mixtral-8x7b", "mamba2-2.7b"])
def test_train_step_decreases_loss(arch):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    state = training_step.init_state(model, KEY)
    step = jax.jit(
        training_step.make_train_step(model, OptConfig(lr=1e-2, warmup_steps=1),
                                      remat=None)
    )
    toks = jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, 1)}
    losses = []
    for _ in range(5):
        state, m = step(state, batch)  # same batch: loss must drop
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses
    assert all(np.isfinite(l) for l in losses)


def test_param_counts_match_analytic():
    """Declared params match the analytic count used for MODEL_FLOPS."""
    from repro.models.params import count_params

    for arch in ARCHS:
        cfg = get_config(arch, reduced=True)
        model = build_model(cfg)
        params = model.init(KEY)
        n = count_params(params)
        a = cfg.num_params()
        assert abs(n - a) / max(a, 1) < 0.02, (arch, n, a)


def test_microbatching_equivalence():
    """Grad accumulation over microbatches == single big batch."""
    cfg = get_config("qwen2-0.5b", reduced=True)
    model = build_model(cfg)
    state1 = training_step.init_state(model, KEY)
    state2 = jax.tree.map(lambda x: x, state1)
    toks = jax.random.randint(KEY, (8, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, 1)}
    s1 = jax.jit(training_step.make_train_step(model, OptConfig(), microbatches=1, remat=None))
    s4 = jax.jit(training_step.make_train_step(model, OptConfig(), microbatches=4, remat=None))
    n1, m1 = s1(state1, batch)
    n4, m4 = s4(state2, batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 5e-3
    for a, b in zip(jax.tree.leaves(n1["params"]), jax.tree.leaves(n4["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-3)


def test_kv_quant_decode_close_to_exact():
    """int8 KV cache: decode logits within 1% of the f32-cache path."""
    from repro.models.transformer import LM

    cfg = get_config("granite-8b", reduced=True)
    m0, mq = LM(cfg), LM(cfg, kv_quant=True)
    params = m0.init(KEY)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    ref, _ = m0.forward(params, toks, dtype=jnp.float32)
    _, cache = mq.prefill(params, toks[:, :14], kv_len=24, dtype=jnp.float32)
    assert cache["blocks"]["sub0"]["attn"]["k_q"].dtype == jnp.int8
    scale = float(jnp.max(jnp.abs(ref)))
    for i in range(2):
        logits, cache = mq.decode_step(
            params, cache, toks[:, 14 + i : 15 + i], dtype=jnp.float32
        )
        err = float(jnp.max(jnp.abs(logits - ref[:, 14 + i])))
        assert err / scale < 0.02, (i, err, scale)


def test_causality_property():
    """Changing future tokens must not change past logits (all archs with
    attention; the cache-consistency test already covers SSM recurrence)."""
    for arch in ("granite-8b", "gemma2-2b", "jamba-v0.1-52b"):
        cfg = get_config(arch, reduced=True)
        model = build_model(cfg)
        params = model.init(KEY)
        t1 = jax.random.randint(KEY, (1, 12), 0, cfg.vocab_size)
        t2 = t1.at[:, 8:].set((t1[:, 8:] + 7) % cfg.vocab_size)
        l1, _ = model.forward(params, t1, dtype=jnp.float32)
        l2, _ = model.forward(params, t2, dtype=jnp.float32)
        np.testing.assert_allclose(
            np.asarray(l1[:, :8]), np.asarray(l2[:, :8]), atol=1e-5,
            err_msg=arch,
        )


def test_sliding_window_property():
    """Tokens outside the L-layer receptive field (L x window) must not
    affect the last logit; tokens just inside it must."""
    cfg = get_config("mixtral-8x7b", reduced=True)  # 2 layers, window=8
    model = build_model(cfg)
    params = model.init(KEY)
    w, L = cfg.sliding_window, cfg.num_layers
    S = L * w + 12
    t1 = jax.random.randint(KEY, (1, S), 0, cfg.vocab_size)
    # outside the receptive field of the last position: < S-1 - L*w
    cut = S - 1 - L * w
    t2 = t1.at[:, :cut].set((t1[:, :cut] + 3) % cfg.vocab_size)
    l1, _ = model.forward(params, t1, dtype=jnp.float32)
    l2, _ = model.forward(params, t2, dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(l1[:, -1]), np.asarray(l2[:, -1]), atol=1e-5
    )
    # sanity: a change INSIDE the window does propagate
    t3 = t1.at[:, S - 2].set((t1[:, S - 2] + 3) % cfg.vocab_size)
    l3, _ = model.forward(params, t3, dtype=jnp.float32)
    assert float(jnp.max(jnp.abs(l3[:, -1] - l1[:, -1]))) > 1e-4
