import os
import sys
from pathlib import Path

# tests must see ONE device (only dryrun.py forces 512); keep any
# inherited flag out of the test environment.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import pytest  # noqa: E402

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)


def pytest_addoption(parser):
    parser.addoption(
        "--update-golden",
        action="store_true",
        default=False,
        help="regenerate golden snapshot files (tests/golden/) instead of "
        "comparing against them",
    )


@pytest.fixture
def update_golden(request):
    return request.config.getoption("--update-golden")
