import os
import sys
from pathlib import Path

# tests must see ONE device (only dryrun.py forces 512); keep any
# inherited flag out of the test environment.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
