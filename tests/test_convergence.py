"""Convergence control plane + fault harness (core/convergence.py,
core/chaos.py): desired-capacity policies, death healing with seeded
backoff, the chaos day's terminal/conservation/replay guarantees, and
the decayed-calibration re-learn after a worker replacement."""
import math

import numpy as np
import pytest

from repro.core import (
    FaultModel,
    PoolSpec,
    Query,
    QueryWork,
    ServiceLevel,
    SimConfig,
    Simulation,
)
from repro.core.calibration import LiveCalibrator
from repro.core.chaos import (
    ChaosConfig,
    ChaosFaultModel,
    LiveChaos,
    PoolChaos,
    wire_sim_chaos,
)
from repro.core.clusters import AutoscaleConfig
from repro.core.convergence import (
    BacklogTriggerPolicy,
    HookPolicy,
    SchedulePolicy,
)
from repro.core.cost_model import CostModel
from repro.core.pools import build_pool
from repro.core.query import reset_qids
from repro.core.workload import generate, scaled_patterns


def _neutral_autoscale(**kw):
    """Autoscale enabled purely as the policy tick source: the reactive
    watermarks are unreachable, so only appended policies can act."""
    kw.setdefault("enabled", True)
    kw.setdefault("high_watermark", 10**9)
    kw.setdefault("low_watermark", -1)
    kw.setdefault("min_chips", 1)
    kw.setdefault("max_chips", 10**6)
    return AutoscaleConfig(**kw)


def _spec(chips=8, autoscale=None, name="vm"):
    return PoolSpec(name=name, kind="reserved", chips=chips, mode="sos",
                    slice_chips=4, autoscale=autoscale)


def _chaos_day(seed=7, chaos_seed=11, horizon_s=20_000.0, **chaos_kw):
    reset_qids()
    qs = generate(horizon_s=horizon_s, seed=seed,
                  patterns=scaled_patterns(0.5))
    cfg = SimConfig(
        seed=seed, horizon_s=horizon_s,
        autoscale=AutoscaleConfig(enabled=True),
        chaos=ChaosConfig(seed=chaos_seed, horizon_s=horizon_s,
                          **chaos_kw),
    )
    return Simulation(cfg).run(qs)


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------

def test_schedule_policy_expands_period_and_fires_latest_due():
    pol = SchedulePolicy(period_s=100.0, offset_s=50.0, chips=8,
                        horizon_s=350.0)
    assert pol.entries == [(50.0, 8), (150.0, 8), (250.0, 8), (350.0, 8)]
    assert pol.next_fire_s(0.0) == 50.0
    assert pol.desired(None, 40.0) is None  # nothing due yet
    # two firings elapsed at once: consumed in order, latest wins
    pol2 = SchedulePolicy(entries=[(10.0, 4), (20.0, 16)])
    assert pol2.desired(None, 25.0) == 16
    assert pol2.next_fire_s(25.0) == math.inf
    assert pol2.desired(None, 30.0) is None  # one-shot: never re-fires


def test_schedule_policy_rejects_bad_args():
    with pytest.raises(ValueError):
        SchedulePolicy()
    with pytest.raises(ValueError):
        SchedulePolicy(period_s=0.0, chips=4)


def test_schedule_policy_scales_pool_in_simulation():
    reset_qids()
    qs = generate(horizon_s=3600.0, seed=0, patterns=scaled_patterns(0.2))
    cfg = SimConfig(
        seed=0, horizon_s=3600.0, events=True,
        pools=[_spec(chips=8, autoscale=_neutral_autoscale(
            scale_delay_s=60.0)),
            PoolSpec(name="cf", kind="elastic", chips=64, startup_s=2.0)],
        convergence_policies={"vm": [
            SchedulePolicy(entries=[(600.0, 16), (1800.0, 8)]),
        ]},
    )
    res = Simulation(cfg).run(qs)
    scales = [r for r in res.events.rows() if r[1] == "scale"]
    assert [(dict(r[3])["pool"], dict(r[3])["to_chips"]) for r in scales] \
        == [("vm", 16), ("vm", 8)]  # (pool, to_chips) in firing order
    # the change lands after the provisioning delay
    assert scales[0][2] >= 600.0
    assert dict(scales[0][3])["at_s"] >= scales[0][2] + 60.0 - 1e-9
    assert all(q.state == "done" for q in res.queries)


def test_hook_policy_overrides_reactive_trigger():
    reset_qids()
    qs = generate(horizon_s=1800.0, seed=1, patterns=scaled_patterns(0.2))
    cfg = SimConfig(
        seed=1, horizon_s=1800.0, events=True,
        pools=[_spec(chips=8, autoscale=_neutral_autoscale(
            scale_delay_s=30.0)),
            PoolSpec(name="cf", kind="elastic", chips=64, startup_s=2.0)],
        convergence_policies={"vm": [
            HookPolicy(lambda pool, now: 12 if now >= 900.0 else None),
        ]},
    )
    res = Simulation(cfg).run(qs)
    scales = [r for r in res.events.rows() if r[1] == "scale"]
    assert scales and dict(scales[0][3])["to_chips"] == 12


def test_unknown_or_elastic_pool_in_policies_raises():
    cfg = SimConfig(pools=[_spec(), PoolSpec(name="cf", kind="elastic",
                                             chips=8, startup_s=1.0)],
                    convergence_policies={"nope": [BacklogTriggerPolicy()]})
    with pytest.raises(ValueError, match="unknown pool"):
        Simulation(cfg)
    cfg2 = SimConfig(pools=[_spec(), PoolSpec(name="cf", kind="elastic",
                                              chips=8, startup_s=1.0)],
                     convergence_policies={"cf": [BacklogTriggerPolicy()]})
    with pytest.raises(ValueError, match="no convergence plane"):
        Simulation(cfg2)


def test_legacy_autoscale_day_unchanged_by_converger_refactor():
    """The watermark policy now lives on PoolConverger; an enabled-
    autoscale day must be byte-identical to the same day with the
    policy evaluated per tick (regression: the refactor may not change
    a single float)."""
    def day(events):
        reset_qids()
        qs = generate(horizon_s=7200.0, seed=5, patterns=scaled_patterns(0.5))
        cfg = SimConfig(seed=5, horizon_s=7200.0, events=events,
                        autoscale=AutoscaleConfig(enabled=True))
        return Simulation(cfg).run(qs)

    a, b = day(events=False), day(events=True)
    sig = lambda res: sorted(  # noqa: E731
        (q.qid, q.finish_time, q.cost, q.chip_seconds, q.cluster)
        for q in res.queries
    )
    assert sig(a) == sig(b)  # the feed is an observer, never an actor
    assert a.events is None and b.events is not None


# ---------------------------------------------------------------------------
# seeded chaos determinism
# ---------------------------------------------------------------------------

def test_pool_chaos_schedules_are_seeded_and_name_stable():
    cfg = ChaosConfig(seed=3, n_deaths=5, stall_prob=0.5, horizon_s=1000.0)
    a, b = PoolChaos(cfg, "vm"), PoolChaos(cfg, "vm")
    assert a.death_times_s == b.death_times_s
    assert a.death_times_s == sorted(a.death_times_s)
    assert [a.draw_provision_failures() for _ in range(20)] == \
           [b.draw_provision_failures() for _ in range(20)]
    other = PoolChaos(cfg, "spot")
    assert other.death_times_s != a.death_times_s
    # exponential backoff, capped
    assert a.backoff_s(0) == cfg.backoff_base_s
    assert a.backoff_s(1) == 2 * cfg.backoff_base_s
    assert a.backoff_s(99) == cfg.backoff_cap_s


def test_pool_chaos_death_cursor_exhausts_to_inf():
    ch = PoolChaos(ChaosConfig(seed=0, n_deaths=2, horizon_s=10.0), "vm")
    assert ch.next_death_s() == ch.death_times_s[0]
    ch.pop_death()
    ch.pop_death()
    assert ch.next_death_s() == math.inf


def test_provision_failures_respect_max_stalls():
    ch = PoolChaos(ChaosConfig(seed=1, stall_prob=1.0, max_stalls=3), "vm")
    assert all(ch.draw_provision_failures() == 3 for _ in range(5))


def test_slow_host_fault_scales_wall_and_bill_together():
    """Slow hosts stretch wall time and billed chip-seconds by the same
    factor — conservation (billed == wall * chips) holds by
    construction."""
    fm = ChaosFaultModel(slow_hosts=frozenset({1}), slow_factor=3.0,
                         n_hosts=4)
    rng = np.random.default_rng(0)
    q_slow = Query(work=QueryWork(), sla=ServiceLevel.RELAXED,
                   submit_time=0.0)
    q_slow.qid = 5  # 5 % 4 == 1: slow slot
    t, billed, retries = fm.stage_execution(2.0, 4, rng, q_slow)
    assert (t, billed, retries) == (6.0, 24.0, 0)
    q_fast = Query(work=QueryWork(), sla=ServiceLevel.RELAXED,
                   submit_time=0.0)
    q_fast.qid = 4  # 4 % 4 == 0: clean slot
    t, billed, _ = fm.stage_execution(2.0, 4, rng, q_fast)
    assert (t, billed) == (2.0, 8.0)


def test_live_chaos_kill_is_seeded_and_fires_once_per_site():
    a = LiveChaos(ChaosConfig(seed=9, live_death_prob=0.5))
    b = LiveChaos(ChaosConfig(seed=9, live_death_prob=0.5))
    verdicts_a = [a.should_kill(q, s) for q in range(20) for s in range(3)]
    first_b = [b.should_kill(q, s) for q in range(20) for s in range(3)]
    assert verdicts_a == first_b  # same seed, same kills
    assert any(verdicts_a)
    # a site never re-fires: the resumed stage survives
    again = [a.should_kill(q, s) for q in range(20) for s in range(3)]
    assert not any(again)
    assert not LiveChaos(ChaosConfig(seed=9)).should_kill(0, 0)  # p=0


# ---------------------------------------------------------------------------
# the chaos day: terminal, conserving, replayable
# ---------------------------------------------------------------------------

def test_chaos_day_every_query_terminal_and_conserving(monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    from repro.core import sanitize
    monkeypatch.setattr(sanitize, "_ENABLED", True)
    res = _chaos_day(n_deaths=8, stall_prob=0.4, slow_host_frac=0.25,
                     slow_factor=1.5)
    assert res.queries and all(q.state == "done" for q in res.queries)
    counts = res.events.counts()
    assert counts.get("death", 0) > 0
    assert counts.get("replace", 0) > 0
    assert counts.get("provision_retry", 0) > 0
    # billing conservation over the whole fault-injected day
    traces = {id(q.stage_trace): q.stage_trace
              for q in res.queries if q.stage_trace}
    assert sum(q.cost for q in res.queries) == pytest.approx(
        sum(e.cost for tr in traces.values() for e in tr), rel=1e-9
    )


def test_chaos_day_replays_bit_identical():
    a = _chaos_day(n_deaths=6, stall_prob=0.3)
    b = _chaos_day(n_deaths=6, stall_prob=0.3)
    assert a.events.fingerprint() == b.events.fingerprint()
    assert sorted((q.qid, q.finish_time, q.cost) for q in a.queries) == \
           sorted((q.qid, q.finish_time, q.cost) for q in b.queries)
    # a different chaos seed is a DIFFERENT day
    c = _chaos_day(chaos_seed=12, n_deaths=6, stall_prob=0.3)
    assert c.events.fingerprint() != a.events.fingerprint()


def test_chaos_death_capacity_heals_back_to_desired():
    res = _chaos_day(n_deaths=5)
    deaths = [r for r in res.events.rows() if r[1] == "death"]
    replaces = [r for r in res.events.rows() if r[1] == "replace"]
    assert deaths, "no deaths landed despite n_deaths=5"
    # every death eventually schedules replacement capacity
    assert replaces
    for r in replaces:
        payload = dict(r[3])
        assert payload["to_chips"] > payload["from_chips"]


def test_wire_sim_chaos_targets_reserved_pools_only():
    vm = build_pool(_spec(chips=8), use_calibration=False)
    cf = build_pool(PoolSpec(name="cf", kind="elastic", chips=8,
                             startup_s=1.0), use_calibration=False)
    wire_sim_chaos([vm, cf], ChaosConfig(seed=0, n_deaths=3,
                                         slow_host_frac=0.5,
                                         slow_factor=2.0))
    assert vm._chaos is not None and vm._chaos.death_times_s
    assert getattr(cf, "_chaos", None) is None
    # slow hosts are a fleet property: both pools get the wrapper
    assert isinstance(vm.fault, ChaosFaultModel)
    assert isinstance(cf.fault, ChaosFaultModel)
    # death_pools narrows deaths but keeps stalls everywhere
    vm2 = build_pool(_spec(chips=8), use_calibration=False)
    spot = build_pool(_spec(chips=8, name="spot"), use_calibration=False)
    wire_sim_chaos([vm2, spot], ChaosConfig(
        seed=0, n_deaths=3, stall_prob=0.5, death_pools=("spot",)))
    assert vm2._chaos.death_times_s == []
    assert spot._chaos.death_times_s
    assert vm2._chaos.stall_prob == 0.5


def test_chaos_preserves_base_fault_model_fields():
    vm = build_pool(_spec(chips=8), use_calibration=False)
    vm.fault = FaultModel(failure_prob=0.25, straggler_prob=0.5,
                          straggler_scale=2.0)
    wire_sim_chaos([vm], ChaosConfig(seed=0, slow_host_frac=0.5,
                                     slow_factor=2.0))
    assert vm.fault.failure_prob == 0.25
    assert vm.fault.straggler_prob == 0.5
    assert vm.fault.straggler_scale == 2.0
    assert vm.fault.slow_factor == 2.0


# ---------------------------------------------------------------------------
# decayed calibration: the replacement re-learns in a few stages
# ---------------------------------------------------------------------------

def _mis_declared_pool(declared=2.0):
    return build_pool(
        PoolSpec(name="vm", kind="reserved", chips=64, mode="sos",
                 slice_chips=16, speed_factor=declared),
        use_calibration=False,
    )


def _feed_walls(cal, pool, w, truth_speed, n):
    truth = CostModel(use_calibration=False, speed_factor=truth_speed)
    stages = truth.plan(w, 16).stages
    for k in range(n):
        s = stages[k % len(stages)]
        cal.observe(pool, w, k % len(stages), 16, s.time_s)


def test_decay_relearns_replacement_speed_within_five_stages():
    """After a worker replacement the pool EWMA is decayed: the next 5
    measured walls dominate the estimate, so the fitted speed lands
    within ~10% of the replacement's truth — against ~40% error for an
    undecayed EWMA at the same alpha."""
    w = QueryWork(arch="paper-default", prompt_tokens=200_000,
                  output_tokens=64)
    decayed_pool = _mis_declared_pool(declared=2.0)
    control_pool = _mis_declared_pool(declared=2.0)
    decayed = LiveCalibrator(alpha=0.25, min_samples=6)
    control = LiveCalibrator(alpha=0.25, min_samples=6)
    for cal, pool in ((decayed, decayed_pool), (control, control_pool)):
        _feed_walls(cal, pool, w, truth_speed=1.0, n=6)
        assert cal.maybe_apply(pool)
        assert pool.cost_model.effective_speed_factor == pytest.approx(1.0)
    # the dead worker's replacement actually runs at 4x declared basis
    assert decayed.decay("vm")
    target = math.log(0.5)  # measured/predicted vs declared=2, truth=4
    _feed_walls(decayed, decayed_pool, w, truth_speed=4.0, n=5)
    _feed_walls(control, control_pool, w, truth_speed=4.0, n=5)
    err = lambda cal: abs(math.log(cal.ratio("vm")) - target)  # noqa: E731
    assert err(decayed) < 0.1
    assert err(control) > 0.3
    assert err(decayed) < err(control) / 4
    # confidence re-earned: the sixth wall re-arms the hot swap and the
    # fitted speed tracks the replacement
    _feed_walls(decayed, decayed_pool, w, truth_speed=4.0, n=1)
    assert decayed.maybe_apply(decayed_pool)
    assert decayed_pool.cost_model.effective_speed_factor == pytest.approx(
        4.0, rel=0.1
    )


def test_decay_without_state_is_a_noop():
    cal = LiveCalibrator(alpha=0.25, min_samples=2)
    assert not cal.decay("vm")


def test_decay_does_not_perturb_legacy_observe_path():
    """States that never decayed must update with the plain alpha —
    decay support cannot change a single float for engines that never
    replace a worker."""
    w = QueryWork(arch="paper-default", prompt_tokens=200_000,
                  output_tokens=64)
    pool_a = _mis_declared_pool()
    pool_b = _mis_declared_pool()
    a = LiveCalibrator(alpha=0.25, min_samples=3)
    b = LiveCalibrator(alpha=0.25, min_samples=3)
    _feed_walls(a, pool_a, w, truth_speed=1.0, n=7)
    _feed_walls(b, pool_b, w, truth_speed=1.0, n=7)
    assert a.ratio("vm") == b.ratio("vm")
