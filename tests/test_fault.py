"""Fault tolerance: checkpoint/restart, exact resume, elastic restore."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.store import CheckpointStore
from repro.launch.train import SimulatedFailure, train


def test_checkpoint_roundtrip(tmp_path):
    store = CheckpointStore(tmp_path)
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((2, 2), jnp.bfloat16), "d": jnp.zeros((), jnp.int32)},
    }
    store.save(3, tree, extra={"stream": {"step": 7, "seed": 0}})
    template = jax.tree.map(lambda x: np.zeros(x.shape, x.dtype), tree)
    restored, extra = store.restore(None, template)
    assert extra["stream"]["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_checkpoint_gc_keeps_latest(tmp_path):
    store = CheckpointStore(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        store.save(s, {"x": jnp.zeros(1)})
    assert store.steps() == [3, 4]


def test_async_save_is_complete(tmp_path):
    store = CheckpointStore(tmp_path)
    tree = {"x": jnp.arange(1000, dtype=jnp.float32)}
    store.save(1, tree, async_=True)
    store.wait()
    restored, _ = store.restore(1, {"x": np.zeros(1000, np.float32)})
    np.testing.assert_array_equal(np.asarray(restored["x"]), np.arange(1000))


def test_crash_restart_exact_resume(tmp_path):
    """Loss trajectory with a mid-run crash+restart must equal the
    uninterrupted run (exact data-stream resume + state restore)."""
    kw = dict(steps=12, batch=4, seq=32, ckpt_every=4, log_every=100)
    ref = train("qwen2-0.5b", ckpt_dir=str(tmp_path / "ref"), **kw)

    with pytest.raises(SimulatedFailure):
        train("qwen2-0.5b", ckpt_dir=str(tmp_path / "ft"), fail_at=7, **kw)
    resumed = train("qwen2-0.5b", ckpt_dir=str(tmp_path / "ft"), **kw)

    assert resumed["steps_run"] == 12 - 4  # resumed from step 4's ckpt
    np.testing.assert_allclose(
        ref["losses"][-resumed["steps_run"]:], resumed["losses"], atol=1e-5
    )
    # final params identical
    for a, b in zip(
        jax.tree.leaves(ref["state"]["params"]),
        jax.tree.leaves(resumed["state"]["params"]),
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_elastic_restore_reshards(tmp_path):
    """A checkpoint written unsharded restores onto a (1,1) mesh with
    NamedShardings (the elastic path used when pod counts change)."""
    from repro.launch.mesh import make_local_mesh
    from repro.parallel.sharding import TRAIN_RULES, tree_shardings

    store = CheckpointStore(tmp_path)
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    store.save(1, tree)
    mesh = make_local_mesh(1, 1)
    sh = tree_shardings(
        {"w": ("fsdp", "ff")},
        {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)},
        TRAIN_RULES,
        mesh,
    )
    restored, _ = store.restore(1, {"w": np.zeros((8, 8), np.float32)}, sh)
    assert restored["w"].sharding.mesh.shape == {"data": 1, "model": 1}
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
