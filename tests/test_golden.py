"""Golden-trace regression gate: a fixed-seed ~500-query day through the
FULL engine (3-pool registry, SOS slices, preemption, spill, spill-back,
backlog autoscale, stage faults) snapshotted to tests/golden/sim_trace.json.

Any behavioral drift — routing, billing, autoscale cadence, fault
sampling order — shows up as a diff against the snapshot. Regenerate
intentionally with:

    PYTHONPATH=src python -m pytest tests/test_golden.py --update-golden
"""
import json
from pathlib import Path

import numpy as np
import pytest

from repro.core import (
    FaultModel,
    Policy,
    PoolSpec,
    SimConfig,
    Simulation,
    SLAConfig,
    generate,
    scaled_patterns,
)
from repro.core.clusters import AutoscaleConfig

GOLDEN = Path(__file__).parent / "golden" / "sim_trace.json"


def _golden_config() -> SimConfig:
    return SimConfig(
        policy=Policy.FORCE,
        use_calibration=False,
        seed=42,
        fault=FaultModel(failure_prob=0.02, straggler_prob=0.02),
        sla=SLAConfig(
            vm_overload_threshold=8,
            preempt_best_effort=True,
            spill_enabled=True,
            spill_back_enabled=True,
            spill_back_low_backlog_s=30.0,
        ),
        pools=[
            PoolSpec(name="vm", kind="reserved", chips=32, mode="sos",
                     slice_chips=16,
                     autoscale=AutoscaleConfig(
                         enabled=True, trigger="backlog", min_chips=32,
                         max_chips=64, step_chips=16, scale_delay_s=120.0,
                         backlog_high_s=60.0, backlog_low_s=5.0)),
            PoolSpec(name="spot", kind="reserved", chips=64, mode="sos",
                     slice_chips=16, speed_factor=0.25,
                     price_multiplier=0.15),
            PoolSpec(name="cf", kind="elastic", chips=64, startup_s=2.0,
                     price_multiplier=10.0),
        ],
    )


def _snapshot() -> dict:
    # ~500 queries: Table 1 (911/day) scaled by 0.55 on the 4h horizon
    qs = generate(horizon_s=14_400.0, seed=42, patterns=scaled_patterns(0.55))
    res = Simulation(_golden_config()).run(qs)
    by = res.by_sla()
    per_sla = {}
    for k, queries in by.items():
        waits = [q.queue_wait or 0.0 for q in queries]
        per_sla[k] = {
            "n": len(queries),
            "p95_wait_s": round(float(np.percentile(waits, 95)), 4)
            if waits else 0.0,
            "cost": round(sum(q.cost for q in queries), 4),
            "stages": sum(len(q.stage_trace) for q in queries),
        }
    s = res.summary()
    return {
        "n": len(res.queries),
        "finished": s["finished"],
        "total_cost": round(res.total_cost(), 4),
        "per_sla": per_sla,
        "stages": s["stages"],
        "preemptions": s["preemptions"],
        "spilled": s["spilled"],
        "spill_backs": s["spill_backs"],
        "retries": s["retries"],
        "violations": s["violations"],
        "by_pool": {
            name: sum(q.cluster == name for q in res.queries)
            for name in ("vm", "spot", "cf")
        },
    }


def _diff(golden: dict, got: dict, prefix: str = "") -> list:
    out = []
    for key in sorted(set(golden) | set(got)):
        g, o = golden.get(key), got.get(key)
        path = f"{prefix}{key}"
        if isinstance(g, dict) and isinstance(o, dict):
            out.extend(_diff(g, o, prefix=path + "."))
        elif g != o:
            out.append(f"  {path}: golden={g!r} got={o!r}")
    return out


def test_golden_trace_matches_snapshot(update_golden):
    got = _snapshot()
    if update_golden:
        GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        GOLDEN.write_text(json.dumps(got, indent=2, sort_keys=True) + "\n")
        pytest.skip(f"golden snapshot regenerated at {GOLDEN}")
    assert GOLDEN.exists(), (
        f"missing {GOLDEN}; generate it with --update-golden"
    )
    golden = json.loads(GOLDEN.read_text())
    diff = _diff(golden, got)
    assert not diff, (
        "simulator behavior drifted from the golden trace "
        "(regenerate intentionally with --update-golden):\n"
        + "\n".join(diff)
    )


def test_golden_run_is_deterministic():
    """The snapshot is reproducible within one process — a prerequisite
    for the golden gate to mean anything."""
    assert _snapshot() == _snapshot()
