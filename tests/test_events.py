"""Audit event feed (core/events.py): append-only capped ring,
deterministic canonical encoding, and the replay fingerprint the chaos
gate compares (benchmarks/chaos.py)."""
import json
import threading

from repro.core.events import DEFAULT_CAP, EventFeed, row_json


def test_emit_assigns_sequential_seqs_and_keeps_payload_sorted():
    feed = EventFeed()
    feed.emit("place", 1.0, qid=3, pool="vm")
    feed.emit("scale", 2.0, pool="vm", to_chips=8, from_chips=4)
    rows = feed.rows()
    assert [r[0] for r in rows] == [0, 1]
    assert rows[0][1] == "place" and rows[0][2] == 1.0
    # payload items are sorted at emit time — encoding order can never
    # depend on keyword order at the call site
    assert rows[1][3] == (
        ("from_chips", 4), ("pool", "vm"), ("to_chips", 8)
    )
    assert len(feed) == 2 and feed.total == 2 and feed.dropped == 0


def test_cap_drops_oldest_and_counts_dropped():
    feed = EventFeed(cap=3)
    for i in range(10):
        feed.emit("e", float(i), i=i)
    assert len(feed) == 3
    assert feed.total == 10
    assert feed.dropped == 7
    assert [r[0] for r in feed.rows()] == [7, 8, 9]
    assert feed.tail(2) == feed.rows()[-2:]


def test_default_cap_bounds_memory():
    assert EventFeed().cap == DEFAULT_CAP


def test_counts_by_kind():
    feed = EventFeed()
    for _ in range(3):
        feed.emit("place", 0.0, qid=0)
    feed.emit("death", 1.0, pool="vm")
    assert dict(feed.counts()) == {"place": 3, "death": 1}


def test_row_json_is_canonical_and_parseable():
    feed = EventFeed()
    feed.emit("fuse", 2.5, qid=7, members=(1, 2, 3))
    s = row_json(feed.rows()[0])
    assert " " not in s  # compact separators: stable fingerprint input
    seq, kind, t_s, items = json.loads(s)
    assert (seq, kind, t_s) == (0, "fuse", 2.5)
    assert items == [["members", [1, 2, 3]], ["qid", 7]]


def test_fingerprint_deterministic_and_sensitive():
    def build(n, salt=0):
        feed = EventFeed()
        for i in range(n):
            feed.emit("e", float(i), i=i + salt)
        return feed

    assert build(50).fingerprint() == build(50).fingerprint()
    assert build(50).fingerprint() != build(51).fingerprint()
    assert build(50).fingerprint() != build(50, salt=1).fingerprint()


def test_fingerprint_covers_dropped_prefix_via_total():
    """Two feeds with identical surviving rows but different histories
    must not collide: the fingerprint binds the total emit count."""
    a = EventFeed(cap=2)
    for i in range(5):
        a.emit("e", float(i), i=i)
    b = EventFeed(cap=2)
    for i in range(3, 5):
        b.emit("e", float(i), i=i)
    # surviving rows carry different seqs AND totals differ — either
    # alone breaks the collision
    assert a.fingerprint() != b.fingerprint()


def test_concurrent_emits_never_lose_or_duplicate_seqs():
    feed = EventFeed()
    n_threads, per = 8, 500

    def emitter(k):
        for i in range(per):
            feed.emit("e", 0.0, worker=k, i=i)

    threads = [threading.Thread(target=emitter, args=(k,))
               for k in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    rows = feed.rows()
    assert feed.total == n_threads * per
    assert sorted(r[0] for r in rows) == list(range(n_threads * per))
