"""The one-switch runtime sanitizer: _GUARDED_BY-driven lock asserts,
post-run conservation / trace-stitching checks, and the bit-identity
contract (sanitized run == unsanitized run, per query)."""
import threading

import pytest

from repro.core import SimConfig, Simulation, sanitize
from repro.core.query import Query, QueryWork, reset_qids
from repro.core.sanitize import SanitizeError, check_result, guard
from repro.core.workload import generate, scaled_patterns


@pytest.fixture
def sanitized():
    prev = sanitize.set_enabled(True)
    yield
    sanitize.set_enabled(prev)


# --- guard(): runtime lock asserts from the _GUARDED_BY registry ----------

class _Guarded:
    _GUARDED_BY = {"state": "_lock", "queue": ("_mu", "_cv")}

    def __init__(self):
        self._lock = threading.Lock()
        self._mu = threading.RLock()
        self._cv = threading.Condition(self._mu)
        self.state = 0
        self.queue = []


def test_guard_raises_without_lock(sanitized):
    obj = _Guarded()
    with pytest.raises(SanitizeError, match="state.*_lock"):
        guard(obj, "state")


def test_guard_passes_with_lock_held(sanitized):
    obj = _Guarded()
    with obj._lock:
        guard(obj, "state")
    # Condition implies the underlying RLock — either satisfies
    with obj._cv:
        guard(obj, "queue")
    with obj._mu:
        guard(obj, "queue")
    with pytest.raises(SanitizeError):
        guard(obj, "queue")


def test_guard_ignores_unregistered_attrs_and_off_switch():
    obj = _Guarded()
    guard(obj, "other")  # not in the registry: no-op
    prev = sanitize.set_enabled(False)
    try:
        guard(obj, "state")  # switch off: no-op even unguarded
    finally:
        sanitize.set_enabled(prev)


def test_live_registries_exist():
    # the registries RL001 lints are the same ones guard() reads
    from repro.core.calibration import LiveCalibrator
    from repro.core.scheduler import CrossPoolFusionIndex

    assert CrossPoolFusionIndex._GUARDED_BY == {"_buckets": "_lock"}
    assert set(LiveCalibrator._GUARDED_BY) == {"_state", "_tables", "_refs"}


def test_guard_catches_unlocked_fusion_index_access(sanitized):
    from repro.core.scheduler import CrossPoolFusionIndex

    idx = CrossPoolFusionIndex()
    with pytest.raises(SanitizeError):
        guard(idx, "_buckets")
    with idx._lock:
        guard(idx, "_buckets")


# --- check_result(): post-run population asserts --------------------------

def _small_day(sanitize_flag, n_factor=0.5, seed=11):
    reset_qids()
    qs = generate(seed=seed, patterns=scaled_patterns(n_factor))
    cfg = SimConfig(seed=seed, fuse_queries=True, cross_pool_fusion=True,
                    sanitize=sanitize_flag)
    return Simulation(cfg).run(qs)


def test_check_result_passes_on_real_run():
    res = _small_day(True)
    assert res.queries


def test_check_result_catches_billing_drift(sanitized):
    res = _small_day(False)
    victim = next(q for q in res.queries
                  if q.stage_trace and q.fused_with == 0)
    victim.chip_seconds *= 1.5  # corrupt the bill, keep the trace
    with pytest.raises(SanitizeError, match="billed|account"):
        check_result(res.queries)


def test_check_result_catches_dropped_stage(sanitized):
    res = _small_day(False)
    victim = next(q for q in res.queries if len(q.stage_trace or ()) >= 2)
    del victim.stage_trace[0]  # a stage vanishes from the record
    with pytest.raises(SanitizeError, match="contiguous"):
        check_result(res.queries)


def test_check_result_catches_overlapping_stages(sanitized):
    res = _small_day(False)
    victim = next(q for q in res.queries if len(q.stage_trace or ()) >= 2)
    tr = victim.stage_trace
    # stage 1 now starts well before stage 0 finishes
    tr[1] = tr[1]._replace(start=tr[0].finish - 1.0)
    with pytest.raises(SanitizeError, match="overlap"):
        check_result(res.queries)


def test_check_result_off_switch_is_a_noop():
    from repro.core.sla import ServiceLevel

    q = Query(work=QueryWork(prompt_tokens=8, output_tokens=8),
              sla=ServiceLevel.IMMEDIATE, submit_time=0.0)
    q.chip_seconds = 123.0  # no trace backs this bill
    prev = sanitize.set_enabled(False)
    try:
        check_result([q])  # sanitizer off: nothing runs
    finally:
        sanitize.set_enabled(prev)


# --- bit-identity: the sanitizer is an observer ---------------------------

def _rows(res):
    return [
        (q.qid, q.cost, q.chip_seconds, q.start_time, q.finish_time,
         q.cluster, q.retries, q.preemptions, q.spilled, q.spill_backs)
        for q in res.queries
    ]


def test_sanitized_run_is_bit_identical():
    base = _rows(_small_day(False))
    sani = _rows(_small_day(True))
    assert base == sani


# --- lock-order enforcement: the static hierarchy, checked live -----------

def test_lock_ranks_match_static_analysis():
    """The runtime table IS the static analysis: recompute the lock
    ranks from the reprolint RL006 lock graph and require equality, so
    neither side can drift without this test failing."""
    from pathlib import Path

    from tools.reprolint import lockgraph

    repo = Path(__file__).resolve().parents[1]
    graph = lockgraph.project_lock_graph(repo)
    assert lockgraph.find_cycles(graph) == []
    assert lockgraph.lock_ranks(graph) == sanitize.LOCK_RANKS


def test_lock_order_descent_raises(sanitized):
    mu = sanitize.ordered_lock("LiveExecutor._mu", threading.RLock())
    fl = sanitize.ordered_lock(
        "CrossPoolFusionIndex._lock", threading.Lock()
    )
    with fl:
        with pytest.raises(SanitizeError, match="ABBA"):
            with mu:
                pass


def test_lock_order_descending_into_index_is_legal(sanitized):
    mu = sanitize.ordered_lock("LiveExecutor._mu", threading.RLock())
    fl = sanitize.ordered_lock(
        "CrossPoolFusionIndex._lock", threading.Lock()
    )
    with mu:
        with mu:  # RLock re-entry is not a descent
            with fl:
                pass
    # the stack drains: a fresh correct-order acquisition still works
    with mu:
        with fl:
            pass


def test_lock_order_condition_over_wrapper(sanitized):
    mu = sanitize.ordered_lock("LiveExecutor._mu", threading.RLock())
    cv = threading.Condition(mu)
    with cv:  # Condition binds the wrapper's acquire/release
        pass
    fl = sanitize.ordered_lock(
        "CrossPoolFusionIndex._lock", threading.Lock()
    )
    with fl:
        with pytest.raises(SanitizeError, match="descends"):
            with cv:
                pass


def test_lock_order_off_switch_is_a_noop():
    mu = sanitize.ordered_lock("LiveExecutor._mu", threading.RLock())
    fl = sanitize.ordered_lock(
        "CrossPoolFusionIndex._lock", threading.Lock()
    )
    with fl:
        with mu:  # would be a violation with the sanitizer on
            pass


def test_simconfig_flag_reaches_pools():
    reset_qids()
    sim = Simulation(SimConfig(sanitize=True))
    assert all(p.sanitize for p in sim.pools)
    reset_qids()
    sim = Simulation(SimConfig(sanitize=False))
    assert not any(p.sanitize for p in sim.pools)


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
