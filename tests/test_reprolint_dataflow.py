"""The RL1xx unit-of-measure dataflow rules and the RL006 lock-order
analysis, demonstrated against the four billing bugs this repo has
actually shipped (seeded back in fixture form), plus the suffix
grammar, summary fixed-point convergence, the CLI result cache, and
GitHub annotation output."""
import json
import os
from pathlib import Path

import pytest

from tools.reprolint import LintCache, lint_paths, lint_text
from tools.reprolint.units import (
    CHIP_S,
    CHIPS,
    DIMENSIONLESS,
    S,
    TOKENS,
    USD,
    USD_PER_CHIP_S,
    unit_from_name,
)

REPO = Path(__file__).resolve().parents[1]
CORE = "src/repro/core/fixture.py"  # path chosen to put rules in scope


def codes(src: str, path: str = CORE) -> list[str]:
    return [f.code for f in lint_text(src, path)]


# --- the unit algebra and suffix grammar ----------------------------------

def test_unit_algebra():
    assert CHIPS * S == CHIP_S
    assert USD / CHIP_S == USD_PER_CHIP_S
    assert CHIP_S / CHIPS == S
    assert (S ** 2) / S == S
    assert CHIP_S / CHIP_S == DIMENSIONLESS
    assert USD_PER_CHIP_S.render() == "usd_per_chip_s"
    assert (CHIP_S * TOKENS).render() == "chips*s*tokens"


@pytest.mark.parametrize("name,unit", [
    ("billed_cs", CHIP_S),
    ("exec_s", S),
    ("startup_s", S),
    ("price_per_chip_s", USD_PER_CHIP_S),
    ("price_per_chip_hour", USD_PER_CHIP_S),  # hours are time too
    ("vm_price_per_chip_s", USD_PER_CHIP_S),
    ("decode_tokens", TOKENS),
    ("slice_chips", CHIPS),
    ("est_cost_usd", USD),
    ("drift_ratio", DIMENSIONLESS),
    ("speed_factor", DIMENSIONLESS),
    # same-dimension repeats collapse: these are seconds, not s^2
    ("drain_time_s", S),
    ("submit_time_s", S),
    # no convention -> no unit
    ("pools", None),
    ("cursor", None),
    ("per_chip_s", None),  # 'per' with no numerator carries nothing
])
def test_suffix_grammar(name, unit):
    assert unit_from_name(name) == unit


# --- the four historical billing bugs, seeded back ------------------------

# Bug 1 (PR 1 era): decode chunks priced at the initial context — token
# counts added straight into a chip-second accumulator.
DECODE_PRICED_AT_CONTEXT = '''
def bill_decode(prompt_tokens, decode_tokens, chips, dt_s):
    prefill_cs = chips * dt_s
    total_cs = prefill_cs + decode_tokens
    return total_cs
'''

# Bug 2 (fusion era): a fused split that dropped the group normalizer —
# the share is chip-seconds * tokens, not chip-seconds.
FUSED_SPLIT_DROPPED_NORMALIZER = '''
def split_bill(total_cs, member_tokens, group_tokens):
    share_cs = total_cs * member_tokens
    return share_cs
'''

# Bug 3: compile seconds padded into the billed wall with a raw
# constant at the accounting sink.
BILLED_COMPILE_PAD = '''
def account(q, stage, cluster, start_s, finish_s, chips, price_per_chip_s):
    billed = (finish_s - start_s) * chips
    account_stage(q, stage, cluster, start_s, finish_s, chips,
                  billed + 2.5, price_per_chip_s, 0)
'''

# Bug 4 (PR 2): pool chips where slice chips belonged — here the
# backlog is divided by BOTH, leaving s/chips in a *_s name.
POOL_CHIPS_VS_SLICE_CHIPS = '''
def queue_delay_estimate(pool, backlog_cs, slice_chips):
    wait_s = backlog_cs / pool.chips / slice_chips
    return wait_s
'''


def test_rl101_decode_priced_at_initial_context():
    findings = lint_text(DECODE_PRICED_AT_CONTEXT, CORE)
    assert [f.code for f in findings] == ["RL101"]
    assert "chip_s" in findings[0].message
    assert "tokens" in findings[0].message


def test_rl102_fused_split_dropped_normalizer():
    findings = lint_text(FUSED_SPLIT_DROPPED_NORMALIZER, CORE)
    assert [f.code for f in findings] == ["RL102"]
    assert "share_cs" in findings[0].message


def test_rl103_billed_compile_seconds_pad():
    findings = lint_text(BILLED_COMPILE_PAD, CORE)
    assert [f.code for f in findings] == ["RL103"]
    assert "billed_cs" in findings[0].message
    assert "2.5" in findings[0].message


def test_rl102_pool_chips_vs_slice_chips():
    findings = lint_text(POOL_CHIPS_VS_SLICE_CHIPS, CORE)
    assert [f.code for f in findings] == ["RL102"]
    assert "wait_s" in findings[0].message


# --- the surrounding checker behaviors ------------------------------------

def test_rl101_seeded_positional_arg_mismatch():
    src = '''
def account(q, stage, cluster, start_s, finish_s, chips,
            exec_s, price_per_chip_s):
    account_stage(q, stage, cluster, start_s, finish_s, chips,
                  exec_s, price_per_chip_s, 0)
'''
    findings = lint_text(src, CORE)
    assert [f.code for f in findings] == ["RL101"]
    assert "billed_cs" in findings[0].message


def test_rl101_united_kwarg_mismatch():
    src = '''
def quote(exec_s):
    return Quote(est_cost=exec_s)
'''
    findings = lint_text(src, CORE)
    assert [f.code for f in findings] == ["RL101"]
    assert "est_cost" in findings[0].message


def test_rl101_cross_unit_comparison():
    src = '''
def admit(deadline_s, billed_cs):
    return billed_cs < deadline_s
'''
    assert codes(src) == ["RL101"]


def test_multiplicative_conversion_factors_are_legal():
    # hours and seconds share a dimension: /3600.0 is a pure scale
    src = '''
def price(pool):
    price_per_chip_s = pool.price_per_chip_hour / 3600.0
    return price_per_chip_s
'''
    assert codes(src) == []


def test_rl102_function_suffix_vs_return():
    src = '''
def drain_time_s(backlog_cs):
    return backlog_cs
'''
    findings = lint_text(src, CORE)
    assert [f.code for f in findings] == ["RL102"]
    assert "drain_time_s" in findings[0].message


def test_summary_fixed_point_converges_on_recursion():
    # mutually recursive chip-second passthroughs: the fixed point must
    # terminate and agree with the suffix — no findings
    src = '''
def ping_cs(n, unit_cs):
    if n <= 0:
        return unit_cs
    return pong_cs(n - 1, unit_cs)

def pong_cs(n, unit_cs):
    if n <= 0:
        return unit_cs
    return ping_cs(n - 1, unit_cs)
'''
    assert codes(src) == []


def test_summary_fixed_point_flags_recursive_lie():
    # self-recursion whose base case returns seconds from a *_cs name:
    # the summary stabilizes at s and the suffix check fires
    src = '''
def backoff_cs(n, base_s):
    if n <= 0:
        return base_s
    return backoff_cs(n - 1, base_s) + base_s
'''
    assert codes(src) == ["RL102"]


def test_rl1xx_scoped_to_core():
    assert codes(DECODE_PRICED_AT_CONTEXT, "benchmarks/scale.py") == []


def test_rl1xx_suppression_applies():
    src = DECODE_PRICED_AT_CONTEXT.replace(
        "total_cs = prefill_cs + decode_tokens",
        "total_cs = prefill_cs + decode_tokens"
        "  # reprolint: disable=RL101 -- seeded fixture",
    )
    assert codes(src) == []


# --- RL006: lock-order (ABBA) cycles --------------------------------------

ABBA = '''
import threading

class Pool:
    _GUARDED_BY = {"waiting": "_mu"}

    def __init__(self):
        self._mu = threading.Lock()
        self._lock = threading.Lock()
        self.waiting = []

    def place(self):
        with self._mu:
            with self._lock:
                pass

    def drain(self):
        with self._lock:
            with self._mu:
                pass
'''


def test_rl006_abba_nested_withs():
    findings = lint_text(ABBA, CORE)
    assert [f.code for f in findings] == ["RL006"]
    assert "ABBA" in findings[0].message
    assert "Pool._mu -> Pool._lock" in findings[0].message


def test_rl006_consistent_order_is_clean():
    clean = ABBA.replace(
        "with self._lock:\n            with self._mu:",
        "with self._mu:\n            with self._lock:",
    )
    assert codes(clean) == []


def test_rl006_cycle_through_method_calls():
    # the inversion hides behind calls: place() holds _mu and calls a
    # helper that takes _lock; drain() holds _lock and calls a helper
    # that takes _mu — only the acquisition summaries see the cycle
    src = '''
import threading

class Pool:
    _GUARDED_BY = {"waiting": "_mu"}

    def __init__(self):
        self._mu = threading.Lock()
        self._lock = threading.Lock()
        self.waiting = []

    def place(self):
        with self._mu:
            self._index_add()

    def _index_add(self):
        with self._lock:
            pass

    def drain(self):
        with self._lock:
            self._pop()

    def _pop(self):
        with self._mu:
            pass
'''
    findings = lint_text(src, CORE)
    assert [f.code for f in findings] == ["RL006"]
    assert "ABBA" in findings[0].message


def test_rl006_repo_lock_hierarchy_is_acyclic():
    from tools.reprolint import lockgraph

    graph = lockgraph.project_lock_graph(REPO)
    assert lockgraph.find_cycles(graph) == []
    ranks = lockgraph.lock_ranks(graph)
    # the load-bearing repo fact: the fusion index lock is innermost
    assert ranks["CrossPoolFusionIndex._lock"] > ranks["LiveExecutor._mu"]


# --- the CLI result cache -------------------------------------------------

def _seed_tree(root: Path, body: str) -> Path:
    f = root / "src" / "repro" / "core" / "fixture.py"
    f.parent.mkdir(parents=True)
    f.write_text(body)
    return f


def test_cache_round_trip_and_hit(tmp_path):
    f = _seed_tree(tmp_path, DECODE_PRICED_AT_CONTEXT)
    cache_file = tmp_path / "cache.json"

    cache = LintCache(cache_file)
    first = lint_paths(["src"], tmp_path, cache=cache)
    cache.save()
    assert [x.code for x in first] == ["RL101"]
    assert cache_file.exists()

    # prove the second run is SERVED from the cache: tamper the stored
    # message and watch it come back verbatim (mtime unchanged)
    raw = json.loads(cache_file.read_text())
    entry = raw["entries"]["src/repro/core/fixture.py"]
    entry["findings"][0][2] = "tampered-proof-of-cache-hit"
    cache_file.write_text(json.dumps(raw))
    second = lint_paths(["src"], tmp_path, cache=LintCache(cache_file))
    assert second[0].message == "tampered-proof-of-cache-hit"


def test_cache_invalidated_by_content_change(tmp_path):
    f = _seed_tree(tmp_path, DECODE_PRICED_AT_CONTEXT)
    cache_file = tmp_path / "cache.json"
    cache = LintCache(cache_file)
    lint_paths(["src"], tmp_path, cache=cache)
    cache.save()

    f.write_text(FUSED_SPLIT_DROPPED_NORMALIZER)
    cache2 = LintCache(cache_file)
    got = lint_paths(["src"], tmp_path, cache=cache2)
    assert [x.code for x in got] == ["RL102"]


def test_cache_touch_without_change_still_hits(tmp_path):
    f = _seed_tree(tmp_path, DECODE_PRICED_AT_CONTEXT)
    cache_file = tmp_path / "cache.json"
    cache = LintCache(cache_file)
    lint_paths(["src"], tmp_path, cache=cache)
    cache.save()
    before = json.loads(cache_file.read_text())

    os.utime(f, ns=(1, 1))  # mtime changes, content does not
    cache2 = LintCache(cache_file)
    got = lint_paths(["src"], tmp_path, cache=cache2)
    cache2.save()
    assert [x.code for x in got] == ["RL101"]
    after = json.loads(cache_file.read_text())
    entry = after["entries"]["src/repro/core/fixture.py"]
    assert entry["mtime_ns"] == 1
    assert entry["sha256"] == \
        before["entries"]["src/repro/core/fixture.py"]["sha256"]


# --- CLI: --format github and --cache flags -------------------------------

def test_cli_github_annotations(tmp_path, capsys):
    from tools.reprolint.__main__ import main

    _seed_tree(tmp_path, DECODE_PRICED_AT_CONTEXT)
    rc = main(["src", "--root", str(tmp_path), "--format", "github"])
    out = capsys.readouterr().out
    assert rc == 1
    assert out.startswith(
        "::error file=src/repro/core/fixture.py,line=4,title=RL101::"
    )


def test_cli_cache_flags(tmp_path, capsys):
    from tools.reprolint.__main__ import main

    _seed_tree(tmp_path, DECODE_PRICED_AT_CONTEXT)
    cache_file = tmp_path / ".reprolint_cache.json"
    args = ["src", "--root", str(tmp_path), "--cache", str(cache_file)]
    assert main(args) == 1
    assert cache_file.exists()
    capsys.readouterr()
    assert main(args) == 1  # cached run reports the same findings
    assert "RL101" in capsys.readouterr().out
    assert main(args + ["--no-cache"]) == 1
