"""End-to-end behaviour tests: the live engine runs the paper's SLA
machinery against real jitted model execution, and the data pipeline /
input-spec layers stay consistent with the model contracts."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_config
from repro.core.live import LiveConfig, LiveEngine
from repro.core.query import Query, QueryWork
from repro.core.sla import Policy, ServiceLevel
from repro.data.batches import TokenStream, make_batch, prefill_specs, train_specs
from repro.models import build_model


def test_live_engine_end_to_end_sla():
    """Real execution: immediate runs first; relaxed obeys its deadline;
    BoE waits for an idle cost-efficient worker; CF bills the multiplier."""
    eng = LiveEngine(
        LiveConfig(policy=Policy.AUTO, cf_startup_s=0.05)
    )
    qs = [
        Query(work=QueryWork(arch="paper-default", batch=1),
              sla=ServiceLevel.IMMEDIATE, submit_time=0.0),
        Query(work=QueryWork(arch="paper-default", batch=1),
              sla=ServiceLevel.RELAXED, submit_time=0.0),
        Query(work=QueryWork(arch="paper-default", batch=1),
              sla=ServiceLevel.BEST_EFFORT, submit_time=0.0),
    ]
    for q in qs:
        eng.submit(q)
        time.sleep(0.02)
    done = eng.drain(3, timeout=240)
    assert len(done) == 3
    by = {q.sla: q for q in done}
    imm, rel, boe = (
        by[ServiceLevel.IMMEDIATE],
        by[ServiceLevel.RELAXED],
        by[ServiceLevel.BEST_EFFORT],
    )
    assert imm.pending_time < 0.5
    assert rel.pending_time <= eng.cfg.sla.relaxed_deadline_s + 1.0
    assert boe.dequeue_time >= rel.dequeue_time  # BoE drains last
    for q in done:
        assert q.finish_time is not None and q.cost > 0
        if q.cluster == "cf":
            assert q.cost / q.chip_seconds == pytest.approx(
                eng.cfg.vm_price * eng.cfg.cf_price_multiplier
            )


def test_token_stream_restartable_and_sharded():
    cfg = get_config("qwen2-0.5b", reduced=True)
    s1 = TokenStream(cfg, batch=8, seq=16, seed=3)
    batches = [s1.next() for _ in range(3)]
    s2 = TokenStream(cfg, batch=8, seq=16, seed=3)
    s2.seek({"step": 2, "seed": 3})
    np.testing.assert_array_equal(
        np.asarray(batches[2]["tokens"]), np.asarray(s2.next()["tokens"])
    )
    # host sharding partitions the global batch deterministically
    h0 = TokenStream(cfg, batch=8, seq=16, seed=3, host_index=0, host_count=2)
    h1 = TokenStream(cfg, batch=8, seq=16, seed=3, host_index=1, host_count=2)
    b0, b1 = h0.next(), h1.next()
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(np.asarray(b0["tokens"]), np.asarray(b1["tokens"]))


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_match_model_contract(arch):
    """input_specs structures must be exactly what the step fns consume."""
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    cell = SHAPES["train_4k"]
    ts = train_specs(cfg, cell)
    assert ts["tokens"].shape[0] == cell.global_batch
    if cfg.frontend == "vision_patches":
        assert ts["tokens"].shape[1] + cfg.frontend_tokens == cell.seq_len
        assert "patch_embeds" in ts
    else:
        assert ts["tokens"].shape[1] == cell.seq_len
    if cfg.is_encoder_decoder:
        assert "enc_embeds" in ts
    ps = prefill_specs(cfg, SHAPES["prefill_32k"])
    assert "targets" not in ps
    # decode cache specs build for every arch without error
    spec = model.cache_spec(2, 64)
    assert spec["lengths"].shape == (2,)


def test_make_batch_matches_specs():
    for arch in ("internvl2-76b", "seamless-m4t-large-v2", "mamba2-2.7b"):
        cfg = get_config(arch, reduced=True)
        b = make_batch(jax.random.PRNGKey(0), cfg, batch=2, seq=16)
        model = build_model(cfg)
        loss, _ = model.loss(model.init(jax.random.PRNGKey(1)), b)
        assert np.isfinite(float(loss))


def test_cells_and_skips_enumerate_assignment():
    from repro.configs import cells, runnable

    all_cells = list(cells())
    assert len(all_cells) == 40  # 10 archs x 4 shapes
    skipped = [(a, c.name) for a, c in all_cells if not runnable(a, c)[0]]
    # long_500k runs only for subquadratic archs (ssm/hybrid/pure-SWA)
    assert all(name == "long_500k" for _, name in skipped)
    runnable_long = {a for a, c in all_cells if c.name == "long_500k"
                     and runnable(a, c)[0]}
    assert runnable_long == {"mamba2-2.7b", "jamba-v0.1-52b", "mixtral-8x7b"}


def test_serve_engine_continuous_batching():
    """launch/serve.py: ragged slots, SLA admission order, finished fills."""
    from repro.launch.serve import Request, ServeEngine

    eng = ServeEngine("paper-default", slots=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, eng.cfg.vocab_size, size=6 + i),
                max_new=3,
                sla=[ServiceLevel.BEST_EFFORT, ServiceLevel.IMMEDIATE,
                     ServiceLevel.RELAXED][i % 3])
        for i in range(4)
    ]
    eng.run(reqs, max_steps=60)
    assert all(r.finish_t is not None for r in reqs)
    assert all(len(r.out_tokens) >= r.max_new for r in reqs)
    # immediate admitted no later than the BoE submitted first
    imm = next(r for r in reqs if r.sla is ServiceLevel.IMMEDIATE)
    boe = next(r for r in reqs if r.sla is ServiceLevel.BEST_EFFORT)
    assert imm.start_t <= boe.start_t + 1e-6


def test_query_fusion_preserves_queries_and_cuts_queue_pressure():
    from repro.core import Policy, generate, run_sim

    qs = generate(horizon_s=3600, seed=2)
    res = run_sim(generate(horizon_s=3600, seed=2), policy=Policy.FORCE,
                  fuse_queries=True, use_calibration=False)
    assert len(res.queries) == len(qs)  # every member query reported
    assert len({q.qid for q in res.queries}) == len(qs)
    assert not res.pending_violations(300.0)
    for q in res.queries:
        assert q.finish_time is not None and q.cost > 0
