"""Per-query chips-per-stage allocation (core/allocation.py) and the
drift-gated admission control riding on it: frontier sweep semantics,
the SOS capacity accounting it required, the plan-cache LRU bound, and
the scheduler/simulator wiring."""
import math

import pytest

from repro.core import (
    AllocationConfig,
    Allocator,
    CostModel,
    PoolSpec,
    Policy,
    Query,
    QueryWork,
    ServiceLevel,
    SimConfig,
    Simulation,
    build_pool,
    default_pool_specs,
    generate,
    price_menu,
)
from repro.core.calibration import CalibrationTable
from repro.core.clusters import AutoscaleConfig, CostEfficientCluster
from repro.core.scheduler import QueryCoordinator
from repro.core.sla import SLAConfig

ARCH = "paper-default"


def _mk(sla, t, tokens=100_000, out=8):
    return Query(
        work=QueryWork(arch=ARCH, prompt_tokens=tokens, output_tokens=out),
        sla=sla,
        submit_time=t,
    )


def _work(tokens=512, out=128, batch=8):
    return QueryWork(
        arch=ARCH, kind="infer", batch=batch,
        prompt_tokens=tokens, output_tokens=out,
    )


def _norm_finish(res):
    base = min(q.qid for q in res.queries)
    return [
        (q.qid - base, q.cluster, q.finish_time, q.cost)
        for q in sorted(res.queries, key=lambda q: q.qid)
    ]


# ---------------------------------------------------------------------------
# the frontier sweep and per-level choice
# ---------------------------------------------------------------------------

def test_frontier_is_monotone_under_parallel_overhead():
    """Nonzero overhead makes wider strictly faster AND strictly more
    expensive — a real frontier, not a degenerate tie."""
    cm = CostModel(use_calibration=False, parallel_overhead=0.02)
    alloc = Allocator(cm, AllocationConfig(min_chips=8, max_chips=32,
                                           step_chips=8))
    pts = alloc.frontier(_work())
    execs = [p.exec_s for p in pts]
    costs = [p.chip_seconds for p in pts]
    assert execs == sorted(execs, reverse=True)
    assert costs == sorted(costs)


def test_per_level_choice_immediate_buys_wider_than_best_effort():
    cm = CostModel(use_calibration=False, parallel_overhead=0.02)
    alloc = Allocator(cm, AllocationConfig(min_chips=8, max_chips=16,
                                           step_chips=8))
    w = _work()
    imm = alloc.choose(w, ServiceLevel.IMMEDIATE)
    boe = alloc.choose(w, ServiceLevel.BEST_EFFORT)
    assert imm == 16  # latency-optimal (no target set)
    assert boe == 8  # cost-optimal
    assert imm > boe


def test_relaxed_meets_target_else_degrades_to_cost_optimal():
    cm = CostModel(use_calibration=False, parallel_overhead=0.02)
    w = _work()
    wide_t = cm.plan(w, 16).exec_time
    # a target only the wide slice meets -> relaxed buys the wide slice
    alloc = Allocator(cm, AllocationConfig(
        min_chips=8, max_chips=16, step_chips=8,
        rel_exec_target_s=wide_t * 1.01,
    ))
    assert alloc.choose(w, ServiceLevel.RELAXED) == 16
    # an unmeetable target -> the pending queue absorbs the deadline
    alloc2 = Allocator(cm, AllocationConfig(
        min_chips=8, max_chips=16, step_chips=8,
        rel_exec_target_s=wide_t * 0.5,
    ))
    assert alloc2.choose(w, ServiceLevel.RELAXED) == 8


def test_immediate_target_picks_cheapest_feasible_width():
    cm = CostModel(use_calibration=False, parallel_overhead=0.02)
    w = _work()
    narrow_t = cm.plan(w, 8).exec_time
    alloc = Allocator(cm, AllocationConfig(
        min_chips=8, max_chips=16, step_chips=8,
        imm_exec_target_s=narrow_t * 1.01,
    ))
    # the narrow width already meets the target and is cheaper
    assert alloc.choose(w, ServiceLevel.IMMEDIATE) == 8


def test_degenerate_zero_overhead_frontier_collapses_to_widest():
    """The pure roofline is exactly chips-linear: every width bills the
    same chip-seconds, so the tie-break takes the faster (wider) point —
    wider is free."""
    cm = CostModel(use_calibration=False)
    alloc = Allocator(cm, AllocationConfig(min_chips=8, max_chips=16,
                                           step_chips=8))
    w = _work()
    for lvl in ServiceLevel:
        assert alloc.choose(w, lvl) == 16


def test_widths_grid_keeps_ragged_max():
    cfg = AllocationConfig(min_chips=4, max_chips=10, step_chips=4)
    assert cfg.widths() == (4, 8, 10)
    assert AllocationConfig(min_chips=4, max_chips=4).widths() == (4,)


def test_allocation_config_validation():
    with pytest.raises(ValueError):
        AllocationConfig(min_chips=0)
    with pytest.raises(ValueError):
        AllocationConfig(min_chips=8, max_chips=4)
    with pytest.raises(ValueError):
        AllocationConfig(step_chips=0)


def test_choose_memoized_and_invalidated_by_calibration_version():
    table = CalibrationTable()
    cm = CostModel(use_calibration=False, calibration=table,
                   parallel_overhead=0.02)
    alloc = Allocator(cm, AllocationConfig(min_chips=8, max_chips=16,
                                           step_chips=8))
    w = _work()
    alloc.choose(w, ServiceLevel.IMMEDIATE)
    alloc.choose(w, ServiceLevel.IMMEDIATE)
    assert alloc.stats() == {"hits": 1, "misses": 1, "size": 1}
    table.set_factor(ARCH, "infer", 2.0)  # hot swap -> version bump
    alloc.choose(w, ServiceLevel.IMMEDIATE)
    assert alloc.stats()["misses"] == 2


# ---------------------------------------------------------------------------
# satellite 2: the plan cache is bounded LRU with counters
# ---------------------------------------------------------------------------

def test_plan_cache_is_bounded_lru_with_counters():
    cm = CostModel(use_calibration=False)
    cm.PLAN_CACHE_MAX = 4  # shrink the bound for the test
    shapes = [_work(tokens=1000 * (i + 1)) for i in range(6)]
    for w in shapes:
        cm.plan(w, 4)
    st = cm.plan_cache_stats()
    assert (st["hits"], st["misses"], st["size"]) == (0, 6, 4)
    cm.plan(shapes[5], 4)  # most recent: still cached
    assert cm.plan_cache_stats()["hits"] == 1
    cm.plan(shapes[0], 4)  # oldest: evicted -> re-planned
    assert cm.plan_cache_stats()["misses"] == 7
    # LRU, not FIFO: touching an old entry protects it from eviction
    cm.plan(shapes[3], 4)  # hit -> becomes most recent
    cm.plan(_work(tokens=99_000), 4)  # evicts the LRU entry (shapes[4])
    hits = cm.plan_cache_stats()["hits"]
    cm.plan(shapes[3], 4)
    assert cm.plan_cache_stats()["hits"] == hits + 1
    cm.plan(shapes[4], 4)
    assert cm.plan_cache_stats()["misses"] == 9


def test_allocator_sweep_stays_inside_plan_cache():
    """Re-sweeping the same work shapes is pure cache hits — the memo
    plus the LRU keep a million-query day from re-planning."""
    cm = CostModel(use_calibration=False, parallel_overhead=0.02)
    alloc = Allocator(cm, AllocationConfig(min_chips=8, max_chips=32,
                                           step_chips=8))
    w = _work()
    for lvl in ServiceLevel:
        alloc.choose(w, lvl)
    misses = cm.plan_cache_stats()["misses"]
    alloc._memo.clear()  # force re-sweeps without a version change
    for lvl in ServiceLevel:
        alloc.choose(w, lvl)
    st = cm.plan_cache_stats()
    assert st["misses"] == misses  # every re-sweep plan was cached


# ---------------------------------------------------------------------------
# satellite 1: SOS admission vs pending scale-in (overcommit regression)
# ---------------------------------------------------------------------------

def _sos_pool(chips=32, slice_chips=16, **auto):
    # inert watermarks: autoscale stays enabled (so pending scale-ins
    # apply) but never self-schedules one during the test
    a = AutoscaleConfig(enabled=True, min_chips=16, max_chips=chips,
                        step_chips=16, scale_delay_s=60.0,
                        scale_in_delay_s=60.0, low_watermark=0,
                        high_watermark=99, **auto)
    return CostEfficientCluster(
        chips=chips, mode="sos", sos_slice_chips=slice_chips,
        cost_model=CostModel(use_calibration=False), autoscale=a,
    )


def test_sos_admission_respects_pending_scale_in():
    """The regression: a pending scale-in caps what admission may
    commit. The old check read raw current chips, so a query admitted
    in the delay window overcommitted the post-scale slice."""
    pool = _sos_pool()
    pool.submit(_mk(ServiceLevel.BEST_EFFORT, 0.0), 0.0)
    assert pool._used_chips == 16
    assert pool.has_capacity()  # 16 + 16 <= 32, no scale-in pending
    pool._pending_scale.append((60.0, 16))  # scheduled scale-in to 16
    assert pool.effective_capacity() == 16
    assert not pool.has_capacity()  # old code: 16 + 16 <= 32 -> admitted
    q2 = _mk(ServiceLevel.BEST_EFFORT, 1.0)
    pool.submit(q2, 1.0)
    assert len(pool.waiting) == 1  # waits out the scale-in window
    assert q2.start_time is None


def test_sos_admits_exact_fit_at_the_boundary():
    """The fix must not over-reserve either: an exact fit against the
    effective capacity still admits, and a pending scale-OUT never caps
    admission below current capacity."""
    pool = _sos_pool()
    pool.submit(_mk(ServiceLevel.BEST_EFFORT, 0.0), 0.0)
    pool._pending_scale.append((60.0, 48))  # scale-OUT pending
    assert pool.effective_capacity() == 32
    assert pool.has_capacity()
    q2 = _mk(ServiceLevel.BEST_EFFORT, 1.0)
    pool.submit(q2, 1.0)  # exact fit: 16 + 16 == 32
    assert len(pool.waiting) == 0
    assert pool._used_chips == 32
    assert not pool.has_capacity()  # full now


def test_used_chips_counter_tracks_running_slices_exactly():
    pool = _sos_pool(chips=48)
    for i in range(3):
        pool.submit(_mk(ServiceLevel.BEST_EFFORT, float(i)), float(i))
    assert pool._used_chips == len(pool.running) * pool.slice_chips == 48
    t = pool.next_event_time()
    while t is not None:
        pool.advance_to(t)
        t = pool.next_event_time()
    assert len(pool.running) == 0
    assert pool._used_chips == 0


# ---------------------------------------------------------------------------
# satellite 4: the backlog watermark's -1e-6 early re-eval fudge
# ---------------------------------------------------------------------------

def test_backlog_watermark_re_eval_neither_skips_nor_loops():
    """``_next_backlog_eval`` schedules the passive cold-crossing check
    a hair (1e-6) EARLY. A re-eval landing exactly on that float-grid
    time sees the backlog still a hair above the watermark: it must not
    fire early, must not reschedule the check past the true crossing
    (that would skip it), and must be idempotent at the same ``now`` (no
    zero-progress re-trigger loop); the first re-eval past the true
    crossing fires exactly one scale-in."""
    a = AutoscaleConfig(enabled=True, min_chips=4, max_chips=16,
                        step_chips=4, scale_delay_s=10.0,
                        scale_in_delay_s=5.0, trigger="backlog",
                        backlog_high_s=1e9, backlog_low_s=1.0)
    pool = CostEfficientCluster(
        chips=8, mode="sos", sos_slice_chips=4,
        cost_model=CostModel(use_calibration=False), autoscale=a,
    )
    pool.submit(
        _mk(ServiceLevel.BEST_EFFORT, 0.0, tokens=3_000_000), 0.0
    )
    t_eval = pool._as_next_eval
    assert math.isfinite(t_eval) and t_eval > 0.0
    # exactly on the scheduled grid point: the fudge means the drain is
    # still (just) above the watermark -> no early fire
    pool.tick(t_eval)
    assert pool._pending_scale == []
    assert pool.drain_time_s(t_eval) > a.backlog_low_s
    # the re-eval must not move the check past the true crossing: the
    # recomputed time is identical (state unchanged), so the crossing
    # stays armed rather than skipped
    assert pool._as_next_eval == t_eval
    # idempotent at the same now — a repeated tick makes no state change
    # (the event loop's poll stride provides the forward progress)
    pool.tick(t_eval)
    assert pool._pending_scale == [] and pool._as_next_eval == t_eval
    # first re-eval past the true crossing (fudge + epsilon): exactly
    # one scale-in fires
    pool.tick(t_eval + 2e-6)
    assert len(pool._pending_scale) == 1
    eff_at, target = pool._pending_scale[0]
    assert target == 4 and eff_at == pytest.approx(t_eval + 2e-6 + 5.0)


# ---------------------------------------------------------------------------
# the allocator threaded through pools / quotes / routing
# ---------------------------------------------------------------------------

def test_build_pool_attaches_allocator_and_overhead():
    spec = PoolSpec(
        name="r", kind="reserved", chips=64, mode="sos", slice_chips=16,
        allocation=AllocationConfig(min_chips=8, max_chips=16, step_chips=8),
        parallel_overhead=0.02,
    )
    pool = build_pool(spec, use_calibration=False)
    assert pool.allocator is not None
    assert pool.cost_model.parallel_overhead == 0.02
    q_imm = _mk(ServiceLevel.IMMEDIATE, 0.0)
    q_boe = _mk(ServiceLevel.BEST_EFFORT, 0.0)
    assert pool.effective_chips(q_imm) == 16
    assert pool.effective_chips(q_boe) == 8
    # the level is a planning input: quotes price each level's own width
    assert pool.quote_cost(q_imm) > pool.quote_cost(q_boe)


def test_single_point_grid_is_bit_identical_to_fixed_slice():
    """Allocator OFF vs a degenerate ON (one grid point == slice_chips,
    zero overhead): per-query results identical — the allocation axis
    changes nothing until it can actually choose."""
    def specs(alloc):
        return [
            PoolSpec(name="r", kind="reserved", chips=64, mode="sos",
                     slice_chips=16, allocation=alloc),
            PoolSpec(name="cf", kind="elastic", chips=64, startup_s=2.0,
                     price_multiplier=10.0),
        ]

    qs = list(generate(horizon_s=3600, seed=5))
    off = Simulation(SimConfig(use_calibration=False,
                               pools=specs(None))).run(qs)
    qs2 = list(generate(horizon_s=3600, seed=5))
    on = Simulation(SimConfig(use_calibration=False, pools=specs(
        AllocationConfig(min_chips=16, max_chips=16, step_chips=4)
    ))).run(qs2)
    assert _norm_finish(off) == _norm_finish(on)


def test_price_menu_quotes_per_level_width():
    overhead = dict(parallel_overhead=0.02)
    alloc = AllocationConfig(min_chips=8, max_chips=16, step_chips=8)
    pools = [
        build_pool(PoolSpec(name="r", kind="reserved", chips=64,
                            mode="sos", slice_chips=16, allocation=alloc,
                            **overhead), use_calibration=False),
        build_pool(PoolSpec(name="cf", kind="elastic", chips=64,
                            startup_s=2.0, price_multiplier=10.0,
                            allocation=alloc, **overhead),
                   use_calibration=False),
    ]
    menu = price_menu(_work(), pools=pools)
    imm, rel, boe = menu
    assert imm.sla == "immediate" and boe.sla == "best_effort"
    # immediate is quoted at the latency-optimal width: faster and (at
    # nonzero overhead) more expensive than best-effort's cost-optimal
    assert imm.est_exec_s < boe.est_exec_s
    assert imm.est_cost > boe.est_cost


def test_price_menu_without_allocator_matches_single_probe():
    """Satellite bit-compat: a registry with no allocator prices every
    level from one BEST_EFFORT probe — the legacy path, unchanged."""
    pools = [
        build_pool(PoolSpec(name="r", kind="reserved", chips=64,
                            mode="sos", slice_chips=16),
                   use_calibration=False),
        build_pool(PoolSpec(name="cf", kind="elastic", chips=64,
                            startup_s=2.0, price_multiplier=10.0),
                   use_calibration=False),
    ]
    w = _work()
    menu = price_menu(w, pools=pools)
    probe = Query(work=w, sla=ServiceLevel.BEST_EFFORT, submit_time=0.0)
    expect = {
        p.name: p.cost_model.plan(w, p.effective_chips(probe))
        for p in pools
    }
    # every level's exec/cost derives from the single probe's plans
    assert menu[0].est_exec_s == min(pl.exec_time for pl in expect.values())
    assert menu[2].est_cost == \
        expect["r"].chip_seconds * pools[0].price_per_chip_s
    assert menu[1].as_dict() == {**menu[2].as_dict(),
                                 "sla": "relaxed",
                                 "est_pending_s": 300.0}


# ---------------------------------------------------------------------------
# drift-gated admission control
# ---------------------------------------------------------------------------

def _feed(table, ratio, n=5):
    for _ in range(n):
        table.observe_drift(1.0, ratio)


def test_drift_ewma_semantics():
    t = CalibrationTable(drift_bound=0.25, drift_min_samples=4)
    v0 = t.version
    assert t.drift_ratio() is None and not t.drift_exceeded()
    _feed(t, 2.0, n=3)
    assert t.drift_ratio() == pytest.approx(2.0)
    assert not t.drift_exceeded()  # below min_samples
    _feed(t, 2.0, n=1)
    assert t.drift_exceeded()
    assert t.version == v0  # drift gates admission, never rescales plans
    t.reset_drift()
    assert t.drift_samples() == 0 and not t.drift_exceeded()
    # unarmed table never trips regardless of evidence
    u = CalibrationTable()
    _feed(u, 3.0, n=10)
    assert not u.drift_exceeded()


def test_drift_fields_roundtrip_only_when_armed():
    plain = CalibrationTable()
    assert "drift_bound" not in plain.as_dict()  # legacy payload intact
    armed = CalibrationTable(drift_bound=0.3, drift_alpha=0.5,
                             drift_min_samples=2)
    back = CalibrationTable.from_dict(armed.as_dict())
    assert (back.drift_bound, back.drift_alpha, back.drift_min_samples) \
        == (0.3, 0.5, 2)


def _two_pool_coord(drift_action="reprice"):
    slow = build_pool(
        PoolSpec(name="slow", kind="reserved", chips=64, mode="sos",
                 slice_chips=16, speed_factor=0.9, drift_bound=0.25,
                 drift_action=drift_action),
        use_calibration=False,
    )
    honest = build_pool(
        PoolSpec(name="honest", kind="reserved", chips=64, mode="sos",
                 slice_chips=16, speed_factor=0.5),
        use_calibration=False,
    )
    coord = QueryCoordinator([slow, honest], policy=Policy.AUTO,
                             cfg=SLAConfig())
    return slow, honest, coord


def test_coordinator_reprices_drifted_quotes():
    slow, honest, coord = _two_pool_coord()
    q = _mk(ServiceLevel.IMMEDIATE, 0.0)
    # gate armed but not tripped: the (lying) faster quote wins
    assert coord.route(q, 0.0) == "slow"
    # the pool measures 3x slower than it quotes -> gate trips; its
    # repriced quote loses to the honestly-slower pool
    _feed(slow.cost_model.calibration, 3.0)
    q2 = _mk(ServiceLevel.IMMEDIATE, 1.0)
    assert coord.route(q2, 1.0) == "honest"
    assert coord.drift_reprices >= 1
    assert coord.drift_rejects == 0


def test_coordinator_rejects_drifted_pool_while_alternatives_remain():
    slow, honest, coord = _two_pool_coord(drift_action="reject")
    _feed(slow.cost_model.calibration, 3.0)
    q = _mk(ServiceLevel.IMMEDIATE, 0.0)
    assert coord.route(q, 0.0) == "honest"
    assert coord.drift_rejects >= 1


def test_rejected_only_pool_falls_back_to_reprice():
    """Admission control reroutes; it never strands a query."""
    only = build_pool(
        PoolSpec(name="only", kind="reserved", chips=64, mode="sos",
                 slice_chips=16, drift_bound=0.25, drift_action="reject"),
        use_calibration=False,
    )
    coord = QueryCoordinator([only], policy=Policy.AUTO, cfg=SLAConfig())
    _feed(only.cost_model.calibration, 3.0)
    q = _mk(ServiceLevel.IMMEDIATE, 0.0)
    assert coord.route(q, 0.0) == "only"


def test_build_pool_drift_action_validated():
    with pytest.raises(ValueError):
        build_pool(PoolSpec(name="x", drift_action="explode"),
                   use_calibration=False)


def test_build_pool_arms_drift_gate():
    spec = PoolSpec(name="x", kind="reserved", drift_bound=0.3)
    pool = build_pool(spec, use_calibration=False)
    assert pool.cost_model.calibration.drift_bound == 0.3
    # an injected table's own bound wins over the spec's
    injected = CalibrationTable(drift_bound=0.1)
    pool2 = build_pool(spec, use_calibration=False, calibration=injected)
    assert pool2.cost_model.calibration is injected
    assert injected.drift_bound == 0.1
    # an injected unarmed table gets the spec's bound
    bare = CalibrationTable()
    build_pool(spec, use_calibration=False, calibration=bare)
    assert bare.drift_bound == 0.3


def test_sim_counts_drift_interventions_and_observer_feeds_walls():
    table = CalibrationTable(drift_bound=0.25)
    _feed(table, 2.0)  # pool declared 2x wrong, measured pre-day
    assert table.drift_exceeded()
    cfg = SimConfig(policy=Policy.LATENCY_AWARE, use_calibration=False,
                    pools=default_pool_specs(),
                    calibrations={"vm": table})
    res = Simulation(cfg).run(generate(horizon_s=1800, seed=7))
    assert res.drift_reprices >= 1
    s = res.summary()
    assert s["drift_reprices"] == res.drift_reprices
    assert s["drift_rejects"] == res.drift_rejects == 0
    # the day's own stage walls fed the EWMA (the observer is wired)
    assert table.drift_samples() > 5


def test_sim_without_drift_gate_reports_zero():
    res = Simulation(SimConfig(use_calibration=False)).run(
        generate(horizon_s=600, seed=1)
    )
    assert res.drift_reprices == 0 and res.drift_rejects == 0
    assert res.summary()["drift_reprices"] == 0
