"""Live engine on the pool registry (core/live.py): stage-boundary
checkpointing makes preemption / spill / spill-back EXACT on real jitted
model work, failures surface instead of hanging the drain, and billing
flows through the same per-stage accounting as the simulator.

Every test runs under a hard SIGALRM timeout: a hung drain (the bug
class this file guards against) fails fast instead of stalling CI."""
import os
import signal
import threading
import time
from pathlib import Path

import pytest

from repro.core.live import LiveConfig, LiveEngine, LiveExecutor
from repro.core.pools import PoolSpec
from repro.core.query import Query, QueryWork
from repro.core.sla import Policy, ServiceLevel, SLAConfig


@pytest.fixture(autouse=True)
def _hard_timeout():
    """Per-test hard timeout: a live-engine regression that blocks (a
    swallowed worker exception, a stuck drain) must fail the test, not
    stall the whole workflow."""
    if not hasattr(signal, "SIGALRM"):  # pragma: no cover — non-POSIX
        yield
        return
    limit = int(os.environ.get("LIVE_TEST_TIMEOUT_S", "180"))

    def fire(signum, frame):
        raise TimeoutError(f"live test exceeded the {limit}s hard timeout")

    old = signal.signal(signal.SIGALRM, fire)
    signal.alarm(limit)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


def _q(sla, arch="paper-default", batch=1):
    return Query(work=QueryWork(arch=arch, batch=batch), sla=sla,
                 submit_time=0.0)


def _wait_until(pred, timeout=60.0, period=0.002):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(period)
    return False


def _assert_conserved(q, n_stages):
    """Checkpointed execution conserves chip-seconds: every plan stage
    ran exactly once (no re-billed chunks, no holes) and the query's
    bill is exactly the sum of its stage trace."""
    assert sorted(e.index for e in q.stage_trace) == list(range(n_stages))
    assert sum(e.chip_seconds for e in q.stage_trace) == pytest.approx(
        q.chip_seconds
    )
    assert sum(e.cost for e in q.stage_trace) == pytest.approx(q.cost)


# ---------------------------------------------------------------------------
# tentpole: checkpointed preemption — exact resume on real work
# ---------------------------------------------------------------------------

def test_preempt_resumes_from_checkpoint_without_rebilling():
    """An IMMEDIATE arrival bumps a running BEST_EFFORT query at a chunk
    boundary; the BoE query resumes from its decode checkpoint and never
    re-runs a completed chunk."""
    eng = LiveEngine(LiveConfig(
        pools=[PoolSpec(name="vm", kind="reserved", chips=1)],
        sla=SLAConfig(relaxed_deadline_s=10.0, poll_period_s=0.02,
                      vm_overload_threshold=1_000,
                      preempt_best_effort=True),
        decode_tokens=192, decode_chunk_tokens=1,
    ))
    n_stages = 1 + 192
    boe = _q(ServiceLevel.BEST_EFFORT)
    imm = _q(ServiceLevel.IMMEDIATE)
    eng.submit(boe)
    # wait until the BoE query is mid-plan, then submit the IMMEDIATE
    assert _wait_until(lambda: 0 < len(boe.stage_trace) < n_stages - 10)
    eng.submit(imm)
    done = eng.drain(2, timeout=120)
    assert len(done) == 2
    assert boe.state == "done" and imm.state == "done"
    assert boe.preemptions >= 1
    assert imm.finish_time < boe.finish_time  # the preemptor cut the line
    _assert_conserved(boe, n_stages)
    _assert_conserved(imm, n_stages)
    # chip-seconds already spent before preemption stayed billed
    assert boe.chip_seconds > 0 and boe.cost == pytest.approx(boe.chip_seconds)


# ---------------------------------------------------------------------------
# tentpole: mid-query spill to the elastic pool at the elastic price
# ---------------------------------------------------------------------------

def test_spill_lands_remaining_stages_on_elastic_at_elastic_price():
    eng = LiveEngine(LiveConfig(
        policy=Policy.AUTO,
        cf_startup_s=0.02,
        sla=SLAConfig(relaxed_deadline_s=10.0, poll_period_s=0.02,
                      vm_overload_threshold=2, spill_enabled=True,
                      spill_min_remaining_s=0.0),
        decode_tokens=192, decode_chunk_tokens=1,
    ))
    n_stages = 1 + 192
    rel = _q(ServiceLevel.RELAXED)
    imm = _q(ServiceLevel.IMMEDIATE)
    eng.submit(rel)
    assert _wait_until(lambda: 0 < len(rel.stage_trace) < n_stages - 10)
    eng.submit(imm)  # vm not overloaded (1 running < 2) -> waits on vm
    done = eng.drain(2, timeout=120)
    assert len(done) == 2 and rel.state == "done"
    assert rel.spilled and rel.cluster == "cf"
    _assert_conserved(rel, n_stages)
    by_pool = {}
    for e in rel.stage_trace:
        by_pool.setdefault(e.cluster, []).append(e)
    assert set(by_pool) == {"vm", "cf"}
    # remaining stages billed at the elastic unit price, earlier at vm's
    for e in by_pool["vm"]:
        assert e.cost == pytest.approx(e.chip_seconds * eng.cfg.vm_price)
    for e in by_pool["cf"]:
        assert e.cost == pytest.approx(
            e.chip_seconds * eng.cfg.vm_price * eng.cfg.cf_price_multiplier
        )
    # the spill is a clean split: vm ran a prefix, cf ran the suffix
    first_cf = min(e.index for e in by_pool["cf"])
    assert max(e.index for e in by_pool["vm"]) < first_cf


def test_spill_back_returns_remaining_stages_to_reserved():
    """Symmetric spill: a spilled query hands its remaining stages back
    to an idle reserved pool at its next chunk boundary."""
    eng = LiveEngine(LiveConfig(
        cf_startup_s=0.02,
        sla=SLAConfig(relaxed_deadline_s=10.0, poll_period_s=0.02,
                      vm_overload_threshold=2,
                      spill_back_enabled=True,
                      spill_min_remaining_s=0.0,
                      spill_back_low_backlog_s=30.0),
        decode_tokens=64, decode_chunk_tokens=1,
    ))
    n_stages = 1 + 64
    q = _q(ServiceLevel.RELAXED)
    q.work = eng.live_work(q.work)
    q.effective_sla = ServiceLevel.RELAXED
    q.spilled = True  # arrived here via a spill; vm has since gone idle
    q.submit_time = q.dequeue_time = eng.now()
    eng.coordinator.by_name["cf"].submit(q, eng.now())
    done = eng.drain(1, timeout=120)
    assert done == [q] and q.state == "done"
    assert q.spill_backs >= 1 and q.cluster == "vm"
    _assert_conserved(q, n_stages)
    clusters = [e.cluster for e in q.stage_trace]
    assert clusters[0] == "cf" and clusters[-1] == "vm"


# ---------------------------------------------------------------------------
# satellite: failures surface; drain never waits out its timeout
# ---------------------------------------------------------------------------

def test_failed_query_surfaces_and_drain_returns_promptly():
    eng = LiveEngine(LiveConfig(
        sla=SLAConfig(relaxed_deadline_s=10.0, poll_period_s=0.02,
                      vm_overload_threshold=2),
    ))

    def boom(arch, batch):
        raise RuntimeError("injected model failure")

    eng.models.ensure = boom
    q = _q(ServiceLevel.IMMEDIATE)
    t0 = time.monotonic()
    eng.submit(q)
    done = eng.drain(1, timeout=60.0)
    took = time.monotonic() - t0
    assert q in done
    assert q.state == "failed"
    assert "injected model failure" in q.error
    assert q.finish_time is not None
    assert took < 10.0, f"drain waited {took:.1f}s on a failed query"


def test_drain_timeout_honored_against_deep_backlog():
    """A timed-out drain must not secretly run the whole backlog to
    completion during shutdown: started queries abandon at their next
    chunk boundary, queued ones are dropped."""
    eng = LiveEngine(LiveConfig(
        pools=[PoolSpec(name="vm", kind="reserved", chips=1)],
        sla=SLAConfig(relaxed_deadline_s=10.0, poll_period_s=0.02,
                      vm_overload_threshold=1_000),
        decode_tokens=256, decode_chunk_tokens=256,  # ~one long chunk
    ))
    eng.models.ensure("paper-default", 1)  # compile outside the window
    n = 12
    for _ in range(n):
        eng.submit(_q(ServiceLevel.IMMEDIATE))
    t0 = time.monotonic()
    done = eng.drain(n, timeout=0.2)
    took = time.monotonic() - t0
    # the backlog (~n long decode chunks on one worker) was NOT drained
    assert len(done) < n
    assert took < 5.0, f"drain+shutdown took {took:.1f}s on a deep backlog"


def test_failure_does_not_block_other_queries():
    eng = LiveEngine(LiveConfig(
        sla=SLAConfig(relaxed_deadline_s=10.0, poll_period_s=0.02,
                      vm_overload_threshold=2),
    ))
    real_ensure = eng.models.ensure

    def selective(arch, batch):
        if arch == "qwen2-0.5b":
            raise RuntimeError("injected: bad arch")
        return real_ensure(arch, batch)

    eng.models.ensure = selective
    bad = _q(ServiceLevel.IMMEDIATE, arch="qwen2-0.5b")
    good = _q(ServiceLevel.IMMEDIATE)
    eng.submit(bad)
    eng.submit(good)
    done = eng.drain(2, timeout=120)
    assert len(done) == 2
    assert bad.state == "failed" and "bad arch" in bad.error
    assert good.state == "done" and good.cost > 0
    _assert_conserved(good, len(good.stage_trace))


# ---------------------------------------------------------------------------
# satellite: routing under concurrent submits (the _vm_busy race)
# ---------------------------------------------------------------------------

def test_concurrent_submits_route_and_account_consistently():
    """Regression for the unlocked `_vm_busy` counter: hammer submits
    from several threads and verify the queue-state the router reads
    never corrupts — every query completes exactly once, fully billed,
    and the pools end empty."""
    eng = LiveEngine(LiveConfig(
        policy=Policy.AUTO,
        cf_startup_s=0.01,
        sla=SLAConfig(relaxed_deadline_s=10.0, poll_period_s=0.02,
                      vm_overload_threshold=2),
    ))
    n_threads, per_thread = 4, 6
    queries = [_q(ServiceLevel.IMMEDIATE)
               for _ in range(n_threads * per_thread)]

    def submit_block(i):
        for q in queries[i * per_thread:(i + 1) * per_thread]:
            eng.submit(q)

    threads = [threading.Thread(target=submit_block, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    done = eng.drain(len(queries), timeout=120)
    assert len(done) == len(queries)
    assert len({q.qid for q in done}) == len(queries)  # no duplicates
    assert all(q.state == "done" for q in done)
    clusters = {q.cluster for q in done}
    assert "vm" in clusters and "cf" in clusters  # overflow engaged
    for q in done:
        _assert_conserved(q, len(q.stage_trace))
        price = eng.cfg.vm_price * (
            eng.cfg.cf_price_multiplier
            if all(e.cluster == "cf" for e in q.stage_trace) else 1.0
        )
        if len({e.cluster for e in q.stage_trace}) == 1:
            assert q.cost == pytest.approx(q.chip_seconds * price)
    for pool in eng.pools:
        assert pool.run_queue_len == 0


# ---------------------------------------------------------------------------
# satellite: single-pool run matches the whole-query engine's totals
# ---------------------------------------------------------------------------

def test_single_pool_matches_whole_query_totals():
    """With one pool and no preempt/spill, chunked execution bills the
    same window the old whole-query engine did: the sum of stage walls
    is the query's exec window (minus only inter-stage bookkeeping),
    at the reserved unit price."""
    eng = LiveEngine(LiveConfig(
        pools=[PoolSpec(name="vm", kind="reserved", chips=1)],
        sla=SLAConfig(relaxed_deadline_s=10.0, poll_period_s=0.02,
                      vm_overload_threshold=1_000),
    ))
    qs = [_q(ServiceLevel.IMMEDIATE) for _ in range(3)]
    for q in qs:
        eng.submit(q)
    done = eng.drain(len(qs), timeout=120)
    assert len(done) == len(qs)
    # prefill + ceil(4 / 2) decode chunks
    n_stages = 1 + -(-eng.cfg.decode_tokens // eng.cfg.decode_chunk_tokens)
    for q in done:
        assert q.state == "done" and q.cluster == "vm"
        _assert_conserved(q, n_stages)
        assert q.cost == pytest.approx(q.chip_seconds * eng.cfg.vm_price)
        # billed chip-seconds ARE the execution window (stage walls are
        # contiguous inside it); jit compile is warmed outside it
        assert q.chip_seconds <= q.exec_time + 1e-9
        assert q.chip_seconds == pytest.approx(q.exec_time, rel=0.5)


def test_first_query_not_billed_for_jit_compile():
    """Billing skew fix: the first query of an arch pays the same
    chip-seconds as a later identical query, because compilation is
    warmed outside the billed window (recorded in models.compile_s)."""
    eng = LiveEngine(LiveConfig(
        pools=[PoolSpec(name="vm", kind="reserved", chips=1)],
        sla=SLAConfig(relaxed_deadline_s=10.0, poll_period_s=0.02,
                      vm_overload_threshold=1_000),
    ))
    first, second = _q(ServiceLevel.IMMEDIATE), _q(ServiceLevel.IMMEDIATE)
    eng.submit(first)
    eng.submit(second)
    done = eng.drain(2, timeout=120)
    assert len(done) == 2
    compile_s = eng.models.compile_s[("paper-default", 1)]
    assert compile_s > 0.0
    # the first query's bill must not carry the compile time: it is the
    # same order as the warm second query, far below compile_s
    assert first.chip_seconds < compile_s / 4
    assert second.chip_seconds < compile_s / 4


# ---------------------------------------------------------------------------
# live calibration loop: quotes converge onto measured stage walls
# ---------------------------------------------------------------------------

def _median(vals):
    vals = sorted(vals)
    return vals[len(vals) // 2]


def test_live_calibration_shrinks_drift_on_mis_declared_pool():
    """A pool DECLARED 2x faster than this host actually runs: the live
    loop fits a speed correction from the measured stage walls and
    hot-swaps it at a stage boundary. Judged in the run's own frame, on
    the post-swap decode walls: a static model wrong by exactly the
    claimed 2x must mispredict them ~2x, while the loop's online quotes
    track them. Two runs of the shared drift probe: the first fits the
    host's TRUE speed (the analytic model's scale on CPU worker threads
    is arbitrary), the second is declared at 2x that — a genuinely
    2x-wrong constant."""
    from repro.core.calibration import measure_live_speed_drift
    from repro.core.cost_model import CostModel

    ref_eng, _ = measure_live_speed_drift(declared_speed=1.0)
    true_speed = ref_eng.pools[0].cost_model.effective_speed_factor
    eng, walls = measure_live_speed_drift(declared_speed=2.0 * true_speed)
    pool = eng.pools[0]
    assert eng.calibrator.samples("vm") >= eng.cfg.calibration_min_samples
    assert pool.cost_model.calibration is not None  # the hot swap landed
    fitted = pool.cost_model.effective_speed_factor
    late = [w for w in walls if w[0] >= eng.cfg.calibration_min_samples]
    assert len(late) >= 20
    declared = CostModel(use_calibration=False,
                         decode_chunk_tokens=eng.cfg.decode_chunk_tokens,
                         speed_factor=2.0 * fitted)
    drift_declared = _median([
        abs(declared.plan(work, 1).stages[index].time_s - wall) / wall
        for _, work, index, wall, _ in late
    ])
    drift_calibrated = _median([
        abs(pred - wall) / wall for _, _, _, wall, pred in late
    ])
    assert drift_calibrated < drift_declared


def test_live_pool_fits_offline_dryrun_dir():
    """PoolSpec.dryrun_dir works on LIVE pools exactly as on simulated
    ones: the pool's quotes run at the fitted speed (the checked-in
    fixtures record a 0.5x pool), not the declared constant."""
    fixtures = Path(__file__).parent / "fixtures" / "dryrun"
    eng = LiveEngine(LiveConfig(
        pools=[PoolSpec(name="vm", kind="reserved", chips=1,
                        dryrun_dir=str(fixtures))],
        sla=SLAConfig(relaxed_deadline_s=10.0, poll_period_s=0.02,
                      vm_overload_threshold=1_000),
    ))
    try:
        cm = eng.pools[0].cost_model
        assert cm.effective_speed_factor == pytest.approx(0.5, rel=0.05)
        assert cm.calibration is not None
    finally:
        eng.shutdown()


# ---------------------------------------------------------------------------
# the live registry answers the same placement questions as the sim's
# ---------------------------------------------------------------------------

def test_live_price_menu_quotes_from_registry():
    eng = LiveEngine(LiveConfig())
    try:
        menu = {m.sla: m for m in eng.price_menu(QueryWork())}
        assert menu["immediate"].pool == "cf"
        assert menu["relaxed"].pool == "vm"
        assert menu["relaxed"].est_cost < menu["immediate"].est_cost
        assert menu["best_effort"].est_cost == menu["relaxed"].est_cost
        assert menu["immediate"].est_pending_s == 0.0
        est = eng.coordinator.estimate(
            Query(work=eng.live_work(QueryWork()),
                  sla=ServiceLevel.IMMEDIATE, submit_time=0.0)
        )
        assert set(est) == {"vm", "cf"}
        assert est["cf"]["cost"] > est["vm"]["cost"]
    finally:
        eng.shutdown()


# ---------------------------------------------------------------------------
# satellite: a saturated live elastic pool quotes its drain, not just
# startup — and live pools share the cross-pool fusion index
# ---------------------------------------------------------------------------

def test_live_elastic_quote_includes_drain_when_saturated():
    """The live elastic pool is bounded at `chips` workers (unlike the
    sim's unbounded burst tier): once every worker is busy, a new task
    waits for the backlog to drain, so the quote must be startup_s +
    predicted drain at current occupancy — not startup_s alone."""
    eng = LiveEngine(LiveConfig(
        pools=[PoolSpec(name="cf", kind="elastic", chips=2, startup_s=0.05,
                        price_multiplier=10.0)],
        sla=SLAConfig(relaxed_deadline_s=10.0, poll_period_s=0.02,
                      vm_overload_threshold=1_000),
    ))
    try:
        eng._stop.set()  # freeze execution: quote from injected state
        pool = eng.pools[0]
        probe = Query(work=eng.live_work(QueryWork()),
                      sla=ServiceLevel.IMMEDIATE, submit_time=0.0)
        assert pool._queue_delay_estimate(probe, 0.0) == pytest.approx(
            pool.startup_s
        )
        # saturate: as many committed placements as workers
        occupants = [
            Query(work=eng.live_work(QueryWork()),
                  sla=ServiceLevel.IMMEDIATE, submit_time=0.0)
            for _ in range(pool.workers)
        ]
        with pool._mu:
            for q in occupants:
                pool.running[q.qid] = (q, object())
        drain = pool.predicted_backlog_cs(0.0) / pool.workers
        assert drain > 0.0
        est = pool._queue_delay_estimate(probe, 0.0)
        assert est == pytest.approx(pool.startup_s + drain)
        # the full quote reflects it too
        assert pool.quote(probe, 0.0)["latency_s"] == pytest.approx(
            pool.startup_s + drain
            + pool.cost_model.plan(probe.work, 1).exec_time
        )
    finally:
        eng.shutdown()


def test_live_pools_share_cross_pool_fusion_index():
    """Two live reserved pools + cross_pool_fusion: waiters queued on
    DIFFERENT pools merge into one batched query at placement time,
    through the same CrossPoolFusionIndex the simulator uses. Workers
    are frozen so the fusion decision is deterministic."""
    eng = LiveEngine(LiveConfig(
        pools=[PoolSpec(name="a", kind="reserved", chips=1),
               PoolSpec(name="b", kind="reserved", chips=1)],
        fuse_queries=True, cross_pool_fusion=True,
        sla=SLAConfig(relaxed_deadline_s=10.0, poll_period_s=0.02,
                      vm_overload_threshold=1_000),
    ))
    try:
        eng._stop.set()  # freeze workers: waiters stay queued
        a, b = eng.pools
        assert a.wait_observer is eng.coordinator.fusion
        w1 = Query(work=eng.live_work(QueryWork()),
                   sla=ServiceLevel.BEST_EFFORT, submit_time=0.0)
        w2 = Query(work=eng.live_work(QueryWork()),
                   sla=ServiceLevel.BEST_EFFORT, submit_time=0.0)
        a.submit(w1, 0.0)
        b.submit(w2, 0.0)
        fresh = Query(work=eng.live_work(QueryWork()),
                      sla=ServiceLevel.BEST_EFFORT, submit_time=0.0)
        fresh.effective_sla = ServiceLevel.BEST_EFFORT
        eng.coordinator.route(fresh, 0.0)
        merged = [q for q in list(a.waiting) + list(b.waiting)
                  if q.members is not None]
        assert len(merged) == 1
        assert sorted(m.qid for m in merged[0].members) == sorted(
            [fresh.qid, w1.qid, w2.qid]
        )
        assert w1 not in a.waiting and w2 not in b.waiting
        # a second withdraw of an already-claimed mate must fail cleanly
        assert not a.withdraw(w1)
    finally:
        eng.shutdown()


def test_live_fused_execution_unpacks_with_exact_split():
    """End-to-end: a fused batch executes as ONE jitted run and drains
    as its members, with the billed split summing bit-exactly."""
    from repro.core.scheduler import fuse_queries

    eng = LiveEngine(LiveConfig(
        pools=[PoolSpec(name="vm", kind="reserved", chips=1)],
        sla=SLAConfig(relaxed_deadline_s=10.0, poll_period_s=0.02,
                      vm_overload_threshold=1_000),
    ))
    try:
        members = [
            Query(work=eng.live_work(QueryWork()),
                  sla=ServiceLevel.IMMEDIATE, submit_time=0.0)
            for _ in range(3)
        ]
        fused = fuse_queries(members, now=0.0)
        fused.work = eng.live_work(fused.work)
        eng.submit(fused)
        out = eng.drain(3, timeout=60.0)
        assert len(out) == 3 and all(q.state == "done" for q in out)
        assert {q.qid for q in out} == {m.qid for m in members}
        assert sum(q.cost for q in out) == fused.cost
        assert sum(q.chip_seconds for q in out) == fused.chip_seconds
        assert all(q.fused_with == 3 for q in out)
    finally:
        eng.shutdown()


# ---------------------------------------------------------------------------
# satellite: worker death between checkpoints never hangs the drain
# ---------------------------------------------------------------------------

@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_worker_death_mid_stage_fails_query_instead_of_hanging():
    """A worker thread dying between checkpoints (BaseException escapes
    the stage loop) leaves the query permanently 'running' in the old
    engine — drain() hung. The stage-boundary reaper must fail it with
    Query.error set and return the drain promptly."""
    from repro.core.chaos import WorkerDeath

    eng = LiveEngine(LiveConfig(
        pools=[PoolSpec(name="vm", kind="reserved", chips=1)],
        sla=SLAConfig(relaxed_deadline_s=10.0, poll_period_s=0.02,
                      vm_overload_threshold=1_000),
        stage_deadline_s=0.5,  # convergence OFF: the reaper acts alone
    ))
    try:
        pool = eng.pools[0]

        def dying(lm, q):
            raise WorkerDeath("injected: thread death between checkpoints")

        pool._run_stage_work = dying
        q = _q(ServiceLevel.IMMEDIATE)
        t0 = time.monotonic()
        eng.submit(q)
        done = eng.drain(1, timeout=60.0)
        took = time.monotonic() - t0
        assert q in done
        assert q.state == "failed"
        assert q.error is not None and "stage deadline" in q.error
        assert q.finish_time is not None
        assert took < 15.0, f"drain waited {took:.1f}s on a dead worker"
    finally:
        eng.shutdown()


@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_convergence_plane_respawns_worker_and_resumes_from_checkpoint():
    """With the convergence plane ON the same death is healed: the dead
    worker is respawned, the in-flight query resumes from its decode
    checkpoint on the replacement, and every stage is billed exactly
    once (the lost stage re-runs; completed stages never re-bill)."""
    from repro.core.chaos import WorkerDeath

    eng = LiveEngine(LiveConfig(
        pools=[PoolSpec(name="vm", kind="reserved", chips=1)],
        sla=SLAConfig(relaxed_deadline_s=10.0, poll_period_s=0.02,
                      vm_overload_threshold=1_000),
        stage_deadline_s=0.5, convergence=True, events=True,
    ))
    try:
        pool = eng.pools[0]
        real = pool._run_stage_work
        fired = []

        def die_once(lm, q):
            # kill the worker on the first decode stage: the prefill
            # checkpoint exists, so the plane can resume past it
            if q.stage_cursor == 1 and not fired:
                fired.append(q.qid)
                raise WorkerDeath("injected: mid-decode death")
            return real(lm, q)

        pool._run_stage_work = die_once
        q = _q(ServiceLevel.IMMEDIATE)
        eng.submit(q)
        done = eng.drain(1, timeout=60.0)
        assert q in done
        assert q.state == "done", q.error
        assert fired == [q.qid]
        _assert_conserved(q, len(q.stage_trace))
        assert q.stage_trace[0].stage == "prefill"
        assert eng.plane.deaths == 1
        assert eng.plane.resumes == 1
        assert eng.plane.replacements >= 1
        # the dead thread's slot holds a respawned replacement (name
        # gains the 'r' suffix): the pool returned to full width and
        # the replacement is what ran the query to completion
        assert [t.name for t in pool._threads] == ["live-vm-0r"]
        counts = dict(eng.events.counts())
        assert counts["death"] == 1 and counts["resume"] == 1
        assert counts.get("replace", 0) >= 1
    finally:
        eng.shutdown()


@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_max_resumes_bounds_repeated_deaths():
    """A query whose placement dies on every attempt must converge to a
    terminal failure after max_resumes, not loop forever."""
    from repro.core.chaos import WorkerDeath

    eng = LiveEngine(LiveConfig(
        pools=[PoolSpec(name="vm", kind="reserved", chips=1)],
        sla=SLAConfig(relaxed_deadline_s=10.0, poll_period_s=0.02,
                      vm_overload_threshold=1_000),
        stage_deadline_s=0.5, convergence=True, max_resumes=1,
    ))
    try:
        pool = eng.pools[0]

        def always_die(lm, q):
            if q.stage_cursor == 1:
                raise WorkerDeath("injected: persistent decode death")
            return LiveExecutor._run_stage_work(pool, lm, q)

        pool._run_stage_work = always_die
        q = _q(ServiceLevel.IMMEDIATE)
        eng.submit(q)
        done = eng.drain(1, timeout=60.0)
        assert q in done
        assert q.state == "failed"
        assert "stage deadline" in q.error
        assert eng.plane.resumes == 1  # resumed once, then failed
    finally:
        eng.shutdown()


# ---------------------------------------------------------------------------
# satellite: elastic provisioning sleep is interruptible
# ---------------------------------------------------------------------------

def test_elastic_startup_sleep_does_not_block_shutdown():
    """LiveElasticPool used to time.sleep(startup_s) per task — a
    shutdown during provisioning waited out the full startup. The sleep
    is now the engine's stop event, so shutdown wall stays far below
    startup_s."""
    startup_s = 30.0
    eng = LiveEngine(LiveConfig(
        pools=[PoolSpec(name="cf", kind="elastic", chips=2,
                        startup_s=startup_s, price_multiplier=10.0)],
        sla=SLAConfig(relaxed_deadline_s=10.0, poll_period_s=0.02,
                      vm_overload_threshold=1_000),
    ))
    for _ in range(3):
        eng.submit(_q(ServiceLevel.IMMEDIATE))
    # wait until at least one task is inside the provisioning sleep
    pool = eng.pools[0]
    assert _wait_until(lambda: pool.run_queue_len > 0, timeout=10.0)
    t0 = time.monotonic()
    eng.shutdown()
    took = time.monotonic() - t0
    assert took < startup_s / 3, (
        f"shutdown took {took:.1f}s — the provisioning sleep is not "
        f"interruptible"
    )
