"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't error, collection
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    FaultModel,
    Policy,
    Query,
    QueryWork,
    ServiceLevel,
    SLAConfig,
    run_sim,
)
from repro.core.cost_model import CostModel
from repro.core.engine import ClusterExecutor
from repro.parallel.compress import dequantize_int8, ef_compress, quantize_int8
from repro.parallel.sharding import TRAIN_RULES, spec_for


# ---------------------------------------------------------------------------
# int8 error-feedback compression
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=2, max_size=64))
def test_ef_identity_invariant(vals):
    """x + err == deq(q) + new_err (error feedback loses nothing)."""
    x = jnp.asarray(vals, jnp.float32)
    err = jnp.zeros_like(x)
    q, scale, new_err = ef_compress(x, err)
    lhs = np.asarray(x + err)
    rhs = np.asarray(dequantize_int8(q, scale) + new_err)
    np.testing.assert_allclose(lhs, rhs, atol=1e-5 * (1 + np.abs(lhs).max()))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_ef_error_accumulation_bounded(seed):
    """Repeated EF compression of the same signal: residual stays bounded
    by one quantization step (no drift)."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (32,))
    err = jnp.zeros_like(x)
    for _ in range(10):
        q, scale, err = ef_compress(x, err)
        assert float(jnp.max(jnp.abs(err))) <= float(scale) * 1.01


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-1e4, 1e4, allow_nan=False), min_size=1, max_size=32))
def test_quantize_int8_range_and_scale(vals):
    x = jnp.asarray(vals, jnp.float32)
    q, scale = quantize_int8(x)
    assert q.dtype == jnp.int8
    assert int(jnp.max(jnp.abs(q.astype(jnp.int32)))) <= 127
    err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
    assert err.max() <= float(scale) * 0.5 + 1e-6


# ---------------------------------------------------------------------------
# sharding spec fallback
# ---------------------------------------------------------------------------

_mesh = None


def _get_mesh():
    global _mesh
    if _mesh is None:
        from repro.launch.mesh import make_local_mesh

        _mesh = make_local_mesh(1, 1)
    return _mesh


class _FakeMesh:
    """Mesh stand-in with arbitrary axis sizes (spec_for only reads shape)."""

    def __init__(self, data, model):
        self.shape = {"data": data, "model": model}


@settings(max_examples=100, deadline=None)
@given(
    dims=st.lists(st.integers(1, 512), min_size=1, max_size=4),
    data=st.sampled_from([1, 2, 4, 8, 16]),
    model=st.sampled_from([1, 2, 4, 8, 16]),
)
def test_spec_for_always_valid(dims, data, model):
    """Every produced spec divides dims and never reuses a mesh axis."""
    names = ["fsdp", "heads", "ff", "vocab"][: len(dims)]
    mesh = _FakeMesh(data, model)
    spec = spec_for(tuple(dims), tuple(names), TRAIN_RULES, mesh)
    used = []
    for dim, entry in zip(dims, spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            assert a not in used, (spec, dims)
            used.append(a)
            size *= mesh.shape[a]
        assert dim % size == 0, (spec, dims)


# ---------------------------------------------------------------------------
# SLA guarantees under random streams
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n=st.integers(5, 40),
    policy=st.sampled_from([Policy.AUTO, Policy.FORCE]),
)
def test_relaxed_pending_guarantee_any_stream(seed, n, policy):
    """For ANY arrival pattern the relaxed pending time stays <= deadline
    and every query eventually finishes exactly once."""
    rng = np.random.default_rng(seed)
    qs = []
    for i in range(n):
        sla = ServiceLevel(int(rng.integers(0, 3)))
        qs.append(
            Query(
                work=QueryWork(
                    arch="paper-default",
                    prompt_tokens=int(rng.integers(10_000, 2_000_000)),
                    output_tokens=int(rng.integers(1, 64)),
                ),
                sla=sla,
                submit_time=float(rng.uniform(0, 600)),
            )
        )
    res = run_sim(qs, policy=policy, use_calibration=False)
    assert len(res.queries) == n  # everything finishes, nothing duplicated
    assert len({q.qid for q in res.queries}) == n
    for q in res.queries:
        assert q.finish_time is not None
        assert q.finish_time >= q.start_time >= q.dequeue_time >= q.submit_time
        if q.effective_sla is ServiceLevel.RELAXED:
            assert q.pending_time <= 300.0 + 1e-6
        if q.effective_sla is ServiceLevel.IMMEDIATE:
            assert q.pending_time == 0.0
    # billing consistency: every finished query was billed for its work
    for q in res.queries:
        assert q.cost > 0 and q.chip_seconds > 0


# ---------------------------------------------------------------------------
# stage-engine invariants under arbitrary preempt/spill/retry sequences
# ---------------------------------------------------------------------------

def _random_stream(seed: int, n: int) -> list[Query]:
    rng = np.random.default_rng(seed)
    return [
        Query(
            work=QueryWork(
                arch="paper-default",
                prompt_tokens=int(rng.integers(50_000, 3_000_000)),
                output_tokens=int(rng.integers(1, 256)),
            ),
            sla=ServiceLevel(int(rng.integers(0, 3))),
            submit_time=float(rng.uniform(0, 600)),
        )
        for _ in range(n)
    ]


def _run_heap_checked(seed: int, n: int, spill_back: bool,
                      hot_swap: bool = False, queries=None, **sim_kw):
    """A contended SOS sim with preemption + spill (+ spill-back) + stage
    faults, re-checking after EVERY executor advance: (a) the heap
    discipline — every running stage has exactly one valid heap entry,
    and no valid entry refers to a retired run — and (b) the backlog
    equivalence — the O(1) incremental ``predicted_backlog_cs`` counter
    matches the full O(running+waiting) recompute scan. With
    ``hot_swap``, a calibration table is swapped into EVERY pool's cost
    model MID-RUN (each pool after its own 10th advance) — the
    invariants must survive the live model update."""
    from repro.core.calibration import CalibrationTable

    orig = ClusterExecutor.advance_to
    advances: dict[int, int] = {}

    def checked(self, now):
        out = orig(self, now)
        self.check_heap_invariant()
        self.check_backlog_invariant(now)
        advances[id(self)] = advances.get(id(self), 0) + 1
        if hot_swap and advances[id(self)] == 10:
            # mid-run hot swap: later stages of RUNNING queries re-plan
            # 2x slower; structure is invariant so cursors stay valid
            self.cost_model.set_calibration(
                CalibrationTable(speed_factor=0.5)
            )
        return out

    ClusterExecutor.advance_to = checked
    try:
        return run_sim(
            queries if queries is not None else _random_stream(seed, n),
            vm_mode="sos", vm_chips=32, sos_slice_chips=16,
            use_calibration=False, seed=seed,
            fault=FaultModel(failure_prob=0.1, straggler_prob=0.1),
            sla=SLAConfig(
                preempt_best_effort=True, spill_enabled=True,
                spill_back_enabled=spill_back,
                spill_back_low_backlog_s=30.0, vm_overload_threshold=3,
            ),
            **sim_kw,
        )
    finally:
        ClusterExecutor.advance_to = orig


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n=st.integers(5, 25),
    spill_back=st.booleans(),
    hot_swap=st.booleans(),
)
def test_heap_discipline_any_preempt_spill_retry_sequence(
    seed, n, spill_back, hot_swap
):
    """The engine's core data-structure invariant survives ANY sequence
    of preemptions, cross-pool spills, spill-backs, and stage retries —
    including a mid-run calibration hot swap."""
    res = _run_heap_checked(seed, n, spill_back, hot_swap)
    assert len(res.queries) == n
    for q in res.queries:
        assert q.finish_time is not None and q.state == "done"
        # every stage ran exactly once, in order, across all pool hops
        idx = [e.index for e in q.stage_trace]
        assert idx == list(range(len(idx)))


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n=st.integers(5, 25),
    spill_back=st.booleans(),
    hot_swap=st.booleans(),
)
def test_billed_chip_seconds_are_conserved(seed, n, spill_back, hot_swap):
    """Billing conservation: each query's billed chip-seconds equal the
    sum of its per-stage trace records — bit for bit through preemption,
    pool hops, retry re-billing, and a mid-run calibration hot swap —
    and its cost is the per-stage cost at each executing pool's own
    price."""
    res = _run_heap_checked(seed, n, spill_back, hot_swap)
    for q in res.queries:
        assert q.chip_seconds == pytest.approx(
            sum(e.chip_seconds for e in q.stage_trace)
        )
        assert q.cost == pytest.approx(sum(e.cost for e in q.stage_trace))
        # a retried stage bills MORE than its clean run, never less
        assert q.chip_seconds > 0 and q.cost > 0


# ---------------------------------------------------------------------------
# fusion invariants (within-pool AND cross-pool placement-time fusion)
# ---------------------------------------------------------------------------

def _fusable_stream(seed: int, n: int) -> list[Query]:
    """A stream drawn from FEW work shapes, so fusion groups actually
    form (duplicate (arch, kind, prompt, output) keys are the fusion
    opportunity)."""
    rng = np.random.default_rng(seed)
    shapes = [
        QueryWork(arch="paper-default", prompt_tokens=200_000,
                  output_tokens=32),
        QueryWork(arch="paper-default", prompt_tokens=800_000,
                  output_tokens=64),
        QueryWork(arch="qwen2-0.5b", prompt_tokens=200_000,
                  output_tokens=32),
    ]
    return [
        Query(
            work=shapes[int(rng.integers(0, len(shapes)))],
            sla=ServiceLevel(int(rng.integers(0, 3))),
            submit_time=float(rng.uniform(0, 300)),
        )
        for _ in range(n)
    ]


def _check_fusion_invariants(res, n: int) -> None:
    """Conservation + trace integrity across fusion/unpack:
    every submitted query comes back exactly once; chip-seconds and
    costs are conserved — the sum over queries equals the sum over the
    (deduplicated) stage traces bit-for-bit up to the exact-sum split —
    and each executed trace is overlap-free with contiguous indices."""
    assert len(res.queries) == n
    assert len({q.qid for q in res.queries}) == n
    for q in res.queries:
        assert q.finish_time is not None and q.state == "done"
        assert q.cost > 0 and q.chip_seconds > 0
    # stage traces are SHARED by fused members (member 0 carries the
    # fused run's trace): deduplicate by identity before summing
    seen_traces: dict[int, list] = {}
    for q in res.queries:
        if q.stage_trace:
            seen_traces[id(q.stage_trace)] = q.stage_trace
    trace_cs = sum(
        e.chip_seconds for tr in seen_traces.values() for e in tr
    )
    trace_cost = sum(e.cost for tr in seen_traces.values() for e in tr)
    assert sum(q.chip_seconds for q in res.queries) == pytest.approx(
        trace_cs, rel=1e-9
    )
    assert sum(q.cost for q in res.queries) == pytest.approx(
        trace_cost, rel=1e-9
    )
    for tr in seen_traces.values():
        assert [e.index for e in tr] == list(range(len(tr)))
        for a, b in zip(tr, tr[1:]):
            assert b.start >= a.finish - 1e-9  # no overlap across hops


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n=st.integers(8, 30),
    cross=st.booleans(),
    spill_back=st.booleans(),
)
def test_fusion_conserves_chip_seconds_under_preempt_spill_retry(
    seed, n, cross, spill_back
):
    """Fusion — including cross-pool placement-time fusion — preserves
    chip-second/cost conservation and gap/overlap-free stage traces
    under arbitrary preempt/spill/retry, with the heap AND incremental-
    backlog invariants re-checked after every advance."""
    res = _run_heap_checked(
        seed, n, spill_back, queries=_fusable_stream(seed, n),
        fuse_queries=True, cross_pool_fusion=cross,
    )
    _check_fusion_invariants(res, n)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(8, 25))
def test_fusion_off_is_invariant_to_cross_pool_flag(seed, n):
    """Degeneracy: with fuse_queries=False the cross_pool_fusion flag —
    and the whole fusion index machinery — must be inert: per-query
    results are identical field-for-field."""
    def go(cross):
        qs = _fusable_stream(seed, n)
        return run_sim(
            qs, vm_mode="sos", vm_chips=32, sos_slice_chips=16,
            use_calibration=False, seed=seed,
            fault=FaultModel(failure_prob=0.1, straggler_prob=0.1),
            cross_pool_fusion=cross,
            sla=SLAConfig(preempt_best_effort=True, spill_enabled=True,
                          vm_overload_threshold=3),
        )

    a, b = go(False), go(True)
    sig_a = sorted(
        (q.submit_time, q.cost, q.chip_seconds, q.finish_time, q.cluster)
        for q in a.queries
    )
    sig_b = sorted(
        (q.submit_time, q.cost, q.chip_seconds, q.finish_time, q.cluster)
        for q in b.queries
    )
    assert sig_a == sig_b


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    tokens=st.integers(1_000, 5_000_000),
    chips=st.sampled_from([4, 8, 16, 32, 64, 128]),
)
def test_cost_model_positive_and_scale_monotone(tokens, chips):
    cm = CostModel(use_calibration=False)
    w = QueryWork(arch="internlm2-1.8b", prompt_tokens=tokens, output_tokens=4)
    t = cm.exec_time(w, chips)
    assert t > 0
    assert cm.exec_time(w, chips * 2) <= t + 1e-12
