"""Equivalence gate for the vectorized core (ISSUE 6).

The batched event drain (core/simulator.py), the vectorized arrival
generation (core/workload.py), and the vectorized decode-chunk/suffix-sum
plan math (core/cost_model.py) must be BIT-identical to their scalar
references — same floats, same ordering, same traces. Each vectorized
path keeps its scalar oracle alive (``SimConfig.scalar_core``,
``_decode_chunk_time_scalar``, an inline reference loop here) and this
module locks the two together: on a golden-style full-featured day, and
under seeded random preempt/spill/retry days (plus hypothesis-driven
ones when hypothesis is installed), with chip-second conservation and
gap/overlap-free stage traces re-asserted on every run.
"""
from __future__ import annotations

import itertools
import math
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from repro.configs import get_config
from repro.core import (
    FaultModel,
    Policy,
    PoolSpec,
    Query,
    QueryWork,
    ServiceLevel,
    SimConfig,
    Simulation,
    SLAConfig,
)
from repro.core.clusters import AutoscaleConfig
from repro.core.cost_model import (
    CostModel,
    _decode_chunk_time,
    _decode_chunk_time_scalar,
)
from repro.core.query import reset_qids
from repro.core.workload import TABLE1, _arrival_times, generate, scaled_patterns

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # optional dep — the seeded gates below always run
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# workload generation: vectorized vs per-query reference loop
# ---------------------------------------------------------------------------

def _generate_reference(horizon_s: float, seed: int, patterns) -> list[Query]:
    """The pre-vectorization per-query loop, kept inline as the oracle:
    one work dataclass per query, sla_cycle indexed per query."""
    rng = np.random.default_rng(seed)
    queries: list[Query] = []
    for spec in patterns:
        times = np.sort(_arrival_times(spec, horizon_s, rng))
        for i, t in enumerate(times):
            prompt = spec.db_gb * 98_304 // max(spec.batch, 1)
            work = QueryWork(
                arch=spec.arch, kind="serve", batch=spec.batch,
                prompt_tokens=int(prompt), output_tokens=spec.output_tokens,
            )
            sla = spec.sla_cycle[i % len(spec.sla_cycle)]
            queries.append(Query(work=work, sla=sla, submit_time=float(t),
                                 source=spec.name))
    queries.sort(key=lambda q: q.submit_time)
    return queries


@pytest.mark.parametrize("seed,factor", [(0, 1.0), (42, 0.55), (7, 2.0)])
def test_generate_matches_reference_loop(seed, factor):
    pats = scaled_patterns(factor) if factor != 1.0 else TABLE1
    reset_qids()
    vec = generate(horizon_s=14_400.0, seed=seed, patterns=pats)
    reset_qids()
    ref = _generate_reference(14_400.0, seed, pats)
    assert len(vec) == len(ref)
    for a, b in zip(vec, ref):
        assert a.qid == b.qid  # same construction order
        assert a.submit_time == b.submit_time  # exact float
        assert a.sla is b.sla
        assert a.source == b.source
        assert a.work == b.work


# ---------------------------------------------------------------------------
# cost model: vectorized decode-chunk walk and suffix sums vs scalar
# ---------------------------------------------------------------------------

ARCHS = ("qwen2-0.5b", "internlm2-1.8b", "granite-8b", "mixtral-8x7b",
         "phi3.5-moe-42b-a6.6b", "paper-default")


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_chunk_time_matches_scalar(arch):
    cfg = get_config(arch)
    for batch, ctx0, n, chips in itertools.product(
        (1, 2, 4), (0, 7, 983_040), (1, 5, 64, 333), (8, 64)
    ):
        vec = _decode_chunk_time(cfg, batch, ctx0, n, chips)
        ref = _decode_chunk_time_scalar(cfg, batch, ctx0, n, chips)
        assert vec == ref, (arch, batch, ctx0, n, chips)


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("chips", [8, 32])
def test_stage_plan_suffix_sums_match_sequential(arch, chips):
    cm = CostModel(use_calibration=False)
    w = QueryWork(arch=arch, prompt_tokens=500_000, output_tokens=96)
    plan = cm.plan(w, chips)
    acc_t, acc_cs = 0.0, 0.0
    times, css = [0.0], [0.0]
    for s in reversed(plan.stages):
        acc_t = acc_t + s.time_s  # same order as np.cumsum (sequential)
        acc_cs = acc_cs + s.chip_seconds
        times.append(acc_t)
        css.append(acc_cs)
    assert list(plan._suffix_time) == times[::-1]
    assert list(plan._suffix_cs) == css[::-1]
    assert plan.remaining_time(0) == plan._suffix_time[0]
    assert plan.remaining_chip_seconds(len(plan.stages)) == 0.0


# ---------------------------------------------------------------------------
# batched event drain vs scalar core: bit-identical full days
# ---------------------------------------------------------------------------

def _signature(res):
    """Everything observable about a run, exact floats included."""
    per_query = [
        (q.qid, q.submit_time, q.cost, q.chip_seconds, q.start_time,
         q.finish_time, q.cluster, q.retries, q.preemptions, q.spilled,
         q.spill_backs, tuple(q.stage_trace))
        for q in sorted(res.queries, key=lambda q: q.qid)
    ]
    completion_order = [q.qid for q in res.queries]
    return per_query, completion_order


def _check_physics(res) -> None:
    """Chip-second conservation + gap/overlap-free per-query traces —
    the invariants the drain must preserve regardless of batching."""
    seen: dict[int, list] = {}
    for q in res.queries:
        assert q.finish_time is not None and q.state == "done"
        if q.stage_trace:
            seen[id(q.stage_trace)] = q.stage_trace
    for q in res.queries:
        if id(q.stage_trace) in seen:  # fused members share the trace
            continue
    for tr in seen.values():
        assert [e.index for e in tr] == list(range(len(tr)))
        for a, b in zip(tr, tr[1:]):
            assert b.start >= a.finish - 1e-9  # no overlap across hops
    total_q = sum(q.chip_seconds for q in res.queries)
    total_tr = sum(e.chip_seconds for tr in seen.values() for e in tr)
    assert total_q == pytest.approx(total_tr, rel=1e-9)


def _run_both(cfg_factory, qs_factory):
    """One day, twice: scalar oracle vs batched drain, fresh queries and
    qids each time so the comparison is free of cross-run state."""
    outs = []
    for scalar in (True, False):
        reset_qids()
        cfg = cfg_factory()
        cfg.scalar_core = scalar
        res = Simulation(cfg).run(qs_factory())
        _check_physics(res)
        outs.append(res)
    return outs


def _golden_style_cfg(seed: int = 42) -> SimConfig:
    """The golden trace's shape: 3 heterogeneous pools, stage faults,
    backlog autoscale, preemption + spill + spill-back — every feature
    the drain's safety argument has to hold under at once."""
    return SimConfig(
        policy=Policy.FORCE, use_calibration=False, seed=seed,
        fault=FaultModel(failure_prob=0.02, straggler_prob=0.02),
        sla=SLAConfig(vm_overload_threshold=3, preempt_best_effort=True,
                      spill_enabled=True, spill_back_enabled=True,
                      spill_back_low_backlog_s=5.0),
        pools=[
            PoolSpec(name="vm", kind="reserved", chips=32, mode="sos",
                     slice_chips=16,
                     autoscale=AutoscaleConfig(
                         enabled=True, min_chips=32, max_chips=64,
                         step_chips=16, scale_delay_s=120.0,
                         trigger="backlog", backlog_high_s=60.0,
                         backlog_low_s=5.0)),
            PoolSpec(name="spot", kind="reserved", chips=64, mode="sos",
                     slice_chips=16, speed_factor=0.25,
                     price_multiplier=0.15),
            PoolSpec(name="cf", kind="elastic", chips=64, startup_s=2.0,
                     price_multiplier=10.0),
        ],
    )


def test_batched_drain_bit_identical_on_golden_style_day():
    a, b = _run_both(
        _golden_style_cfg,
        lambda: generate(horizon_s=14_400.0, seed=42,
                         patterns=scaled_patterns(8.0)),
    )
    # the day must actually exercise every feature the drain's safety
    # argument has to hold under — a quiet day proves nothing
    assert sum(q.preemptions for q in a.queries) > 0
    assert sum(q.spilled for q in a.queries) > 50
    assert sum(q.retries for q in a.queries) > 100
    assert sum(q.spill_backs for q in a.queries) > 5
    assert _signature(a) == _signature(b)


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 7, 11])
def test_batched_drain_bit_identical_random_days(seed):
    """Seeded random preempt/spill/retry days (the deterministic gate
    that runs even without hypothesis installed)."""
    _assert_drain_equivalent(seed, n=int(10 + (seed * 13) % 30),
                             spill_back=bool(seed % 2),
                             fuse=bool(seed % 3 == 0))


def _random_stream(seed: int, n: int) -> list[Query]:
    rng = np.random.default_rng(seed)
    return [
        Query(
            work=QueryWork(
                arch="paper-default",
                prompt_tokens=int(rng.integers(50_000, 3_000_000)),
                output_tokens=int(rng.integers(1, 256)),
            ),
            sla=ServiceLevel(int(rng.integers(0, 3))),
            submit_time=float(rng.uniform(0, 600)),
        )
        for _ in range(n)
    ]


def _assert_drain_equivalent(seed: int, n: int, spill_back: bool,
                             fuse: bool = False) -> None:
    def cfg_factory():
        return SimConfig(
            vm_mode="sos", vm_chips=32, sos_slice_chips=16,
            use_calibration=False, seed=seed, fuse_queries=fuse,
            fault=FaultModel(failure_prob=0.1, straggler_prob=0.1),
            sla=SLAConfig(preempt_best_effort=True, spill_enabled=True,
                          spill_back_enabled=spill_back,
                          spill_back_low_backlog_s=30.0,
                          vm_overload_threshold=3),
        )
    a, b = _run_both(cfg_factory, lambda: _random_stream(seed, n))
    assert _signature(a) == _signature(b)


if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(0, 10_000),
        n=st.integers(5, 30),
        spill_back=st.booleans(),
        fuse=st.booleans(),
    )
    def test_batched_drain_bit_identical_hypothesis(seed, n, spill_back,
                                                    fuse):
        """Hypothesis-driven random preempt/spill/retry days: the drain
        must be bit-identical to the scalar oracle on ANY of them."""
        _assert_drain_equivalent(seed, n, spill_back, fuse)


# ---------------------------------------------------------------------------
# sweep harness: sharded == serial, any worker count / completion order
# ---------------------------------------------------------------------------

_TIMING_FIELDS = {"wall_s", "gen_s", "accounting_s", "qps"}


def _strip_timing(row: dict) -> dict:
    return {k: v for k, v in row.items() if k not in _TIMING_FIELDS}


def test_sweep_sharded_equals_serial():
    from benchmarks.sweep import build_cells, run_sweep

    cells = build_cells(["engine_off", "pools3_backlog"], 2, 300, 0)
    serial, _ = run_sweep(
        build_cells(["engine_off", "pools3_backlog"], 2, 300, 0), 1)
    sharded, _ = run_sweep(cells, 2)
    assert set(serial) == set(sharded)
    for cell_id in serial:
        assert _strip_timing(serial[cell_id]) == _strip_timing(
            sharded[cell_id]), cell_id


def test_sweep_seed_tree_is_deterministic():
    """The SeedSequence.spawn tree depends only on (grid, master seed):
    rebuilding the same grid yields byte-identical child states, and a
    different master seed yields different ones."""
    from benchmarks.sweep import build_cells

    a = build_cells(["engine_off"], 3, 500, 0)
    b = build_cells(["engine_off"], 3, 500, 0)
    c = build_cells(["engine_off"], 3, 500, 1)
    for x, y in zip(a, b):
        assert x["ss"].entropy == y["ss"].entropy
        assert x["ss"].spawn_key == y["ss"].spawn_key
        assert np.array_equal(x["ss"].generate_state(4),
                              y["ss"].generate_state(4))
    assert not np.array_equal(a[0]["ss"].generate_state(4),
                              c[0]["ss"].generate_state(4))


def test_scalar_core_env_flag(monkeypatch):
    """REPRO_SCALAR_CORE=1 forces the oracle loop without touching the
    config — the hook the equivalence suite and bisection runs use."""
    monkeypatch.setenv("REPRO_SCALAR_CORE", "1")
    reset_qids()
    res = Simulation(SimConfig(use_calibration=False)).run(
        _random_stream(3, 12))
    assert len(res.queries) == 12
    assert all(q.finish_time is not None for q in res.queries)
