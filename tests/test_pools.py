"""Multi-pool executor registry (core/pools.py): quote-based routing,
backlog-driven autoscale, symmetric spill-back, and degeneracy to the
PR-1 two-cluster simulator."""
import itertools

import pytest

from repro.core import (
    CostModel,
    PoolSpec,
    Policy,
    Query,
    QueryWork,
    ServiceLevel,
    SimConfig,
    Simulation,
    SLAConfig,
    build_pool,
    default_pool_specs,
    generate,
    run_sim,
)
from repro.core.clusters import AutoscaleConfig, CostEfficientCluster

PIN_VM = dict(vm_overload_threshold=10**9)  # keep the coordinator reserved


def _mk(sla, t, tokens=100_000, out=8, arch="paper-default"):
    return Query(
        work=QueryWork(arch=arch, prompt_tokens=tokens, output_tokens=out),
        sla=sla,
        submit_time=t,
    )


def _norm_finish(res):
    """Per-query (relative qid, cluster, finish, cost) — the bit-for-bit
    comparison key (qids are globally counted, so compare relative)."""
    base = min(q.qid for q in res.queries)
    return [
        (q.qid - base, q.cluster, q.finish_time, q.cost)
        for q in sorted(res.queries, key=lambda q: q.qid)
    ]


# ---------------------------------------------------------------------------
# registry degeneracy: the new machinery reproduces PR-1 exactly
# ---------------------------------------------------------------------------

def test_default_registry_is_the_legacy_vm_cf_pair():
    """SimConfig(pools=None) and an explicit default spec list are the
    same system: same per-query finish times and costs."""
    legacy = run_sim(generate(horizon_s=3600, seed=3), use_calibration=False)
    cfg = SimConfig(use_calibration=False, pools=default_pool_specs())
    explicit = Simulation(cfg).run(generate(horizon_s=3600, seed=3))
    assert _norm_finish(legacy) == _norm_finish(explicit)


def test_single_pool_registry_degenerates_to_pr1():
    """A registry of ONE reserved pool routes everything there and
    reproduces the legacy simulator with the elastic pool unreachable
    (overload threshold pinned) — same seed, same per-query finish
    times, bit for bit."""
    sla = SLAConfig(**PIN_VM)
    legacy = run_sim(
        generate(horizon_s=3600, seed=4), vm_mode="sos", vm_chips=64,
        sos_slice_chips=16, use_calibration=False, sla=sla,
    )
    solo = Simulation(SimConfig(
        use_calibration=False, sla=sla,
        pools=[PoolSpec(name="vm", kind="reserved", chips=64, mode="sos",
                        slice_chips=16)],
    )).run(generate(horizon_s=3600, seed=4))
    assert all(q.cluster == "vm" for q in solo.queries)
    assert _norm_finish(legacy) == _norm_finish(solo)


def test_single_pool_registry_handles_every_policy():
    for policy in (Policy.AUTO, Policy.FORCE, Policy.LATENCY_AWARE):
        res = Simulation(SimConfig(
            policy=policy, use_calibration=False,
            pools=[PoolSpec(name="vm", kind="reserved", chips=64, mode="sos",
                            slice_chips=16)],
        )).run([_mk(ServiceLevel(i % 3), float(i)) for i in range(9)])
        assert all(q.finish_time is not None for q in res.queries)
        assert all(q.cluster == "vm" for q in res.queries)


# ---------------------------------------------------------------------------
# satellite fix: estimate() and should_spill() plan with the SAME chips
# ---------------------------------------------------------------------------

def test_effective_chips_is_the_single_planning_accessor():
    """SOS pools plan on the isolated sub-slice, POS pools on the whole
    slice — and quotes, spill thresholds, and execution all read the one
    effective_chips accessor (the old estimate() planned VM latency with
    .chips while should_spill used .slice_chips; quotes were wrong in
    SOS mode)."""
    q = _mk(ServiceLevel.IMMEDIATE, 0.0, tokens=500_000, out=16)
    sos = Simulation(SimConfig(vm_mode="sos", vm_chips=64, sos_slice_chips=16,
                               use_calibration=False))
    pos = Simulation(SimConfig(vm_mode="pos", vm_chips=64,
                               use_calibration=False))
    assert sos.vm.effective_chips(q) == sos.vm.slice_chips == 16
    assert pos.vm.effective_chips(q) == pos.vm.chips == 64


def test_quote_and_spill_threshold_agree_on_the_plan():
    """The vm quote and the spill policy derive from the same remaining-
    stage plan: an idle SOS pool quotes exactly the slice execution time,
    and the spill threshold compares against that same plan's remaining
    time."""
    sim = Simulation(SimConfig(
        vm_mode="sos", vm_chips=64, sos_slice_chips=16, use_calibration=False,
        sla=SLAConfig(spill_enabled=True, spill_min_remaining_s=5.0, **PIN_VM),
    ))
    q = _mk(ServiceLevel.IMMEDIATE, 0.0, tokens=500_000, out=16)
    plan = sim.vm.cost_model.plan(q.work, sim.vm.effective_chips(q))
    quote = sim.coordinator.estimate(q, now=0.0)["vm"]
    assert quote["latency_s"] == pytest.approx(plan.exec_time)  # idle: no wait
    assert quote["cost"] == pytest.approx(
        plan.chip_seconds * sim.vm.price_per_chip_s
    )
    # should_spill's "worth the premium" test reads the same plan: with a
    # displacing waiter present, the verdict flips exactly at the plan's
    # remaining time, not at a whole-pool-chips replanning of it
    sim.vm.waiting.append(_mk(ServiceLevel.IMMEDIATE, 0.0))
    assert sim.coordinator.should_spill(q, 0.0) == (
        plan.remaining_time(q.stage_cursor) >= 5.0
    )
    fat = SLAConfig(spill_enabled=True,
                    spill_min_remaining_s=plan.exec_time + 1.0, **PIN_VM)
    sim.coordinator.cfg = fat
    assert not sim.coordinator.should_spill(q, 0.0)


# ---------------------------------------------------------------------------
# quotes across a heterogeneous registry
# ---------------------------------------------------------------------------

def _three_pool_specs(**vm_kw):
    return [
        PoolSpec(name="vm", kind="reserved", chips=64, mode="sos",
                 slice_chips=16, **vm_kw),
        PoolSpec(name="spot", kind="reserved", chips=256, mode="sos",
                 slice_chips=16, speed_factor=0.25, price_multiplier=0.15),
        PoolSpec(name="cf", kind="elastic", chips=64, startup_s=2.0,
                 price_multiplier=10.0),
    ]


def test_quotes_expose_the_cost_latency_frontier():
    """The slow cheap pool quotes higher latency and lower cost than the
    fast reserved pool; the elastic pool quotes low latency at a premium
    — the frontier the coordinator routes across."""
    sim = Simulation(SimConfig(pools=_three_pool_specs(),
                               use_calibration=False))
    q = _mk(ServiceLevel.IMMEDIATE, 0.0, tokens=1_000_000, out=32)
    est = sim.coordinator.estimate(q, now=0.0)
    assert set(est) == {"vm", "spot", "cf"}
    assert est["spot"]["latency_s"] > est["vm"]["latency_s"]
    assert est["spot"]["cost"] < est["vm"]["cost"]
    assert est["cf"]["cost"] > est["vm"]["cost"]


def test_force_routes_tiers_by_quote():
    """FORCE: relaxed/BoE land on the cheapest reserved quote (the spot
    pool), IMMEDIATE on the fastest open reserved quote (the v5e pool)."""
    sim = Simulation(SimConfig(
        policy=Policy.FORCE, pools=_three_pool_specs(), use_calibration=False,
        sla=SLAConfig(**PIN_VM),
    ))
    imm = _mk(ServiceLevel.IMMEDIATE, 0.0, tokens=1_000_000, out=32)
    boe = _mk(ServiceLevel.BEST_EFFORT, 0.0, tokens=1_000_000, out=32)
    res = sim.run([imm, boe])
    by = {q.qid: q for q in res.queries}
    assert by[imm.qid].cluster == "vm"
    assert by[boe.qid].cluster == "spot"
    # billed at the pool's own price and speed
    spot = sim.coordinator.by_name["spot"]
    assert by[boe.qid].cost == pytest.approx(
        by[boe.qid].chip_seconds * spot.price_per_chip_s
    )


def test_speed_factor_scales_times_not_structure():
    """A 0.25x pool runs every stage 4x longer on the SAME plan
    structure — the invariant that keeps a mid-plan cursor valid when a
    query hops pools."""
    w = QueryWork(arch="paper-default", prompt_tokens=400_000, output_tokens=70)
    fast = CostModel(use_calibration=False).plan(w, 16)
    slow = CostModel(use_calibration=False, speed_factor=0.25).plan(w, 16)
    assert [s.name for s in fast.stages] == [s.name for s in slow.stages]
    assert slow.exec_time == pytest.approx(4 * fast.exec_time)
    assert slow.chip_seconds == pytest.approx(4 * fast.chip_seconds)


# ---------------------------------------------------------------------------
# backlog-driven autoscale
# ---------------------------------------------------------------------------

def _autoscale_vm(trigger, **kw):
    auto = AutoscaleConfig(
        enabled=True, trigger=trigger, min_chips=16, max_chips=64,
        step_chips=16, scale_delay_s=180.0, high_watermark=8,
        backlog_high_s=1.0, backlog_low_s=0.01, **kw,
    )
    return CostEfficientCluster(
        chips=16, mode="sos", sos_slice_chips=16,
        cost_model=CostModel(use_calibration=False), autoscale=auto,
    )


def test_backlog_scale_out_fires_before_run_queue_would():
    """One huge QUEUED query is a large predicted backlog long before it
    is a long run queue: the backlog trigger schedules a scale-out while
    the run-queue trigger (queue length 2 < watermark 8) stays idle."""
    rq = _autoscale_vm("run_queue")
    for _ in range(2):
        rq.submit(_mk(ServiceLevel.IMMEDIATE, 0.0, tokens=5_000_000, out=64), 0.0)
    assert rq._pending_scale == []  # 2 < high_watermark: no reaction
    bl = _autoscale_vm("backlog")
    for _ in range(2):  # one runs on the single slice, one queues
        bl.submit(_mk(ServiceLevel.IMMEDIATE, 0.0, tokens=5_000_000, out=64), 0.0)
    assert bl._pending_scale, "backlog trigger must schedule a scale-out"
    (at, chips) = bl._pending_scale[0]
    assert at == pytest.approx(180.0) and chips == 32


def test_backlog_scale_out_needs_queued_work():
    """A long RUNNING stage inflates the backlog, but new slices can't
    help it: a query that a free slice admits immediately must not read
    as backlog pressure (the trigger is evaluated AFTER admission)."""
    bl = _autoscale_vm("backlog")
    bl.submit(_mk(ServiceLevel.IMMEDIATE, 0.0, tokens=5_000_000, out=64), 0.0)
    assert bl._pending_scale == []  # admitted instantly: nothing queued


def test_backlog_scale_in_when_drained():
    vm = _autoscale_vm("backlog")
    vm.chips = 64
    vm._admit(0.0)  # idle: drain time 0 <= low watermark -> scale in
    assert vm._pending_scale and vm._pending_scale[0][1] == 48


def test_predicted_backlog_counts_running_and_waiting_remainders():
    cm = CostModel(use_calibration=False)
    vm = CostEfficientCluster(chips=16, mode="sos", sos_slice_chips=16,
                              cost_model=cm)
    a = _mk(ServiceLevel.IMMEDIATE, 0.0, tokens=1_000_000, out=32)
    b = _mk(ServiceLevel.IMMEDIATE, 0.0, tokens=1_000_000, out=32)
    vm.submit(a, 0.0)  # runs (1 slice)
    vm.submit(b, 0.0)  # waits
    expected = 2 * cm.plan(a.work, 16).chip_seconds
    assert vm.predicted_backlog_cs(0.0) == pytest.approx(expected)
    # the backlog decays as the running stage executes — by elapsed time
    # on the slice, capped at the current stage's remaining work
    later = vm.predicted_backlog_cs(1.0)
    assert expected - 1.0 * 16 <= later < expected


def test_autoscaled_registry_pool_runs_end_to_end():
    auto = AutoscaleConfig(enabled=True, trigger="backlog", min_chips=16,
                           max_chips=64, step_chips=16, scale_delay_s=60.0,
                           backlog_high_s=5.0, backlog_low_s=0.5)
    res = Simulation(SimConfig(
        use_calibration=False,
        pools=[PoolSpec(name="vm", kind="reserved", chips=16, mode="sos",
                        slice_chips=16, autoscale=auto)],
    )).run(generate(horizon_s=3600, seed=6))
    assert all(q.finish_time is not None for q in res.queries)


# ---------------------------------------------------------------------------
# symmetric spill-back
# ---------------------------------------------------------------------------

def _spill_back_run(spill_back: bool):
    pools = [
        PoolSpec(name="vm", kind="reserved", chips=4, mode="sos",
                 slice_chips=4),
        PoolSpec(name="cf", kind="elastic", chips=64, startup_s=2.0,
                 price_multiplier=10.0),
    ]
    cfg = SimConfig(pools=pools, use_calibration=False, sla=SLAConfig(
        spill_enabled=True, spill_back_enabled=spill_back,
        spill_back_low_backlog_s=1e9, **PIN_VM,
    ))
    long_q = _mk(ServiceLevel.IMMEDIATE, 0.0, tokens=2_000_000, out=2048)
    rival = _mk(ServiceLevel.IMMEDIATE, 30.0, tokens=100_000, out=8)
    sim = Simulation(cfg)
    res = sim.run([long_q, rival])
    return sim, {q.qid: q for q in res.queries}[long_q.qid]


def test_spill_back_returns_to_the_reserved_pool():
    sim, q = _spill_back_run(True)
    assert q.spilled and q.spill_backs >= 1 and q.state == "done"
    segments = [k for k, _ in itertools.groupby(e.cluster for e in q.stage_trace)]
    assert segments[0] == "vm" and "cf" in segments and segments[-1] == "vm"
    # every stage ran exactly once, in order: nothing stranded or re-run
    assert [e.index for e in q.stage_trace] == list(range(len(q.stage_trace)))
    # chip-seconds conserved across both hops
    assert q.chip_seconds == pytest.approx(
        sum(e.chip_seconds for e in q.stage_trace)
    )
    # each stage billed at the price of the pool it ran on
    for e in q.stage_trace:
        pool = sim.coordinator.by_name[e.cluster]
        assert e.cost == pytest.approx(e.chip_seconds * pool.price_per_chip_s)


def test_spill_back_is_cheaper_than_one_way_spill():
    _, back = _spill_back_run(True)
    _, stay = _spill_back_run(False)
    assert back.spill_backs >= 1 and stay.spill_backs == 0
    assert back.cost < stay.cost  # elastic premium paid for fewer stages


def test_spill_back_never_strands_a_query_mid_stage():
    """Under a contended stream with spill + spill-back on, every query
    finishes, and every pool hop happens at a stage boundary (stage
    indices strictly increasing, each exactly once)."""
    res = run_sim(
        generate(horizon_s=3600, seed=5), vm_mode="sos", vm_chips=32,
        sos_slice_chips=16, use_calibration=False,
        sla=SLAConfig(preempt_best_effort=True, spill_enabled=True,
                      spill_back_enabled=True, spill_back_low_backlog_s=60.0,
                      vm_overload_threshold=4),
    )
    assert all(q.finish_time is not None for q in res.queries)
    assert all(q.state == "done" for q in res.queries)
    for q in res.queries:
        idx = [e.index for e in q.stage_trace]
        assert idx == sorted(set(idx)), f"stage re-run or lost on Q{q.qid}"
        assert q.chip_seconds == pytest.approx(
            sum(e.chip_seconds for e in q.stage_trace)
        )


def test_spill_back_waits_for_low_backlog():
    """With the low watermark at 0 the reserved pool never looks drained
    enough, so a spilled query stays on the elastic pool (one-way PR-1
    spill)."""
    pools = [
        PoolSpec(name="vm", kind="reserved", chips=4, mode="sos",
                 slice_chips=4),
        PoolSpec(name="cf", kind="elastic", chips=64, startup_s=2.0,
                 price_multiplier=10.0),
    ]
    cfg = SimConfig(pools=pools, use_calibration=False, sla=SLAConfig(
        spill_enabled=True, spill_back_enabled=True,
        spill_back_low_backlog_s=-1.0, **PIN_VM,
    ))
    long_q = _mk(ServiceLevel.IMMEDIATE, 0.0, tokens=2_000_000, out=2048)
    rival = _mk(ServiceLevel.IMMEDIATE, 30.0, tokens=100_000, out=8)
    res = Simulation(cfg).run([long_q, rival])
    q = {x.qid: x for x in res.queries}[long_q.qid]
    assert q.spilled and q.spill_backs == 0
    assert [e.cluster for e in q.stage_trace][-1] == "cf"
