"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU), swept over
shapes, GQA ratios, dtypes, and masking variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ops import flash_attention_diff, sdpa_flash
from repro.kernels.ref import (
    decode_attention_ref,
    flash_attention_ref,
    ssd_scan_ref,
    ssd_sequential_ref,
)
from repro.kernels.ssd_scan import ssd_scan

# full Pallas sweeps run in interpret mode on CPU and dominate suite
# time; `pytest -m "not slow"` gives the fast tier-1 signal
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


def _qkv(B, Sq, Sk, H, K, hd, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, Sk, K, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, Sk, K, hd)).astype(dtype)
    return q, k, v


FLASH_CASES = [
    # B, Sq, H, K, hd, causal, window, softcap
    (2, 256, 8, 4, 64, True, 0, 0.0),
    (1, 384, 4, 2, 128, True, 128, 0.0),
    (2, 128, 8, 8, 64, True, 0, 50.0),  # MHA + gemma softcap
    (1, 256, 14, 2, 64, False, 0, 0.0),  # qwen2-ish GQA, non-causal
    (1, 256, 4, 1, 128, True, 0, 0.0),  # MQA
    (2, 256, 8, 4, 32, True, 256, 30.0),  # window >= S (no-op) + cap
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_oracle(case, dtype):
    B, S, H, K, hd, causal, win, cap = case
    q, k, v = _qkv(B, S, S, H, K, hd, dtype)
    out = flash_attention(
        q, k, v, causal=causal, window=win, softcap=cap, interpret=True
    )
    ref = flash_attention_ref(q, k, v, causal=causal, window=win, softcap=cap)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


def test_flash_attention_grad_matches_oracle():
    q, k, v = _qkv(1, 128, 128, 4, 2, 64, jnp.float32)

    def f_kernel(q, k, v):
        return jnp.sum(flash_attention_diff(q, k, v, True, 0, 0.0) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(flash_attention_ref(q, k, v, causal=True) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)


DECODE_CASES = [
    # B, H, K, hd, Smax, window, fill
    (2, 8, 4, 64, 256, 0, 100),
    (2, 4, 2, 128, 256, 128, 37),
    (1, 8, 1, 64, 512, 0, 511),  # MQA, nearly-full cache
    (3, 4, 4, 32, 128, 0, 0),  # empty-ish cache (only slot 0)
]


@pytest.mark.parametrize("case", DECODE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_oracle(case, dtype):
    B, H, K, hd, Smax, win, fill = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, Smax, K, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, Smax, K, hd)).astype(dtype)
    lengths = jnp.full((B,), fill, jnp.int32)
    pos = jnp.where(
        jnp.arange(Smax)[None] <= lengths[:, None], jnp.arange(Smax)[None], -1
    ).astype(jnp.int32)
    out = decode_attention(q, k, v, pos, lengths, window=win, interpret=True)
    ref = decode_attention_ref(q, k, v, pos, lengths, window=win)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


def test_decode_attention_ring_cache():
    """Ring-buffer slot order (wrapped positions) must not matter."""
    B, H, K, hd, Smax = 1, 4, 2, 64, 128
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, Smax, K, hd))
    v = jax.random.normal(ks[2], (B, Smax, K, hd))
    # wrapped: absolute positions 200..327 stored at slot p % 128
    abs_pos = jnp.arange(200, 200 + Smax)
    slots = abs_pos % Smax
    pos = jnp.zeros((B, Smax), jnp.int32).at[0, slots].set(abs_pos.astype(jnp.int32))
    lengths = jnp.array([327], jnp.int32)
    out = decode_attention(q, k, v, pos, lengths, window=128, interpret=True)
    ref = decode_attention_ref(q, k, v, pos, lengths, window=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


SSD_CASES = [
    # B, S, H, P, N, chunk
    (2, 256, 4, 64, 32, 128),
    (1, 256, 2, 32, 64, 64),
    (2, 512, 2, 64, 128, 128),  # mamba2-2.7b-like head
    (1, 128, 8, 16, 16, 32),  # jamba-like small state
]


@pytest.mark.parametrize("case", SSD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_matches_oracles(case, dtype):
    B, S, H, P, N, chunk = case
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = (jax.random.normal(ks[3], (B, S, H, N)) * 0.5).astype(dtype)
    Cm = (jax.random.normal(ks[4], (B, S, H, N)) * 0.5).astype(dtype)
    yk, hk = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    yr, hr = ssd_scan_ref(x, dt, A, Bm, Cm, chunk=chunk)
    ys, hs = ssd_sequential_ref(x, dt, A, Bm, Cm)
    tol = 2e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(yk, np.float32), np.asarray(yr, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(yk, np.float32), np.asarray(ys, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hs), atol=tol, rtol=tol)


def test_ssd_chunk_invariance():
    """The chunked algorithm must be exactly chunk-size independent."""
    B, S, H, P, N = 1, 256, 2, 32, 32
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, H, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, H, N)) * 0.5
    outs = [ssd_scan_ref(x, dt, A, Bm, Cm, chunk=c)[0] for c in (32, 64, 128, 256)]
    for o in outs[1:]:
        # chunk-size independent up to f32 accumulation order
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=2e-4, rtol=1e-4)


def test_sdpa_flash_model_integration():
    """The registered 'pallas' impl matches 'jnp' inside a real model."""
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("granite-8b", reduced=True)
    mj = build_model(cfg, impl="jnp")
    mp = build_model(cfg, impl="pallas")
    params = mj.init(KEY)
    toks = jax.random.randint(KEY, (2, 128), 0, cfg.vocab_size)
    lj, _ = mj.forward(params, toks, dtype=jnp.float32)
    lp, _ = mp.forward(params, toks, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(lj), np.asarray(lp), atol=1e-3)
