"""Every reprolint rule demonstrated against seeded regressions —
including re-introducing the PR-3 ``_vm_busy`` unguarded access and a
version-less memo — plus suppression semantics, the baseline ratchet,
and a self-check that the repo itself is clean against the committed
baseline."""
from pathlib import Path

import pytest

from tools.reprolint import (
    META_CODE,
    apply_baseline,
    baseline_counts,
    lint_paths,
    lint_text,
    load_baseline,
    save_baseline,
)

REPO = Path(__file__).resolve().parents[1]
CORE = "src/repro/core/fixture.py"  # path chosen to put rules in scope


def codes(src: str, path: str = CORE) -> list[str]:
    return [f.code for f in lint_text(src, path)]


# --- RL001: lock discipline (the PR-3 _vm_busy race) ----------------------

VM_BUSY_REGRESSION = '''
import threading

class VMCluster:
    _GUARDED_BY = {"_vm_busy": "_lock"}

    def __init__(self):
        self._vm_busy = 0
        self._lock = threading.Lock()

    def start(self, q):
        self._vm_busy += 1  # the PR-3 bug, verbatim shape
'''


def test_rl001_catches_vm_busy_regression():
    findings = lint_text(VM_BUSY_REGRESSION, CORE)
    assert [f.code for f in findings] == ["RL001"]
    assert "_vm_busy" in findings[0].message
    assert findings[0].line == 12


def test_rl001_with_lock_and_locked_suffix_pass():
    src = '''
class VMCluster:
    _GUARDED_BY = {"_vm_busy": "_lock"}
    def start(self, q):
        with self._lock:
            self._vm_busy += 1
    def _start_locked(self, q):
        self._vm_busy += 1
'''
    assert codes(src) == []


def test_rl001_init_exempt_but_other_methods_are_not():
    src = '''
class C:
    _GUARDED_BY = {"x": "_lock"}
    def __init__(self):
        self.x = 0
    def poke(self):
        return self.x
'''
    findings = lint_text(src, CORE)
    assert [f.code for f in findings] == ["RL001"]
    assert findings[0].line == 7


def test_rl001_nested_function_loses_the_lock():
    # a closure runs AFTER the with-block exits: the exact shape the
    # old engine's executor futures had
    src = '''
class C:
    _GUARDED_BY = {"x": "_lock"}
    def defer(self):
        with self._lock:
            return lambda: self.x
'''
    assert codes(src) == ["RL001"]


def test_rl001_condition_alias_and_inherited_registry():
    src = '''
class Base:
    _GUARDED_BY = {"waiting": ("_mu", "_cv")}

class Pool(Base):
    def ok(self):
        with self._cv:
            return self.waiting
    def bad(self):
        return self.waiting
'''
    findings = lint_text(src, CORE)
    assert [(f.code, f.line) for f in findings] == [("RL001", 10)]


# --- RL002: version-keyed caches (PR-4 / PR-7 bug classes) ----------------

def test_rl002_catches_versionless_memo():
    src = '''
class Planner:
    def __init__(self):
        self._plan_cache = {}
    def plan(self, key):
        if key not in self._plan_cache:
            self._plan_cache[key] = object()
        return self._plan_cache[key]
'''
    assert codes(src) == ["RL002"]


def test_rl002_catches_unbounded_lru_cache():
    src = '''
import functools

@functools.lru_cache(maxsize=None)
def default_table():
    return object()
'''
    assert codes(src) == ["RL002"]
    # the PR-4 fix shape — bounded — passes
    assert codes(src.replace("maxsize=None", "maxsize=8")) == []


def test_rl002_eviction_or_version_key_passes():
    evicting = '''
class Planner:
    def __init__(self):
        self._plan_cache = {}
    def plan(self, key):
        if len(self._plan_cache) > 4096:
            self._plan_cache.clear()
        return self._plan_cache.setdefault(key, object())
'''
    versioned = '''
class Planner:
    def __init__(self):
        self._plan_cache = {}
    def plan(self, key, table):
        return self._plan_cache[(key, table.version)]
'''
    assert codes(evicting) == []
    assert codes(versioned) == []


def test_rl002_scoped_to_core():
    src = "class C:\n    def __init__(self):\n        self._cache = {}\n"
    assert codes(src, "benchmarks/fixture.py") == []


# --- RL003: determinism ---------------------------------------------------

def test_rl003_wall_clock_and_global_rng():
    src = '''
import time
import random

def f():
    t0 = time.time()
    return time.perf_counter() - t0
'''
    got = codes(src, "src/repro/launch/fixture.py")
    assert got == ["RL003", "RL003"]  # import random + time.time


def test_rl003_np_random_global_vs_generator():
    src = '''
import numpy as np

def f():
    bad = np.random.rand(3)
    rng = np.random.default_rng(0)
    return bad, rng.random(3)
'''
    assert codes(src) == ["RL003"]


def test_rl003_np_sum_and_set_iteration_in_core_only():
    src = '''
import numpy as np

def f(xs, pools):
    total = np.sum(xs)
    alive = {p for p in pools}
    for p in alive:
        total += p.burn
    for p in sorted(alive):
        total += p.burn
    return total, xs.sum()
'''
    assert codes(src) == ["RL003", "RL003"]  # np.sum + bare-set loop
    # launch scripts: wall-clock rules apply, bit-identity rules don't
    assert codes(src, "src/repro/launch/fixture.py") == []


# --- RL004: swallowed exceptions ------------------------------------------

def test_rl004_catches_swallowed_and_accepts_handled():
    swallowed = '''
def f():
    try:
        work()
    except Exception:
        return None
'''
    assert codes(swallowed) == ["RL004"]
    for handled in (
        "raise",
        "q.error = err",
        "self._fail(q, err)",
    ):
        src = f'''
def f(self, q):
    try:
        work()
    except Exception as err:
        {handled}
'''
        assert codes(src) == [], handled
    narrow = '''
def f():
    try:
        work()
    except ValueError:
        return None
'''
    assert codes(narrow) == []


# --- RL005: slots / identity ----------------------------------------------

def test_rl005_query_module_requires_slots_and_identity():
    path = "src/repro/core/query.py"
    unslotted = "class Query:\n    pass\n"
    assert [f.code for f in lint_text(unslotted, path)] == ["RL005"]
    eq_override = '''
from dataclasses import dataclass

@dataclass(eq=False, slots=True)
class Query:
    qid: int
    def __eq__(self, other):
        return self.qid == other.qid
'''
    assert [f.code for f in lint_text(eq_override, path)] == ["RL005"]
    good = '''
from dataclasses import dataclass

@dataclass(eq=False, slots=True)
class Query:
    qid: int
'''
    assert lint_text(good, path) == []


def test_rl005_named_hot_classes_anywhere_in_core():
    src = "class WaitingQueue:\n    pass\n"
    assert codes(src) == ["RL005"]
    assert codes('class WaitingQueue:\n    __slots__ = ("_q",)\n') == []
    # NamedTuple counts as slotted
    src = "from typing import NamedTuple\nclass StageEvent(NamedTuple):\n    qid: int\n"
    assert codes(src) == []


# --- suppressions and the RL000 meta rule ---------------------------------

def test_suppression_requires_reason():
    with_reason = (
        "import random  "
        "# reprolint: disable=RL003 -- fixture: demo jitter only\n"
    )
    assert codes(with_reason) == []
    reasonless = "import random  # reprolint: disable=RL003\n"
    got = codes(reasonless)
    assert got == [META_CODE, "RL003"]  # disable rejected AND rule fires


def test_suppression_only_silences_named_code():
    src = (
        "import random  "
        "# reprolint: disable=RL001 -- wrong code on purpose\n"
    )
    assert codes(src) == ["RL003"]


# --- baseline ratchet -----------------------------------------------------

def test_baseline_round_trip_and_ratchet(tmp_path):
    findings = lint_text(VM_BUSY_REGRESSION, CORE)
    bl = tmp_path / "baseline.json"
    save_baseline(bl, findings)
    loaded = load_baseline(bl)
    assert loaded == baseline_counts(findings) == {f"{CORE}::RL001": 1}
    # grandfathered hit passes...
    assert apply_baseline(findings, loaded) == []
    # ...but a SECOND occurrence of the same (file, rule) fails
    assert len(apply_baseline(findings * 2, loaded)) == 1


def test_rl000_is_never_baselinable(tmp_path):
    findings = lint_text(
        "import random  # reprolint: disable=RL003\n", CORE
    )
    bl = tmp_path / "baseline.json"
    save_baseline(bl, findings)
    left = apply_baseline(findings, load_baseline(bl))
    assert [f.code for f in left] == [META_CODE]


# --- the repo itself is clean against the committed baseline --------------

def test_repo_is_clean_against_committed_baseline():
    findings = lint_paths(["src", "tests", "benchmarks"], root=REPO)
    baseline = load_baseline(REPO / "tools" / "reprolint" / "baseline.json")
    left = apply_baseline(findings, baseline)
    assert left == [], "\n".join(f.render() for f in left)


def test_committed_baseline_is_empty_for_core():
    baseline = load_baseline(REPO / "tools" / "reprolint" / "baseline.json")
    core_keys = [k for k in baseline if k.startswith("src/repro/core/")]
    assert core_keys == []


def test_cli_exit_codes(tmp_path):
    from tools.reprolint.__main__ import main

    bad = tmp_path / "src" / "repro" / "core"
    bad.mkdir(parents=True)
    (bad / "fixture.py").write_text(VM_BUSY_REGRESSION)
    rel = ["src/repro/core/fixture.py"]
    assert main([*rel, "--root", str(tmp_path)]) == 1
    bl = tmp_path / "bl.json"
    assert main([*rel, "--root", str(tmp_path),
                 "--write-baseline", str(bl)]) == 0
    assert main([*rel, "--root", str(tmp_path), "--baseline", str(bl)]) == 0


def test_syntax_error_is_a_finding():
    assert codes("def broken(:\n") == [META_CODE]


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
