"""Per-pool calibration (core/calibration.py): decode-context pricing,
offline dry-run fits, cache invalidation, and the live EWMA loop."""
import json
from pathlib import Path

import pytest

from repro.configs import get_config
from repro.core import (
    CalibrationTable,
    CostExplorer,
    CostModel,
    LiveCalibrator,
    PoolSpec,
    Query,
    QueryWork,
    ServiceLevel,
    SimConfig,
    Simulation,
    build_pool,
    fit_dryruns,
    price_menu,
)
from repro.core.calibration import invalidate_default_calibration
from repro.core.cost_model import _analytic_step

FIXTURES = Path(__file__).parent / "fixtures" / "dryrun"


# ---------------------------------------------------------------------------
# satellite fix: decode chunks are priced at their own (grown) context
# ---------------------------------------------------------------------------

def test_decode_chunk_times_monotone_in_context():
    """Later chunks read a longer KV cache, so equal-size chunk times are
    non-decreasing in context (the old model priced every chunk at the
    initial context — all equal)."""
    cm = CostModel(use_calibration=False, decode_chunk_tokens=32)
    w = QueryWork(arch="paper-default", prompt_tokens=4096, output_tokens=512)
    times = [s.time_s for s in cm.plan(w, 8).stages[1:]]  # 16 full chunks
    assert len(times) == 16
    assert all(b >= a for a, b in zip(times, times[1:]))
    assert times[-1] > times[0]  # strictly grows across the generation


def test_long_generation_quotes_more_than_split_generations():
    """Acceptance: one 512-token generation decodes into contexts the 16
    independent 32-token generations never reach, so it quotes strictly
    more decode chip-seconds at the same prompt."""
    cm = CostModel(use_calibration=False, decode_chunk_tokens=32)
    long_w = QueryWork(arch="paper-default", prompt_tokens=8192,
                       output_tokens=512)
    short_w = QueryWork(arch="paper-default", prompt_tokens=8192,
                        output_tokens=32)
    decode_cs = lambda w: sum(  # noqa: E731
        s.chip_seconds for s in cm.plan(w, 8).stages[1:]
    )
    assert decode_cs(long_w) > 16 * decode_cs(short_w)


def test_decode_chunking_still_preserves_totals_and_structure():
    """Context growth is token-exact: chunk boundaries are a scheduling
    choice, never a cost — and structure stays chips/speed-independent,
    so mid-plan cursors survive pool hops."""
    w = QueryWork(arch="paper-default", prompt_tokens=4096, output_tokens=100)
    chunked = CostModel(use_calibration=False, decode_chunk_tokens=32)
    whole = CostModel(use_calibration=False, decode_chunk_tokens=0)
    assert chunked.exec_time(w, 8) == pytest.approx(whole.exec_time(w, 8))
    assert chunked.chip_seconds(w, 8) == pytest.approx(whole.chip_seconds(w, 8))
    fast = chunked.plan(w, 8)
    slow = CostModel(use_calibration=False, decode_chunk_tokens=32,
                     speed_factor=0.25).plan(w, 64)
    assert [s.name for s in fast.stages] == [s.name for s in slow.stages]


# ---------------------------------------------------------------------------
# offline fit: dry-run JSONs -> speed_factor + per-(arch, kind) factors
# ---------------------------------------------------------------------------

def _write_dryrun(dir_, arch, kind, step_s, *, chips=256, hw_tag=None,
                  tokens=None, name=None):
    tokens = tokens or {"serve": 32 * 32768, "train": 256 * 4096}[kind]
    rec = {
        "arch": arch, "kind": kind, "shape": "synthetic", "chips": chips,
        "tokens": tokens, "status": "ok",
        "roofline": {"terms": {"step_s": step_s}},
    }
    if hw_tag:
        rec["hw"] = hw_tag
    path = Path(dir_) / (name or f"{arch}__{kind}__{hw_tag or 'x'}.json")
    path.write_text(json.dumps(rec))


def _analytic(arch, kind, chips=256):
    tokens = {"serve": 32 * 32768, "train": 256 * 4096}[kind]
    return _analytic_step(get_config(arch), tokens, kind, chips=chips)


def test_offline_fit_recovers_known_speed_ratio(tmp_path):
    """Round trip: dry-runs synthesized at a known speed ratio fit back
    to that speed_factor, with every residual factor at 1.0."""
    speed_true = 0.25
    for arch in ("paper-default", "qwen2-0.5b"):
        for kind in ("serve", "train"):
            _write_dryrun(tmp_path, arch, kind,
                          _analytic(arch, kind) / speed_true)
    table = fit_dryruns(tmp_path)
    assert table.speed_factor == pytest.approx(speed_true, rel=1e-6)
    for arch in ("paper-default", "qwen2-0.5b"):
        for kind in ("serve", "train"):
            assert table.factor(arch, kind) == pytest.approx(1.0, rel=1e-6)


def test_offline_fit_separates_speed_from_arch_kind_residuals(tmp_path):
    """A wobble one pool speed cannot absorb lands in the per-(arch,
    kind) factors, and the fitted model reproduces every measurement."""
    speed_true, wobble = 0.5, {("paper-default", "serve"): 1.2,
                               ("paper-default", "train"): 1.0 / 1.2}
    for (arch, kind), f in wobble.items():
        _write_dryrun(tmp_path, arch, kind,
                      _analytic(arch, kind) * f / speed_true)
    table = fit_dryruns(tmp_path)
    # geomean of the wobbles is 1, so the speed comes out exact
    assert table.speed_factor == pytest.approx(speed_true, rel=1e-6)
    for (arch, kind), f in wobble.items():
        measured = _analytic(arch, kind) * f / speed_true
        fitted = (_analytic(arch, kind) * table.factor(arch, kind)
                  / table.speed_factor)
        assert fitted == pytest.approx(measured, rel=1e-6)


def test_offline_fit_filters_by_hw_tag(tmp_path):
    """A mixed directory: only the records carrying the pool's hw tag
    contribute to its fit."""
    _write_dryrun(tmp_path, "paper-default", "serve",
                  _analytic("paper-default", "serve") / 0.25, hw_tag="spot")
    _write_dryrun(tmp_path, "paper-default", "serve",
                  _analytic("paper-default", "serve") / 1.0, hw_tag="v5e")
    spot = fit_dryruns(tmp_path, hw_tag="spot")
    v5e = fit_dryruns(tmp_path, hw_tag="v5e")
    assert spot.speed_factor == pytest.approx(0.25, rel=1e-6)
    assert v5e.speed_factor == pytest.approx(1.0, rel=1e-6)


def test_offline_fit_raises_on_empty_dir(tmp_path):
    with pytest.raises(ValueError, match="no usable dry-run records"):
        fit_dryruns(tmp_path)
    with pytest.raises(ValueError, match="hw_tag"):
        _write_dryrun(tmp_path, "paper-default", "serve", 1.0, hw_tag="a")
        fit_dryruns(tmp_path, hw_tag="does-not-exist")


def test_checked_in_fixtures_fit():
    """The CI calibration-smoke fixtures: a 0.5x pool with small
    per-(arch, kind) wobbles, recorded in dryrun.py's canonical shapes
    (kind and tokens derived from the shape name)."""
    table = fit_dryruns(FIXTURES)
    assert table.speed_factor == pytest.approx(0.5, rel=0.05)
    assert table.factor("paper-default", "serve") > table.factor(
        "paper-default", "train"
    )
    assert len(table.as_dict()["factors"]) == 4


def test_pool_spec_dryrun_dir_fits_the_pool(tmp_path):
    """PoolSpec.dryrun_dir replaces the declared speed_factor constant
    with a fitted one: the pool plans (and quotes) at measured speed."""
    for kind in ("serve", "train"):
        _write_dryrun(tmp_path, "paper-default", kind,
                      _analytic("paper-default", kind) / 0.5)
    spec = PoolSpec(name="vm", kind="reserved", chips=64, mode="sos",
                    slice_chips=16, speed_factor=1.0,
                    dryrun_dir=str(tmp_path))
    pool = build_pool(spec, use_calibration=False)
    assert pool.cost_model.effective_speed_factor == pytest.approx(0.5)
    declared = build_pool(
        PoolSpec(name="vm", kind="reserved", chips=64, mode="sos",
                 slice_chips=16, speed_factor=1.0),
        use_calibration=False,
    )
    w = QueryWork(arch="paper-default", prompt_tokens=200_000, output_tokens=8)
    assert pool.cost_model.exec_time(w, 16) == pytest.approx(
        2 * declared.cost_model.exec_time(w, 16)
    )


def test_sim_calibrations_flow_into_quotes_and_billing():
    """SimConfig.calibrations injects fitted tables into the registry:
    quotes, placement, and billing all run on the corrected model."""
    table = CalibrationTable(speed_factor=0.5, source="test")
    pools = [PoolSpec(name="vm", kind="reserved", chips=64, mode="sos",
                      slice_chips=16)]
    cal = Simulation(SimConfig(use_calibration=False, pools=pools,
                               calibrations={"vm": table}))
    base = Simulation(SimConfig(use_calibration=False, pools=pools))
    q = Query(work=QueryWork(arch="paper-default", prompt_tokens=200_000,
                             output_tokens=8),
              sla=ServiceLevel.IMMEDIATE, submit_time=0.0)
    assert cal.vm.quote(q, 0.0)["latency_s"] == pytest.approx(
        2 * base.vm.quote(q, 0.0)["latency_s"]
    )
    res = cal.run([q])
    done = res.queries[0]
    # billed on the corrected model: 2x the chip-seconds of the declared
    assert done.chip_seconds == pytest.approx(
        cal.vm.cost_model.plan(done.work, 16).chip_seconds
    )
    assert done.chip_seconds == pytest.approx(
        2 * base.vm.cost_model.plan(done.work, 16).chip_seconds
    )


# ---------------------------------------------------------------------------
# satellite fix: calibration updates invalidate the plan caches
# ---------------------------------------------------------------------------

def test_calibration_update_between_two_plans_takes_effect():
    """Regression: the old module-level lru_cache + CostModel._plan_cache
    never invalidated, so an update after first use silently no-opped."""
    table = CalibrationTable()
    cm = CostModel(use_calibration=False, calibration=table)
    w = QueryWork(arch="paper-default", prompt_tokens=100_000, output_tokens=16)
    before = cm.plan(w, 16)
    t0 = before.exec_time
    table.set_speed_factor(0.5)  # the pool is actually 2x slower
    after = cm.plan(w, 16)
    assert after.exec_time == pytest.approx(2 * t0)
    table.set_factor("paper-default", "serve", 2.0)
    assert cm.plan(w, 16).exec_time == pytest.approx(4 * t0)
    # structure never moves — only times (the cursor-validity invariant)
    assert [s.name for s in before.stages] == [
        s.name for s in cm.plan(w, 16).stages
    ]


def test_default_table_is_invalidatable(tmp_path, monkeypatch):
    """The results/dryrun-backed default table re-reads records after
    invalidate_default_calibration() — the lru_cache never could."""
    import repro.core.cost_model as cost_model_mod

    monkeypatch.setattr(cost_model_mod, "RESULTS", tmp_path)
    try:
        invalidate_default_calibration()  # drop factors cached pre-patch
        arch, kind = "paper-default", "serve"
        an = _analytic(arch, kind)
        rec = {"chips": 256, "roofline": {"terms": {"step_s": an * 2.0}}}
        path = tmp_path / f"{arch}__prefill_32k__16x16.json"
        path.write_text(json.dumps(rec))
        cm = CostModel(use_calibration=True)
        w = QueryWork(arch=arch, prompt_tokens=100_000, output_tokens=0)
        t_before = cm.exec_time(w, 16)
        path.write_text(json.dumps(
            {"chips": 256, "roofline": {"terms": {"step_s": an * 4.0}}}
        ))
        assert cm.exec_time(w, 16) == pytest.approx(t_before)  # cached
        invalidate_default_calibration()
        assert cm.exec_time(w, 16) == pytest.approx(2 * t_before)
    finally:
        invalidate_default_calibration()  # leave no fixture factors behind


def test_set_calibration_invalidates_plan_cache():
    cm = CostModel(use_calibration=False)
    w = QueryWork(arch="paper-default", prompt_tokens=100_000, output_tokens=16)
    t0 = cm.exec_time(w, 16)
    cm.set_calibration(CalibrationTable(speed_factor=0.25))
    assert cm.exec_time(w, 16) == pytest.approx(4 * t0)
    cm.set_calibration(None)
    assert cm.exec_time(w, 16) == pytest.approx(t0)


def test_table_persistence_round_trip(tmp_path):
    table = CalibrationTable(
        factors={("paper-default", "serve"): 1.25}, speed_factor=0.5,
        source="unit",
    )
    p = tmp_path / "table.json"
    table.save(p)
    back = CalibrationTable.load(p)
    assert back.speed_factor == pytest.approx(0.5)
    assert back.factor("paper-default", "serve") == pytest.approx(1.25)
    assert back.factor("paper-default", "train") == 1.0  # no loader: 1.0
    assert back.source == "unit"


# ---------------------------------------------------------------------------
# the live EWMA loop (threadless unit level; threaded in test_live.py)
# ---------------------------------------------------------------------------

def _mis_declared_pool(declared=2.0):
    return build_pool(
        PoolSpec(name="vm", kind="reserved", chips=64, mode="sos",
                 slice_chips=16, speed_factor=declared),
        use_calibration=False,
    )


def test_live_calibrator_converges_on_mis_declared_speed():
    """A pool declared 2x fast actually running at 1x: the EWMA over
    measured/predicted stage ratios fits the speed back to 1x and the
    hot swap makes subsequent quotes match the measured walls."""
    pool = _mis_declared_pool(declared=2.0)
    truth = CostModel(use_calibration=False, speed_factor=1.0)
    w = QueryWork(arch="paper-default", prompt_tokens=200_000,
                  output_tokens=64)
    cal = LiveCalibrator(alpha=0.5, min_samples=3)
    walls = truth.plan(w, 16)  # 3 stages: prefill + two 32-tok chunks
    for i, s in enumerate(walls.stages):
        cal.observe(pool, w, i, 16, s.time_s)
    assert cal.ratio("vm") == pytest.approx(2.0)
    drift_before = abs(pool.cost_model.plan(w, 16).exec_time
                       - walls.exec_time) / walls.exec_time
    assert cal.maybe_apply(pool)
    drift_after = abs(pool.cost_model.plan(w, 16).exec_time
                      - walls.exec_time) / walls.exec_time
    assert drift_after < drift_before / 10
    assert pool.cost_model.effective_speed_factor == pytest.approx(1.0)
    # idempotent below the epsilon: no churn re-planning every stage
    assert not cal.maybe_apply(pool)


def test_live_calibrator_needs_min_samples():
    pool = _mis_declared_pool()
    cal = LiveCalibrator(alpha=0.5, min_samples=10)
    w = QueryWork(arch="paper-default", prompt_tokens=100_000, output_tokens=8)
    wall = CostModel(use_calibration=False).plan(w, 16).stages[0].time_s
    for _ in range(3):
        cal.observe(pool, w, 0, 16, wall)
    assert not cal.maybe_apply(pool)
    assert pool.cost_model.calibration is None


def test_live_calibrator_persists_and_resumes(tmp_path):
    path = tmp_path / "live_cal.json"
    pool = _mis_declared_pool(declared=2.0)
    cal = LiveCalibrator(alpha=0.5, min_samples=2, path=path)
    truth = CostModel(use_calibration=False, speed_factor=1.0)
    w = QueryWork(arch="paper-default", prompt_tokens=100_000, output_tokens=8)
    for i, s in enumerate(truth.plan(w, 16).stages):
        cal.observe(pool, w, i, 16, s.time_s)
    assert cal.maybe_apply(pool)  # apply also persists
    assert path.exists()
    resumed = LiveCalibrator(alpha=0.5, min_samples=2, path=path)
    assert resumed.ratio("vm") == pytest.approx(cal.ratio("vm"))
    pool2 = _mis_declared_pool(declared=2.0)
    assert resumed.maybe_apply(pool2)  # loaded samples count
    assert pool2.cost_model.effective_speed_factor == pytest.approx(
        pool.cost_model.effective_speed_factor
    )


def test_live_loop_preserves_offline_factors_through_hot_swap():
    """A pool with an offline dry-run fit keeps its per-(arch, kind)
    factors when the live loop refines the speed: the EWMA is measured
    against a reference that already includes those factors, so the two
    fits compose instead of the swap discarding the offline one."""
    offline = CalibrationTable(
        factors={("paper-default", "serve"): 1.5}, speed_factor=1.0,
        source="dryrun:test",
    )
    pool = build_pool(
        PoolSpec(name="vm", kind="reserved", chips=64, mode="sos",
                 slice_chips=16, speed_factor=1.0),
        use_calibration=False, calibration=offline,
    )
    cal = LiveCalibrator(alpha=0.5, min_samples=1)
    w = QueryWork(arch="paper-default", prompt_tokens=200_000,
                  output_tokens=0)
    # measured walls: the offline factor is REAL but the pool is 2x
    # slower than even the offline fit believed
    wall = 2.0 * pool.cost_model.plan(w, 16).stages[0].time_s
    cal.observe(pool, w, 0, 16, wall)
    assert cal.maybe_apply(pool)
    swapped = pool.cost_model.calibration
    assert swapped is not offline  # the live table took over...
    assert swapped.factor("paper-default", "serve") == pytest.approx(1.5)
    assert swapped.speed_factor == pytest.approx(0.5)  # declared 1.0 / 2
    assert pool.cost_model.plan(w, 16).stages[0].time_s == pytest.approx(
        wall
    )


def test_live_loop_resets_when_declared_speed_changes():
    """Persisted EWMA state measured against an old declared speed must
    not be applied to a re-declared pool: apply refuses until fresh
    walls rebuild the state against the new reference."""
    old = _mis_declared_pool(declared=2.0)
    cal = LiveCalibrator(alpha=0.5, min_samples=2)
    w = QueryWork(arch="paper-default", prompt_tokens=100_000,
                  output_tokens=8)
    truth = CostModel(use_calibration=False, speed_factor=1.0)
    for i, s in enumerate(truth.plan(w, 16).stages):
        cal.observe(old, w, i, 16, s.time_s)
    assert cal.samples("vm") >= 2
    fixed = _mis_declared_pool(declared=1.0)  # operator corrected it
    assert not cal.maybe_apply(fixed)  # stale reference: refuse
    assert fixed.cost_model.calibration is None
    # fresh walls restart the EWMA against the new declared speed
    cal.observe(fixed, w, 0, 16, truth.plan(w, 16).stages[0].time_s)
    assert cal.samples("vm") == 1


def test_price_menu_rejects_ambiguous_calibration():
    """calibration corrects only the legacy knob pair — combining it
    with pools or an explicit cost_model must raise, never silently
    quote uncorrected prices."""
    w = QueryWork(arch="paper-default", prompt_tokens=100_000,
                  output_tokens=8)
    table = CalibrationTable(speed_factor=0.5)
    with pytest.raises(ValueError, match="silently-ignored"):
        price_menu(w, cost_model=CostModel(use_calibration=False),
                   calibration=table)
    pool = build_pool(PoolSpec(name="vm", kind="reserved", chips=4),
                      use_calibration=False)
    with pytest.raises(ValueError, match="silently-ignored"):
        price_menu(w, pools=[pool], calibration=table)


def test_summary_cluster_share_on_n_pool_registry():
    from repro.core import generate

    pools = [PoolSpec(name="v5e", kind="reserved", chips=64, mode="sos",
                      slice_chips=16)]
    res = Simulation(SimConfig(use_calibration=False, pools=pools)).run(
        generate(horizon_s=1800, seed=3)
    )
    s = res.summary()
    assert set(s["cluster_share"]) == {"v5e"}
    assert "vm_share" not in s  # no pool named vm: no fake legacy key


def test_stage_observer_feeds_the_loop_from_a_simulated_pool():
    """engine.ClusterExecutor.stage_observer closes the loop in-sim: the
    calibrator reads every completed stage's wall without touching the
    accounting path."""
    pool = _mis_declared_pool(declared=2.0)
    cal = LiveCalibrator(alpha=0.5, min_samples=4)
    pool.stage_observer = lambda q, stage, ev: cal.observe(
        pool, q.work, ev.index, ev.chips, ev.finish - ev.start
    )
    q = Query(work=QueryWork(arch="paper-default", prompt_tokens=200_000,
                             output_tokens=64),
              sla=ServiceLevel.IMMEDIATE, submit_time=0.0)
    q.dequeue_time = 0.0
    pool.submit(q, 0.0)
    pool.advance_to(1e9)
    assert q.state == "done"
    # the sim executes exactly the declared model, so the loop reads
    # ratio 1.0 — predicted == measured closes with zero drift
    assert cal.samples("vm") == len(q.stage_trace)
    assert cal.ratio("vm") == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# satellite fix: CostExplorer.aggregate on an N-pool registry
# ---------------------------------------------------------------------------

def test_cost_explorer_reports_per_pool_cluster_share():
    qs = []
    for i, name in enumerate(["v5e", "v5e", "spot", "cf"]):
        q = Query(work=QueryWork(), sla=ServiceLevel.IMMEDIATE,
                  submit_time=0.0)
        q.cluster = name
        q.dequeue_time = q.start_time = 0.0
        q.finish_time = 1.0
        q.cost = q.chip_seconds = 1.0
        qs.append(q)
    agg = CostExplorer(qs).aggregate()
    assert agg["cluster_share"] == {"v5e": 0.5, "spot": 0.25, "cf": 0.25}
    assert "vm_share" not in agg  # no pool named vm: no fake legacy key


def test_cost_explorer_keeps_derived_vm_share_for_legacy_pair():
    from repro.core import generate, run_sim

    res = run_sim(generate(horizon_s=1800, seed=2), use_calibration=False)
    agg = CostExplorer(res.queries).aggregate()
    assert set(agg["cluster_share"]) <= {"vm", "cf"}
    assert agg["vm_share"] == agg["cluster_share"]["vm"]
    assert sum(agg["cluster_share"].values()) == pytest.approx(1.0, abs=0.01)


# ---------------------------------------------------------------------------
# calibrated quotes flow into the price menu
# ---------------------------------------------------------------------------

def test_price_menu_reflects_pool_calibration(tmp_path):
    for kind in ("serve", "train"):
        _write_dryrun(tmp_path, "paper-default", kind,
                      _analytic("paper-default", kind) / 0.5)
    spec_cal = PoolSpec(name="vm", kind="reserved", chips=4,
                        dryrun_dir=str(tmp_path))
    spec_raw = PoolSpec(name="vm", kind="reserved", chips=4)
    w = QueryWork(arch="paper-default", prompt_tokens=200_000,
                  output_tokens=16)
    menu_cal = {m.sla: m for m in price_menu(
        w, pools=[build_pool(spec_cal, use_calibration=False)])}
    menu_raw = {m.sla: m for m in price_menu(
        w, pools=[build_pool(spec_raw, use_calibration=False)])}
    # the fitted 0.5x pool takes 2x the time and bills 2x chip-seconds
    assert menu_cal["relaxed"].est_exec_s == pytest.approx(
        2 * menu_raw["relaxed"].est_exec_s, rel=1e-6
    )
    assert menu_cal["relaxed"].est_cost == pytest.approx(
        2 * menu_raw["relaxed"].est_cost, rel=1e-4
    )
