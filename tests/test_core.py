"""The paper's scheduling semantics (§4.2/§4.3) + simulator behavior."""
import numpy as np
import pytest

from repro.core import (
    FaultModel,
    Policy,
    Query,
    QueryWork,
    ServiceLevel,
    SimConfig,
    Simulation,
    SLAConfig,
    generate,
    run_sim,
)
from repro.core.cost_model import CostModel
from repro.core.workload import TABLE1, stream_histogram


def _mk(sla, t, arch="paper-default", tokens=100_000):
    return Query(
        work=QueryWork(arch=arch, prompt_tokens=tokens, output_tokens=8),
        sla=sla,
        submit_time=t,
    )


# ---------------------------------------------------------------------------
# Table 1 workload
# ---------------------------------------------------------------------------

def test_workload_matches_table1():
    qs = generate(horizon_s=3600, seed=0)
    by_src = {}
    for q in qs:
        by_src.setdefault(q.source, []).append(q)
    assert len(by_src["dashboard"]) == 720
    assert len(by_src["manual_adhoc"]) == 34
    assert len(by_src["manual_daily"]) == 87
    assert len(by_src["off_peak"]) == 22
    assert len(by_src["regular_report"]) == 48
    # SLA mixes (Table 1 ratios)
    dash = by_src["dashboard"]
    assert sum(q.sla is ServiceLevel.RELAXED for q in dash) == 540  # 3/4
    assert all(q.sla is ServiceLevel.IMMEDIATE for q in by_src["manual_adhoc"])
    assert all(q.sla is ServiceLevel.BEST_EFFORT for q in by_src["off_peak"])
    assert all(q.sla is ServiceLevel.RELAXED for q in by_src["regular_report"])
    daily = by_src["manual_daily"]
    assert sum(q.sla is ServiceLevel.IMMEDIATE for q in daily) == 58  # 2/3

    # determinism
    qs2 = generate(horizon_s=3600, seed=0)
    assert [q.submit_time for q in qs2] == [q.submit_time for q in qs]


def test_stream_histogram_covers_all_patterns():
    qs = generate(horizon_s=3600, seed=1)
    hist, edges = stream_histogram(qs, 3600, bins=24)
    assert set(hist) == {p.name for p in TABLE1}
    assert all(sum(v) > 0 for v in hist.values())


# ---------------------------------------------------------------------------
# Scheduler semantics
# ---------------------------------------------------------------------------

def test_immediate_starts_immediately():
    qs = [_mk(ServiceLevel.IMMEDIATE, float(t)) for t in range(5)]
    res = run_sim(qs, use_calibration=False)
    for q in res.queries:
        assert q.pending_time == 0.0


def test_relaxed_pending_bounded_by_deadline():
    # saturate the VM so relaxed queries are queue-held to the limit
    qs = [_mk(ServiceLevel.IMMEDIATE, 0.0, tokens=3_000_000) for _ in range(16)]
    qs += [_mk(ServiceLevel.RELAXED, 1.0, tokens=50_000) for _ in range(20)]
    res = run_sim(qs, use_calibration=False)
    rel = [q for q in res.queries if q.sla is ServiceLevel.RELAXED]
    assert rel
    assert all(q.pending_time <= 300.0 + 1e-6 for q in rel)
    assert not res.pending_violations(300.0)


def test_boe_waits_for_idle():
    # BoE submitted while VM busy must start only after VM drains
    big = [_mk(ServiceLevel.IMMEDIATE, 0.0, tokens=2_000_000) for _ in range(4)]
    boe = [_mk(ServiceLevel.BEST_EFFORT, 1.0, tokens=50_000)]
    res = run_sim(big + boe, use_calibration=False)
    boe_q = [q for q in res.queries if q.sla is ServiceLevel.BEST_EFFORT][0]
    imm_busy_until = min(
        q.finish_time for q in res.queries if q.sla is ServiceLevel.IMMEDIATE
        and q.cluster == "vm"
    )
    assert boe_q.dequeue_time >= imm_busy_until - 2.0  # poll-period slack


def test_force_pins_relaxed_to_vm():
    imm = [_mk(ServiceLevel.IMMEDIATE, 0.0, tokens=2_000_000) for _ in range(12)]
    rel = [_mk(ServiceLevel.RELAXED, 0.0, tokens=50_000) for _ in range(6)]
    res_f = run_sim(imm + rel, policy=Policy.FORCE, use_calibration=False)
    for q in res_f.queries:
        if q.sla is ServiceLevel.RELAXED:
            assert q.cluster == "vm"


def test_auto_spills_on_overload():
    qs = [_mk(ServiceLevel.IMMEDIATE, 0.0, tokens=2_000_000) for _ in range(20)]
    res = run_sim(qs, policy=Policy.AUTO, use_calibration=False)
    assert any(q.cluster == "cf" for q in res.queries)
    assert any(q.cluster == "vm" for q in res.queries)


def test_without_sla_everything_immediate():
    qs = [_mk(ServiceLevel.BEST_EFFORT, float(t)) for t in range(5)]
    res = run_sim(qs, sla_enabled=False, use_calibration=False)
    for q in res.queries:
        assert q.effective_sla is ServiceLevel.IMMEDIATE
        assert q.pending_time == 0.0
        assert q.sla is ServiceLevel.BEST_EFFORT  # reporting keeps original


# ---------------------------------------------------------------------------
# The paper's headline results (Fig 6/7 directionality)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def paper_runs():
    out = {}
    for name, kw in [
        ("auto1", dict(policy=Policy.AUTO, sla_enabled=True)),
        ("auto0", dict(policy=Policy.AUTO, sla_enabled=False)),
        ("force1", dict(policy=Policy.FORCE, sla_enabled=True)),
    ]:
        qs = generate(horizon_s=14_400, seed=0)
        out[name] = run_sim(qs, use_calibration=False, **kw)
    return out


def test_cost_ordering_matches_paper(paper_runs):
    """force w/ SLA < auto w/ SLA < auto w/o SLA (paper: -65.5%, -22.2%)."""
    c_auto1 = paper_runs["auto1"].total_cost()
    c_auto0 = paper_runs["auto0"].total_cost()
    c_force1 = paper_runs["force1"].total_cost()
    assert c_force1 < c_auto1 < c_auto0
    force_red = 1 - c_force1 / c_auto0
    auto_red = 1 - c_auto1 / c_auto0
    assert 0.55 <= force_red <= 0.75, force_red  # paper: 0.655
    assert 0.15 <= auto_red <= 0.40, auto_red  # paper: 0.222


def test_no_pending_violations_in_paper_stream(paper_runs):
    for name, res in paper_runs.items():
        assert not res.pending_violations(300.0), name


def test_immediate_cost_rises_with_sla(paper_runs):
    """Enabling SLA pushes immediate queries to the elastic pool (paper
    §5.3: +45.5% auto / +99.9% force)."""
    imm0 = paper_runs["auto0"].cost_by_sla()["imm"]
    assert paper_runs["auto1"].cost_by_sla()["imm"] > imm0
    assert paper_runs["force1"].cost_by_sla()["imm"] > imm0


def test_boe_and_relaxed_cheaper_with_sla(paper_runs):
    by0 = paper_runs["auto0"].cost_by_sla()
    for run in ("auto1", "force1"):
        by1 = paper_runs[run].cost_by_sla()
        assert by1["boe"] < by0["boe"]
        assert by1["rel"] < by0["rel"]


# ---------------------------------------------------------------------------
# SOS vs POS determinism (paper §3.3 vision)
# ---------------------------------------------------------------------------

def test_sos_exec_times_deterministic_pos_not():
    def exec_times(mode, n_bg):
        qs = [_mk(ServiceLevel.IMMEDIATE, 0.0, tokens=500_000)]
        qs += [_mk(ServiceLevel.IMMEDIATE, 0.0, tokens=2_000_000) for _ in range(n_bg)]
        res = run_sim(
            qs, vm_mode=mode, use_calibration=False, vm_chips=64,
            sos_slice_chips=16,
            sla=SLAConfig(vm_overload_threshold=10**9),  # keep all on VM
        )
        probe = [q for q in res.queries if q.work.prompt_tokens == 500_000][0]
        return probe.exec_time

    # POS: the probe's exec time depends on concurrency (interference)
    assert exec_times("pos", 3) > exec_times("pos", 0) * 1.5
    # SOS: isolated slices -> identical regardless of load
    assert abs(exec_times("sos", 3) - exec_times("sos", 0)) < 1e-6


def test_fault_model_straggler_speculation_bounds_tail():
    fm = FaultModel(straggler_prob=1.0, straggler_scale=10.0, speculation=True)
    rng = np.random.default_rng(0)
    q = _mk(ServiceLevel.IMMEDIATE, 0.0)
    times = [fm.stage_execution(10.0, 1, rng, q)[0] for _ in range(100)]
    assert max(times) <= 10.0 * (1 + fm.speculation_cap) + 1e-9
    fm2 = FaultModel(straggler_prob=1.0, straggler_scale=10.0, speculation=False)
    times2 = [fm2.stage_execution(10.0, 1, rng, q)[0] for _ in range(100)]
    assert max(times2) > 10.0 * 2  # unbounded tail without speculation


def test_fault_model_failures_retry():
    fm = FaultModel(failure_prob=1.0)
    rng = np.random.default_rng(0)
    q = _mk(ServiceLevel.IMMEDIATE, 0.0)
    t, billed, retries = fm.stage_execution(5.0, 2, rng, q)
    assert t == 10.0 and q.retries == 1 and retries == 1
    assert billed == 20.0  # the re-run of the failed stage is billed


# ---------------------------------------------------------------------------
# Cost model sanity
# ---------------------------------------------------------------------------

def test_cost_model_monotonicity():
    cm = CostModel(use_calibration=False)
    w = QueryWork(arch="granite-8b", prompt_tokens=100_000, output_tokens=32)
    assert cm.exec_time(w, 8) > cm.exec_time(w, 64)
    w2 = QueryWork(arch="granite-8b", prompt_tokens=400_000, output_tokens=32)
    assert cm.exec_time(w2, 8) > cm.exec_time(w, 8)
    assert cm.chip_seconds(w2, 8) > cm.chip_seconds(w, 8)


def test_cost_model_train_queries():
    cm = CostModel(use_calibration=False)
    w = QueryWork(arch="qwen2-0.5b", kind="train", batch=8, seq_len=4096,
                  train_steps=10)
    plan = cm.plan(w, 16)
    assert plan.exec_time > 0 and plan.chip_seconds > 0
    assert plan.stages[0].name == "train_steps"


# ---------------------------------------------------------------------------
# Beyond-paper: execution-time SLAs (latency-aware routing)
# ---------------------------------------------------------------------------

def test_latency_aware_routing_meets_targets():
    from repro.core import Policy

    tight = _mk(ServiceLevel.IMMEDIATE, 0.0, tokens=2_000_000)
    tight.latency_target_s = 10.0  # only the big elastic slice can meet it
    loose = _mk(ServiceLevel.IMMEDIATE, 0.0, tokens=2_000_000)
    loose.latency_target_s = 10_000.0
    # pre-load the VM so its quote includes queueing
    bg = [_mk(ServiceLevel.IMMEDIATE, 0.0, tokens=3_000_000) for _ in range(6)]
    res = run_sim(bg + [tight, loose], policy=Policy.LATENCY_AWARE,
                  use_calibration=False)
    by_id = {q.qid: q for q in res.queries}
    assert by_id[tight.qid].cluster == "cf"  # forced to the fast pool
    assert by_id[loose.qid].cluster == "vm"  # cheapest pool suffices
    assert by_id[tight.qid].exec_time <= 10.0 + 1e-6


def test_estimate_quotes_are_consistent():
    from repro.core import Policy
    from repro.core.simulator import SimConfig, Simulation

    sim = Simulation(SimConfig(policy=Policy.LATENCY_AWARE, use_calibration=False))
    q = _mk(ServiceLevel.IMMEDIATE, 0.0, tokens=1_000_000)
    est = sim.coordinator.estimate(q)
    assert est["cf"]["latency_s"] < est["vm"]["latency_s"] * 10
    assert est["cf"]["cost"] > est["vm"]["cost"]  # elastic is pricier
    assert all(v["latency_s"] > 0 and v["cost"] > 0 for v in est.values())


# ---------------------------------------------------------------------------
# Beyond-paper: cost visibility (Q7), price menu (Q6), elastic scaling
# ---------------------------------------------------------------------------

def test_price_menu_orders_levels():
    from repro.core import price_menu

    w = QueryWork(arch="granite-8b", prompt_tokens=500_000, output_tokens=16)
    menu = {q.sla: q for q in price_menu(w, cost_model=CostModel(False))}
    assert menu["relaxed"].est_cost < menu["immediate"].est_cost
    assert menu["best_effort"].est_cost == menu["relaxed"].est_cost
    assert menu["immediate"].est_pending_s == 0.0
    assert menu["relaxed"].est_pending_s == 300.0
    assert menu["immediate"].est_exec_s < menu["relaxed"].est_exec_s
    # the legacy knob pair reports which pool backs each level
    assert menu["immediate"].pool == "cf"
    assert menu["relaxed"].pool == "vm"


def test_price_menu_quotes_pool_registry():
    """Pool-aware frontier: the menu is quoted from per-pool rows of an
    executor registry — each pool's own cost model, slice sizing, and
    unit price — instead of the hardcoded vm/cf knobs."""
    from repro.core import PoolSpec, build_pool, price_menu

    w = QueryWork(arch="granite-8b", prompt_tokens=500_000, output_tokens=16)
    specs = [
        PoolSpec(name="vm", kind="reserved", chips=4),
        PoolSpec(name="spot", kind="reserved", chips=64, slice_chips=16,
                 speed_factor=0.25, price_multiplier=0.15),
        PoolSpec(name="cf", kind="elastic", chips=64,
                 price_multiplier=10.0),
    ]
    pools = [build_pool(s, use_calibration=False) for s in specs]
    menu = {q.sla: q for q in price_menu(w, pools=pools)}
    # relaxed/BoE ride the cheapest reserved pool: the slow spot tier
    assert menu["relaxed"].pool == "spot"
    assert menu["best_effort"].est_cost == menu["relaxed"].est_cost
    # immediate is priced at the worst-case (elastic) pool
    assert menu["immediate"].pool == "cf"
    assert menu["immediate"].est_cost > menu["relaxed"].est_cost
    assert menu["immediate"].est_exec_s < menu["relaxed"].est_exec_s
    # registry quotes agree with the pools they came from
    q = Query(work=w, sla=ServiceLevel.IMMEDIATE, submit_time=0.0)
    cf = next(p for p in pools if p.name == "cf")
    assert menu["immediate"].est_cost == pytest.approx(cf.quote_cost(q), rel=1e-6)


def test_cost_explorer_brush_and_trace(tmp_path):
    from repro.core import CostExplorer, export_trace, generate

    res = run_sim(generate(horizon_s=3600, seed=1), use_calibration=False)
    ex = CostExplorer(res.queries)
    agg = ex.aggregate()
    assert agg["n"] == len(res.queries) and agg["total_cost"] > 0
    dash = ex.brush(source="dashboard")
    assert 0 < dash.aggregate()["n"] < agg["n"]
    by_sla = ex.by("sla")
    assert set(by_sla) <= {"imm", "rel", "boe"}
    assert sum(v["n"] for v in by_sla.values()) == agg["n"]
    expensive = ex.brush(cost=lambda c: c > agg["mean_cost"])
    assert 0 < expensive.aggregate()["n"] < agg["n"]
    path = tmp_path / "trace.jsonl"
    assert export_trace(res.queries, str(path)) == agg["n"]
    assert path.read_text().count("\n") == agg["n"]


def test_autoscaler_grows_and_shrinks():
    from repro.core import AutoscaleConfig

    auto = AutoscaleConfig(enabled=True, min_chips=4, max_chips=32,
                           step_chips=8, scale_delay_s=60.0,
                           high_watermark=4, low_watermark=0)
    # heavy burst, then silence
    qs = [_mk(ServiceLevel.IMMEDIATE, float(i % 5), tokens=3_000_000)
          for i in range(24)]
    res = run_sim(qs, use_calibration=False, autoscale=auto,
                  sla=SLAConfig(vm_overload_threshold=10**9))
    sim_chips_grew = any(q.cluster == "vm" for q in res.queries)
    assert sim_chips_grew
    # the same burst WITHOUT autoscaling takes longer end-to-end
    res_fixed = run_sim(
        [_mk(ServiceLevel.IMMEDIATE, float(i % 5), tokens=3_000_000)
         for i in range(24)],
        use_calibration=False,
        sla=SLAConfig(vm_overload_threshold=10**9),
    )
    assert max(q.finish_time for q in res.queries) < \
        max(q.finish_time for q in res_fixed.queries)
