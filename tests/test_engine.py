"""Stage-level execution engine (core/engine.py): stage cursors,
stage-boundary preemption, cross-cluster spill, stage-granular fault
retry billing, exact per-stage finish times, and determinism."""
import numpy as np
import pytest

from repro.core import (
    FaultModel,
    HighElasticCluster,
    Policy,
    Query,
    QueryWork,
    ServiceLevel,
    SimConfig,
    Simulation,
    SLAConfig,
    generate,
    run_sim,
)
from repro.core.cost_model import CostModel


def _mk(sla, t, tokens=100_000, out=8, arch="paper-default"):
    return Query(
        work=QueryWork(arch=arch, prompt_tokens=tokens, output_tokens=out),
        sla=sla,
        submit_time=t,
    )


PIN_VM = dict(vm_overload_threshold=10**9)  # keep the coordinator on the VM


# ---------------------------------------------------------------------------
# chunked decode plans
# ---------------------------------------------------------------------------

def test_decode_chunking_preserves_totals():
    w = QueryWork(arch="paper-default", prompt_tokens=200_000, output_tokens=100)
    chunked = CostModel(use_calibration=False, decode_chunk_tokens=32).plan(w, 8)
    whole = CostModel(use_calibration=False, decode_chunk_tokens=0).plan(w, 8)
    names = [s.name for s in chunked.stages]
    assert names[0] == "prefill"
    assert names[1:] == [
        "decode[0:32]", "decode[32:64]", "decode[64:96]", "decode[96:100]"
    ]
    assert chunked.exec_time == pytest.approx(whole.exec_time)
    assert chunked.chip_seconds == pytest.approx(whole.chip_seconds)
    # structure depends on the work only, never on the slice size — the
    # invariant that keeps a mid-plan cursor valid across a spill
    assert names == [s.name for s in
                     CostModel(use_calibration=False).plan(w, 64).stages]


def test_remaining_views_follow_cursor():
    w = QueryWork(arch="paper-default", prompt_tokens=200_000, output_tokens=64)
    plan = CostModel(use_calibration=False).plan(w, 8)
    assert plan.remaining_time(0) == pytest.approx(plan.exec_time)
    assert plan.remaining_time(1) == pytest.approx(
        plan.exec_time - plan.stages[0].time_s
    )
    assert plan.remaining_chip_seconds(len(plan.stages)) == 0.0


# ---------------------------------------------------------------------------
# exact per-stage finish times (the old collect_finished stamped the
# event-processing time, inflating exec_time for queries that finished
# between events)
# ---------------------------------------------------------------------------

def test_elastic_finish_time_is_exact_not_event_time():
    cm = CostModel(use_calibration=False)
    cf = HighElasticCluster(cost_model=cm, startup_s=2.0)
    q = _mk(ServiceLevel.IMMEDIATE, 0.0, tokens=500_000, out=16)
    q.dequeue_time = 0.0
    cf.submit(q, 0.0)
    done = cf.advance_to(10_000.0)  # event arrives long after the finish
    assert done == [q]
    expected = 2.0 + cm.plan(q.work, cf.slice_for(q)).exec_time
    assert q.finish_time == pytest.approx(expected)
    assert q.exec_time == pytest.approx(expected - 2.0)


def test_stage_trace_tiles_execution():
    qs = [_mk(ServiceLevel.IMMEDIATE, 0.0, tokens=400_000, out=70)]
    res = run_sim(qs, vm_mode="sos", vm_chips=32, sos_slice_chips=32,
                  use_calibration=False, sla=SLAConfig(**PIN_VM))
    (q,) = res.queries
    trace = q.stage_trace
    assert [e.stage for e in trace][0] == "prefill"
    assert len(trace) == 1 + 3  # 70 decode tokens -> 3 chunks of <=32
    # stages are contiguous and cover [start, finish]
    assert trace[0].start == pytest.approx(q.start_time)
    for a, b in zip(trace, trace[1:]):
        assert b.start == pytest.approx(a.finish)
    assert trace[-1].finish == pytest.approx(q.finish_time)
    # billing is exactly the sum of the per-stage bills
    assert q.chip_seconds == pytest.approx(sum(e.chip_seconds for e in trace))
    assert q.cost == pytest.approx(sum(e.cost for e in trace))


# ---------------------------------------------------------------------------
# stage-boundary preemption of BEST_EFFORT by IMMEDIATE
# ---------------------------------------------------------------------------

def _preemption_run(preempt: bool):
    # one SOS slice: the BoE query holds it, the IMMEDIATE query arrives
    # mid-decode (long chunked generation = many preemption points)
    boe = _mk(ServiceLevel.BEST_EFFORT, 0.0, tokens=2_000_000, out=2048)
    imm = _mk(ServiceLevel.IMMEDIATE, 30.0, tokens=100_000, out=8)
    res = run_sim(
        [boe, imm],
        vm_mode="sos", vm_chips=4, sos_slice_chips=4,
        use_calibration=False,
        sla=SLAConfig(preempt_best_effort=preempt, **PIN_VM),
    )
    by = {q.sla: q for q in res.queries}
    return by[ServiceLevel.BEST_EFFORT], by[ServiceLevel.IMMEDIATE]


def test_preemption_lets_immediate_jump_the_slice():
    boe_on, imm_on = _preemption_run(True)
    boe_off, imm_off = _preemption_run(False)
    # without preemption the IMMEDIATE query waits out the whole BoE run
    assert imm_off.start_time >= boe_off.finish_time - 1e-6
    # with preemption it starts at the next stage boundary instead
    assert imm_on.start_time < imm_off.start_time - 1.0
    assert boe_on.preemptions >= 1 and imm_on.preemptions == 0
    # the preempted query resumes at its next unfinished stage and finishes
    assert boe_on.finish_time is not None
    assert boe_on.state == "done"
    # chip-seconds already spent are kept and billed, never re-run: the
    # bill equals the plan's total in both worlds (monotone, no re-billing)
    assert boe_on.chip_seconds == pytest.approx(boe_off.chip_seconds)
    assert len(boe_on.stage_trace) == len(boe_off.stage_trace)
    # stage indices are strictly increasing: no stage ran twice
    idx = [e.index for e in boe_on.stage_trace]
    assert idx == sorted(set(idx))


def test_preemption_invariant_under_paper_stream():
    qs = generate(horizon_s=3600, seed=5)
    res = run_sim(qs, vm_mode="sos", vm_chips=64, sos_slice_chips=16,
                  use_calibration=False,
                  sla=SLAConfig(preempt_best_effort=True))
    assert all(q.finish_time is not None for q in res.queries)
    # every preempted query still ran each stage exactly once
    for q in res.queries:
        if q.preemptions:
            idx = [e.index for e in q.stage_trace]
            assert idx == sorted(set(idx))


# ---------------------------------------------------------------------------
# cross-cluster spill: remaining stages move to the elastic cluster
# ---------------------------------------------------------------------------

def test_spill_moves_remaining_stages_to_cf_at_cf_rate():
    long_q = _mk(ServiceLevel.IMMEDIATE, 0.0, tokens=2_000_000, out=2048)
    rival = _mk(ServiceLevel.IMMEDIATE, 30.0, tokens=100_000, out=8)
    cfg = SimConfig(
        vm_mode="sos", vm_chips=4, sos_slice_chips=4, use_calibration=False,
        sla=SLAConfig(spill_enabled=True, spill_min_remaining_s=5.0, **PIN_VM),
    )
    sim = Simulation(cfg)
    res = sim.run([long_q, rival])
    by_id = {q.qid: q for q in res.queries}
    spilled = by_id[long_q.qid]
    assert spilled.spilled and spilled.finish_time is not None
    clusters = [e.cluster for e in spilled.stage_trace]
    assert clusters[0] == "vm" and clusters[-1] == "cf"
    # once spilled, it never comes back mid-plan
    assert clusters == sorted(clusters, key=lambda c: c != "vm")
    # remaining stages are billed at the elastic rate, earlier ones at the
    # reserved rate
    for e in spilled.stage_trace:
        price = (sim.vm if e.cluster == "vm" else sim.cf).price_per_chip_s
        assert e.cost == pytest.approx(e.chip_seconds * price)
    assert sim.cf.price_per_chip_s > sim.vm.price_per_chip_s
    # the freed slice goes to the waiting query at the spill boundary
    assert by_id[rival.qid].start_time < spilled.finish_time
    # no stage lost or duplicated across the handoff
    idx = [e.index for e in spilled.stage_trace]
    assert idx == list(range(len(idx)))


def test_boe_is_never_spilled_to_the_expensive_pool():
    boe = _mk(ServiceLevel.BEST_EFFORT, 0.0, tokens=2_000_000, out=2048)
    imm = _mk(ServiceLevel.IMMEDIATE, 30.0, tokens=100_000, out=8)
    res = run_sim(
        [boe, imm],
        vm_mode="sos", vm_chips=4, sos_slice_chips=4, use_calibration=False,
        sla=SLAConfig(spill_enabled=True, **PIN_VM),
    )
    boe_q = [q for q in res.queries if q.sla is ServiceLevel.BEST_EFFORT][0]
    assert not boe_q.spilled
    assert all(e.cluster == "vm" for e in boe_q.stage_trace)


# ---------------------------------------------------------------------------
# stage-granular faults: a retry re-runs (and re-bills) ONLY the failed
# stage
# ---------------------------------------------------------------------------

def test_fault_rebills_only_failed_stages():
    qs = [_mk(ServiceLevel.IMMEDIATE, 0.0, tokens=400_000, out=70)]
    res = run_sim(qs, vm_mode="sos", vm_chips=32, sos_slice_chips=32,
                  use_calibration=False, fault=FaultModel(failure_prob=0.5),
                  seed=7, sla=SLAConfig(**PIN_VM))
    (q,) = res.queries
    plan = CostModel(use_calibration=False).plan(q.work, 32)
    assert q.retries > 0  # seed chosen so some stage actually failed
    failed_cs = ok_cs = 0.0
    for e, s in zip(q.stage_trace, plan.stages):
        # each stage bills its own work once per run: 1x clean, 2x retried
        assert e.chip_seconds == pytest.approx(s.chip_seconds * (1 + e.retries))
        (failed_cs, ok_cs) = (
            (failed_cs + s.chip_seconds, ok_cs) if e.retries
            else (failed_cs, ok_cs + s.chip_seconds)
        )
    assert 0 < failed_cs < plan.chip_seconds  # partial failure, not all-or-nothing
    assert q.chip_seconds == pytest.approx(plan.chip_seconds + failed_cs)


def test_all_stages_failing_doubles_the_bill_exactly():
    qs = [_mk(ServiceLevel.IMMEDIATE, 0.0, tokens=400_000, out=70)]
    res = run_sim(qs, vm_mode="sos", vm_chips=32, sos_slice_chips=32,
                  use_calibration=False, fault=FaultModel(failure_prob=1.0),
                  sla=SLAConfig(**PIN_VM))
    (q,) = res.queries
    plan = CostModel(use_calibration=False).plan(q.work, 32)
    assert q.retries == len(plan.stages)
    # per-stage retries double each stage — NOT (n+1)x as a whole-query
    # re-run would
    assert q.chip_seconds == pytest.approx(2 * plan.chip_seconds)


# ---------------------------------------------------------------------------
# BoE fusion safety (scheduler satellite): kind + output_tokens must match
# ---------------------------------------------------------------------------

def _mini_service(fuse=True):
    from repro.core.clusters import CostEfficientCluster
    from repro.core.scheduler import BoEScheduler, QueryCoordinator

    cm = CostModel(use_calibration=False)
    vm = CostEfficientCluster(chips=64, mode="sos", sos_slice_chips=16,
                              cost_model=cm)
    cf = HighElasticCluster(cost_model=cm)
    coord = QueryCoordinator(vm, cf, Policy.AUTO, SLAConfig())
    return BoEScheduler(coord, SLAConfig(), fuse=fuse)


def test_boe_fusion_never_mixes_train_and_serve():
    boe = _mini_service()
    serve = Query(work=QueryWork(arch="qwen2-0.5b", kind="serve",
                                 prompt_tokens=4096, output_tokens=16),
                  sla=ServiceLevel.BEST_EFFORT, submit_time=0.0)
    train = Query(work=QueryWork(arch="qwen2-0.5b", kind="train", batch=1,
                                 prompt_tokens=4096, output_tokens=16,
                                 train_steps=4),
                  sla=ServiceLevel.BEST_EFFORT, submit_time=0.0)
    boe.enqueue(serve)
    boe.enqueue(train)
    (head,) = boe.poll(0.0)
    assert head is serve and head.members is None


def test_boe_fusion_requires_matching_output_tokens():
    boe = _mini_service()
    a = Query(work=QueryWork(arch="qwen2-0.5b", prompt_tokens=4096,
                             output_tokens=16),
              sla=ServiceLevel.BEST_EFFORT, submit_time=0.0)
    b = Query(work=QueryWork(arch="qwen2-0.5b", prompt_tokens=4096,
                             output_tokens=128),
              sla=ServiceLevel.BEST_EFFORT, submit_time=0.0)
    c = Query(work=QueryWork(arch="qwen2-0.5b", prompt_tokens=4096,
                             output_tokens=16),
              sla=ServiceLevel.BEST_EFFORT, submit_time=0.0)
    for q in (a, b, c):
        boe.enqueue(q)
    (head,) = boe.poll(0.0)
    assert getattr(head, "members", None) == [a, c]  # b excluded


# ---------------------------------------------------------------------------
# determinism: same seed => identical results, with every engine feature on
# ---------------------------------------------------------------------------

def test_engine_determinism_same_seed_same_summary():
    def go():
        qs = generate(horizon_s=3600, seed=3)
        return run_sim(
            qs, vm_mode="sos", vm_chips=64, sos_slice_chips=16,
            use_calibration=False, seed=11,
            fault=FaultModel(failure_prob=0.05, straggler_prob=0.05),
            sla=SLAConfig(preempt_best_effort=True, spill_enabled=True),
        )

    r1, r2 = go(), go()
    assert r1.summary() == r2.summary()

    def norm(res):  # qids are globally counted: compare relative ids
        base = min(q.qid for q in res.queries)
        return [(e.qid - base, e.stage, e.finish) for e in res.stage_events()]

    assert norm(r1) == norm(r2)
