"""Sharding rules, program builder, and multi-device lowering (subprocess)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.models import build_model
from repro.parallel.sharding import (
    LONG_RULES,
    SERVE_RULES,
    TRAIN_RULES,
    rules_for,
    spec_for,
    with_pod_axis,
)

REPO = Path(__file__).resolve().parents[1]


class _FakeMesh:
    def __init__(self, **shape):
        self.shape = shape


def test_spec_fallbacks_match_arch_realities():
    mesh = _FakeMesh(data=16, model=16)
    # granite: 32 heads shard over model; head_dim falls out
    s = spec_for((4096, 32, 128), ("fsdp", "heads", "head_dim"), TRAIN_RULES, mesh)
    assert tuple(s) == ("data", "model", None)
    # gemma2: 8 heads cannot shard 16-way; head_dim=256 claims model
    s = spec_for((2304, 8, 256), ("fsdp", "heads", "head_dim"), TRAIN_RULES, mesh)
    assert tuple(s) == ("data", None, "model")
    # mixtral MoE: 8 experts can't shard 16-way -> ff claims model (TP-MoE)
    s = spec_for((8, 4096, 14336), ("experts", "fsdp", "ff"), TRAIN_RULES, mesh)
    assert tuple(s) == (None, "data", "model")
    # phi3.5: 16 experts -> EP over model, ff unsharded
    s = spec_for((16, 4096, 6400), ("experts", "fsdp", "ff"), TRAIN_RULES, mesh)
    assert tuple(s) == ("model", "data", None)


def test_pod_axis_extends_batch():
    r = with_pod_axis(TRAIN_RULES)
    assert r["batch"] == ("pod", "data")
    assert r["heads"] == "model"


def test_rules_for_long_shards_weights_and_kv_seq():
    r = rules_for("long", multi_pod=False)
    assert r["kv_seq"] == "data" and r["fsdp"] == "data" and r["batch"] is None


def test_serve_rules_keep_batch_on_data():
    r = rules_for("decode", multi_pod=False)
    assert r["batch"] == "data" and r["fsdp"] is None


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mixtral-8x7b", "mamba2-2.7b"])
def test_param_axes_cover_every_leaf(arch):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    axes = model.param_axes()
    shapes = model.param_shapes(jnp.float32)
    flat_a = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
    flat_s = jax.tree.leaves(shapes)
    assert len(flat_a) == len(flat_s)
    for a, s in zip(flat_a, flat_s):
        assert len(a) == len(s.shape), (a, s.shape)


def test_cache_axes_cover_every_leaf():
    cfg = get_config("jamba-v0.1-52b", reduced=True)
    model = build_model(cfg)
    spec = model.cache_spec(4, 64)
    axes = model.cache_axes(spec)
    flat_a = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
    flat_s = jax.tree.leaves(spec)
    assert len(flat_a) == len(flat_s)


_SUBPROC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp
from repro.launch.programs import build_program
from repro.perf.hlo import collective_summary

from repro.launch.mesh import mesh_axis_kwargs
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"), **mesh_axis_kwargs(3))
prog = build_program("mixtral-8x7b", "train_4k", mesh, reduced=True)
with mesh:
    compiled = prog.lower().compile()
cs = collective_summary(compiled.as_text(), 8)
print("WIRE", cs["total_wire_bytes_per_chip"])
assert cs["count"] > 0, "multi-axis training must produce collectives"
print("OK")
"""


def test_multipod_lowering_smoke_subprocess():
    """Reduced mixtral train lowers+compiles on a (pod,data,model) mesh."""
    r = subprocess.run(
        [sys.executable, "-c", _SUBPROC_SCRIPT, str(REPO / "src")],
        capture_output=True, text=True, timeout=560,
        env={**os.environ, "XLA_FLAGS": ""},
    )
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_compressed_dp_subprocess():
    """Int8 EF-compressed DP halves gradient wire bytes (4 host devices)."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import build_model
from repro.optim.adamw import OptConfig
from repro.training.dp_compressed import init_state, make_dp_train_step
from repro.data.batches import make_batch
from repro.perf.hlo import collective_summary

from repro.launch.mesh import mesh_axis_kwargs
mesh = jax.make_mesh((4,), ("data",), **mesh_axis_kwargs(1))
cfg = get_config("qwen2-0.5b", reduced=True)
model = build_model(cfg)
state = init_state(model, jax.random.PRNGKey(0))
batch = make_batch(jax.random.PRNGKey(1), cfg, batch=8, seq=32)
wires, losses = {}, {}
for compress in (False, True):
    step = make_dp_train_step(model, OptConfig(), mesh, compress=compress)
    with mesh:
        jitted = jax.jit(step)
        comp = jitted.lower(state, batch).compile()
        wires[compress] = collective_summary(comp.as_text(), 4)["total_wire_bytes_per_chip"]
        _, m = jitted(state, batch)
        losses[compress] = float(m["loss"])
assert wires[True] < 0.6 * wires[False], wires
assert abs(losses[True] - losses[False]) < 1e-2, losses
print("OK")
"""
    r = subprocess.run(
        [sys.executable, "-c", script, str(REPO / "src")],
        capture_output=True, text=True, timeout=560,
        env={**os.environ, "XLA_FLAGS": ""},
    )
    assert "OK" in r.stdout, r.stdout + r.stderr
