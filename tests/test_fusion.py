"""Multi-query fusion: the indexed pending queues, cross-pool
placement-time fusion, and the exact-sum billing split (docs/fusion.md).

These tests run without hypothesis — the randomized invariant sweeps
live in tests/test_properties.py; here each mechanism is pinned down
deterministically."""
import numpy as np
import pytest

from repro.core import (
    FaultModel,
    Policy,
    PoolSpec,
    Query,
    QueryWork,
    ServiceLevel,
    SimConfig,
    Simulation,
    SLAConfig,
    run_sim,
)
from repro.core.cost_model import CostModel
from repro.core.clusters import CostEfficientCluster, HighElasticCluster
from repro.core.scheduler import (
    CrossPoolFusionIndex,
    PendingQueue,
    QueryCoordinator,
    fuse_queries,
    fusion_key,
    pop_fused,
    unpack_fused,
)


def _q(arch="qwen2-0.5b", prompt=200_000, out=16, sla=ServiceLevel.IMMEDIATE,
       t=0.0, batch=1):
    return Query(
        work=QueryWork(arch=arch, kind="serve", batch=batch,
                       prompt_tokens=prompt, output_tokens=out),
        sla=sla, submit_time=t,
    )


# ---------------------------------------------------------------------------
# PendingQueue: the indexed pending queue behind pop_fused
# ---------------------------------------------------------------------------

def test_pending_queue_is_fifo_and_fuses_in_bucket_order():
    pq = PendingQueue()
    a = _q(out=16, t=0)
    b = _q(out=64, t=1)  # different bucket
    c = _q(out=16, t=2)
    d = _q(out=16, t=3)
    for q in (a, b, c, d):
        pq.append(q)
    assert len(pq) == 4 and pq.head() is a
    head = pq.popleft()
    assert head is a
    # the head's group comes straight off its bucket, FIFO, head excluded
    assert pq.take_fusable(head, 8) == [c, d]
    assert len(pq) == 1 and pq.head() is b  # stale copies skipped
    assert pq.popleft() is b and len(pq) == 0


def test_pop_fused_matches_naive_scan_semantics():
    """The indexed pop must select exactly what the old O(n) scan
    selected: the head plus the first fuse_max-1 compatible queries in
    queue order."""
    rng = np.random.default_rng(7)
    qs = [
        _q(out=int(rng.choice([16, 64])), prompt=int(rng.choice([1, 2])) * 100_000,
           t=float(i))
        for i in range(40)
    ]
    pq = PendingQueue()
    naive = list(qs)
    for q in qs:
        pq.append(q)
    while naive:
        expect_head = naive.pop(0)
        expect_same = [q for q in naive
                       if fusion_key(q.work) == fusion_key(expect_head.work)][:3]
        got = pop_fused(pq, 0.0, True, 4)
        if expect_same:
            assert got.members == [expect_head] + expect_same
        else:
            assert got is expect_head
        for m in expect_same:
            naive.remove(m)
    assert len(pq) == 0


def test_pending_queue_train_queries_never_indexed():
    pq = PendingQueue()
    t1 = Query(work=QueryWork(arch="qwen2-0.5b", kind="train",
                              train_steps=2, prompt_tokens=1, output_tokens=0),
               sla=ServiceLevel.BEST_EFFORT, submit_time=0.0)
    s1 = _q()
    pq.append(t1)
    pq.append(s1)
    head = pop_fused(pq, 0.0, True, 8)
    assert head is t1 and head.members is None


# ---------------------------------------------------------------------------
# exact-sum billing split
# ---------------------------------------------------------------------------

def test_unpack_split_sums_exactly_and_shares_by_tokens():
    members = [_q(batch=1, t=0), _q(batch=3, t=1), _q(batch=2, t=2)]
    fused = fuse_queries(members, now=5.0)
    fused.start_time, fused.finish_time = 10.0, 20.0
    fused.cluster, fused.state = "vm", "done"
    fused.chip_seconds = 123.456789012345
    fused.cost = 0.9876543210987654
    out = unpack_fused(fused)
    assert out == members
    # bit-exact conservation — no float residue anywhere, and in
    # particular none silently parked on member 0
    assert sum(m.cost for m in out) == fused.cost
    assert sum(m.chip_seconds for m in out) == fused.chip_seconds
    # split follows token shares (batch-weighted) to float accuracy
    tot = sum(m.work.total_tokens for m in members)
    for m in out[:-1]:
        assert m.cost == pytest.approx(
            fused.cost * m.work.total_tokens / tot, rel=1e-12
        )
        assert m.fused_with == 3
        assert (m.start_time, m.finish_time) == (10.0, 20.0)
    # the fused trace/counters live on member 0 only
    assert out[0].stage_trace is fused.stage_trace


def test_unpack_split_exact_on_adversarial_eighths():
    """8 equal members: the 0.125 shares reproduce the rounding residue
    that used to leak (sum != total by 1 ulp) — the repair must close it
    for any total."""
    rng = np.random.default_rng(3)
    for _ in range(200):
        members = [_q(t=float(i)) for i in range(8)]
        fused = fuse_queries(members, now=0.0)
        fused.chip_seconds = float(rng.uniform(1e-6, 1e6))
        fused.cost = float(rng.uniform(1e-9, 1e3))
        fused.state = "done"
        out = unpack_fused(fused)
        assert sum(m.cost for m in out) == fused.cost
        assert sum(m.chip_seconds for m in out) == fused.chip_seconds


# ---------------------------------------------------------------------------
# cross-pool placement-time fusion
# ---------------------------------------------------------------------------

def _two_pool_coordinator(cross=True):
    cm = CostModel(use_calibration=False)
    a = CostEfficientCluster(chips=16, mode="sos", sos_slice_chips=16,
                             cost_model=cm)
    a.name = "a"
    b = CostEfficientCluster(chips=16, mode="sos", sos_slice_chips=16,
                             cost_model=CostModel(use_calibration=False))
    b.name = "b"
    coord = QueryCoordinator([a, b], policy=Policy.FORCE, cfg=SLAConfig(),
                             cross_pool_fusion=cross)
    return coord, a, b


def test_cross_pool_fusion_merges_waiters_from_other_pools():
    coord, a, b = _two_pool_coordinator()
    # saturate pool a so submissions to it WAIT; pool b stays free —
    # an arriving IMMEDIATE only fuses when a slice is free for the
    # batch to start on
    a.submit(_q(prompt=900_000), 0.0)
    w1, w2 = _q(t=1.0), _q(t=2.0)
    a.submit(w1, 1.0)
    a.submit(w2, 2.0)
    assert w1 in a.waiting and w2 in a.waiting
    # a compatible fresh query routes: the waiters are pulled out of
    # the busy pool and the merged batch starts on the free one
    fresh = _q(t=3.0)
    pool_name = coord.route(fresh, 3.0)
    assert pool_name == "b"
    merged = [r.query for r in b.running if r.query.members is not None]
    assert len(merged) == 1
    assert merged[0].members == [fresh, w1, w2]
    assert w1 not in a.waiting and w2 not in a.waiting
    assert merged[0].work.batch == 3


def test_cross_pool_fusion_skips_relaxed_level():
    """RELAXED work is batched by its pending queue before placement —
    the placement-time index must leave it alone."""
    coord, a, b = _two_pool_coordinator()
    a.submit(_q(prompt=900_000), 0.0)
    w = _q(t=1.0, sla=ServiceLevel.RELAXED)
    w.effective_sla = ServiceLevel.RELAXED
    a.submit(w, 1.0)
    fresh = _q(t=2.0, sla=ServiceLevel.RELAXED)
    fresh.effective_sla = ServiceLevel.RELAXED
    coord.route(fresh, 2.0)
    assert fresh.members is None and w in a.waiting


def test_cross_pool_fusion_respects_sla_and_key():
    coord, a, b = _two_pool_coordinator()
    a.submit(_q(prompt=900_000), 0.0)  # saturate
    boe = _q(t=1.0, sla=ServiceLevel.BEST_EFFORT)
    other_shape = _q(t=1.0, out=64)
    a.submit(boe, 1.0)
    a.submit(other_shape, 1.0)
    fresh = _q(t=2.0)
    coord.route(fresh, 2.0)
    # neither the BoE waiter (different level) nor the 64-token waiter
    # (different fusion key) may ride the IMMEDIATE head
    assert fresh.members is None
    assert boe in a.waiting and other_shape in a.waiting


def test_withdraw_keeps_backlog_and_index_consistent():
    coord, a, b = _two_pool_coordinator()
    a.submit(_q(prompt=900_000), 0.0)
    w = _q(t=1.0)
    a.submit(w, 1.0)
    before = a.predicted_backlog_cs(1.0)
    assert a.withdraw(w)
    after = a.predicted_backlog_cs(1.0)
    assert after < before
    a.check_backlog_invariant(1.0)  # incremental == scan after withdraw
    assert not a.withdraw(w)  # second claim must fail


def test_preempted_queries_never_fuse():
    """A preempted query (stage_cursor > 0) must not enter the fusion
    index: a merged query restarts from stage 0, which would replay the
    preempted query's completed stages."""
    index = CrossPoolFusionIndex()
    coord, a, b = _two_pool_coordinator()
    q = _q()
    q.stage_cursor = 2
    q.state = "preempted"
    index.add(a, q)
    assert index.candidates(_q(), 8) == []


# ---------------------------------------------------------------------------
# end-to-end: the 3-pool day
# ---------------------------------------------------------------------------

def _day(fuse, cross, seed=0, n=60):
    from repro.core.workload import generate, scaled_patterns

    qs = generate(horizon_s=3600.0, seed=seed,
                  patterns=scaled_patterns(n / 911))
    cfg = SimConfig(
        policy=Policy.FORCE, use_calibration=False, seed=seed,
        fuse_queries=fuse, cross_pool_fusion=cross,
        sla=SLAConfig(vm_overload_threshold=4, preempt_best_effort=True,
                      spill_enabled=True),
        pools=[
            PoolSpec(name="vm", kind="reserved", chips=16, mode="sos",
                     slice_chips=16),
            PoolSpec(name="spot", kind="reserved", chips=32, mode="sos",
                     slice_chips=16, speed_factor=0.25,
                     price_multiplier=0.15),
            PoolSpec(name="cf", kind="elastic", chips=64, startup_s=2.0,
                     price_multiplier=10.0),
        ],
    )
    return Simulation(cfg).run(qs)


def test_cross_pool_fusion_day_conserves_and_everyone_finishes():
    res = _day(fuse=True, cross=True)
    assert all(q.state == "done" for q in res.queries)
    s = res.summary()
    assert s["finished"] == s["n"]
    # per-member bills sum exactly to the fused runs' totals: total
    # billed == total traced (traces shared by members, dedupe by id)
    traces = {id(q.stage_trace): q.stage_trace
              for q in res.queries if q.stage_trace}
    assert sum(q.cost for q in res.queries) == pytest.approx(
        sum(e.cost for tr in traces.values() for e in tr), rel=1e-9
    )


def test_cross_pool_fusion_never_costs_more_than_within():
    """On a contended day, placement-time fusion across pools can only
    merge MORE compatible work into shared batches — billed cost must
    not exceed the within-pool-fusion run's."""
    within = _day(fuse=True, cross=False, n=400)
    cross = _day(fuse=True, cross=True, n=400)
    assert cross.summary()["fused_queries"] >= within.summary()["fused_queries"]
    assert cross.total_cost() <= within.total_cost() + 1e-9


def test_fuse_off_day_identical_with_and_without_cross_flag():
    a = _day(fuse=False, cross=False, n=200)
    b = _day(fuse=False, cross=True, n=200)
    sig = lambda res: sorted(  # noqa: E731
        (q.submit_time, q.cost, q.chip_seconds, q.finish_time, q.cluster)
        for q in res.queries
    )
    assert sig(a) == sig(b)


def test_unpack_split_exact_on_mixed_batches():
    """Members with wildly different token counts (mixed batches) hit
    the parity-trap corner of the exact-sum repair: a dominant last
    member puts the residue in the total's own binade, where a bad
    prefix alignment makes every candidate land on a rounding tie. The
    repair must escape it for any total."""
    rng = np.random.default_rng(11)
    for _ in range(500):
        n = int(rng.integers(2, 9))
        members = [
            _q(batch=int(rng.integers(1, 4097)),
               prompt=int(rng.integers(100, 5000)), out=32, t=0.0)
            for _ in range(n)
        ]
        fused = fuse_queries(members, now=0.0)
        fused.chip_seconds = float(rng.uniform(1e-6, 1e7))
        fused.cost = float(rng.uniform(1e-9, 1e5))
        fused.state = "done"
        out = unpack_fused(fused)
        assert sum(m.cost for m in out) == fused.cost
        assert sum(m.chip_seconds for m in out) == fused.chip_seconds


def test_pending_queue_no_bookkeeping_growth_when_fuse_off():
    """With fusion off (the default), popped queries must leave no
    bucket or stale entries behind — a long-lived engine would
    otherwise leak one strong Query reference per drained query."""
    pq = PendingQueue(fuse=False)
    for i in range(500):
        pq.append(_q(t=float(i)))
    for _ in range(500):
        pop_fused(pq, 0.0, False, 8)
    assert len(pq) == 0
    assert not pq._stale and not pq._buckets


def test_withdraw_clears_stale_preempt_flag():
    """Fusion withdrawing an IMMEDIATE waiter must take its preemption
    request with it — otherwise the flagged BEST_EFFORT run is bumped
    at its next boundary with nobody waiting for the slice."""
    vm = CostEfficientCluster(chips=16, mode="sos", sos_slice_chips=16,
                              cost_model=CostModel(use_calibration=False),
                              preempt_best_effort=True)
    boe = _q(prompt=900_000, sla=ServiceLevel.BEST_EFFORT)
    vm.submit(boe, 0.0)  # runs
    imm = _q(t=1.0)
    vm.submit(imm, 1.0)  # waits -> flags the running BoE query
    (run,) = vm.running
    assert run.preempt_requested
    assert vm.withdraw(imm)
    assert not run.preempt_requested and not vm._flagged


def test_fifo_drained_pools_leave_no_lane_entries():
    """Elastic (and POS) pools drain `waiting` strictly FIFO and never
    call pop_best — the lane bookkeeping must still be reclaimed, not
    grow one dead cell per query forever."""
    cf = HighElasticCluster(cost_model=CostModel(use_calibration=False))
    for i in range(2000):
        cf.submit(_q(t=float(i), sla=ServiceLevel.RELAXED), float(i))
    assert sum(len(lane) for lane in cf.waiting._lanes) == 0


# ---------------------------------------------------------------------------
# satellite: faults inside a cross-pool fused group
# ---------------------------------------------------------------------------

class _FailSecondStage(FaultModel):
    """Deterministic fault: the second stage executed on this pool fails
    once and is re-run (wall and bill double for that stage only)."""

    def __init__(self):
        self.calls = 0

    def stage_execution(self, base, chips, rng, q):
        self.calls += 1
        if self.calls == 2:
            q.retries += 1
            return 2.0 * base, 2.0 * base * chips, 1
        return base, base * chips, 0


def test_fused_group_fault_rebills_one_stage_and_splits_exactly():
    """A stage failure inside a cross-pool fused batch re-runs — and
    re-bills — only the failed stage, and the inflated total still
    splits across members with the 1-ulp exact-sum guarantee."""
    def run(fault):
        coord, a, b = _two_pool_coordinator()
        if fault is not None:
            b.fault = fault
        a.submit(_q(prompt=900_000), 0.0)  # saturate a: waiters queue
        w1, w2 = _q(t=1.0), _q(t=2.0)
        a.submit(w1, 1.0)
        a.submit(w2, 2.0)
        fresh = _q(t=3.0)
        assert coord.route(fresh, 3.0) == "b"
        merged = [r.query for r in b.running if r.query.members is not None]
        assert len(merged) == 1
        b.advance_to(1e9)
        return merged[0]

    fm = _FailSecondStage()
    faulty = run(fm)
    control = run(None)
    assert faulty.state == "done" and control.state == "done"
    assert fm.calls == len(faulty.stage_trace)
    # exactly one stage carries the retry, and only it re-billed
    hit = [e for e in faulty.stage_trace if e.retries == 1]
    assert len(hit) == 1 and hit[0].index == 1
    assert sum(e.retries for e in faulty.stage_trace) == 1
    for e, c in zip(faulty.stage_trace, control.stage_trace):
        if e.retries:
            assert e.finish - e.start == pytest.approx(2.0 * (c.finish - c.start))
            assert e.chip_seconds == pytest.approx(2.0 * c.chip_seconds)
        else:
            assert e.finish - e.start == pytest.approx(c.finish - c.start)
            assert e.chip_seconds == pytest.approx(c.chip_seconds)
    assert faulty.retries == 1
    # the inflated bill still splits bit-exactly across the members
    members = unpack_fused(faulty)
    assert len(members) == 3
    assert sum(m.cost for m in members) == faulty.cost
    assert sum(m.chip_seconds for m in members) == faulty.chip_seconds
    assert all(m.state == "done" for m in members)
    assert faulty.cost > control.cost  # the re-run was billed, once
