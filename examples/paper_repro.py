"""Reproduce the paper's headline results (Figs. 6-7) end to end:
CAB workload -> flexible-SLA scheduling -> cost/exec-time by service level.

    PYTHONPATH=src python examples/paper_repro.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import Policy, generate, run_sim


def main():
    runs = {}
    for name, kw in [
        ("auto w/ SLA", dict(policy=Policy.AUTO, sla_enabled=True)),
        ("auto w/o SLA", dict(policy=Policy.AUTO, sla_enabled=False)),
        ("force w/ SLA", dict(policy=Policy.FORCE, sla_enabled=True)),
    ]:
        qs = generate(horizon_s=14_400, seed=0)
        runs[name] = run_sim(qs, **kw)
        s = runs[name].summary()
        print(f"{name:13s} total=${s['total_cost']:8.2f}  by-sla={s['cost_by_sla']}"
              f"  violations={s['violations']}")
    base = runs["auto w/o SLA"].total_cost()
    print(f"\nauto  w/ SLA cost reduction: {1 - runs['auto w/ SLA'].total_cost()/base:6.1%} (paper: 22.2%)")
    print(f"force w/ SLA cost reduction: {1 - runs['force w/ SLA'].total_cost()/base:6.1%} (paper: 65.5%)")


if __name__ == "__main__":
    main()
