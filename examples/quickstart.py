"""Quickstart: build any assigned architecture, train a few steps, serve a
few tokens — all on CPU with reduced configs.

    PYTHONPATH=src python examples/quickstart.py [arch]
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.data.batches import make_batch
from repro.models import build_model
from repro.optim.adamw import OptConfig
from repro.training import step as training_step


def main(arch: str = "mixtral-8x7b"):
    print(f"architectures available: {list(ARCHS)}")
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    print(f"\n== {arch} (reduced) :: {cfg.num_params():,} params ==")

    # --- train three steps ---
    state = training_step.init_state(model, jax.random.PRNGKey(0))
    step = jax.jit(training_step.make_train_step(model, OptConfig(lr=1e-3), remat=None))
    batch = make_batch(jax.random.PRNGKey(1), cfg, batch=4, seq=32)
    for i in range(3):
        state, m = step(state, batch)
        print(f"train step {i}: loss={float(m['loss']):.4f}")

    # --- serve: prefill + greedy decode ---
    params = state["params"]
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 12), 0, cfg.vocab_size)
    logits, cache = model.prefill(params, prompt, kv_len=64, dtype=jnp.float32)
    tok = jnp.argmax(logits, -1)[:, None]
    out = [int(tok[0, 0])]
    for _ in range(8):
        logits, cache = model.decode_step(params, cache, tok, dtype=jnp.float32)
        tok = jnp.argmax(logits, -1)[:, None]
        out.append(int(tok[0, 0]))
    print(f"generated token ids: {out}")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "mixtral-8x7b")
