"""Flexible-SLA serving demo (the paper's core contribution, live).

Queries with Immediate / Relaxed / Best-of-Effort service levels hit the
REAL scheduling stack — pending queues -> relaxed/BoE schedulers ->
query coordinator over a PoolSpec registry — and execute real jitted
reduced models on thread-backed pools: a serialized cost-efficient
worker and an elastic task pool at 10x unit price.

The demo shows the stage-boundary machinery on live work:
  1. the admission-time price menu, quoted from the live registry;
  2. an IMMEDIATE arrival preempting a running BEST_EFFORT query at a
     decode-chunk boundary — the BoE query resumes from its checkpoint
     and re-runs nothing (its stage trace stays gap- and overlap-free).

    PYTHONPATH=src python examples/serve_sla.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.live import LiveConfig, LiveEngine
from repro.core.query import Query, QueryWork
from repro.core.sla import Policy, ServiceLevel, SLAConfig


def main():
    eng = LiveEngine(LiveConfig(
        policy=Policy.AUTO,
        cf_startup_s=0.2,
        sla=SLAConfig(relaxed_deadline_s=10.0, poll_period_s=0.05,
                      vm_overload_threshold=2, preempt_best_effort=True),
        decode_tokens=96, decode_chunk_tokens=2,
    ))

    print("price menu (quoted from the live pool registry):")
    for row in eng.price_menu(QueryWork(arch="paper-default")):
        print(f"  {row.sla:12s} pool={row.pool:4s}"
              f" pending<={row.est_pending_s:6.1f}s"
              f" est_cost={row.est_cost:.6f}")

    eng.models.ensure("paper-default", 1)  # warm jit outside the demo clock

    def submit(name, sla):
        q = Query(work=QueryWork(arch="paper-default", batch=1), sla=sla,
                  submit_time=0.0, source=name)
        eng.submit(q)
        return q

    qs = [submit("nightly report", ServiceLevel.BEST_EFFORT)]
    # let the BoE query get mid-plan, then hit it with an IMMEDIATE: it
    # is bumped at its next chunk boundary and the IMMEDIATE cuts in
    deadline = time.monotonic() + 60.0
    while not (0 < len(qs[0].stage_trace) < 40):
        if qs[0].state == "failed":
            raise SystemExit(f"BoE query failed: {qs[0].error}")
        if len(qs[0].stage_trace) >= 40 or qs[0].state == "done":
            break  # missed the window; proceed — drain still completes
        if time.monotonic() > deadline:
            break
        time.sleep(0.002)
    qs.append(submit("ad-hoc analysis", ServiceLevel.IMMEDIATE))
    qs.append(submit("dashboard refresh", ServiceLevel.RELAXED))
    time.sleep(0.2)
    qs.append(submit("dashboard refresh", ServiceLevel.RELAXED))
    qs.append(submit("ad-hoc analysis", ServiceLevel.IMMEDIATE))
    done = eng.drain(len(qs), timeout=300)

    print(f"\n{'query':20s} {'sla':4s} {'cluster':8s} {'pending':>8s}"
          f" {'exec':>7s} {'cost':>8s} {'stages':>6s} {'preempt':>7s}")
    total = {"vm": 0.0, "cf": 0.0}
    for q in sorted(done, key=lambda q: q.qid):
        total[q.cluster] += q.cost
        print(f"{q.source:20s} {q.sla.short:4s} {q.cluster:8s}"
              f" {q.pending_time:7.2f}s {q.exec_time:6.2f}s {q.cost:8.3f}"
              f" {len(q.stage_trace):6d} {q.preemptions:7d}")

    boe = next(q for q in done if q.sla is ServiceLevel.BEST_EFFORT)
    indices = sorted(e.index for e in boe.stage_trace)
    conserved = (
        indices == list(range(len(indices)))
        and abs(sum(e.chip_seconds for e in boe.stage_trace)
                - boe.chip_seconds) < 1e-9
    )
    print(f"\nBoE preempted {boe.preemptions}x at chunk boundaries;"
          f" resumed from checkpoint: {len(boe.stage_trace)} stages,"
          f" no re-run ({'exact' if conserved else 'MISMATCH'}:"
          f" sum(stage chip-s) == billed {boe.chip_seconds:.4f})")
    print(f"cost split: cost-efficient={total['vm']:.2f}"
          f" high-elastic={total['cf']:.2f}"
          f"  (elastic unit price is {eng.cfg.cf_price_multiplier}x)")
    compile_s = sum(eng.models.compile_s.values())
    print(f"jit compile warmed outside the billed window:"
          f" {compile_s:.2f}s never billed")


if __name__ == "__main__":
    main()
