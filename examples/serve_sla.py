"""Flexible-SLA serving demo (the paper's core contribution, live).

Queries with Immediate / Relaxed / Best-of-Effort service levels hit the
real scheduling stack (pending queues -> relaxed/BoE schedulers -> query
coordinator) and execute real reduced models on two "clusters":
a serialized cost-efficient worker and an elastic pool at 10x unit price.

    PYTHONPATH=src python examples/serve_sla.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.live import LiveConfig, LiveEngine
from repro.core.query import Query, QueryWork
from repro.core.sla import Policy, ServiceLevel


def main():
    eng = LiveEngine(LiveConfig(policy=Policy.AUTO, cf_startup_s=0.2))
    plan = [
        ("dashboard refresh", ServiceLevel.IMMEDIATE),
        ("dashboard refresh", ServiceLevel.RELAXED),
        ("ad-hoc analysis", ServiceLevel.IMMEDIATE),
        ("nightly report", ServiceLevel.BEST_EFFORT),
        ("dashboard refresh", ServiceLevel.RELAXED),
    ]
    qs = []
    for name, sla in plan:
        q = Query(work=QueryWork(arch="paper-default", batch=1), sla=sla,
                  submit_time=0.0, source=name)
        qs.append(q)
        eng.submit(q)
        time.sleep(0.1)
    done = eng.drain(len(qs), timeout=300)
    print(f"\n{'query':20s} {'sla':4s} {'cluster':8s} {'pending':>8s} {'exec':>7s} {'cost':>8s}")
    total = {"vm": 0.0, "cf": 0.0}
    for q in sorted(done, key=lambda q: q.qid):
        total[q.cluster] += q.cost
        print(f"{q.source:20s} {q.sla.short:4s} {q.cluster:8s}"
              f" {q.pending_time:7.2f}s {q.exec_time:6.2f}s {q.cost:8.3f}")
    print(f"\ncost split: cost-efficient={total['vm']:.2f}"
          f" high-elastic={total['cf']:.2f}"
          f"  (elastic unit price is {eng.cfg.cf_price_multiplier}x)")


if __name__ == "__main__":
    main()
