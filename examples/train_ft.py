"""Fault-tolerant training demo: crash mid-run, restart, exact resume.

    PYTHONPATH=src python examples/train_ft.py
"""
import shutil
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.train import SimulatedFailure, train


def main():
    ckpt = Path(tempfile.mkdtemp(prefix="repro_ft_"))
    kw = dict(steps=20, batch=4, seq=32, ckpt_every=5, log_every=5,
              ckpt_dir=str(ckpt))
    print("== run with an injected failure at step 13 ==")
    try:
        train("qwen2-0.5b", fail_at=13, **kw)
    except SimulatedFailure as e:
        print(f"!! {e} — restarting from the latest checkpoint")
    out = train("qwen2-0.5b", **kw)
    print(f"resumed and finished: final loss {out['final_loss']:.4f}"
          f" (ran {out['steps_run']} steps after restart)")
    shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
