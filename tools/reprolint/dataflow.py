"""Unit-of-measure dataflow analysis (RL101/RL102/RL103).

An abstract interpreter over stdlib ``ast``: every expression evaluates
to an abstract value carrying a :class:`~tools.reprolint.units.Unit`
(or *unknown*, the silent top), environments map local names to values,
and the transfer functions are the unit algebra — ``+``/``-``/
comparisons require equal dimensions, ``*``/``/`` add/subtract
exponents. Numeric literals are *adoptive*: dimensionless until they
meet a united operand (so ``acc = 0.0; acc += dt_s`` types ``acc`` as
seconds without annotation).

Interprocedural layer: each function gets a **summary** (its return
unit, or a tuple of units for multi-returns), computed as a fixed
point over the call graph — within the file under lint always, and
across ``src/repro/core`` + ``src/repro/launch`` when a project root
is attached (the CLI and ``lint_paths`` do this). A function whose
body yields no concrete return unit falls back to its own name's
suffix (``_run_remaining_cs`` summarizes as chip-seconds even when
its branches defeat inference).

The three rules this module backs:

  RL101  unit-mismatched ``+``/``-``/comparison operands (also: an
         argument whose unit contradicts a known parameter, and
         branch-divergent "mixed" locals used in arithmetic)
  RL102  a product/quotient (or any concretely-united expression)
         assigned to a name whose suffix declares a different unit
  RL103  a non-zero numeric literal in an *additive* position flowing
         into a billing sink (``account_stage``/``Quote`` arguments
         with usd or chip-second dimensions, or stores to billing
         attributes) — multiplicative conversion factors like
         ``/ 3600.0`` stay legal
"""
from __future__ import annotations

import ast
import hashlib
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from . import Finding
from .units import (
    BILLING_ATTRS,
    CHIP_S,
    DIMENSIONLESS,
    SEED_FUNCS,
    Unit,
    lookup_name,
    unit_from_name,
)

CORE = "src/repro/core/"
#: directories whose call graph feeds the interprocedural summaries
SUMMARY_SCOPE = ("src/repro/core", "src/repro/launch")

_PASSTHROUGH_CALLS = {"abs", "float", "round", "int", "fsum", "floor",
                      "ceil", "trunc", "copysign", "nextafter"}
_EXTREMUM_CALLS = {"min", "max"}


class Val:
    """Abstract value: a concrete unit, unknown (``unit is None``), a
    branch-divergent mixed set, a literal, or a tuple of units."""

    __slots__ = ("unit", "mixed", "literal", "tup")

    def __init__(self, unit: Optional[Unit] = None, *, mixed=None,
                 literal: bool = False, tup=None) -> None:
        self.unit = unit
        self.mixed = mixed  # frozenset[Unit] | None
        self.literal = literal
        self.tup = tup  # tuple[Unit | None, ...] | None

    @property
    def concrete(self) -> bool:
        return self.unit is not None


UNKNOWN = Val()


def _render_mixed(mixed) -> str:
    return " | ".join(sorted(u.render() for u in mixed))


class Summaries:
    """Function-summary table with bare-name joins: ``table`` maps a
    qualname (``Class.method`` or ``func``) to a Unit, a tuple of
    units, or None (unknown)."""

    def __init__(self, table: Optional[Dict[str, object]] = None) -> None:
        self.table: Dict[str, object] = dict(table or {})
        self._bare: Dict[str, object] = {}
        self._rebuild()

    def _rebuild(self) -> None:
        by_bare: Dict[str, list] = {}
        for qual, value in self.table.items():
            by_bare.setdefault(qual.rsplit(".", 1)[-1], []).append(value)
        self._bare = {
            name: vals[0]
            if all(v == vals[0] for v in vals) else None
            for name, vals in by_bare.items()
        }

    def resolve(self, bare: str, qual: Optional[str] = None):
        if qual is not None and qual in self.table:
            return self.table[qual]
        return self._bare.get(bare)

    def digest(self) -> str:
        lines = []
        for qual in sorted(self.table):
            value = self.table[qual]
            if isinstance(value, tuple):
                rendered = ",".join(
                    u.render() if u else "?" for u in value
                )
            else:
                rendered = value.render() if value else "?"
            lines.append(f"{qual}={rendered}")
        return hashlib.sha256("\n".join(lines).encode()).hexdigest()


def _collect_functions(tree: ast.Module):
    """All (qualname, node, class_name) triples, nested defs included
    (their qualname is dotted through the enclosing function)."""
    out: List[Tuple[str, ast.AST, Optional[str]]] = []

    def visit(body, prefix: str, cls: Optional[str]) -> None:
        for node in body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}{node.name}"
                out.append((qual, node, cls))
                visit(node.body, f"{qual}.", None)
            elif isinstance(node, ast.ClassDef):
                visit(node.body, f"{node.name}.", node.name)

    visit(tree.body, "", None)
    return out


class _Ctx:
    def __init__(self, path: str, summaries: Summaries,
                 emit_enabled: bool = True) -> None:
        self.path = path
        self.summaries = summaries
        self.emit_enabled = emit_enabled
        self.findings: List[Finding] = []
        self._seen: set = set()

    def emit(self, line: int, code: str, message: str) -> None:
        if not self.emit_enabled:
            return
        key = (line, code, message)
        if key in self._seen:
            return
        self._seen.add(key)
        self.findings.append(Finding(self.path, line, code, message))


class _FuncFlow:
    """Abstract interpretation of one function (or the module body)."""

    def __init__(self, ctx: _Ctx, node, cls: Optional[str],
                 qual: Optional[str]) -> None:
        self.ctx = ctx
        self.node = node
        self.cls = cls
        self.qual = qual
        self.returns: List[Val] = []

    # -- entry points ------------------------------------------------------

    def run(self) -> List[Val]:
        env = self._param_env()
        # two passes stabilize loop-carried units; findings dedup in ctx
        self._exec_block(self.node.body, env)
        self._exec_block(self.node.body, self._param_env())
        return self.returns

    def run_module(self) -> None:
        env: Dict[str, Val] = {}
        body = [
            n for n in self.node.body
            if not isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef))
        ]
        self._exec_block(body, env)

    def _param_env(self) -> Dict[str, Val]:
        env: Dict[str, Val] = {}
        entry = SEED_FUNCS.get(self.qual or "") or SEED_FUNCS.get(
            getattr(self.node, "name", "") or ""
        )
        params = (entry or {}).get("params", {})
        args = self.node.args
        for a in (*args.posonlyargs, *args.args, *args.kwonlyargs):
            unit = params.get(a.arg)
            if unit is None:
                unit = lookup_name(a.arg)
            if unit is not None:
                env[a.arg] = Val(unit)
        return env

    # -- statements --------------------------------------------------------

    def _exec_block(self, body, env: Dict[str, Val]) -> None:
        for stmt in body:
            self._exec(stmt, env)

    def _exec(self, stmt, env: Dict[str, Val]) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return  # analyzed via their own _FuncFlow
        if isinstance(stmt, ast.Return):
            if stmt.value is not None:
                self.returns.append(self._eval(stmt.value, env))
            return
        if isinstance(stmt, ast.Assign):
            val = self._eval(stmt.value, env)
            for target in stmt.targets:
                self._assign(target, val, stmt.value, env)
            return
        if isinstance(stmt, ast.AnnAssign):
            if stmt.value is not None:
                val = self._eval(stmt.value, env)
                self._assign(stmt.target, val, stmt.value, env)
            return
        if isinstance(stmt, ast.AugAssign):
            self._aug_assign(stmt, env)
            return
        if isinstance(stmt, ast.Expr):
            self._eval(stmt.value, env)
            return
        if isinstance(stmt, ast.If):
            self._eval(stmt.test, env)
            then_env = dict(env)
            self._exec_block(stmt.body, then_env)
            else_env = dict(env)
            self._exec_block(stmt.orelse, else_env)
            merged = self._merge(then_env, else_env)
            env.clear()
            env.update(merged)
            return
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            itv = self._eval(stmt.iter, env)
            loop_env = dict(env)
            # the element of a united container shares its unit
            self._assign(stmt.target, Val(itv.unit), None, loop_env)
            self._exec_block(stmt.body, loop_env)
            self._exec_block(stmt.body, loop_env)
            merged = self._merge(env, loop_env)
            env.clear()
            env.update(merged)
            self._exec_block(stmt.orelse, env)
            return
        if isinstance(stmt, ast.While):
            self._eval(stmt.test, env)
            loop_env = dict(env)
            self._exec_block(stmt.body, loop_env)
            self._exec_block(stmt.body, loop_env)
            merged = self._merge(env, loop_env)
            env.clear()
            env.update(merged)
            self._exec_block(stmt.orelse, env)
            return
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self._eval(item.context_expr, env)
                if item.optional_vars is not None:
                    self._assign(item.optional_vars, UNKNOWN, None, env)
            self._exec_block(stmt.body, env)
            return
        if isinstance(stmt, ast.Try):
            self._exec_block(stmt.body, env)
            for handler in stmt.handlers:
                self._exec_block(handler.body, env)
            self._exec_block(stmt.orelse, env)
            self._exec_block(stmt.finalbody, env)
            return
        if isinstance(stmt, (ast.Raise, ast.Assert)):
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.expr):
                    self._eval(child, env)
            return
        if isinstance(stmt, ast.Delete):
            for t in stmt.targets:
                if isinstance(t, ast.Name):
                    env.pop(t.id, None)
            return
        # match statements, global/nonlocal, pass, imports, ...
        for child in ast.iter_child_nodes(stmt):
            if isinstance(child, ast.expr):
                self._eval(child, env)
            elif isinstance(child, ast.stmt):
                self._exec(child, env)
            elif isinstance(child, (ast.match_case,)):
                case_env = dict(env)
                self._exec_block(child.body, case_env)
                merged = self._merge(env, case_env)
                env.clear()
                env.update(merged)

    @staticmethod
    def _merge(env_a: Dict[str, Val], env_b: Dict[str, Val]):
        out: Dict[str, Val] = {}
        for name in sorted(env_a.keys() & env_b.keys()):
            va, vb = env_a[name], env_b[name]
            if va.unit is not None and va.unit == vb.unit:
                out[name] = Val(va.unit)
            elif va.unit is not None and vb.unit is not None:
                out[name] = Val(mixed=frozenset((va.unit, vb.unit)))
            elif va.mixed or vb.mixed:
                both = (va.mixed or frozenset()) | (vb.mixed or frozenset())
                for v in (va, vb):
                    if v.unit is not None:
                        both = both | {v.unit}
                out[name] = Val(mixed=both)
            elif va.literal and vb.literal:
                out[name] = Val(literal=True)
            elif va.unit is not None or vb.unit is not None:
                unit = va.unit if va.unit is not None else vb.unit
                other = vb if va.unit is not None else va
                # literal on the other path adopts; unknown stays unknown
                out[name] = Val(unit) if other.literal else UNKNOWN
        return out

    # -- assignments -------------------------------------------------------

    def _assign(self, target, val: Val, rhs, env: Dict[str, Val]) -> None:
        if isinstance(target, ast.Name):
            declared = lookup_name(target.id)
            self._check_store(target.id, declared, val, rhs,
                              target.lineno)
            if declared is not None:
                env[target.id] = Val(declared)
            else:
                env[target.id] = val
            return
        if isinstance(target, ast.Attribute):
            declared = lookup_name(target.attr)
            self._check_store(target.attr, declared, val, rhs,
                              target.lineno)
            if target.attr in BILLING_ATTRS and rhs is not None:
                self._flag_additive_literals(rhs, target.attr)
            return
        if isinstance(target, (ast.Tuple, ast.List)):
            parts = None
            if val.tup is not None and len(val.tup) == len(target.elts):
                parts = [Val(u) for u in val.tup]
            for i, elt in enumerate(target.elts):
                self._assign(elt, parts[i] if parts else UNKNOWN,
                             None, env)
            return
        if isinstance(target, ast.Starred):
            self._assign(target.value, UNKNOWN, None, env)
            return
        if isinstance(target, ast.Subscript):
            self._eval(target.value, env)
            self._eval(target.slice, env)

    def _check_store(self, name: str, declared: Optional[Unit],
                     val: Val, rhs, line: int) -> None:
        if declared is None:
            return
        kind = "expression"
        if isinstance(rhs, ast.BinOp):
            if isinstance(rhs.op, ast.Mult):
                kind = "product"
            elif isinstance(rhs.op, (ast.Div, ast.FloorDiv)):
                kind = "quotient"
        if val.mixed:
            self.ctx.emit(
                line, "RL102",
                f"'{name}' is suffixed {declared.render()} but holds "
                f"mixed units across branches "
                f"({_render_mixed(val.mixed)}); rename or unify",
            )
            return
        if val.concrete and not val.literal and val.unit != declared:
            self.ctx.emit(
                line, "RL102",
                f"{kind} of unit {val.unit.render()} assigned to "
                f"'{name}', whose name declares {declared.render()}",
            )

    def _aug_assign(self, stmt: ast.AugAssign, env: Dict[str, Val]) -> None:
        target = stmt.target
        if isinstance(target, ast.Name):
            cur = env.get(target.id)
            if cur is None:
                unit = lookup_name(target.id)
                cur = Val(unit) if unit else UNKNOWN
        elif isinstance(target, ast.Attribute):
            unit = lookup_name(target.attr)
            cur = Val(unit) if unit else UNKNOWN
        else:
            cur = UNKNOWN
        rv = self._eval(stmt.value, env)
        opname = type(stmt.op).__name__
        if isinstance(stmt.op, (ast.Add, ast.Sub)):
            result = self._combine_add(cur, rv, stmt.lineno, opname)
            if isinstance(target, ast.Attribute) and \
                    target.attr in BILLING_ATTRS:
                self._flag_additive_literals(stmt.value, target.attr)
        elif isinstance(stmt.op, ast.Mult):
            result = self._combine_mul(cur, rv)
        elif isinstance(stmt.op, (ast.Div, ast.FloorDiv)):
            result = self._combine_div(cur, rv)
        else:
            result = UNKNOWN
        if isinstance(target, ast.Name):
            declared = lookup_name(target.id)
            self._check_store(target.id, declared, result, stmt, stmt.lineno)
            env[target.id] = Val(declared) if declared else result
        elif isinstance(target, ast.Attribute):
            declared = lookup_name(target.attr)
            self._check_store(target.attr, declared, result, stmt,
                              stmt.lineno)

    # -- expressions -------------------------------------------------------

    def _eval(self, node, env: Dict[str, Val]) -> Val:
        if isinstance(node, ast.Constant):
            if isinstance(node.value, (int, float)) and not isinstance(
                node.value, bool
            ):
                return Val(literal=True)
            return UNKNOWN
        if isinstance(node, ast.Name):
            if node.id in env:
                return env[node.id]
            unit = lookup_name(node.id)
            return Val(unit) if unit else UNKNOWN
        if isinstance(node, ast.Attribute):
            self._eval(node.value, env)
            unit = lookup_name(node.attr)
            return Val(unit) if unit else UNKNOWN
        if isinstance(node, ast.BinOp):
            return self._eval_binop(node, env)
        if isinstance(node, ast.UnaryOp):
            v = self._eval(node.operand, env)
            if isinstance(node.op, (ast.UAdd, ast.USub)):
                return v
            return UNKNOWN
        if isinstance(node, ast.Compare):
            return self._eval_compare(node, env)
        if isinstance(node, ast.Call):
            return self._eval_call(node, env)
        if isinstance(node, ast.IfExp):
            self._eval(node.test, env)
            a = self._eval(node.body, env)
            b = self._eval(node.orelse, env)
            if a.unit is not None and a.unit == b.unit:
                return Val(a.unit)
            if a.unit is not None and (b.literal or b.unit is None):
                return Val(a.unit) if b.literal else UNKNOWN
            if b.unit is not None and a.literal:
                return Val(b.unit)
            return UNKNOWN
        if isinstance(node, ast.Tuple):
            vals = [self._eval(e, env) for e in node.elts]
            return Val(tup=tuple(v.unit for v in vals))
        if isinstance(node, ast.Subscript):
            v = self._eval(node.value, env)
            self._eval(node.slice, env)
            if v.tup is not None and isinstance(node.slice, ast.Constant) \
                    and isinstance(node.slice.value, int) \
                    and -len(v.tup) <= node.slice.value < len(v.tup):
                elem = v.tup[node.slice.value]
                return Val(elem) if elem is not None else UNKNOWN
            # an element of a united container carries the same unit
            return Val(v.unit) if v.unit is not None else UNKNOWN
        if isinstance(node, ast.BoolOp):
            for v in node.values:
                self._eval(v, env)
            return UNKNOWN
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                             ast.DictComp)):
            comp_env = dict(env)
            for gen in node.generators:
                self._eval(gen.iter, comp_env)
                self._assign(gen.target, UNKNOWN, None, comp_env)
                for cond in gen.ifs:
                    self._eval(cond, comp_env)
            if isinstance(node, ast.DictComp):
                self._eval(node.key, comp_env)
                self._eval(node.value, comp_env)
            else:
                self._eval(node.elt, comp_env)
            return UNKNOWN
        if isinstance(node, ast.Lambda):
            return UNKNOWN
        if isinstance(node, (ast.NamedExpr,)):
            v = self._eval(node.value, env)
            self._assign(node.target, v, node.value, env)
            return v
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.expr):
                self._eval(child, env)
        return UNKNOWN

    def _eval_binop(self, node: ast.BinOp, env) -> Val:
        lv = self._eval(node.left, env)
        rv = self._eval(node.right, env)
        if isinstance(node.op, (ast.Add, ast.Sub)):
            return self._combine_add(lv, rv, node.lineno,
                                     type(node.op).__name__)
        if isinstance(node.op, ast.Mult):
            return self._combine_mul(lv, rv)
        if isinstance(node.op, (ast.Div, ast.FloorDiv)):
            return self._combine_div(lv, rv)
        if isinstance(node.op, ast.Mod):
            return Val(lv.unit) if lv.concrete else rv
        if isinstance(node.op, ast.Pow):
            if lv.concrete and isinstance(node.right, ast.Constant) \
                    and isinstance(node.right.value, int):
                return Val(lv.unit ** node.right.value)
            if lv.concrete and lv.unit == DIMENSIONLESS:
                return Val(DIMENSIONLESS)
            return UNKNOWN
        return UNKNOWN

    def _combine_add(self, lv: Val, rv: Val, line: int,
                     opname: str) -> Val:
        op = {"Add": "+", "Sub": "-"}.get(opname, opname)
        for v, other in ((lv, rv), (rv, lv)):
            if v.mixed and (other.concrete or other.mixed):
                self.ctx.emit(
                    line, "RL101",
                    f"operand of '{op}' holds mixed units across "
                    f"branches ({_render_mixed(v.mixed)})",
                )
                return UNKNOWN
        if lv.concrete and rv.concrete and lv.unit != rv.unit:
            self.ctx.emit(
                line, "RL101",
                f"'{op}' mixes {lv.unit.render()} and "
                f"{rv.unit.render()}",
            )
            return UNKNOWN
        if lv.concrete:
            return Val(lv.unit)
        if rv.concrete:
            return Val(rv.unit)
        if lv.literal and rv.literal:
            return Val(literal=True)
        return UNKNOWN

    @staticmethod
    def _combine_mul(lv: Val, rv: Val) -> Val:
        if lv.literal and rv.literal:
            return Val(literal=True)
        if lv.literal and rv.concrete:
            return Val(rv.unit)
        if rv.literal and lv.concrete:
            return Val(lv.unit)
        if lv.concrete and rv.concrete:
            return Val(lv.unit * rv.unit)
        return UNKNOWN

    @staticmethod
    def _combine_div(lv: Val, rv: Val) -> Val:
        if lv.literal and rv.literal:
            return Val(literal=True)
        if lv.concrete and rv.literal:
            return Val(lv.unit)
        if lv.literal and rv.concrete:
            return Val(DIMENSIONLESS / rv.unit)
        if lv.concrete and rv.concrete:
            return Val(lv.unit / rv.unit)
        return UNKNOWN

    def _eval_compare(self, node: ast.Compare, env) -> Val:
        vals = [self._eval(node.left, env)]
        for comp in node.comparators:
            vals.append(self._eval(comp, env))
        for (a, b), op in zip(zip(vals, vals[1:]), node.ops):
            if isinstance(op, (ast.In, ast.NotIn, ast.Is, ast.IsNot,
                               ast.Eq, ast.NotEq)):
                continue
            sym = {"Lt": "<", "LtE": "<=", "Gt": ">",
                   "GtE": ">="}.get(type(op).__name__, "cmp")
            for v, other in ((a, b), (b, a)):
                if v.mixed and (other.concrete or other.mixed):
                    self.ctx.emit(
                        node.lineno, "RL101",
                        f"operand of '{sym}' holds mixed units across "
                        f"branches ({_render_mixed(v.mixed)})",
                    )
            if a.concrete and b.concrete and a.unit != b.unit:
                self.ctx.emit(
                    node.lineno, "RL101",
                    f"'{sym}' compares {a.unit.render()} with "
                    f"{b.unit.render()}",
                )
        return UNKNOWN

    # -- calls -------------------------------------------------------------

    def _call_name(self, func) -> Tuple[Optional[str], Optional[str]]:
        if isinstance(func, ast.Name):
            return func.id, func.id
        if isinstance(func, ast.Attribute):
            qual = None
            if isinstance(func.value, ast.Name) and func.value.id == "self" \
                    and self.cls:
                qual = f"{self.cls}.{func.attr}"
            return func.attr, qual
        return None, None

    def _eval_call(self, node: ast.Call, env) -> Val:
        bare, qual = self._call_name(node.func)
        if not isinstance(node.func, ast.Name):
            self._eval(node.func, env)
        arg_vals = [self._eval(a, env) for a in node.args
                    if not isinstance(a, ast.Starred)]
        kw_vals = {kw.arg: self._eval(kw.value, env)
                   for kw in node.keywords if kw.arg is not None}
        for kw in node.keywords:
            if kw.arg is None:
                self._eval(kw.value, env)

        if bare in _EXTREMUM_CALLS and len(node.args) >= 2:
            result = UNKNOWN
            for i, v in enumerate(arg_vals[1:], start=1):
                prev = arg_vals[i - 1]
                if prev.concrete and v.concrete and prev.unit != v.unit:
                    self.ctx.emit(
                        node.lineno, "RL101",
                        f"'{bare}' mixes {prev.unit.render()} and "
                        f"{v.unit.render()}",
                    )
            for v in arg_vals:
                if v.concrete:
                    result = Val(v.unit)
                    break
            else:
                if arg_vals and all(v.literal for v in arg_vals):
                    result = Val(literal=True)
            return result
        if bare in _PASSTHROUGH_CALLS and arg_vals:
            return arg_vals[0]
        if bare == "len":
            return Val(DIMENSIONLESS)
        if bare in ("sum", "fsum") and node.args and isinstance(
            node.args[0], (ast.GeneratorExp, ast.ListComp)
        ):
            # a sum over a comprehension carries its element's unit
            comp = node.args[0]
            comp_env = dict(env)
            for gen in comp.generators:
                self._assign(gen.target, UNKNOWN, None, comp_env)
            elt = self._eval(comp.elt, comp_env)
            return Val(elt.unit) if elt.concrete else UNKNOWN
        if bare == "sum":
            return UNKNOWN

        entry = None
        if qual is not None and qual in SEED_FUNCS:
            entry = SEED_FUNCS[qual]
        elif bare is not None and bare in SEED_FUNCS:
            entry = SEED_FUNCS[bare]
        elif bare is not None:
            dotted = [v for k, v in sorted(SEED_FUNCS.items())
                      if k.endswith(f".{bare}")]
            if len(dotted) == 1:
                entry = dotted[0]

        seen_params: set = set()
        if entry is not None:
            params: Dict[str, Unit] = entry.get("params", {})
            order: List[str] = entry.get("order", [])
            sink = bool(entry.get("billing_sink"))
            bound: List[Tuple[str, Val, ast.AST]] = []
            for i, a in enumerate(node.args):
                if isinstance(a, ast.Starred) or i >= len(order):
                    continue
                bound.append((order[i], arg_vals[i], a))
            for kw in node.keywords:
                if kw.arg is not None and kw.arg in params:
                    bound.append((kw.arg, kw_vals[kw.arg], kw.value))
            for pname, v, arg_node in bound:
                want = params.get(pname)
                if want is None:
                    continue
                seen_params.add(pname)
                if v.concrete and not v.literal and v.unit != want:
                    self.ctx.emit(
                        arg_node.lineno, "RL101",
                        f"argument '{pname}' of {bare} expects "
                        f"{want.render()}, got {v.unit.render()}",
                    )
                if v.mixed:
                    self.ctx.emit(
                        arg_node.lineno, "RL101",
                        f"argument '{pname}' of {bare} holds mixed "
                        f"units across branches "
                        f"({_render_mixed(v.mixed)})",
                    )
                if sink and want is not None and (
                    "usd" in dict(want.dims) or want == CHIP_S
                ):
                    self._flag_additive_literals(arg_node,
                                                 f"{bare}({pname}=...)")

        # kwargs whose NAME declares a unit are checked on every call
        for kw in node.keywords:
            if kw.arg is None or kw.arg in seen_params:
                continue
            want = lookup_name(kw.arg)
            if want is None:
                continue
            v = kw_vals[kw.arg]
            if v.concrete and not v.literal and v.unit != want:
                self.ctx.emit(
                    kw.value.lineno, "RL101",
                    f"keyword '{kw.arg}' declares {want.render()}, "
                    f"got {v.unit.render()}",
                )
            if v.mixed:
                self.ctx.emit(
                    kw.value.lineno, "RL101",
                    f"keyword '{kw.arg}' holds mixed units across "
                    f"branches ({_render_mixed(v.mixed)})",
                )

        if entry is not None:
            ret = entry.get("return")
            if ret is not None:
                return Val(ret)
            return UNKNOWN
        if bare is not None:
            summary = self.ctx.summaries.resolve(bare, qual)
            if isinstance(summary, Unit):
                return Val(summary)
            if isinstance(summary, tuple):
                return Val(tup=summary)
        return UNKNOWN

    def _flag_additive_literals(self, node, sink: str) -> None:
        for line, value in _additive_literals(node):
            self.ctx.emit(
                line, "RL103",
                f"numeric literal {value!r} flows into billing sink "
                f"'{sink}' in an additive position; bind it to a "
                f"unit-suffixed name first",
            )


def _additive_literals(node):
    """Non-zero numeric literals in additive positions of ``node`` —
    direct value, ``+``/``-`` operands, min/max/abs arguments, ternary
    branches. Multiplicative factors (``* 1.5``, ``/ 3600.0``) are
    conversion constants and stay out."""
    if isinstance(node, ast.Constant):
        if isinstance(node.value, (int, float)) and not isinstance(
            node.value, bool
        ) and node.value != 0:
            yield node.lineno, node.value
        return
    if isinstance(node, ast.BinOp) and isinstance(
        node.op, (ast.Add, ast.Sub)
    ):
        yield from _additive_literals(node.left)
        yield from _additive_literals(node.right)
        return
    if isinstance(node, ast.UnaryOp) and isinstance(
        node.op, (ast.UAdd, ast.USub)
    ):
        yield from _additive_literals(node.operand)
        return
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("min", "max", "abs"):
        for a in node.args:
            yield from _additive_literals(a)
        return
    if isinstance(node, ast.IfExp):
        yield from _additive_literals(node.body)
        yield from _additive_literals(node.orelse)


# --- interprocedural summaries --------------------------------------------

def _summary_of(returns: List[Val], name: str):
    units = {v.unit for v in returns if v.unit is not None}
    tups = {v.tup for v in returns if v.tup is not None}
    if len(units) == 1 and not tups:
        return next(iter(units))
    if len(tups) == 1 and not units:
        return next(iter(tups))
    # the function's own name-suffix is the fallback annotation
    return unit_from_name(name)


def compute_summaries(trees, base: Optional[Dict[str, object]] = None,
                      max_iter: int = 12) -> Dict[str, object]:
    """Fixed point of per-function return-unit summaries over the call
    graph spanned by ``trees`` (an iterable of ast.Module)."""
    funcs = []
    for tree in trees:
        funcs.extend(_collect_functions(tree))
    table: Dict[str, object] = dict(base or {})
    for _ in range(max_iter):
        changed = False
        summaries = Summaries(table)
        for qual, node, cls in funcs:
            ctx = _Ctx("<summary>", summaries, emit_enabled=False)
            flow = _FuncFlow(ctx, node, cls, qual)
            value = _summary_of(flow.run(), node.name)
            if table.get(qual, "∅") != value:
                table[qual] = value
                changed = True
        if not changed:
            break
    return table


# --- project-level summary index ------------------------------------------

_PROJECT_ROOT: Optional[Path] = None
_INDEX_CACHE: Dict[tuple, Tuple[Dict[str, object], str]] = {}


def set_project_root(root: Optional[Path]) -> None:
    """Attach (or detach, with None) the repo root whose ``core/`` +
    ``launch/`` call graph feeds cross-module summaries."""
    global _PROJECT_ROOT
    _PROJECT_ROOT = Path(root) if root is not None else None


def reset_project_cache() -> None:
    _INDEX_CACHE.clear()


def _project_files() -> List[Path]:
    if _PROJECT_ROOT is None:
        return []
    out: List[Path] = []
    for scope in SUMMARY_SCOPE:
        d = _PROJECT_ROOT / scope
        if d.is_dir():
            out.extend(sorted(d.rglob("*.py")))
    return [p for p in out if "__pycache__" not in p.parts]


def project_summaries() -> Tuple[Dict[str, object], str]:
    """(summary table, digest) for the attached project root; empty
    when detached. Cached per (root, file stats) so repeated lints in
    one process parse the project once."""
    files = _project_files()
    if not files:
        return {}, ""
    key_parts = []
    for p in files:
        st = p.stat()
        key_parts.append((str(p), st.st_mtime_ns, st.st_size))
    key = (str(_PROJECT_ROOT), tuple(key_parts))
    hit = _INDEX_CACHE.get(key)
    if hit is not None:
        return hit
    trees = []
    for p in files:
        try:
            trees.append(ast.parse(p.read_text()))
        except SyntaxError:
            continue  # RL000 reports it; summaries just skip the file
    table = compute_summaries(trees)
    digest = Summaries(table).digest()
    _INDEX_CACHE.clear()
    _INDEX_CACHE[key] = (table, digest)
    return table, digest


# --- the rule objects ------------------------------------------------------

def unit_findings(tree: ast.Module, path: str) -> List[Finding]:
    """All RL101/RL102/RL103 findings for one module, memoized on the
    tree (the three rule objects share one analysis)."""
    cached = getattr(tree, "_reprolint_unit_findings", None)
    if cached is not None:
        return cached
    base, _digest = project_summaries()
    local = compute_summaries([tree], base=base)
    summaries = Summaries({**base, **local})
    ctx = _Ctx(path, summaries)
    for qual, node, cls in _collect_functions(tree):
        returns = _FuncFlow(ctx, node, cls, qual).run()
        # a function whose NAME declares a unit must return it — this
        # is how 'predicted_backlog_s returning chip-seconds' surfaces
        declared = unit_from_name(node.name)
        units = {v.unit for v in returns if v.unit is not None}
        if declared is not None and len(units) == 1:
            got = next(iter(units))
            if got != declared:
                ctx.emit(
                    node.lineno, "RL102",
                    f"function '{node.name}' is suffixed "
                    f"{declared.render()} but returns {got.render()}",
                )
    _FuncFlow(ctx, tree, None, None).run_module()
    findings = sorted(ctx.findings, key=lambda f: (f.line, f.code))
    tree._reprolint_unit_findings = findings
    return findings


class _UnitRule:
    """Shared shape for the three unit rules; each filters one code
    out of the shared analysis so per-code suppressions keep working."""

    code = ""
    title = ""

    def applies(self, path: str) -> bool:
        return path.startswith(CORE)

    def check(self, tree: ast.Module, path: str) -> List[Finding]:
        return [f for f in unit_findings(tree, path) if f.code == self.code]


class UnitMismatch(_UnitRule):
    """RL101 — unit-mismatched ``+``/``-``/comparisons (the PR-4
    initial-context decode pricing class: tokens added to
    chip-seconds)."""

    code = "RL101"
    title = "unit-mismatched additive/comparison operands"


class UnitAssignment(_UnitRule):
    """RL102 — a wrong-dimension product/quotient assigned to a
    unit-suffixed name (the PR-2 pool-chips-vs-slice-chips class and
    the PR-5 fused-split class)."""

    code = "RL102"
    title = "wrong-dimension expression assigned to unit-suffixed name"


class UnitLiteral(_UnitRule):
    """RL103 — an unannotated numeric literal flowing additively into
    a billing sink (the PR-3 billed-compile-seconds class)."""

    code = "RL103"
    title = "raw numeric literal flows into a billing sink"
