"""The five repo-native rules. Each encodes a bug class this repo has
shipped and fixed; docs/static-analysis.md carries the full catalog with
the historical incident behind every rule.

Rules are plain objects with ``code``, ``applies(path)`` (repo-relative
posix path scoping) and ``check(tree, path) -> list[Finding]``. All
analysis is stdlib ``ast`` — no imports of the code under lint.
"""
from __future__ import annotations

import ast
import re
from typing import Iterable, Optional

from . import Finding

CORE = "src/repro/core/"
#: wall-clock / global-RNG scope: core, the launch scripts (their timing
#: numbers feed calibration records), and the sharded sweep harness
WALLCLOCK_SCOPE = (CORE, "src/repro/launch/", "benchmarks/sweep.py")
#: float-summation / set-iteration scope: where bit-identical replay is
#: a contract (docs/sweeps.md)
DETERMINISM_SCOPE = (CORE, "benchmarks/sweep.py")
#: RL005: whole-module slots/identity discipline
HOT_MODULES = ("src/repro/core/query.py",)
#: RL005: named hot-path classes checked wherever they live in core/
HOT_CLASSES = {"_Run", "WaitingQueue", "PendingQueue", "StageEvent"}

_CACHE_NAME_RE = re.compile(r"cache|memo", re.IGNORECASE)
_VERSION_TOKEN_RE = re.compile(r"version|epoch|\bver\b", re.IGNORECASE)
_NP_RANDOM_OK = {
    "default_rng", "Generator", "SeedSequence", "BitGenerator",
    "PCG64", "Philox",
}


def _in(path: str, prefixes: Iterable[str]) -> bool:
    return any(
        path == p or (p.endswith("/") and path.startswith(p))
        for p in prefixes
    )


def _self_attr(node: ast.AST) -> Optional[str]:
    """'x' when node is ``self.x``, else None."""
    if (
        isinstance(node, ast.Attribute)
        and isinstance(node.value, ast.Name)
        and node.value.id == "self"
    ):
        return node.attr
    return None


# ---------------------------------------------------------------------------
# RL001 — lock discipline (the PR-3 ``_vm_busy`` data race)
# ---------------------------------------------------------------------------

class LockDiscipline:
    """Attributes a class declares in its ``_GUARDED_BY`` registry may
    only be touched (via ``self``) inside a ``with self.<lock>`` block
    naming one of the declared locks, or inside a ``*_locked``-suffixed
    method (whose callers the runtime sanitizer covers —
    ``repro.core.sanitize`` reads the SAME registry). ``__init__`` /
    ``__post_init__`` are exempt: state is built before threads exist.
    Nested functions and lambdas are analyzed with NO locks held — they
    run later, outside the enclosing critical section (exactly how the
    old engine's futures dropped the lock the submitter held)."""

    code = "RL001"
    title = "guarded attribute accessed outside its lock"

    def applies(self, path: str) -> bool:
        return path.endswith(".py")

    def check(self, tree: ast.Module, path: str) -> list[Finding]:
        findings: list[Finding] = []
        classes = {
            n.name: n for n in tree.body if isinstance(n, ast.ClassDef)
        }
        registries: dict[str, dict[str, tuple[str, ...]]] = {}

        def registry_of(name: str) -> dict[str, tuple[str, ...]]:
            if name in registries:
                return registries[name]
            node = classes.get(name)
            merged: dict[str, tuple[str, ...]] = {}
            if node is not None:
                for base in node.bases:  # same-module bases inherit
                    if isinstance(base, ast.Name) and base.id in classes:
                        merged.update(registry_of(base.id))
                merged.update(_parse_registry(node))
            registries[name] = merged
            return merged

        for name, node in classes.items():
            reg = registry_of(name)
            if not reg:
                continue
            for stmt in node.body:
                if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    self._check_method(stmt, reg, path, findings)
        return findings

    def _check_method(
        self,
        fn: ast.AST,
        reg: dict[str, tuple[str, ...]],
        path: str,
        findings: list[Finding],
    ) -> None:
        if fn.name in ("__init__", "__post_init__") or fn.name.endswith(
            "_locked"
        ):
            return
        self._walk(fn.body, frozenset(), reg, path, findings)

    def _walk(self, nodes, held, reg, path, findings) -> None:
        for node in (nodes if isinstance(nodes, list) else [nodes]):
            attr = _self_attr(node)
            if attr is not None and attr in reg:
                if not (held & set(reg[attr])):
                    findings.append(Finding(
                        path, node.lineno, self.code,
                        f"'self.{attr}' is declared guarded by "
                        f"{'/'.join(reg[attr])} but accessed outside a "
                        f"'with self.<lock>' block (and not in a "
                        f"'*_locked' method)",
                    ))
                continue  # self.<attr> has no interesting children
            if isinstance(node, ast.With):
                acquired = set()
                for item in node.items:
                    a = _self_attr(item.context_expr)
                    if a is not None:
                        acquired.add(a)
                    else:
                        self._walk(item.context_expr, held, reg, path,
                                   findings)
                self._walk(node.body, held | acquired, reg, path, findings)
                continue
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # a nested def runs later: no lock from here is held then
                self._walk(node.body, frozenset(), reg, path, findings)
                continue
            if isinstance(node, ast.Lambda):
                self._walk(node.body, frozenset(), reg, path, findings)
                continue
            for child in ast.iter_child_nodes(node):
                self._walk(child, held, reg, path, findings)


def _parse_registry(cls: ast.ClassDef) -> dict[str, tuple[str, ...]]:
    """The class's literal ``_GUARDED_BY = {"attr": "lock" | ("l1",
    "l2")}`` dict, empty when absent or non-literal."""
    for stmt in cls.body:
        value = None
        if isinstance(stmt, ast.Assign) and any(
            isinstance(t, ast.Name) and t.id == "_GUARDED_BY"
            for t in stmt.targets
        ):
            value = stmt.value
        elif (
            isinstance(stmt, ast.AnnAssign)
            and isinstance(stmt.target, ast.Name)
            and stmt.target.id == "_GUARDED_BY"
        ):
            value = stmt.value
        if not isinstance(value, ast.Dict):
            continue
        reg: dict[str, tuple[str, ...]] = {}
        for k, v in zip(value.keys, value.values):
            if not (isinstance(k, ast.Constant) and isinstance(k.value, str)):
                continue
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                reg[k.value] = (v.value,)
            elif isinstance(v, (ast.Tuple, ast.List)):
                locks = tuple(
                    e.value for e in v.elts
                    if isinstance(e, ast.Constant) and isinstance(e.value, str)
                )
                if locks:
                    reg[k.value] = locks
        return reg
    return {}


# ---------------------------------------------------------------------------
# RL002 — version-keyed caches (PR-4 stale lru_cache, PR-7 unbounded memo)
# ---------------------------------------------------------------------------

class VersionKeyedCaches:
    """A dict used as a memo in core/ (name matching cache/memo) must
    show eviction or bounding evidence in its class — ``.pop`` /
    ``.popitem`` / ``.clear`` calls or a ``len(...)`` bound check — or
    key/tag entries with a version token (``*version*`` / ``*epoch*``
    in a subscript key). ``functools.cache`` and
    ``lru_cache(maxsize=None)`` are unbounded and never invalidate:
    always flagged (the PR-4 calibration bug was exactly such a cache
    outliving the data it memoized)."""

    code = "RL002"
    title = "memo without eviction bound or version key"

    def applies(self, path: str) -> bool:
        return _in(path, (CORE,))

    def check(self, tree: ast.Module, path: str) -> list[Finding]:
        findings: list[Finding] = []
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    bad = self._unbounded_decorator(dec)
                    if bad:
                        findings.append(Finding(
                            path, dec.lineno, self.code,
                            f"'{bad}' memoizes without bound or "
                            f"invalidation; use a version-keyed or "
                            f"evicting cache",
                        ))
        for scope in [tree] + [
            n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)
        ]:
            findings.extend(self._check_scope(scope, path))
        return findings

    @staticmethod
    def _unbounded_decorator(dec: ast.AST) -> Optional[str]:
        def name_of(n):
            if isinstance(n, ast.Name):
                return n.id
            if isinstance(n, ast.Attribute):
                return n.attr
            return None

        if name_of(dec) == "cache":
            return "functools.cache"
        if isinstance(dec, ast.Call) and name_of(dec.func) == "lru_cache":
            for kw in dec.keywords:
                if kw.arg == "maxsize" and (
                    isinstance(kw.value, ast.Constant)
                    and kw.value.value is None
                ):
                    return "lru_cache(maxsize=None)"
            if dec.args and (
                isinstance(dec.args[0], ast.Constant)
                and dec.args[0].value is None
            ):
                return "lru_cache(None)"
        return None

    def _check_scope(self, scope: ast.AST, path: str) -> list[Finding]:
        """Memo dicts assigned in this class (``self.<name>``) or module
        (bare ``<name>``) scope, with compliance evidence searched over
        the whole scope subtree."""
        memos: dict[str, int] = {}  # name -> first assignment line
        is_class = isinstance(scope, ast.ClassDef)
        body = scope.body if is_class else [
            n for n in scope.body if not isinstance(n, ast.ClassDef)
        ]
        container = ast.Module(body=body, type_ignores=[]) if not is_class \
            else scope
        for node in ast.walk(container):
            if not isinstance(node, (ast.Assign, ast.AnnAssign)):
                continue
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                name = _self_attr(t) if is_class else (
                    t.id if isinstance(t, ast.Name) else None
                )
                if name is None and is_class and isinstance(t, ast.Name):
                    name = t.id  # class-level default
                if (
                    name
                    and _CACHE_NAME_RE.search(name)
                    and node.value is not None
                    and self._is_dict_ctor(node.value)
                    and name not in memos
                ):
                    memos[name] = node.lineno
        out: list[Finding] = []
        for name, line in memos.items():
            if not self._has_evidence(container, name):
                out.append(Finding(
                    path, line, self.code,
                    f"memo dict '{name}' has no eviction bound "
                    f"(.pop/.popitem/.clear or len() check) and no "
                    f"version/epoch-keyed entries",
                ))
        return out

    @staticmethod
    def _is_dict_ctor(node: ast.AST) -> bool:
        if isinstance(node, (ast.Dict, ast.DictComp)):
            return True
        if isinstance(node, ast.Call):
            fn = node.func
            fname = fn.id if isinstance(fn, ast.Name) else (
                fn.attr if isinstance(fn, ast.Attribute) else None
            )
            return fname in ("dict", "OrderedDict", "defaultdict")
        return False

    @staticmethod
    def _names_memo(node: ast.AST, name: str) -> bool:
        return _self_attr(node) == name or (
            isinstance(node, ast.Name) and node.id == name
        )

    def _has_evidence(self, scope: ast.AST, name: str) -> bool:
        for node in ast.walk(scope):
            # self._memo.pop(...) / .popitem() / .clear()
            if (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in ("pop", "popitem", "clear")
                and self._names_memo(node.func.value, name)
            ):
                return True
            # len(self._memo) bound check (inside a Compare)
            if isinstance(node, ast.Compare):
                for sub in ast.walk(node):
                    if (
                        isinstance(sub, ast.Call)
                        and isinstance(sub.func, ast.Name)
                        and sub.func.id == "len"
                        and sub.args
                        and self._names_memo(sub.args[0], name)
                    ):
                        return True
            # self._memo[key-with-version-token] (read or write)
            if isinstance(node, ast.Subscript) and self._names_memo(
                node.value, name
            ):
                for sub in ast.walk(node.slice):
                    token = None
                    if isinstance(sub, ast.Attribute):
                        token = sub.attr
                    elif isinstance(sub, ast.Name):
                        token = sub.id
                    if token and _VERSION_TOKEN_RE.search(token):
                        return True
        return False


# ---------------------------------------------------------------------------
# RL003 — determinism (bit-identical replay is a contract, docs/sweeps.md)
# ---------------------------------------------------------------------------

class Determinism:
    """No wall-clock time in duration math (``time.time`` /
    ``datetime.now``; monotonic/perf_counter are fine), no global RNG
    (stdlib ``random``, ``np.random.<fn>`` module state; seeded
    ``default_rng`` / ``SeedSequence`` / ``jax.random`` are fine). In
    the bit-identity scope additionally: no ``np.sum`` over float
    arrays (pairwise-summation tree != sequential accumulation — the
    drift PR 6 engineered the cost model around) and no iteration over
    bare ``set``s (hash-order feeds heaps/fingerprints; ``sorted(...)``
    the set first)."""

    code = "RL003"
    title = "nondeterminism hazard"

    def applies(self, path: str) -> bool:
        return _in(path, WALLCLOCK_SCOPE)

    def check(self, tree: ast.Module, path: str) -> list[Finding]:
        findings: list[Finding] = []
        add = findings.append
        for node in ast.walk(tree):
            if isinstance(node, ast.Attribute):
                base = node.value
                if (
                    node.attr == "time"
                    and isinstance(base, ast.Name)
                    and base.id == "time"
                ):
                    add(Finding(
                        path, node.lineno, self.code,
                        "time.time() is wall-clock (NTP steps, DST): use "
                        "time.perf_counter()/monotonic() for durations",
                    ))
                elif node.attr in ("now", "utcnow", "today") and (
                    (isinstance(base, ast.Name) and base.id in
                     ("datetime", "date"))
                    or (isinstance(base, ast.Attribute) and base.attr in
                        ("datetime", "date"))
                ):
                    add(Finding(
                        path, node.lineno, self.code,
                        f"datetime.{node.attr}() is wall-clock; pass "
                        f"timestamps in explicitly",
                    ))
                elif (
                    isinstance(base, ast.Attribute)
                    and base.attr == "random"
                    and isinstance(base.value, ast.Name)
                    and base.value.id in ("np", "numpy")
                    and node.attr not in _NP_RANDOM_OK
                ):
                    add(Finding(
                        path, node.lineno, self.code,
                        f"np.random.{node.attr} uses process-global RNG "
                        f"state; thread a seeded np.random.Generator "
                        f"instead",
                    ))
            elif isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name == "random":
                        add(Finding(
                            path, node.lineno, self.code,
                            "stdlib 'random' is process-global state; use "
                            "np.random.default_rng(seed)",
                        ))
            elif isinstance(node, ast.ImportFrom):
                if node.module == "random":
                    add(Finding(
                        path, node.lineno, self.code,
                        "stdlib 'random' is process-global state; use "
                        "np.random.default_rng(seed)",
                    ))
        if _in(path, DETERMINISM_SCOPE):
            findings.extend(self._check_bit_identity(tree, path))
        return findings

    def _check_bit_identity(self, tree, path) -> list[Finding]:
        out: list[Finding] = []
        for node in ast.walk(tree):
            if (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "sum"
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id in ("np", "numpy")
            ):
                out.append(Finding(
                    path, node.lineno, self.code,
                    "np.sum uses pairwise summation (result depends on "
                    "array layout); accumulate sequentially or math.fsum",
                ))
        # bare-set iteration: per function scope, names bound to sets.
        # Each scope is walked WITHOUT descending into nested defs (they
        # get their own scope entry), so nothing is flagged twice.
        scopes = [tree] + [
            n for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        ]

        def walk_scope(root):
            stack = list(root.body)
            while stack:
                node = stack.pop()
                yield node
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue  # nested def: its own scope entry covers it
                stack.extend(ast.iter_child_nodes(node))

        for scope in scopes:
            set_names = set()
            for node in walk_scope(scope):
                if isinstance(node, ast.Assign) and self._is_set_expr(
                    node.value
                ):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            set_names.add(t.id)
            for node in walk_scope(scope):
                iters = []
                if isinstance(node, (ast.For, ast.AsyncFor)):
                    iters = [node.iter]
                elif isinstance(node, (ast.ListComp, ast.SetComp,
                                       ast.DictComp, ast.GeneratorExp)):
                    iters = [g.iter for g in node.generators]
                for it in iters:
                    if self._is_set_expr(it) or (
                        isinstance(it, ast.Name) and it.id in set_names
                    ):
                        out.append(Finding(
                            path, it.lineno, self.code,
                            "iterating a bare set: hash order leaks into "
                            "event/fingerprint order; sorted(...) it",
                        ))
        return out

    @staticmethod
    def _is_set_expr(node: ast.AST) -> bool:
        if isinstance(node, (ast.Set, ast.SetComp)):
            return True
        return (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name)
            and node.func.id in ("set", "frozenset")
        )


# ---------------------------------------------------------------------------
# RL004 — swallowed exceptions (the PR-3 swallowed-futures class)
# ---------------------------------------------------------------------------

class SwallowedExceptions:
    """``except Exception`` / ``except BaseException`` / bare ``except``
    in core/ must re-raise, record the failure (assign ``*.error`` or
    call a ``*fail*`` sink), or carry a reasoned disable comment. The
    live engine's worker futures once swallowed everything — queries
    just never finished."""

    code = "RL004"
    title = "broad except swallows the failure"

    _BROAD = {"Exception", "BaseException"}
    _SINKS = {"_fail", "fail", "record_error"}

    def applies(self, path: str) -> bool:
        return _in(path, (CORE,))

    def check(self, tree: ast.Module, path: str) -> list[Finding]:
        findings: list[Finding] = []
        for node in ast.walk(tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if not self._is_broad(node.type):
                continue
            if self._handles(node):
                continue
            findings.append(Finding(
                path, node.lineno, self.code,
                "broad except neither re-raises, records onto "
                "'*.error', nor calls a failure sink — the error "
                "vanishes",
            ))
        return findings

    def _is_broad(self, t: Optional[ast.AST]) -> bool:
        if t is None:
            return True  # bare except
        if isinstance(t, ast.Name):
            return t.id in self._BROAD
        if isinstance(t, ast.Attribute):
            return t.attr in self._BROAD
        if isinstance(t, ast.Tuple):
            return any(self._is_broad(e) for e in t.elts)
        return False

    def _handles(self, handler: ast.ExceptHandler) -> bool:
        for node in ast.walk(handler):
            if isinstance(node, ast.Raise):
                return True
            if isinstance(node, ast.Call):
                fn = node.func
                name = fn.id if isinstance(fn, ast.Name) else (
                    fn.attr if isinstance(fn, ast.Attribute) else None
                )
                if name in self._SINKS:
                    return True
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for t in targets:
                    if isinstance(t, ast.Attribute) and t.attr == "error":
                        return True
        return False


# ---------------------------------------------------------------------------
# RL005 — slots / identity on hot paths
# ---------------------------------------------------------------------------

class SlotsIdentity:
    """Classes in hot-path modules (``core/query.py``: a 1M-query day
    allocates a million Queries) keep ``__slots__`` — via a literal
    assignment, ``@dataclass(slots=True)``, or NamedTuple — and identity
    equality: no hand-written ``__eq__``/``__hash__`` (queries are
    billing identities, and value equality would break their use as
    dict/heap keys). The named engine queue classes are held to the
    same bar wherever they live in core/."""

    code = "RL005"
    title = "hot-path class missing slots or identity equality"

    def applies(self, path: str) -> bool:
        return _in(path, (CORE,))

    def check(self, tree: ast.Module, path: str) -> list[Finding]:
        whole_module = _in(path, HOT_MODULES)
        findings: list[Finding] = []
        for node in ast.walk(tree):
            if not isinstance(node, ast.ClassDef):
                continue
            if not (whole_module or node.name in HOT_CLASSES):
                continue
            if not self._has_slots(node):
                findings.append(Finding(
                    path, node.lineno, self.code,
                    f"hot-path class '{node.name}' has no __slots__ "
                    f"(add __slots__, @dataclass(slots=True), or "
                    f"NamedTuple)",
                ))
            for stmt in node.body:
                if isinstance(stmt, ast.FunctionDef) and stmt.name in (
                    "__eq__", "__hash__"
                ):
                    findings.append(Finding(
                        path, stmt.lineno, self.code,
                        f"hot-path class '{node.name}' overrides "
                        f"{stmt.name}: these classes are identities, "
                        f"not values",
                    ))
        return findings

    @staticmethod
    def _has_slots(node: ast.ClassDef) -> bool:
        for stmt in node.body:
            if isinstance(stmt, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "__slots__"
                for t in stmt.targets
            ):
                return True
            if (
                isinstance(stmt, ast.AnnAssign)
                and isinstance(stmt.target, ast.Name)
                and stmt.target.id == "__slots__"
            ):
                return True
        for base in node.bases:
            name = base.id if isinstance(base, ast.Name) else (
                base.attr if isinstance(base, ast.Attribute) else None
            )
            if name == "NamedTuple":
                return True
        for dec in node.decorator_list:
            if isinstance(dec, ast.Call):
                fn = dec.func
                fname = fn.id if isinstance(fn, ast.Name) else (
                    fn.attr if isinstance(fn, ast.Attribute) else None
                )
                if fname == "dataclass":
                    for kw in dec.keywords:
                        if kw.arg == "slots" and (
                            isinstance(kw.value, ast.Constant)
                            and kw.value.value is True
                        ):
                            return True
        return False


def _build_rules():
    # imported late: dataflow/lockgraph import Finding from the package
    # root and _parse_registry from here
    from .dataflow import UnitAssignment, UnitLiteral, UnitMismatch
    from .lockgraph import LockOrder

    return [
        LockDiscipline(),
        VersionKeyedCaches(),
        Determinism(),
        SwallowedExceptions(),
        SlotsIdentity(),
        LockOrder(),
        UnitMismatch(),
        UnitAssignment(),
        UnitLiteral(),
    ]


RULES = _build_rules()
