"""Unit-of-measure algebra for the RL1xx dataflow rules.

Every billing bug this repo has shipped was a *unit confusion* —
decode chunks priced at initial context (tokens vs chip-seconds),
compile seconds leaking into billed walls, pool-chips where
slice-chips belonged, fused splits that dropped the price factor. The
checker works in **dimensions**, not scaled units: hours and seconds
are both time, so ``price_per_chip_hour / 3600.0`` stays well-typed while
``billed_cs + compile_s`` does not.

Base dimensions: ``s`` (time), ``chips``, ``tokens``, ``usd``. A
:class:`Unit` is a vector of integer exponents over them —
``chip_s = chips*s``, ``usd_per_chip_s = usd/(chips*s)``,
dimensionless = the empty vector.

Units are inferred from three sources, in priority order:

1. the **suffix grammar** on snake_case names (``*_s``, ``*_cs``,
   ``*_chip_s``, ``*_usd``, ``*_tokens``, ``*_chips``,
   ``*_per_chip_s``, ``*_ratio``/``*_frac``/... -> dimensionless),
2. the **seed registry** below: known attribute names and known
   callable signatures (``CostModel.plan``, ``account_stage``,
   ``Quote``, ``unpack_fused``, ``price_menu``, calibration EWMAs),
3. interprocedural **function summaries** computed by
   ``tools.reprolint.dataflow`` as a fixed point over the call graph
   of ``core/`` + ``launch/``.
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional, Tuple


class Unit:
    """An immutable dimension-exponent vector, e.g. chips^1 * s^1."""

    __slots__ = ("dims",)

    def __init__(self, dims: Iterable[Tuple[str, int]] = ()) -> None:
        object.__setattr__(
            self, "dims",
            tuple(sorted((d, e) for d, e in dims if e != 0)),
        )

    def __setattr__(self, name, value):  # pragma: no cover - immutability
        raise AttributeError("Unit is immutable")

    def __eq__(self, other) -> bool:
        return isinstance(other, Unit) and self.dims == other.dims

    def __hash__(self) -> int:
        return hash(self.dims)

    def __mul__(self, other: "Unit") -> "Unit":
        d = dict(self.dims)
        for dim, exp in other.dims:
            d[dim] = d.get(dim, 0) + exp
        return Unit(d.items())

    def __truediv__(self, other: "Unit") -> "Unit":
        d = dict(self.dims)
        for dim, exp in other.dims:
            d[dim] = d.get(dim, 0) - exp
        return Unit(d.items())

    def __pow__(self, n: int) -> "Unit":
        return Unit((d, e * n) for d, e in self.dims)

    @property
    def dimensionless(self) -> bool:
        return not self.dims

    def __repr__(self) -> str:
        return f"Unit({self.render()})"

    def render(self) -> str:
        """Human name: the repo's canonical spelling where one exists."""
        canon = _CANONICAL.get(self.dims)
        if canon is not None:
            return canon
        num = [d if e == 1 else f"{d}^{e}" for d, e in self.dims if e > 0]
        den = [d if e == -1 else f"{d}^{-e}" for d, e in self.dims if e < 0]
        if not num:
            num = ["1"]
        out = "*".join(num)
        if den:
            out += "/" + "/".join(den)
        return out


DIMENSIONLESS = Unit()
S = Unit([("s", 1)])
CHIPS = Unit([("chips", 1)])
TOKENS = Unit([("tokens", 1)])
USD = Unit([("usd", 1)])
CHIP_S = CHIPS * S
USD_PER_CHIP_S = USD / CHIP_S
TOKENS_PER_CHIP = TOKENS / CHIPS

_CANONICAL = {
    DIMENSIONLESS.dims: "dimensionless",
    S.dims: "s",
    CHIPS.dims: "chips",
    TOKENS.dims: "tokens",
    USD.dims: "usd",
    CHIP_S.dims: "chip_s",
    USD_PER_CHIP_S.dims: "usd_per_chip_s",
    (USD / S).dims: "usd/s",
    (TOKENS / S).dims: "tokens/s",
    (CHIPS * S * S).dims: "chip_s*s",
}


# --- the suffix grammar ----------------------------------------------------

#: one snake_case token -> base unit.  Plural words like ``pools`` or
#: ``stages`` never match: the token must BE a unit word.
_ATOMS: Dict[str, Unit] = {
    "s": S, "sec": S, "secs": S, "second": S, "seconds": S,
    "hour": S, "hours": S, "hr": S, "hrs": S, "ms": S, "time": S,
    "chip": CHIPS, "chips": CHIPS,
    "cs": CHIP_S,
    "tok": TOKENS, "token": TOKENS, "tokens": TOKENS,
    "usd": USD,
}
#: valid only on the numerator side of a ``per`` expression
#: (``price_per_chip_s``); a bare trailing ``price`` carries no unit.
_NUMERATOR_ATOMS: Dict[str, Unit] = {"price": USD, "cost": USD, **_ATOMS}
#: trailing tokens that declare a name dimensionless by convention
_DIMLESS_SUFFIXES = {
    "ratio", "frac", "fraction", "factor", "multiplier", "mult",
    "share", "pct", "util",
}


def unit_from_name(name: str) -> Optional[Unit]:
    """Suffix-implied unit of ``name``, or None when the name carries
    no convention. Grammar (parsed from the end): ``<num>` ``per``
    ``<den>`` | ``<den>``, each side a run of unit atoms —
    ``billed_cs`` -> chip_s, ``price_per_chip_s`` -> usd_per_chip_s,
    ``drift_ratio`` -> dimensionless."""
    toks = [t for t in name.lower().split("_") if t]
    if not toks:
        return None
    j = len(toks)
    den: list[Unit] = []
    while j > 0 and toks[j - 1] in _ATOMS:
        atom = _ATOMS[toks[j - 1]]
        # same-dimension repeats collapse: ``drain_time_s`` and
        # ``submit_time_s`` are seconds, not s^2 (``chip_s`` still
        # multiplies — distinct dimensions)
        if not any(a == atom for a in den):
            den.append(atom)
        j -= 1
    if den and j > 0 and toks[j - 1] == "per":
        j -= 1
        num: list[Unit] = []
        while j > 0 and toks[j - 1] in _NUMERATOR_ATOMS:
            atom = _NUMERATOR_ATOMS[toks[j - 1]]
            if not any(a == atom for a in num):
                num.append(atom)
            j -= 1
        if not num:
            return None  # '<nothing> per chip_s' carries no numerator
        unit = DIMENSIONLESS
        for u in num:
            unit = unit * u
        for u in den:
            unit = unit / u
        return unit
    if den:
        unit = DIMENSIONLESS
        for u in den:
            unit = unit * u
        return unit
    if toks[-1] in _DIMLESS_SUFFIXES:
        return DIMENSIONLESS
    return None


# --- the seed registry -----------------------------------------------------

#: attribute / field names with a repo-wide meaning, consulted for
#: ``x.<attr>`` loads and stores when the suffix grammar is silent.
#: (Suffixed attributes — ``startup_s``, ``billed_cs``, ``est_exec_s``
#: — never need an entry: the grammar already covers them.)
SEED_ATTRS: Dict[str, Unit] = {
    # Query / StageEvent / StagePlan billing identities
    "chip_seconds": CHIP_S,
    "remaining_chip_seconds": CHIP_S,
    "chip_seconds_provisioned": CHIP_S,
    "cost": USD,
    "est_cost": USD,
    # timestamps and durations (the 'time' atom covers *_time already;
    # these are the unsuffixed ones)
    "latency": S,
    "queue_wait": S,
    "start": S,
    "finish": S,
    "deadline": S,
    "remaining": S,
    # prices
    "price_per_chip_s": USD_PER_CHIP_S,
    "price_per_chip_hour": USD_PER_CHIP_S,  # hours are time too
    "vm_price_per_chip_s": USD_PER_CHIP_S,
    "cf_price_per_chip_s": USD_PER_CHIP_S,
    # capacities
    "chips": CHIPS,
    "slice_chips": CHIPS,
    "tokens_per_chip": TOKENS_PER_CHIP,
    # dimensionless knobs and calibration EWMAs (log-ratios)
    "speed_factor": DIMENSIONLESS,
    "price_multiplier": DIMENSIONLESS,
    "cf_multiplier": DIMENSIONLESS,
    "drift_bound": DIMENSIONLESS,
    "retries": DIMENSIONLESS,
    "preemptions": DIMENSIONLESS,
}

#: callable name (bare or ``Class.method``) ->
#:   {"params": {name: Unit}, "order": [positional names after self],
#:    "return": Unit | tuple[Unit, ...] | None,
#:    "billing_sink": bool}
#: ``params`` binds the function body's environment AND types call
#: arguments; ``billing_sink`` marks calls whose usd/chip_s arguments
#: must not absorb raw numeric literals (RL103).
SEED_FUNCS: Dict[str, dict] = {
    # engine.account_stage — THE billing sink: cost = billed_cs *
    # price_per_chip_s, appended to the query's stage trace.
    "account_stage": {
        "params": {
            "start": S, "finish": S, "chips": CHIPS,
            "billed_cs": CHIP_S, "price_per_chip_s": USD_PER_CHIP_S,
            "retries": DIMENSIONLESS,
        },
        "order": ["q", "stage", "cluster", "start", "finish", "chips",
                  "billed_cs", "price_per_chip_s", "retries"],
        "return": None,
        "billing_sink": True,
    },
    # cost_model.CostModel — quotes are priced off these
    "CostModel.chip_seconds": {"params": {"chips": CHIPS},
                               "order": ["work", "chips"],
                               "return": CHIP_S},
    "chip_seconds": {"return": CHIP_S},
    "CostModel.plan": {"params": {"chips": CHIPS},
                       "order": ["work", "chips"], "return": None},
    "exec_time": {"return": S},
    # insights.Quote / price_menu — the public quote surface
    "Quote": {
        "params": {"est_pending_s": S, "est_exec_s": S, "est_cost": USD},
        "return": None,
        "billing_sink": True,
    },
    "price_menu": {
        "params": {"vm_chips": CHIPS,
                   "vm_price_per_chip_s": USD_PER_CHIP_S,
                   "cf_multiplier": DIMENSIONLESS},
        "return": None,
    },
    # scheduler.unpack_fused — splits one fused bill exactly; both the
    # shares and the billed totals are billing state.
    "unpack_fused": {"params": {}, "return": None, "billing_sink": True},
    # calibration EWMAs are log-ratios: dimensionless by construction
    "drift_ratio": {"return": DIMENSIONLESS},
    "observe_drift": {"params": {"predicted_s": S, "measured_s": S},
                      "return": None},
    "speed_correction": {"return": DIMENSIONLESS},
}

#: attribute names that accumulate money / billed chip-seconds: a raw
#: numeric literal added straight into one of these is RL103 even
#: outside a registered sink call.
BILLING_ATTRS = {"cost", "chip_seconds", "est_cost", "billed_cs"}


def lookup_name(name: str) -> Optional[Unit]:
    """Unit implied by a bare name: suffix grammar first, then the
    seed attribute table."""
    u = unit_from_name(name)
    if u is not None:
        return u
    return SEED_ATTRS.get(name)
