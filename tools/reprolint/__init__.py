"""reprolint: AST-based invariant lints for this repo's correctness contracts.

Every rule encodes a bug class this repo has actually shipped and fixed
(docs/static-analysis.md has the catalog):

  RL001  lock discipline     — the PR-3 ``_vm_busy`` data race
  RL002  version-keyed caches — the PR-4 stale ``lru_cache`` / PR-7
                                unbounded plan-cache classes
  RL003  determinism          — wall-clock time, global RNG, pairwise
                                ``np.sum`` drift, bare-set iteration
  RL004  swallowed exceptions — the PR-3 swallowed-futures class
  RL005  slots / identity     — hot-path classes stay slotted, with
                                identity equality

Self-contained on the stdlib (``ast`` + ``tokenize``-free line scanning):
``python -m tools.reprolint [paths...] [--baseline FILE]``.

Inline suppression: ``# reprolint: disable=RL003 -- <reason>`` on the
flagged line. The reason is REQUIRED — a reasonless disable is itself a
finding (RL000), so every grandfathered hit carries its review rationale
in the source.

Baseline: a JSON map of ``"path::code" -> count`` (``--write-baseline``).
Lint passes while per-(file, rule) finding counts stay at or below the
baselined counts — a ratchet that can only tighten.

The RL001 rule and the runtime sanitizer (``repro.core.sanitize``,
``REPRO_SANITIZE=1``) read the SAME ``_GUARDED_BY`` class registries, so
the static race check and the runtime lock-held asserts cannot drift
apart.
"""
from __future__ import annotations

import ast
import json
import re
from dataclasses import dataclass
from pathlib import Path
from typing import Iterable, Optional

META_CODE = "RL000"

_DISABLE_RE = re.compile(
    r"#\s*reprolint:\s*disable=([A-Z0-9,\s]+?)\s*(?:--\s*(\S.*))?$"
)


@dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    path: str  # repo-relative posix path
    line: int
    code: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"


def _suppressions(text: str) -> tuple[dict[int, set[str]], list[int]]:
    """(line -> disabled codes, lines with a reasonless disable). The
    reason string after ``--`` is mandatory: a suppression is a reviewed
    decision, and the review lives in the source next to it."""
    disabled: dict[int, set[str]] = {}
    reasonless: list[int] = []
    for i, line in enumerate(text.splitlines(), start=1):
        m = _DISABLE_RE.search(line)
        if m is None:
            continue
        codes = {c.strip() for c in m.group(1).split(",") if c.strip()}
        if not m.group(2):
            reasonless.append(i)
            continue
        disabled[i] = codes
    return disabled, reasonless


def lint_text(text: str, path: str) -> list[Finding]:
    """Lint one file's source under its repo-relative ``path`` (the path
    decides which rules are in scope). Returns unsuppressed findings."""
    from . import rules

    try:
        tree = ast.parse(text)
    except SyntaxError as err:
        return [Finding(path, err.lineno or 1, META_CODE,
                        f"syntax error: {err.msg}")]
    disabled, reasonless = _suppressions(text)
    findings: list[Finding] = [
        Finding(path, line, META_CODE,
                "reprolint disable comment requires a reason: "
                "'# reprolint: disable=CODE -- <why this is safe>'")
        for line in reasonless
    ]
    for rule in rules.RULES:
        if not rule.applies(path):
            continue
        for f in rule.check(tree, path):
            if rule.code in disabled.get(f.line, ()):
                continue
            findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.code))
    return findings


def iter_py_files(paths: Iterable[str], root: Path) -> list[Path]:
    out: list[Path] = []
    for p in paths:
        target = (root / p) if not Path(p).is_absolute() else Path(p)
        if target.is_file() and target.suffix == ".py":
            out.append(target)
        elif target.is_dir():
            out.extend(
                f for f in sorted(target.rglob("*.py"))
                if "__pycache__" not in f.parts
                and not any(part.startswith(".") for part in f.parts)
            )
    return out


def lint_paths(
    paths: Iterable[str],
    root: Optional[Path] = None,
    cache: Optional["LintCache"] = None,
) -> list[Finding]:
    """Lint every ``.py`` file under ``paths`` (files or directories),
    reporting findings with paths relative to ``root`` (default: cwd).

    The project root is attached for the duration of the run so the
    interprocedural analyses (RL006, RL101–RL103) see the cross-module
    call graph of ``core/`` + ``launch/``; standalone ``lint_text``
    calls stay hermetic. ``cache`` (see :class:`LintCache`) skips
    re-analysis of files whose content and analysis inputs are
    unchanged."""
    from . import dataflow

    root = Path.cwd() if root is None else Path(root)
    findings: list[Finding] = []
    dataflow.set_project_root(root)
    try:
        env_key = dataflow.project_summaries()[1] if cache else ""
        for f in iter_py_files(paths, root):
            try:
                rel = f.resolve().relative_to(root.resolve()).as_posix()
            except ValueError:
                rel = f.as_posix()
            text = f.read_text()
            if cache is not None:
                cached = cache.get(rel, f, text, env_key)
                if cached is not None:
                    findings.extend(cached)
                    continue
            got = lint_text(text, rel)
            if cache is not None:
                cache.put(rel, f, text, env_key, got)
            findings.extend(got)
    finally:
        dataflow.set_project_root(None)
    findings.sort(key=lambda f: (f.path, f.line, f.code))
    return findings


# --- per-file result cache (mtime + content hash keyed) -------------------

#: bump when rule behavior changes: stale caches must miss, not lie
CACHE_SCHEMA = 1


class LintCache:
    """Per-file finding cache for the CLI: a file whose mtime (fast
    path) or content hash (after a touch) and analysis environment are
    unchanged skips re-analysis entirely. The environment key is the
    digest of the interprocedural summary table, so editing ``core/``
    or ``launch/`` invalidates every file that could see different
    cross-module summaries — the cache can never serve findings
    computed against a different call graph."""

    def __init__(self, path) -> None:
        self.path = Path(path)
        self.entries: dict[str, dict] = {}
        self.dirty = False
        try:
            raw = json.loads(self.path.read_text())
        except (OSError, ValueError):
            return
        if isinstance(raw, dict) and raw.get("schema") == CACHE_SCHEMA:
            entries = raw.get("entries", {})
            if isinstance(entries, dict):
                self.entries = entries

    @staticmethod
    def _hash(text: str) -> str:
        import hashlib

        return hashlib.sha256(text.encode()).hexdigest()

    def get(self, rel: str, file: Path, text: str,
            env_key: str) -> Optional[list[Finding]]:
        entry = self.entries.get(rel)
        if not isinstance(entry, dict) or entry.get("env") != env_key:
            return None
        try:
            mtime_ns = file.stat().st_mtime_ns
        except OSError:
            return None
        if entry.get("mtime_ns") != mtime_ns:
            if entry.get("sha256") != self._hash(text):
                return None
            entry["mtime_ns"] = mtime_ns  # touched but identical
            self.dirty = True
        try:
            return [
                Finding(rel, int(line), str(code), str(message))
                for line, code, message in entry.get("findings", [])
            ]
        except (TypeError, ValueError):
            return None

    def put(self, rel: str, file: Path, text: str, env_key: str,
            findings: list[Finding]) -> None:
        try:
            mtime_ns = file.stat().st_mtime_ns
        except OSError:
            return
        self.entries[rel] = {
            "env": env_key,
            "mtime_ns": mtime_ns,
            "sha256": self._hash(text),
            "findings": [[f.line, f.code, f.message] for f in findings],
        }
        self.dirty = True

    def save(self) -> None:
        if not self.dirty:
            return
        payload = {"schema": CACHE_SCHEMA, "entries": self.entries}
        self.path.write_text(json.dumps(payload, sort_keys=True) + "\n")
        self.dirty = False


# --- baseline: a per-(file, rule) count ratchet ---------------------------

def baseline_counts(findings: Iterable[Finding]) -> dict[str, int]:
    counts: dict[str, int] = {}
    for f in findings:
        key = f"{f.path}::{f.code}"
        counts[key] = counts.get(key, 0) + 1
    return counts


def load_baseline(path) -> dict[str, int]:
    d = json.loads(Path(path).read_text())
    entries = d.get("entries", d) if isinstance(d, dict) else {}
    return {str(k): int(v) for k, v in entries.items()}


def save_baseline(path, findings: Iterable[Finding]) -> None:
    payload = {"entries": baseline_counts(findings)}
    Path(path).write_text(
        json.dumps(payload, indent=1, sort_keys=True) + "\n"
    )


def apply_baseline(
    findings: list[Finding], baseline: dict[str, int]
) -> list[Finding]:
    """Findings NOT covered by the baseline: for each (file, rule) the
    first ``baseline[key]`` findings are grandfathered, the rest
    reported. ``RL000`` (meta: malformed suppression) is never
    baselinable — a reasonless disable must be fixed, not ratcheted."""
    remaining = dict(baseline)
    out: list[Finding] = []
    for f in findings:
        key = f"{f.path}::{f.code}"
        if f.code != META_CODE and remaining.get(key, 0) > 0:
            remaining[key] -= 1
            continue
        out.append(f)
    return out
