"""CLI: ``python -m tools.reprolint [paths...] [--baseline FILE]``.

Exit 0 when every finding is covered by the baseline (or there are
none); exit 1 otherwise, printing one ``path:line: CODE message`` per
finding. ``--write-baseline`` regenerates the ratchet file from the
current findings instead of failing.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from . import apply_baseline, lint_paths, load_baseline, save_baseline


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.reprolint",
        description="repo-native invariant lints (RL001-RL005)",
    )
    ap.add_argument(
        "paths", nargs="*", default=["src", "tests", "benchmarks"],
        help="files or directories to lint (default: src tests benchmarks)",
    )
    ap.add_argument(
        "--root", default=None,
        help="repo root findings are reported relative to (default: cwd)",
    )
    ap.add_argument(
        "--baseline", default=None,
        help="JSON baseline of grandfathered per-(file, rule) counts",
    )
    ap.add_argument(
        "--write-baseline", default=None, metavar="FILE",
        help="write the current findings as the new baseline and exit 0",
    )
    args = ap.parse_args(argv)

    root = Path(args.root) if args.root else Path.cwd()
    findings = lint_paths(args.paths or ["src", "tests", "benchmarks"], root)

    if args.write_baseline:
        save_baseline(args.write_baseline, findings)
        print(
            f"reprolint: baseline written to {args.write_baseline} "
            f"({len(findings)} finding(s) grandfathered)"
        )
        return 0

    if args.baseline:
        findings = apply_baseline(findings, load_baseline(args.baseline))

    for f in findings:
        print(f.render())
    if findings:
        print(f"reprolint: {len(findings)} finding(s)", file=sys.stderr)
        return 1
    print("reprolint: clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
