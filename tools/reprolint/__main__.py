"""CLI: ``python -m tools.reprolint [paths...] [--baseline FILE]``.

Exit 0 when every finding is covered by the baseline (or there are
none); exit 1 otherwise, printing one ``path:line: CODE message`` per
finding (``--format github`` emits workflow annotations instead, so
findings surface inline on PRs). ``--write-baseline`` regenerates the
ratchet file from the current findings instead of failing.

``--cache FILE`` keeps a per-file result cache keyed on mtime +
content hash + the interprocedural summary digest, so repeated runs
(CI, pre-commit) skip unchanged files; ``--no-cache`` ignores it.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from . import (
    LintCache,
    apply_baseline,
    lint_paths,
    load_baseline,
    save_baseline,
)


def render_github(finding) -> str:
    # '::' and newlines would terminate the annotation early
    message = finding.message.replace("\n", " ").replace("::", ":")
    return (
        f"::error file={finding.path},line={finding.line},"
        f"title={finding.code}::{message}"
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.reprolint",
        description="repo-native invariant lints "
                    "(RL001-RL006, RL101-RL103)",
    )
    ap.add_argument(
        "paths", nargs="*", default=["src", "tests", "benchmarks"],
        help="files or directories to lint (default: src tests benchmarks)",
    )
    ap.add_argument(
        "--root", default=None,
        help="repo root findings are reported relative to (default: cwd)",
    )
    ap.add_argument(
        "--baseline", default=None,
        help="JSON baseline of grandfathered per-(file, rule) counts",
    )
    ap.add_argument(
        "--write-baseline", default=None, metavar="FILE",
        help="write the current findings as the new baseline and exit 0",
    )
    ap.add_argument(
        "--cache", default=None, metavar="FILE",
        help="per-file result cache (e.g. .reprolint_cache.json); "
             "unchanged files skip re-analysis",
    )
    ap.add_argument(
        "--no-cache", action="store_true",
        help="ignore --cache: analyze every file fresh",
    )
    ap.add_argument(
        "--format", choices=("text", "github"), default="text",
        help="'github' emits ::error workflow annotations",
    )
    args = ap.parse_args(argv)

    root = Path(args.root) if args.root else Path.cwd()
    cache = None
    if args.cache and not args.no_cache:
        cache = LintCache(args.cache)
    findings = lint_paths(
        args.paths or ["src", "tests", "benchmarks"], root, cache=cache
    )
    if cache is not None:
        cache.save()

    if args.write_baseline:
        save_baseline(args.write_baseline, findings)
        print(
            f"reprolint: baseline written to {args.write_baseline} "
            f"({len(findings)} finding(s) grandfathered)"
        )
        return 0

    if args.baseline:
        findings = apply_baseline(findings, load_baseline(args.baseline))

    for f in findings:
        print(render_github(f) if args.format == "github" else f.render())
    if findings:
        print(f"reprolint: {len(findings)} finding(s)", file=sys.stderr)
        return 1
    print("reprolint: clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
