"""RL006 — lock-order (ABBA deadlock) analysis.

Builds the lock-acquisition graph of the threaded core modules from
the same ``_GUARDED_BY`` registries the RL001 rule and the runtime
sanitizer read, plus the ``threading.Lock/RLock/Condition`` attributes
assigned in ``__init__``. A node is one lock, named ``Class.attr``
(``Condition(self._mu)`` aliases to its underlying lock, exactly as
``_GUARDED_BY`` treats ``("_mu", "_cv")`` as one guard). An edge
``A -> B`` means some code path acquires B while holding A — directly
via nested ``with self.<lock>:`` blocks or ``.acquire()`` calls, or
transitively through a method call whose **acquisition summary**
(fixed point over the call graph) includes B.

Any cycle in that graph is a potential ABBA deadlock: two threads
walking the cycle from different entry points can each hold the lock
the other needs. The derived acyclic graph also yields the canonical
**lock hierarchy** (``lock_ranks``) that ``repro.core.sanitize``
enforces at runtime under ``REPRO_SANITIZE=1`` — one static analysis,
two enforcement points.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from . import Finding

CORE = "src/repro/core/"
#: the threaded modules whose cross-file call graph forms one lock
#: hierarchy (everything else is analyzed file-locally)
LOCK_FILES = (
    "src/repro/core/live.py",
    "src/repro/core/scheduler.py",
    "src/repro/core/calibration.py",
    "src/repro/core/convergence.py",
    "src/repro/core/events.py",
    "src/repro/core/chaos.py",
)

_LOCK_CTORS = {"Lock", "RLock", "Condition", "Semaphore",
               "BoundedSemaphore"}


class LockGraph:
    """Nodes are ``Class.attr`` lock names; ``edges[(a, b)]`` holds the
    first (path, line) site where b was acquired under a."""

    def __init__(self) -> None:
        self.nodes: Set[str] = set()
        self.edges: Dict[Tuple[str, str], Tuple[str, int]] = {}

    def add_edge(self, a: str, b: str, site: Tuple[str, int]) -> None:
        if a == b:
            return  # reentrancy is the sanitizer's territory, not ABBA
        self.nodes.update((a, b))
        if (a, b) not in self.edges or site < self.edges[(a, b)]:
            self.edges[(a, b)] = site

    def successors(self, a: str) -> List[str]:
        return sorted(b for (x, b) in self.edges if x == a)


class _ClassLocks:
    """Lock attributes of one class: canonical names plus the alias
    map (``_cv -> _mu`` when ``self._cv = Condition(self._mu)``)."""

    def __init__(self, cls: ast.ClassDef) -> None:
        # imported here, not at module level: rules.py builds its RULES
        # list from this module, so a top-level import would be circular
        from .rules import _parse_registry

        self.name = cls.name
        attrs: Set[str] = set()
        for locks in _parse_registry(cls).values():
            attrs.update(locks)
        self.alias: Dict[str, str] = {}
        for node in ast.walk(cls):
            if not isinstance(node, ast.Assign):
                continue
            value = node.value
            if not (isinstance(value, ast.Call)
                    and self._ctor_name(value.func) in _LOCK_CTORS):
                continue
            for t in node.targets:
                attr = _self_attr(t)
                if attr is None:
                    continue
                attrs.add(attr)
                if self._ctor_name(value.func) == "Condition" and \
                        value.args:
                    inner = _self_attr(value.args[0])
                    if inner is not None:
                        attrs.add(inner)
                        self.alias[attr] = inner
        self.attrs = attrs

    @staticmethod
    def _ctor_name(func: ast.AST) -> Optional[str]:
        if isinstance(func, ast.Attribute):
            return func.attr
        if isinstance(func, ast.Name):
            return func.id
        return None

    def node_for(self, attr: str) -> Optional[str]:
        if attr not in self.attrs:
            return None
        return f"{self.name}.{self.alias.get(attr, attr)}"


def _self_attr(node: ast.AST) -> Optional[str]:
    if (
        isinstance(node, ast.Attribute)
        and isinstance(node.value, ast.Name)
        and node.value.id == "self"
    ):
        return node.attr
    return None


class _Site:
    """One call site inside a method: bare callee name, the locks held
    at the call, whether it is a ``self.`` call, and its location."""

    __slots__ = ("callee", "held", "is_self", "path", "line")

    def __init__(self, callee, held, is_self, path, line):
        self.callee = callee
        self.held = held
        self.is_self = is_self
        self.path = path
        self.line = line


class _Method:
    __slots__ = ("qual", "cls", "name", "direct", "calls")

    def __init__(self, qual, cls, name):
        self.qual = qual
        self.cls = cls
        self.name = name
        self.direct: Set[str] = set()  # lock nodes acquired directly
        self.calls: List[_Site] = []


def _scan_method(meth: _Method, fn, locks: _ClassLocks, path: str,
                 graph: LockGraph) -> None:
    """Record direct acquisitions, direct nested-with edges, and call
    sites with their held-lock snapshots."""

    def walk(nodes, held: frozenset) -> None:
        for node in (nodes if isinstance(nodes, list) else [nodes]):
            if isinstance(node, (ast.With, ast.AsyncWith)):
                acquired: Set[str] = set()
                for item in node.items:
                    attr = _self_attr(item.context_expr)
                    lock = locks.node_for(attr) if attr else None
                    if lock is not None:
                        acquired.add(lock)
                    else:
                        walk(item.context_expr, held)
                for lock in sorted(acquired):
                    meth.direct.add(lock)
                    for h in sorted(held):
                        graph.add_edge(h, lock, (path, node.lineno))
                walk(node.body, held | acquired)
                continue
            if isinstance(node, ast.Call):
                fname = None
                is_self = False
                if isinstance(node.func, ast.Attribute):
                    # self._lock.acquire() is a direct acquisition
                    if node.func.attr == "acquire":
                        attr = _self_attr(node.func.value)
                        lock = locks.node_for(attr) if attr else None
                        if lock is not None:
                            meth.direct.add(lock)
                            for h in sorted(held):
                                graph.add_edge(h, lock,
                                               (path, node.lineno))
                            continue
                    fname = node.func.attr
                    base = node.func.value
                    is_self = isinstance(base, ast.Name) and \
                        base.id == "self"
                elif isinstance(node.func, ast.Name):
                    fname = node.func.id
                if fname is not None:
                    meth.calls.append(_Site(fname, held, is_self, path,
                                            node.lineno))
                for child in ast.iter_child_nodes(node):
                    walk(child, held)
                continue
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                # a nested def runs later: nothing is held then, and
                # its acquisitions are not part of THIS method's call
                body = node.body if isinstance(node.body, list) \
                    else [node.body]
                nested = _Method(f"{meth.qual}.<nested>", meth.cls,
                                 node.name if hasattr(node, "name")
                                 else "<lambda>")
                _scan_nested(nested, body, locks, path, graph)
                continue
            for child in ast.iter_child_nodes(node):
                walk(child, held)

    walk(fn.body, frozenset())


def _scan_nested(meth: _Method, body, locks, path, graph) -> None:
    class _Shim:
        pass

    shim = _Shim()
    shim.body = body
    _scan_method(meth, shim, locks, path, graph)


def build_lock_graph(
    named_trees: List[Tuple[str, ast.Module]],
) -> LockGraph:
    """The combined lock-acquisition graph over ``named_trees`` (a list
    of (repo-relative path, parsed module))."""
    graph = LockGraph()
    methods: List[_Method] = []
    for path, tree in named_trees:
        for cls in tree.body:
            if not isinstance(cls, ast.ClassDef):
                continue
            locks = _ClassLocks(cls)
            if not locks.attrs:
                continue
            for stmt in cls.body:
                if not isinstance(stmt, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                    continue
                meth = _Method(f"{cls.name}.{stmt.name}", cls.name,
                               stmt.name)
                _scan_method(meth, stmt, locks, path, graph)
                methods.append(meth)

    # acquisition summaries: fixed point over the (name-resolved) call
    # graph — sets only grow, so this terminates
    by_name: Dict[str, List[_Method]] = {}
    by_qual: Dict[str, _Method] = {}
    for m in methods:
        by_name.setdefault(m.name, []).append(m)
        by_qual[m.qual] = m
    summaries: Dict[str, Set[str]] = {m.qual: set(m.direct)
                                      for m in methods}

    def resolve(site: _Site, cls: str) -> List[_Method]:
        if site.is_self and f"{cls}.{site.callee}" in by_qual:
            return [by_qual[f"{cls}.{site.callee}"]]
        return by_name.get(site.callee, [])

    changed = True
    while changed:
        changed = False
        for m in methods:
            acc = summaries[m.qual]
            before = len(acc)
            for site in m.calls:
                for callee in resolve(site, m.cls):
                    acc |= summaries[callee.qual]
            if len(acc) != before:
                changed = True

    # edges induced by calls made while holding locks
    for m in methods:
        for site in m.calls:
            if not site.held:
                continue
            acquired: Set[str] = set()
            for callee in resolve(site, m.cls):
                acquired |= summaries[callee.qual]
            for h in sorted(site.held):
                for b in sorted(acquired):
                    graph.add_edge(h, b, (site.path, site.line))
    return graph


def find_cycles(graph: LockGraph) -> List[dict]:
    """Strongly connected components of size > 1, each a potential
    ABBA deadlock. Deterministic: nodes and edges sorted."""
    index: Dict[str, int] = {}
    low: Dict[str, int] = {}
    on_stack: Set[str] = set()
    stack: List[str] = []
    sccs: List[List[str]] = []
    counter = [0]

    def strongconnect(v: str) -> None:
        # iterative Tarjan (the live call graph is small, but recursion
        # limits are not a contract we want to depend on)
        work = [(v, iter(graph.successors(v)))]
        index[v] = low[v] = counter[0]
        counter[0] += 1
        stack.append(v)
        on_stack.add(v)
        while work:
            node, it = work[-1]
            advanced = False
            for w in it:
                if w not in index:
                    index[w] = low[w] = counter[0]
                    counter[0] += 1
                    stack.append(w)
                    on_stack.add(w)
                    work.append((w, iter(graph.successors(w))))
                    advanced = True
                    break
                if w in on_stack:
                    low[node] = min(low[node], index[w])
            if advanced:
                continue
            work.pop()
            if work:
                parent = work[-1][0]
                low[parent] = min(low[parent], low[node])
            if low[node] == index[node]:
                scc = []
                while True:
                    w = stack.pop()
                    on_stack.discard(w)
                    scc.append(w)
                    if w == node:
                        break
                if len(scc) > 1:
                    sccs.append(sorted(scc))

    for v in sorted(graph.nodes):
        if v not in index:
            strongconnect(v)

    out = []
    for scc in sorted(sccs):
        members = set(scc)
        edges = sorted(
            (site, a, b) for (a, b), site in graph.edges.items()
            if a in members and b in members
        )
        out.append({
            "locks": scc,
            "edges": [(a, b, site) for site, a, b in edges],
            "site": edges[0][0],
        })
    return out


def lock_ranks(graph: LockGraph) -> Dict[str, int]:
    """Topological ranks of an acyclic lock graph: acquire in strictly
    increasing rank and no ABBA interleaving is possible. Rank =
    longest path from any source, so every edge strictly increases it.
    Raises ValueError on a cyclic graph."""
    if find_cycles(graph):
        raise ValueError("lock graph has a cycle; no hierarchy exists")
    ranks: Dict[str, int] = {}

    def rank_of(node: str, trail: Tuple[str, ...] = ()) -> int:
        if node in ranks:
            return ranks[node]
        preds = sorted(a for (a, b) in graph.edges if b == node)
        r = 0 if not preds else 1 + max(
            rank_of(p, trail + (node,)) for p in preds
        )
        ranks[node] = r
        return r

    for node in sorted(graph.nodes):
        rank_of(node)
    return ranks


# --- project-level graph (the three threaded modules) ----------------------

_GRAPH_CACHE: Dict[tuple, LockGraph] = {}


def project_lock_graph(root: Path) -> Optional[LockGraph]:
    """The combined graph over ``LOCK_FILES`` under ``root``, cached on
    their stats; None when the files are absent (fixture trees)."""
    files = [(rel, root / rel) for rel in LOCK_FILES]
    files = [(rel, p) for rel, p in files if p.is_file()]
    if not files:
        return None
    key = tuple(
        (rel, p.stat().st_mtime_ns, p.stat().st_size) for rel, p in files
    )
    hit = _GRAPH_CACHE.get(key)
    if hit is not None:
        return hit
    named = []
    for rel, p in files:
        try:
            named.append((rel, ast.parse(p.read_text())))
        except SyntaxError:
            continue
    graph = build_lock_graph(named)
    _GRAPH_CACHE.clear()
    _GRAPH_CACHE[key] = graph
    return graph


def reset_graph_cache() -> None:
    _GRAPH_CACHE.clear()


class LockOrder:
    """RL006 — fail on any cycle in the lock-acquisition graph. For
    the three threaded core modules the graph is built jointly (their
    call graphs interlock); any other core file is analyzed alone, so
    fixture files self-report their cycles."""

    code = "RL006"
    title = "lock-order cycle (potential ABBA deadlock)"

    def applies(self, path: str) -> bool:
        return path.startswith(CORE)

    def check(self, tree: ast.Module, path: str) -> List[Finding]:
        from .dataflow import _PROJECT_ROOT  # shared root attachment

        if path in LOCK_FILES and _PROJECT_ROOT is not None:
            graph = project_lock_graph(_PROJECT_ROOT)
            if graph is None:
                graph = build_lock_graph([(path, tree)])
        else:
            graph = build_lock_graph([(path, tree)])
        findings = []
        for cycle in find_cycles(graph):
            site_path, line = cycle["site"]
            if site_path != path:
                continue  # reported once, at its first edge's file
            chain = ", ".join(
                f"{a} -> {b} ({p}:{ln})" for a, b, (p, ln) in
                cycle["edges"]
            )
            findings.append(Finding(
                path, line, self.code,
                f"lock-order cycle over {{{', '.join(cycle['locks'])}}}"
                f": {chain} — two threads entering from different ends "
                f"deadlock (ABBA)",
            ))
        return findings
