"""Calibration drift report: quote error before/after, per pool.

The honest-pricing claim behind the SLA menu is that per-pool stage-time
predictions match measured execution. This benchmark quantifies the
quote→measurement drift on the 3-pool `benchmarks/scale.py` registry and
shows both calibration directions closing it:

  offline — every pool's speed is DECLARED 2x wrong; "measured" stage
      walls come from a ground-truth registry run of the scaled Table-1
      day. Per pool: median relative quote error of the declared model,
      then of the model corrected by `fit_dryruns` over dry-run JSONs
      synthesized from the pool's true hardware. Calibration must
      strictly lower the median error on EVERY pool.

  online — the same mis-declared models fed the measured walls through
      `LiveCalibrator` (the EWMA loop the live engine runs at stage
      boundaries), showing the loop alone recovers most of the offline
      fit's accuracy.

  live — real `LiveEngine` runs: one fits this host's true speed, a
      second is declared 2x that (a genuinely 2x-wrong constant) with
      `calibrate=True`; the loop hot-swaps a fitted correction mid-run,
      and the report compares quote drift on the post-swap decode walls
      — a static exactly-2x-wrong model vs the loop's online quotes.

Emits BENCH_calibration.json next to the repo root.

Usage: python benchmarks/calibration.py [--factor 5.5] [--fast] [--no-live]
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_config  # noqa: E402
from repro.core import (  # noqa: E402
    CostModel,
    LiveCalibrator,
    Policy,
    PoolSpec,
    SimConfig,
    Simulation,
    SLAConfig,
    fit_dryruns,
)
from repro.core.cost_model import _analytic_step  # noqa: E402
from repro.core.workload import generate, scaled_patterns  # noqa: E402

REPO = Path(__file__).resolve().parents[1]
SEED_DAY_QUERIES = 911  # Table 1 total

# the scale.py 3-pool registry: (name, true speed); every pool's
# DECLARED speed is 2x its true one — the drift calibration must close
TRUE_SPEED = {"vm": 1.0, "spot": 0.25, "cf": 1.0}
DECLARED_SPEED = {name: 2.0 * s for name, s in TRUE_SPEED.items()}

# arch/kind cells synthesized into each pool's dry-run directory
FIT_CELLS = [("paper-default", "serve"), ("paper-default", "train"),
             ("qwen2-0.5b", "serve"), ("granite-8b", "serve")]
CELL_TOKENS = {"serve": 32 * 32768, "train": 256 * 4096}


def _pools3(speed) -> list[PoolSpec]:
    # one v5e slice + a slow spot tier keeps the reserved tier contended
    # at a ~5k-query day, so IMMEDIATE overflow and mid-query spill give
    # the elastic pool real stage walls to calibrate against
    return [
        PoolSpec(name="vm", kind="reserved", chips=16, mode="sos",
                 slice_chips=16, speed_factor=speed["vm"]),
        PoolSpec(name="spot", kind="reserved", chips=64, mode="sos",
                 slice_chips=16, speed_factor=speed["spot"],
                 price_multiplier=0.15),
        PoolSpec(name="cf", kind="elastic", chips=64, startup_s=2.0,
                 speed_factor=speed["cf"], price_multiplier=10.0),
    ]


def _sim_cfg(pools: list[PoolSpec]) -> SimConfig:
    return SimConfig(
        policy=Policy.FORCE, use_calibration=False, seed=0,
        sla=SLAConfig(vm_overload_threshold=4, preempt_best_effort=True,
                      spill_enabled=True, spill_back_enabled=True,
                      spill_back_low_backlog_s=5.0),
        pools=pools,
    )


def _measured_walls(factor: float):
    """Run the ground-truth registry; return per-pool samples of
    (work, stage index, chips, measured wall seconds)."""
    qs = generate(horizon_s=86_400.0, seed=0,
                  patterns=scaled_patterns(factor))
    sim = Simulation(_sim_cfg(_pools3(TRUE_SPEED)))
    res = sim.run(qs)
    samples: dict[str, list] = {name: [] for name in TRUE_SPEED}
    for q in res.queries:
        for e in q.stage_trace:
            if e.retries == 0:  # a clean wall, not a retry re-run
                samples[e.cluster].append(
                    (q.work, e.index, e.chips, e.finish - e.start)
                )
    return samples, len(qs)


def _median_rel_err(cm: CostModel, samples) -> float:
    errs = []
    for work, index, chips, wall in samples:
        pred = cm.plan(work, chips).stages[index].time_s
        if wall > 0:
            errs.append(abs(pred - wall) / wall)
    errs.sort()
    if not errs:
        raise RuntimeError(
            "no measured stage walls for this pool — the workload never "
            "reached it; raise --factor so every pool sees traffic"
        )
    return errs[len(errs) // 2]


def _synth_dryruns(dir_: Path, true_speed: float) -> None:
    """Dry-run JSONs as recorded on this pool's hardware: analytic step
    time at the TRUE speed (what a real dry-run would measure)."""
    for arch, kind in FIT_CELLS:
        an = _analytic_step(get_config(arch), CELL_TOKENS[kind], kind,
                            chips=256)
        rec = {"arch": arch, "kind": kind, "shape": "synthetic",
               "chips": 256, "tokens": CELL_TOKENS[kind], "status": "ok",
               "roofline": {"terms": {"step_s": an / true_speed}}}
        (dir_ / f"{arch}__{kind}.json").write_text(json.dumps(rec))


def offline_report(factor: float) -> dict:
    samples, n = _measured_walls(factor)
    out: dict = {"queries": n, "pools": {}}
    with tempfile.TemporaryDirectory() as tmp:
        for name in TRUE_SPEED:
            declared = CostModel(use_calibration=False,
                                 speed_factor=DECLARED_SPEED[name])
            err_before = _median_rel_err(declared, samples[name])
            # offline: fit this pool's table from its own dry-runs
            pool_dir = Path(tmp) / name
            pool_dir.mkdir()
            _synth_dryruns(pool_dir, TRUE_SPEED[name])
            table = fit_dryruns(pool_dir)
            fitted = CostModel(use_calibration=False,
                               speed_factor=DECLARED_SPEED[name],
                               calibration=table)
            err_after = _median_rel_err(fitted, samples[name])
            # online-in-sim: the EWMA loop fed the same measured walls
            ewma_pool = type("P", (), {})()  # LiveCalibrator reads only
            ewma_pool.name = name  # .name and .cost_model off the pool
            ewma_pool.cost_model = CostModel(
                use_calibration=False, speed_factor=DECLARED_SPEED[name]
            )
            cal = LiveCalibrator(alpha=0.1, min_samples=8)
            for work, index, chips, wall in samples[name][:512]:
                cal.observe(ewma_pool, work, index, chips, wall)
                cal.maybe_apply(ewma_pool)
            err_online = _median_rel_err(ewma_pool.cost_model, samples[name])
            out["pools"][name] = {
                "n_stage_walls": len(samples[name]),
                "true_speed": TRUE_SPEED[name],
                "declared_speed": DECLARED_SPEED[name],
                "fitted_speed_offline": round(table.speed_factor, 4),
                "fitted_speed_online": round(
                    ewma_pool.cost_model.effective_speed_factor, 4
                ),
                "median_quote_err_before": round(err_before, 4),
                "median_quote_err_after": round(err_after, 6),
                "median_quote_err_online": round(err_online, 6),
                "improved": bool(err_after < err_before),
            }
    out["all_pools_improved"] = all(
        p["improved"] for p in out["pools"].values()
    )
    return out


def _median(vals) -> float:
    vals = sorted(vals)
    return vals[len(vals) // 2]


def live_report() -> dict:
    """Real LiveEngine runs: first fit this host's TRUE speed (the
    analytic model's scale on CPU worker threads is arbitrary), then
    re-run with the pool declared 2x that — a genuinely 2x-wrong
    declaration the loop corrects mid-run. Drift is judged on the
    post-swap walls, in the run's OWN frame: a static model pinned at
    exactly 2x the run's final fit (what the declared constant claims,
    with host load drift between runs factored out) vs the loop's
    online quotes on the same walls."""
    from repro.core.calibration import measure_live_speed_drift

    ref_eng, _ = measure_live_speed_drift(declared_speed=1.0)
    true_speed = ref_eng.pools[0].cost_model.effective_speed_factor
    declared_speed = 2.0 * true_speed
    eng, walls = measure_live_speed_drift(declared_speed=declared_speed)
    fitted = eng.pools[0].cost_model.effective_speed_factor
    min_samples = eng.cfg.calibration_min_samples
    late = [w for w in walls if w[0] >= min_samples]
    declared_cm = CostModel(
        use_calibration=False,
        decode_chunk_tokens=eng.cfg.decode_chunk_tokens,
        speed_factor=2.0 * fitted,
    )
    drift_before = _median([
        abs(declared_cm.plan(work, 1).stages[index].time_s - wall) / wall
        for _, work, index, wall, _ in late
    ])
    drift_after = _median([
        abs(pred - wall) / wall for _, _, _, wall, pred in late
    ])
    return {
        "queries": 12,
        "drift_walls": len(late),
        "host_true_speed": round(true_speed, 6),
        "declared_speed": round(declared_speed, 6),
        "fitted_speed": round(fitted, 6),
        "median_drift_declared": round(drift_before, 4),
        "median_drift_calibrated": round(drift_after, 4),
        "drift_shrunk": bool(drift_after < drift_before),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--factor", type=float, default=5.5,
                    help="Table-1 count multiplier (5.5 ~= 5k queries/day)")
    ap.add_argument("--fast", action="store_true",
                    help="1/10th scale smoke run")
    ap.add_argument("--no-live", action="store_true",
                    help="skip the LiveEngine (thread/jit) section")
    args = ap.parse_args()
    factor = args.factor / 10 if args.fast else args.factor

    t0 = time.perf_counter()
    report: dict = {"offline": offline_report(factor)}
    for name, row in report["offline"]["pools"].items():
        print(f"offline[{name}]: {json.dumps(row)}")
    if not args.no_live:
        report["live"] = live_report()
        print(f"live: {json.dumps(report['live'])}")
    report["wall_s"] = round(time.perf_counter() - t0, 2)
    derived = {
        "all_pools_improved": report["offline"]["all_pools_improved"],
        "live_drift_shrunk": report.get("live", {}).get("drift_shrunk"),
        "wall_s": report["wall_s"],
    }
    print(f"derived: {json.dumps(derived)}")
    out = REPO / "BENCH_calibration.json"
    out.write_text(json.dumps(report, indent=1, sort_keys=True) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
