"""Scale benchmark: a 50k-query day through the stage-level engine.

Drives the Table-1 workload scaled to ~50k queries over a 24h horizon in
SOS mode, with stage-boundary preemption + cross-cluster spill ON vs OFF,
and reports simulator throughput (events/s, wall clock) plus the
SLA/cost effects of the two stage-granular policies:

  * imm_p95_wait_s — IMMEDIATE queries' p95 slice wait (preemption wins)
  * violations     — relaxed pending-deadline violations
  * total_cost     — spill trades reserved-rate time for elastic-rate
                     time to free slices under overload

Usage: python benchmarks/scale.py [--factor 55] [--fast]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.core import Policy, SimConfig, Simulation, SLAConfig  # noqa: E402
from repro.core.workload import generate, scaled_patterns  # noqa: E402

DAY_S = 86_400.0
SEED_DAY_QUERIES = 911  # Table 1 total


def run_day(n_target: int, engine_on: bool, seed: int = 0) -> dict:
    factor = n_target / SEED_DAY_QUERIES
    qs = generate(
        horizon_s=DAY_S, seed=seed, patterns=scaled_patterns(factor)
    )
    cfg = SimConfig(
        policy=Policy.AUTO,
        vm_mode="sos",
        vm_chips=64,
        sos_slice_chips=16,  # 4 isolated SOS slices: contended at 50k/day
        use_calibration=False,
        seed=seed,
        sla=SLAConfig(
            vm_overload_threshold=12,
            preempt_best_effort=engine_on,
            spill_enabled=engine_on,
        ),
    )
    sim = Simulation(cfg)
    t0 = time.perf_counter()
    res = sim.run(qs)
    wall = time.perf_counter() - t0
    s = res.summary()
    imm_waits = [
        q.queue_wait or 0.0
        for q in res.queries
        if q.effective_sla is not None and q.effective_sla.short == "imm"
    ]
    stages = s["stages"]
    return {
        "queries": len(qs),
        "wall_s": round(wall, 2),
        "stages": stages,
        "stages_per_s": int(stages / max(wall, 1e-9)),
        "total_cost": s["total_cost"],
        "violations": s["violations"],
        "imm_p95_wait_s": round(float(np.percentile(imm_waits, 95)), 2)
        if imm_waits
        else 0.0,
        "imm_max_wait_s": round(max(imm_waits), 1) if imm_waits else 0.0,
        "preemptions": s["preemptions"],
        "spilled": s["spilled"],
        "vm_share": round(s["vm_share"], 3),
        "finished": s["finished"],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--factor", type=float, default=55.0,
                    help="Table-1 count multiplier (55 ~= 50k queries/day)")
    ap.add_argument("--fast", action="store_true",
                    help="1/10th scale smoke run")
    args = ap.parse_args()
    factor = args.factor / 10 if args.fast else args.factor
    n_target = int(SEED_DAY_QUERIES * factor)

    rows = {}
    for name, on in (("engine_off", False), ("engine_on", True)):
        rows[name] = run_day(n_target, on)
        print(f"{name}: {json.dumps(rows[name])}")

    on, off = rows["engine_on"], rows["engine_off"]
    derived = {
        "total_wall_s": round(on["wall_s"] + off["wall_s"], 2),
        "imm_wait_reduction": round(
            1 - on["imm_p95_wait_s"] / off["imm_p95_wait_s"], 3
        )
        if off["imm_p95_wait_s"] > 0
        else 0.0,
        "violation_delta": on["violations"] - off["violations"],
        "cost_delta_pct": round(
            100 * (on["total_cost"] / max(off["total_cost"], 1e-9) - 1), 2
        ),
    }
    print(f"derived: {json.dumps(derived)}")


if __name__ == "__main__":
    main()
