"""Scale benchmark: 50k- and 1M-query days through the stage engine.

Drives the Table-1 workload scaled over a 24h horizon in SOS mode:

  engine_off / engine_on — the PR-1 pair: stage-boundary preemption +
      cross-cluster spill OFF vs ON on the two-pool (vm/cf) registry.
  pools3_runqueue / pools3_backlog — the 3-pool registry (reserved v5e +
      elastic CF + cheap CPU-spot) under PR-1's run-queue autoscale
      policy vs backlog-driven autoscale + symmetric spill-back. Both
      rows come from the same run of this script, so the dominance claim
      (lower cost at equal-or-better IMMEDIATE p95 wait) is read off one
      printout.
  pools3_fuse_within / pools3_fuse_cross — the same 3-pool day with
      multi-query fusion on: pending-queue fusion alone vs + cross-pool
      placement-time fusion (docs/fusion.md). Run for seeds 0-2; the
      dominance predicate (cross strictly cheaper at equal-or-better
      IMMEDIATE p95) must hold on every seed.
  pools3_1m — a 1M-query day (~20x) on the 3-pool registry with
      cross-pool fusion, exercising the O(1) hot paths (incremental
      backlog counter, indexed fusion, static-quote caches) at the
      scale the paper's economics actually target.

Reported per row:
  * wall_s / qps    — wall seconds and simulated queries per wall-second
  * imm_p95_wait_s  — IMMEDIATE queries' p95 slice wait
  * violations      — relaxed pending-deadline violations
  * total_cost      — billed chip-seconds at each pool's own price
  * provisioned_cs  — reserved capacity paid for (autoscale footprint)
  * fusion_rate     — fraction of queries that executed in a fused batch

Results are written to BENCH_scale.json (--out). ``speedup_vs_pre_pr``
compares the classic rows' qps against the LOAD-CONTROLLED interleaved
pre-overhaul baseline (PRE_PR_INTERLEAVED — the fair 50k comparison,
~1.6-1.7x); the loaded-session baseline (PRE_PR_WALL_S) is reported as
context only. The structural win is asymptotic — PRE_PR_SCALING: the
old engine's per-event scans stop finishing at all past ~100k
queries/day, the scales this PR targets.

Usage: python benchmarks/scale.py [--factor 55] [--fast] [--skip-1m]
                                  [--out BENCH_scale.json] [--budget-s N]
"""
from __future__ import annotations

import argparse
import dataclasses
import gc
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.core import (  # noqa: E402
    AllocationConfig,
    CalibrationTable,
    CostModel,
    Policy,
    PoolSpec,
    SimConfig,
    Simulation,
    SLAConfig,
)
from repro.core.clusters import AutoscaleConfig  # noqa: E402
from repro.core.workload import generate, scaled_patterns  # noqa: E402

DAY_S = 86_400.0
SEED_DAY_QUERIES = 911  # Table 1 total

#: wall seconds of the four classic rows at --factor 55 (50105 queries)
#: measured at the pre-overhaul commit (PR 4 head) on this machine —
#: the first measurement of the working session (shared host, loaded).
PRE_PR_WALL_S = {
    "engine_off": 10.19,
    "engine_on": 13.54,
    "pools3_runqueue": 17.48,
    "pools3_backlog": 15.15,
}
#: the same pre-overhaul rows re-measured strictly INTERLEAVED with the
#: post-overhaul tree (one old run, one new run, alternating; best of 4
#: reps per row), so both sides saw the same host load. This is the
#: fairest 50k-scale comparison: ~1.6-1.7x per row — at 50k the old
#: code's queues are still shallow, so the O(n) scans it does per event
#: only cost ~40% of its runtime. The structural win is asymptotic:
#: scan depth grows with scale (PRE_PR_SCALING), and past ~100k queries
#: a day the old engine stops finishing at all.
PRE_PR_INTERLEAVED = {
    "pre_pr_wall_s": {"engine_off": 5.49, "engine_on": 6.14,
                      "pools3_runqueue": 6.92, "pools3_backlog": 8.35},
    "post_wall_s": {"engine_off": 3.40, "engine_on": 3.54,
                    "pools3_runqueue": 4.28, "pools3_backlog": 4.93},
    "speedup": {"engine_off": 1.61, "engine_on": 1.73,
                "pools3_runqueue": 1.62, "pools3_backlog": 1.69},
}
PRE_PR_QUERIES = 50105
#: the pre-overhaul code's per-event work grows with queue depth
#: (O(running+waiting) backlog scans per quote, O(n) fused pops), so
#: its wall time diverges superlinearly with scale: at a 200k-query day
#: (factor 220) the pre-overhaul `pools3_backlog` row was killed after
#: 45 minutes WITHOUT completing, where the overhauled engine finishes
#: the same day in ~12-24s — and a 1M-query day (`pools3_1m`) in
#: about a minute, which the old code cannot approach at all.
PRE_PR_SCALING = {
    "pools3_backlog_200k": {"pre_pr_wall_s": ">2700 (killed, unfinished)",
                            "post_overhaul_wall_s": "~12-24"},
}


def _report(sim: Simulation, res, wall: float, n: int) -> dict:
    s = res.summary()
    imm_waits = [
        q.queue_wait or 0.0
        for q in res.queries
        if q.effective_sla is not None and q.effective_sla.short == "imm"
    ]
    stages = s["stages"]
    # capacity accounting: reserved pools pay for every provisioned
    # chip-second (used or idle) up to the last completion; elastic
    # usage is pay-per-use (the billed stage costs). This is what the
    # OPERATOR pays — `total_cost` is what queries are billed — so a
    # policy cannot win the comparison by over-provisioning reserved
    # capacity that the billed costs never see.
    end = max(
        (q.finish_time for q in res.queries if q.finish_time is not None),
        default=0.0,
    )
    reserved_capacity_cost = 0.0
    for p in sim.pools:
        if p.pool_kind == "reserved":
            p.accrue_provisioned(end)  # close the tail interval
            reserved_capacity_cost += (
                p.chip_seconds_provisioned * p.price_per_chip_s
            )
    elastic_names = {p.name for p in sim.pools if p.pool_kind == "elastic"}
    elastic_cost = sum(
        e.cost
        for q in res.queries
        for e in q.stage_trace
        if e.cluster in elastic_names
    )
    provisioned = sum(
        getattr(p, "chip_seconds_provisioned", 0.0) for p in sim.pools
    )
    return {
        "queries": n,
        "wall_s": round(wall, 2),
        "qps": int(n / max(wall, 1e-9)),  # simulated queries per wall-sec
        "stages": stages,
        "stages_per_s": int(stages / max(wall, 1e-9)),
        "total_cost": s["total_cost"],
        "capacity_cost": round(reserved_capacity_cost + elastic_cost, 2),
        "elastic_cost": round(elastic_cost, 2),
        "violations": s["violations"],
        "imm_p95_wait_s": round(float(np.percentile(imm_waits, 95)), 2)
        if imm_waits
        else 0.0,
        "imm_max_wait_s": round(max(imm_waits), 1) if imm_waits else 0.0,
        "preemptions": s["preemptions"],
        "spilled": s["spilled"],
        "spill_backs": s["spill_backs"],
        "fused_queries": s["fused_queries"],
        "fusion_rate": round(s["fused_queries"] / max(n, 1), 3),
        "provisioned_cs": int(provisioned),
        "vm_share": round(s.get("vm_share", 0.0), 3),
        "finished": s["finished"],
    }


def _timed_run(sim: Simulation, qs):
    """Run one simulated day under the wall clock, with the cyclic GC
    paused: the run allocates millions of acyclic objects (queries,
    stage events, heap entries) and generational collections would
    otherwise rescan them constantly."""
    gc.collect()
    gc.disable()
    try:
        t0 = time.perf_counter()
        res = sim.run(qs)
        wall = time.perf_counter() - t0
    finally:
        gc.enable()
    return res, wall


def run_day(n_target: int, engine_on: bool, seed: int = 0,
            repeats: int = 1, profile: bool = False) -> dict:
    """PR-1 baseline: the two-pool vm/cf system, stage policies on/off.
    `repeats` re-runs the (deterministic) day and keeps the best wall —
    per-query results are identical across repeats, so only the timing
    noise of a shared machine is filtered out."""
    factor = n_target / SEED_DAY_QUERIES
    def qs_factory():
        return generate(
            horizon_s=DAY_S, seed=seed, patterns=scaled_patterns(factor)
        )
    cfg = SimConfig(
        policy=Policy.AUTO,
        vm_mode="sos",
        vm_chips=64,
        sos_slice_chips=16,  # 4 isolated SOS slices: contended at 50k/day
        use_calibration=False,
        seed=seed,
        sla=SLAConfig(
            vm_overload_threshold=12,
            preempt_best_effort=engine_on,
            spill_enabled=engine_on,
        ),
    )
    return _finish_row(_best_of(cfg, qs_factory, repeats), profile)


def _best_of(cfg: SimConfig, qs_factory, repeats: int):
    """Run the (deterministic) day `repeats` times on freshly generated
    queries — Query objects are mutated by a run — keeping the best
    wall. Per-query results are identical across repeats, so this only
    filters shared-machine timing noise out of the comparison. Arrival
    generation runs OUTSIDE the gc-paused timed region (the wall numbers
    measure the engine only) and its own wall is kept as `gen_s`."""
    best = None
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        qs = qs_factory()
        gen_s = time.perf_counter() - t0
        sim = Simulation(cfg)
        res, wall = _timed_run(sim, qs)
        if best is None or wall < best[2]:
            best = (sim, res, wall, len(qs), gen_s)
    return best


def _finish_row(best, profile: bool) -> dict:
    """Reduce one day's best run to its bench row. `gen_s` (arrival
    generation, outside the timed region) is always recorded; --profile
    adds the per-phase wall breakdown future perf PRs diff against."""
    sim, res, wall, n, gen_s = best
    t0 = time.perf_counter()
    row = _report(sim, res, wall, n)
    accounting_s = time.perf_counter() - t0
    row["gen_s"] = round(gen_s, 3)
    if profile:
        row["profile"] = {
            "arrival_gen_s": round(gen_s, 3),
            "advance_loop_s": round(wall, 3),
            "accounting_s": round(accounting_s, 3),
        }
    return row


def _pools3_specs(autoscale: AutoscaleConfig) -> list[PoolSpec]:
    """Reserved v5e slices + elastic CF + cheap CPU-spot: the registry's
    heterogeneous frontier. The spot pool is 4x slower per chip at 0.15x
    the price (0.6x the cost per query), so relaxed/BoE work routes there
    and the v5e slices stay free for IMMEDIATE queries."""
    return [
        PoolSpec(name="vm", kind="reserved", chips=autoscale.min_chips,
                 mode="sos", slice_chips=16, autoscale=autoscale),
        PoolSpec(name="spot", kind="reserved", chips=256, mode="sos",
                 slice_chips=16, speed_factor=0.25, price_multiplier=0.15),
        PoolSpec(name="cf", kind="elastic", chips=64, startup_s=2.0,
                 price_multiplier=10.0),
    ]


def _pools3_autoscale(backlog_policy: bool) -> AutoscaleConfig:
    return AutoscaleConfig(
        enabled=True,
        min_chips=32,  # small base reservation: bursts NEED the scaler
        max_chips=48,
        step_chips=16,
        scale_delay_s=180.0,  # acquiring spot capacity takes minutes...
        scale_in_delay_s=5.0,  # ...releasing it is fast
        trigger="backlog" if backlog_policy else "run_queue",
        high_watermark=8,  # run-queue policy: react to queue length
        low_watermark=1,
        backlog_high_s=8.0,  # backlog policy: react to predicted drain
        backlog_low_s=2.0,
    )


def run_day_pools3(
    n_target: int,
    backlog_policy: bool,
    seed: int = 0,
    fuse: bool = False,
    cross_pool_fusion: bool = False,
    repeats: int = 1,
    profile: bool = False,
) -> dict:
    """The 3-pool registry. backlog_policy=False reproduces PR-1's
    policies on it (run-queue autoscale trigger, one-way spill);
    backlog_policy=True turns on backlog-driven autoscale + spill-back.
    Everything else — pools, bounds, provisioning delays — is identical,
    so the two rows isolate the policy difference. `fuse` /
    `cross_pool_fusion` add the fusion layers on top (docs/fusion.md)."""
    factor = n_target / SEED_DAY_QUERIES
    def qs_factory():
        return generate(
            horizon_s=DAY_S, seed=seed, patterns=scaled_patterns(factor)
        )
    cfg = SimConfig(
        policy=Policy.FORCE,  # SLA decides the tier; quotes pick the pool
        use_calibration=False,
        seed=seed,
        fuse_queries=fuse,
        cross_pool_fusion=cross_pool_fusion,
        sla=SLAConfig(
            vm_overload_threshold=12,
            preempt_best_effort=True,
            spill_enabled=True,
            spill_back_enabled=backlog_policy,
            spill_back_low_backlog_s=5.0,
        ),
        pools=_pools3_specs(_pools3_autoscale(backlog_policy)),
    )
    return _finish_row(_best_of(cfg, qs_factory, repeats), profile)


# ---------------------------------------------------------------------------
# per-query chips-per-stage allocation (core/allocation.py)
# ---------------------------------------------------------------------------

#: coordination tax of wider slices, applied to EVERY pool in BOTH arms
#: of the comparison — without it the roofline is exactly chips-linear,
#: chip-seconds are width-independent, and the frontier is degenerate
ALLOC_OVERHEAD = 0.02


def _pools3_alloc_specs(
    autoscale: AutoscaleConfig, alloc: bool
) -> list[PoolSpec]:
    """The pools3 registry under a nonzero parallelism tax. The alloc
    arm lets the autoscaled vm tier size slices per (work, service
    level) over {8, 16}: every level buys the cheapest width whose
    full-plan exec time meets its target — for the day's small serve
    shape that is the cost-optimal 8 at every level (1 + 0.02*7 = 1.14x
    chip-seconds vs the fixed slice's 1.30x), while the day's huge
    shape goes wide wherever 8 would blow the level's exec budget
    (IMMEDIATE falls through to the latency-optimal 16, RELAXED's 100s
    budget also forces 16). The spot tier deliberately stays at the
    fixed slice: it is already 4x slower, and narrowing it pushes its
    quoted finishes past relaxed deadlines — the day then re-routes
    onto the autoscaled vm tier and costs ~30% MORE than fixed-slice
    (measured at 50k, seeds 0-2). Allocation is a per-pool opt-in
    precisely so a throughput tier can sit the sweep out."""
    grid = (
        AllocationConfig(min_chips=8, max_chips=16, step_chips=8,
                         imm_exec_target_s=5.0, rel_exec_target_s=100.0)
        if alloc else None
    )
    return [
        PoolSpec(name="vm", kind="reserved", chips=autoscale.min_chips,
                 mode="sos", slice_chips=16, autoscale=autoscale,
                 parallel_overhead=ALLOC_OVERHEAD, allocation=grid),
        PoolSpec(name="spot", kind="reserved", chips=256, mode="sos",
                 slice_chips=16, speed_factor=0.25, price_multiplier=0.15,
                 parallel_overhead=ALLOC_OVERHEAD),
        PoolSpec(name="cf", kind="elastic", chips=64, startup_s=2.0,
                 price_multiplier=10.0, parallel_overhead=ALLOC_OVERHEAD),
    ]


def run_day_alloc(n_target: int, alloc: bool, seed: int = 0,
                  repeats: int = 1, profile: bool = False) -> dict:
    """One pools3 day with the parallelism tax on, slice width fixed at
    16 (`alloc=False`) vs chosen per (work, level) by the allocator
    (`alloc=True`). The alloc rows also record the plan-cache and
    allocator-memo counters, so the report can assert the frontier
    sweep stayed cached across the whole day."""
    factor = n_target / SEED_DAY_QUERIES
    def qs_factory():
        return generate(
            horizon_s=DAY_S, seed=seed, patterns=scaled_patterns(factor)
        )
    cfg = SimConfig(
        policy=Policy.FORCE,
        use_calibration=False,
        seed=seed,
        sla=SLAConfig(
            vm_overload_threshold=12,
            preempt_best_effort=True,
            spill_enabled=True,
            spill_back_enabled=True,
            spill_back_low_backlog_s=5.0,
        ),
        pools=_pools3_alloc_specs(_pools3_autoscale(True), alloc),
    )
    best = _best_of(cfg, qs_factory, repeats)
    row = _finish_row(best, profile)
    if alloc:
        sim = best[0]
        plan_cache = {}
        allocator = {}
        for p in sim.pools:
            plan_cache[p.name] = p.cost_model.plan_cache_stats()
            if p.allocator is not None:
                allocator[p.name] = p.allocator.stats()
        row["plan_cache"] = plan_cache
        row["allocator"] = allocator
        hits = sum(st["hits"] for st in plan_cache.values())
        misses = sum(st["misses"] for st in plan_cache.values())
        row["plan_cache_hit_rate"] = round(hits / max(hits + misses, 1), 4)
    return row


#: the drift-admission scenario, matching benchmarks/calibration.py:
#: every pool's true speed, with the gated pool DECLARED 2x faster —
#: so its quotes are exactly 2x optimistic (median relative quote
#: error 0.5, the uncalibrated baseline in BENCH_calibration.json)
DRIFT_TRUE_SPEED = {"vm": 1.0, "spot": 0.25, "cf": 1.0}
DRIFT_POOL = "vm"


def drift_admission_report(n_target: int, seed: int = 0) -> dict:
    """Calibrated admission control on a pool declared 2x wrong.

    A ground-truth pools3 day (true speeds) supplies measured vm stage
    walls. The declared model quotes them 2x fast — median relative
    quote error 0.5 exactly. Feeding those (predicted, measured) pairs
    into the drift EWMA trips the gate, and repricing quotes by the
    measured drift ratio collapses the median error to ~0. A second sim
    day then runs with the mis-declared vm pool and its pre-armed drift
    table injected, counting the coordinator's actual interventions."""
    factor = n_target / SEED_DAY_QUERIES
    sla = SLAConfig(vm_overload_threshold=12, preempt_best_effort=True,
                    spill_enabled=True, spill_back_enabled=True,
                    spill_back_low_backlog_s=5.0)

    def specs(declared_2x: bool) -> list[PoolSpec]:
        auto = _pools3_autoscale(True)
        out = []
        for s in _pools3_specs(auto):
            speed = DRIFT_TRUE_SPEED[s.name]
            if declared_2x and s.name == DRIFT_POOL:
                speed *= 2.0
            out.append(dataclasses.replace(s, speed_factor=speed))
        return out

    # ground truth: the day at TRUE speeds -> measured vm stage walls
    qs = generate(horizon_s=DAY_S, seed=seed,
                  patterns=scaled_patterns(factor))
    truth = Simulation(SimConfig(
        policy=Policy.FORCE, use_calibration=False, seed=seed, sla=sla,
        pools=specs(False),
    )).run(qs)
    samples = [
        (q.work, e.index, e.chips, e.finish - e.start)
        for q in truth.queries
        for e in q.stage_trace
        if e.cluster == DRIFT_POOL and e.retries == 0
    ]
    declared = CostModel(use_calibration=False,
                         speed_factor=2.0 * DRIFT_TRUE_SPEED[DRIFT_POOL])

    def _median(vals):
        vals = sorted(vals)
        return vals[len(vals) // 2]

    preds = [
        (declared.plan(work, chips).stages[index].time_s, wall)
        for work, index, chips, wall in samples
    ]
    err_before = _median([abs(p - w) / w for p, w in preds if w > 0])
    # arm the gate with the measured drift, then reprice the same quotes
    table = CalibrationTable(drift_bound=0.25)
    for p, w in preds[:256]:
        if p > 0:
            table.observe_drift(p, w)
    ratio = table.drift_ratio()
    err_repriced = _median([
        abs(p * ratio - w) / w for p, w in preds if w > 0
    ])
    # the intervention count: a sim day on the MIS-DECLARED registry
    # with the armed table injected into the drifted pool
    qs2 = generate(horizon_s=DAY_S, seed=seed,
                   patterns=scaled_patterns(factor))
    gated = Simulation(SimConfig(
        policy=Policy.FORCE, use_calibration=False, seed=seed, sla=sla,
        pools=specs(True), calibrations={DRIFT_POOL: table},
    )).run(qs2)
    return {
        "pool": DRIFT_POOL,
        "n_stage_walls": len(samples),
        "declared_speed": 2.0 * DRIFT_TRUE_SPEED[DRIFT_POOL],
        "true_speed": DRIFT_TRUE_SPEED[DRIFT_POOL],
        "drift_ratio": round(ratio, 4),
        "median_quote_err_declared": round(err_before, 4),
        "median_quote_err_repriced": round(err_repriced, 6),
        "uncalibrated_baseline": 0.5,  # BENCH_calibration.json, offline
        "below_uncalibrated_baseline": bool(err_repriced < 0.5),
        "drift_reprices": gated.drift_reprices,
        "drift_rejects": gated.drift_rejects,
    }


def _alloc_section(rows: dict, n_target: int, args) -> dict:
    """The allocation dominance seeds + the drift-admission report.
    Records the first seed's fixed/alloc pair as bench rows and returns
    the `allocation` section of BENCH_scale.json. The dominance
    predicate — allocation no worse on billed cost at equal-or-better
    IMMEDIATE p95 wait — must hold on EVERY seed."""
    seeds = {}
    for seed in range(args.alloc_seeds):
        fixed = run_day_alloc(n_target, False, seed=seed,
                              repeats=args.repeats)
        alloc = run_day_alloc(n_target, True, seed=seed,
                              repeats=args.repeats)
        dominates = bool(
            alloc["total_cost"] <= fixed["total_cost"]
            and alloc["imm_p95_wait_s"] <= fixed["imm_p95_wait_s"]
        )
        seeds[seed] = {"fixed": fixed, "alloc": alloc,
                       "alloc_dominates_fixed": dominates}
        print(f"pools3_alloc seed {seed}: fixed cost "
              f"{fixed['total_cost']} p95 {fixed['imm_p95_wait_s']} | "
              f"alloc cost {alloc['total_cost']} p95 "
              f"{alloc['imm_p95_wait_s']} hit_rate "
              f"{alloc.get('plan_cache_hit_rate')} dominates {dominates}")
    rows["pools3_fixed_slice"] = seeds[0]["fixed"]
    rows["pools3_alloc"] = seeds[0]["alloc"]
    # the drift-admission scenario stays at the calibration benchmark's
    # ~5k scale: its claim is about quote error, not throughput
    n_drift = min(n_target, 5010)
    drift = drift_admission_report(n_drift)
    print(f"drift_admission: {json.dumps(drift)}")
    return {
        "overhead": ALLOC_OVERHEAD,
        "n_target": n_target,
        "seeds": {
            seed: {
                "fixed_cost": s["fixed"]["total_cost"],
                "alloc_cost": s["alloc"]["total_cost"],
                "alloc_cost_delta_pct": round(100 * (
                    s["alloc"]["total_cost"]
                    / max(s["fixed"]["total_cost"], 1e-9) - 1), 2),
                "fixed_imm_p95": s["fixed"]["imm_p95_wait_s"],
                "alloc_imm_p95": s["alloc"]["imm_p95_wait_s"],
                "plan_cache_hit_rate": s["alloc"].get(
                    "plan_cache_hit_rate"),
                "alloc_dominates_fixed": s["alloc_dominates_fixed"],
            }
            for seed, s in seeds.items()
        },
        "alloc_dominates_fixed_all_seeds": bool(all(
            s["alloc_dominates_fixed"] for s in seeds.values()
        )),
        "sweep_cached_all_seeds": bool(all(
            (s["alloc"].get("plan_cache_hit_rate") or 0.0) > 0.9
            for s in seeds.values()
        )),
        "drift_queries": n_drift,
        "drift_admission": drift,
    }


def _check_alloc(allocation: dict) -> None:
    """The CI allocation gate: frontier dominance on every seed, the
    sweep cached, and the drift gate actually intervening."""
    d = allocation["drift_admission"]
    ok = (
        allocation["alloc_dominates_fixed_all_seeds"]
        and allocation["sweep_cached_all_seeds"]
        and d["drift_reprices"] >= 1
        and d["below_uncalibrated_baseline"]
    )
    if not ok:
        print(f"FAIL: allocation gate: {json.dumps(allocation)}")
        raise SystemExit(1)
    print("allocation gate passed: dominance on every seed, sweep "
          f"cached, {d['drift_reprices']} drift reprices, median quote "
          f"error {d['median_quote_err_repriced']} < "
          f"{d['uncalibrated_baseline']}")


def _write_bench(out_path_str: str, sections: dict) -> None:
    """Merge-preserving write: keys other runs own (the sweep harness's
    `sweep` section, the cross-PR `trajectory` list) survive a re-run —
    each tool updates only its own sections of the one file."""
    out_path = Path(out_path_str)
    out = {}
    if out_path.exists():
        try:
            out = json.loads(out_path.read_text())
        except (json.JSONDecodeError, OSError):
            out = {}
    out.update(sections)
    out_path.write_text(json.dumps(out, indent=2, sort_keys=True) + "\n")
    print(f"wrote {out_path_str}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--factor", type=float, default=55.0,
                    help="Table-1 count multiplier (55 ~= 50k queries/day)")
    ap.add_argument("--fast", action="store_true",
                    help="1/10th scale smoke run (implies --skip-1m)")
    ap.add_argument("--skip-1m", action="store_true",
                    help="skip the 1M-query-day row")
    ap.add_argument("--fuse-seeds", type=int, default=3,
                    help="seeds for the fusion dominance rows (0..N-1)")
    ap.add_argument("--alloc-seeds", type=int, default=3,
                    help="seeds for the allocation dominance rows (0..N-1)")
    ap.add_argument("--alloc-only", action="store_true",
                    help="run only the allocation + drift-admission "
                    "sections (the CI allocation-smoke job)")
    ap.add_argument("--check-alloc", action="store_true",
                    help="fail (exit 1) unless allocation dominates "
                    "fixed-slice on every seed, the sweep stayed cached, "
                    "and the drift gate repriced at least one quote")
    ap.add_argument("--out", default=str(
        Path(__file__).resolve().parents[1] / "BENCH_scale.json"),
        help="write the full result JSON here")
    ap.add_argument("--budget-s", type=float, default=None,
                    help="fail (exit 1) if any row's wall exceeds this "
                    "many seconds — the CI scale-smoke regression gate")
    ap.add_argument("--repeats", type=int, default=1,
                    help="re-run each classic row N times, keep the best "
                    "wall (results are deterministic; filters machine "
                    "noise out of the speedup comparison)")
    ap.add_argument("--profile", action="store_true",
                    help="record a per-phase wall breakdown (arrival gen "
                    "/ advance loop / accounting) in every row")
    args = ap.parse_args()
    factor = args.factor / 10 if args.fast else args.factor
    n_target = int(SEED_DAY_QUERIES * factor)

    if args.alloc_only:
        # the CI allocation-smoke path: only the allocation + drift
        # sections run, and only the "allocation" key of the bench file
        # is rewritten (a smoke run must not clobber a full run's rows)
        rows = {}
        allocation = _alloc_section(rows, n_target, args)
        _write_bench(args.out, {"allocation": allocation})
        if args.check_alloc:
            _check_alloc(allocation)
        return

    rows = {}
    for name, on in (("engine_off", False), ("engine_on", True)):
        rows[name] = run_day(n_target, on, repeats=args.repeats,
                             profile=args.profile)
        print(f"{name}: {json.dumps(rows[name])}")
    for name, backlog in (
        ("pools3_runqueue", False),
        ("pools3_backlog", True),
    ):
        rows[name] = run_day_pools3(n_target, backlog, repeats=args.repeats,
                                    profile=args.profile)
        print(f"{name}: {json.dumps(rows[name])}")

    # fusion rows: within-pool (pending-queue) fusion vs + cross-pool
    # placement-time fusion, across seeds — the dominance predicate
    # must hold on EVERY seed
    fusion_seeds = {}
    for seed in range(args.fuse_seeds):
        within = run_day_pools3(n_target, True, seed=seed, fuse=True)
        cross = run_day_pools3(n_target, True, seed=seed, fuse=True,
                               cross_pool_fusion=True)
        fusion_seeds[seed] = {
            "within": within,
            "cross": cross,
            "cross_dominates_within": bool(
                cross["total_cost"] < within["total_cost"]
                and cross["imm_p95_wait_s"] <= within["imm_p95_wait_s"]
            ),
        }
        print(f"pools3_fuse seed {seed}: within cost "
              f"{within['total_cost']} p95 {within['imm_p95_wait_s']} | "
              f"cross cost {cross['total_cost']} p95 "
              f"{cross['imm_p95_wait_s']} fusion_rate "
              f"{cross['fusion_rate']}")
    if fusion_seeds:
        rows["pools3_fuse_within"] = fusion_seeds[0]["within"]
        rows["pools3_fuse_cross"] = fusion_seeds[0]["cross"]

    if not (args.fast or args.skip_1m):
        # the scaling evidence point: the same no-fusion pools3_backlog
        # config at 4x scale — the pre-overhaul code never finished this
        # day (PRE_PR_SCALING); the O(1) engine treats it as routine
        rows["pools3_200k"] = run_day_pools3(200_000, True,
                                             profile=args.profile)
        print(f"pools3_200k: {json.dumps(rows['pools3_200k'])}")
        # the tentpole row: a 1M-query day (20x) through the same 3-pool
        # registry with cross-pool fusion on
        rows["pools3_1m"] = run_day_pools3(
            1_000_000, True, fuse=True, cross_pool_fusion=True,
            profile=args.profile,
        )
        print(f"pools3_1m: {json.dumps(rows['pools3_1m'])}")

    allocation = (
        _alloc_section(rows, n_target, args) if args.alloc_seeds > 0
        else None
    )

    on, off = rows["engine_on"], rows["engine_off"]
    bl, rq = rows["pools3_backlog"], rows["pools3_runqueue"]
    fw = rows.get("pools3_fuse_within")
    fc = rows.get("pools3_fuse_cross")
    derived = {
        "total_wall_s": round(sum(r["wall_s"] for r in rows.values()), 2),
        "imm_wait_reduction": round(
            1 - on["imm_p95_wait_s"] / off["imm_p95_wait_s"], 3
        )
        if off["imm_p95_wait_s"] > 0
        else 0.0,
        "violation_delta": on["violations"] - off["violations"],
        "cost_delta_pct": round(
            100 * (on["total_cost"] / max(off["total_cost"], 1e-9) - 1), 2
        ),
        # backlog-driven autoscale + spill-back vs PR-1's run-queue
        # policy on the same 3-pool registry, from THIS run
        "pools3_cost_delta_pct": round(
            100 * (bl["total_cost"] / max(rq["total_cost"], 1e-9) - 1), 2
        ),
        "pools3_capacity_cost_delta_pct": round(
            100 * (bl["capacity_cost"] / max(rq["capacity_cost"], 1e-9) - 1), 2
        ),
        "pools3_imm_p95_delta_s": round(
            bl["imm_p95_wait_s"] - rq["imm_p95_wait_s"], 2
        ),
        # dominance must hold under BOTH accountings: billed query cost
        # AND operator capacity cost (provisioned reserved + elastic
        # usage) — otherwise over-provisioning could buy the win
        "backlog_dominates_runqueue": bool(
            bl["total_cost"] < rq["total_cost"]
            and bl["capacity_cost"] < rq["capacity_cost"]
            and bl["imm_p95_wait_s"] <= rq["imm_p95_wait_s"]
        ),
        # cross-pool fusion vs within-pool fusion, per seed AND overall
        "fuse_cross_cost_delta_pct": round(
            100 * (fc["total_cost"] / max(fw["total_cost"], 1e-9) - 1), 2
        ) if fc else None,
        "cross_fusion_dominates_within": bool(fusion_seeds and all(
            s["cross_dominates_within"] for s in fusion_seeds.values()
        )),
        "fusion_seeds": {
            seed: {
                "within_cost": s["within"]["total_cost"],
                "cross_cost": s["cross"]["total_cost"],
                "within_imm_p95": s["within"]["imm_p95_wait_s"],
                "cross_imm_p95": s["cross"]["imm_p95_wait_s"],
                "cross_fusion_rate": s["cross"]["fusion_rate"],
                "cross_dominates_within": s["cross_dominates_within"],
            }
            for seed, s in fusion_seeds.items()
        },
    }
    # hot-path speedup vs the pre-overhaul code, comparable only at the
    # canonical 50k scale (same seeds, same rows, same machine class)
    if n_target == PRE_PR_QUERIES:
        # HEADLINE speedup: against the load-controlled interleaved
        # baseline — the fair comparison. The loaded-session baseline
        # is kept as context only (it flatters this run by however much
        # quieter the machine is now than it was then).
        fair = PRE_PR_INTERLEAVED["pre_pr_wall_s"]
        speedups = {
            name: round(
                (rows[name]["queries"] / rows[name]["wall_s"])
                / (PRE_PR_QUERIES / fair[name]), 2,
            )
            for name in fair
        }
        derived["speedup_vs_pre_pr"] = speedups
        derived["min_speedup_vs_pre_pr"] = min(speedups.values())
        derived["pre_pr_interleaved"] = PRE_PR_INTERLEAVED
        derived["pre_pr_loaded_baseline_wall_s"] = PRE_PR_WALL_S
        derived["speedup_vs_loaded_baseline"] = {
            name: round(
                (rows[name]["queries"] / rows[name]["wall_s"])
                / (PRE_PR_QUERIES / PRE_PR_WALL_S[name]), 2,
            )
            for name in PRE_PR_WALL_S
        }
        derived["pre_pr_scaling"] = PRE_PR_SCALING
    print(f"derived: {json.dumps(derived)}")

    sections = {"rows": rows, "derived": derived,
                "n_target": n_target, "factor": factor}
    if allocation is not None:
        sections["allocation"] = allocation
    _write_bench(args.out, sections)

    if args.check_alloc:
        if allocation is None:
            print("FAIL: --check-alloc needs --alloc-seeds > 0")
            raise SystemExit(1)
        _check_alloc(allocation)
    if args.budget_s is not None:
        over = {
            name: r["wall_s"] for name, r in rows.items()
            if r["wall_s"] > args.budget_s
        }
        if over:
            print(f"FAIL: rows over the {args.budget_s}s wall budget: {over}")
            raise SystemExit(1)
        print(f"all rows within the {args.budget_s}s wall budget")


if __name__ == "__main__":
    main()
