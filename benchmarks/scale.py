"""Scale benchmark: a 50k-query day through the stage-level engine.

Drives the Table-1 workload scaled to ~50k queries over a 24h horizon in
SOS mode, across three systems:

  engine_off / engine_on — the PR-1 pair: stage-boundary preemption +
      cross-cluster spill OFF vs ON on the two-pool (vm/cf) registry.
  pools3_runqueue / pools3_backlog — the 3-pool registry (reserved v5e +
      elastic CF + cheap CPU-spot) under PR-1's run-queue autoscale
      policy vs backlog-driven autoscale + symmetric spill-back. Both
      rows come from the same run of this script, so the dominance claim
      (lower cost at equal-or-better IMMEDIATE p95 wait) is read off one
      printout.

Reported per row:
  * imm_p95_wait_s — IMMEDIATE queries' p95 slice wait
  * violations     — relaxed pending-deadline violations
  * total_cost     — billed chip-seconds at each pool's own price
  * provisioned_cs — reserved capacity paid for (autoscale footprint)

Usage: python benchmarks/scale.py [--factor 55] [--fast]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.core import (  # noqa: E402
    Policy,
    PoolSpec,
    SimConfig,
    Simulation,
    SLAConfig,
)
from repro.core.clusters import AutoscaleConfig  # noqa: E402
from repro.core.workload import generate, scaled_patterns  # noqa: E402

DAY_S = 86_400.0
SEED_DAY_QUERIES = 911  # Table 1 total


def _report(sim: Simulation, res, wall: float, n: int) -> dict:
    s = res.summary()
    imm_waits = [
        q.queue_wait or 0.0
        for q in res.queries
        if q.effective_sla is not None and q.effective_sla.short == "imm"
    ]
    stages = s["stages"]
    # capacity accounting: reserved pools pay for every provisioned
    # chip-second (used or idle) up to the last completion; elastic
    # usage is pay-per-use (the billed stage costs). This is what the
    # OPERATOR pays — `total_cost` is what queries are billed — so a
    # policy cannot win the comparison by over-provisioning reserved
    # capacity that the billed costs never see.
    end = max(
        (q.finish_time for q in res.queries if q.finish_time is not None),
        default=0.0,
    )
    reserved_capacity_cost = 0.0
    for p in sim.pools:
        if p.pool_kind == "reserved":
            p.accrue_provisioned(end)  # close the tail interval
            reserved_capacity_cost += (
                p.chip_seconds_provisioned * p.price_per_chip_s
            )
    elastic_names = {p.name for p in sim.pools if p.pool_kind == "elastic"}
    elastic_cost = sum(
        e.cost
        for q in res.queries
        for e in q.stage_trace
        if e.cluster in elastic_names
    )
    provisioned = sum(
        getattr(p, "chip_seconds_provisioned", 0.0) for p in sim.pools
    )
    return {
        "queries": n,
        "wall_s": round(wall, 2),
        "stages": stages,
        "stages_per_s": int(stages / max(wall, 1e-9)),
        "total_cost": s["total_cost"],
        "capacity_cost": round(reserved_capacity_cost + elastic_cost, 2),
        "elastic_cost": round(elastic_cost, 2),
        "violations": s["violations"],
        "imm_p95_wait_s": round(float(np.percentile(imm_waits, 95)), 2)
        if imm_waits
        else 0.0,
        "imm_max_wait_s": round(max(imm_waits), 1) if imm_waits else 0.0,
        "preemptions": s["preemptions"],
        "spilled": s["spilled"],
        "spill_backs": s["spill_backs"],
        "provisioned_cs": int(provisioned),
        "vm_share": round(s.get("vm_share", 0.0), 3),
        "finished": s["finished"],
    }


def run_day(n_target: int, engine_on: bool, seed: int = 0) -> dict:
    """PR-1 baseline: the two-pool vm/cf system, stage policies on/off."""
    factor = n_target / SEED_DAY_QUERIES
    qs = generate(
        horizon_s=DAY_S, seed=seed, patterns=scaled_patterns(factor)
    )
    cfg = SimConfig(
        policy=Policy.AUTO,
        vm_mode="sos",
        vm_chips=64,
        sos_slice_chips=16,  # 4 isolated SOS slices: contended at 50k/day
        use_calibration=False,
        seed=seed,
        sla=SLAConfig(
            vm_overload_threshold=12,
            preempt_best_effort=engine_on,
            spill_enabled=engine_on,
        ),
    )
    sim = Simulation(cfg)
    t0 = time.perf_counter()
    res = sim.run(qs)
    wall = time.perf_counter() - t0
    return _report(sim, res, wall, len(qs))


def _pools3_specs(autoscale: AutoscaleConfig) -> list[PoolSpec]:
    """Reserved v5e slices + elastic CF + cheap CPU-spot: the registry's
    heterogeneous frontier. The spot pool is 4x slower per chip at 0.15x
    the price (0.6x the cost per query), so relaxed/BoE work routes there
    and the v5e slices stay free for IMMEDIATE queries."""
    return [
        PoolSpec(name="vm", kind="reserved", chips=autoscale.min_chips,
                 mode="sos", slice_chips=16, autoscale=autoscale),
        PoolSpec(name="spot", kind="reserved", chips=256, mode="sos",
                 slice_chips=16, speed_factor=0.25, price_multiplier=0.15),
        PoolSpec(name="cf", kind="elastic", chips=64, startup_s=2.0,
                 price_multiplier=10.0),
    ]


def run_day_pools3(n_target: int, backlog_policy: bool, seed: int = 0) -> dict:
    """The 3-pool registry. backlog_policy=False reproduces PR-1's
    policies on it (run-queue autoscale trigger, one-way spill);
    backlog_policy=True turns on backlog-driven autoscale + spill-back.
    Everything else — pools, bounds, provisioning delays — is identical,
    so the two rows isolate the policy difference."""
    factor = n_target / SEED_DAY_QUERIES
    qs = generate(
        horizon_s=DAY_S, seed=seed, patterns=scaled_patterns(factor)
    )
    autoscale = AutoscaleConfig(
        enabled=True,
        min_chips=32,  # small base reservation: bursts NEED the scaler
        max_chips=48,
        step_chips=16,
        scale_delay_s=180.0,  # acquiring spot capacity takes minutes...
        scale_in_delay_s=5.0,  # ...releasing it is fast
        trigger="backlog" if backlog_policy else "run_queue",
        high_watermark=8,  # run-queue policy: react to queue length
        low_watermark=1,
        backlog_high_s=8.0,  # backlog policy: react to predicted drain
        backlog_low_s=2.0,
    )
    cfg = SimConfig(
        policy=Policy.FORCE,  # SLA decides the tier; quotes pick the pool
        use_calibration=False,
        seed=seed,
        sla=SLAConfig(
            vm_overload_threshold=12,
            preempt_best_effort=True,
            spill_enabled=True,
            spill_back_enabled=backlog_policy,
            spill_back_low_backlog_s=5.0,
        ),
        pools=_pools3_specs(autoscale),
    )
    sim = Simulation(cfg)
    t0 = time.perf_counter()
    res = sim.run(qs)
    wall = time.perf_counter() - t0
    return _report(sim, res, wall, len(qs))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--factor", type=float, default=55.0,
                    help="Table-1 count multiplier (55 ~= 50k queries/day)")
    ap.add_argument("--fast", action="store_true",
                    help="1/10th scale smoke run")
    args = ap.parse_args()
    factor = args.factor / 10 if args.fast else args.factor
    n_target = int(SEED_DAY_QUERIES * factor)

    rows = {}
    for name, on in (("engine_off", False), ("engine_on", True)):
        rows[name] = run_day(n_target, on)
        print(f"{name}: {json.dumps(rows[name])}")
    for name, backlog in (
        ("pools3_runqueue", False),
        ("pools3_backlog", True),
    ):
        rows[name] = run_day_pools3(n_target, backlog)
        print(f"{name}: {json.dumps(rows[name])}")

    on, off = rows["engine_on"], rows["engine_off"]
    bl, rq = rows["pools3_backlog"], rows["pools3_runqueue"]
    derived = {
        "total_wall_s": round(sum(r["wall_s"] for r in rows.values()), 2),
        "imm_wait_reduction": round(
            1 - on["imm_p95_wait_s"] / off["imm_p95_wait_s"], 3
        )
        if off["imm_p95_wait_s"] > 0
        else 0.0,
        "violation_delta": on["violations"] - off["violations"],
        "cost_delta_pct": round(
            100 * (on["total_cost"] / max(off["total_cost"], 1e-9) - 1), 2
        ),
        # the tentpole claim, both numbers from THIS run: backlog-driven
        # autoscale + spill-back vs PR-1's run-queue policy on the same
        # 3-pool registry
        "pools3_cost_delta_pct": round(
            100 * (bl["total_cost"] / max(rq["total_cost"], 1e-9) - 1), 2
        ),
        "pools3_capacity_cost_delta_pct": round(
            100 * (bl["capacity_cost"] / max(rq["capacity_cost"], 1e-9) - 1), 2
        ),
        "pools3_imm_p95_delta_s": round(
            bl["imm_p95_wait_s"] - rq["imm_p95_wait_s"], 2
        ),
        # dominance must hold under BOTH accountings: billed query cost
        # AND operator capacity cost (provisioned reserved + elastic
        # usage) — otherwise over-provisioning could buy the win
        "backlog_dominates_runqueue": bool(
            bl["total_cost"] < rq["total_cost"]
            and bl["capacity_cost"] < rq["capacity_cost"]
            and bl["imm_p95_wait_s"] <= rq["imm_p95_wait_s"]
        ),
    }
    print(f"derived: {json.dumps(derived)}")


if __name__ == "__main__":
    main()
