"""Process-sharded sweep harness: N (scenario, seed, policy) cells in
parallel workers, merged into one BENCH_scale.json.

Reproducing the paper's headline economics (−65.5% resource cost on CAB
days without pending-SLA violations) takes sweeps over policies × seeds
× scenarios, not single days — and every ROADMAP scale item is gated on
sweep throughput. This harness shards the grid across worker PROCESSES
(the simulator is pure Python + numpy: threads would serialize on the
GIL) and merges per-cell rows into the shared bench JSON.

Determinism (docs/sweeps.md):
  * The cell grid is enumerated in a fixed order (scenario list order ×
    seed index), and every per-cell RNG derives from one
    ``np.random.SeedSequence.spawn`` tree: root(master_seed) spawns one
    child per cell by cell INDEX, and each child spawns the pair
    (workload rng, simulation rng). No RNG state is shared across
    cells, so results are a function of the cell spec alone.
  * Rows are merged keyed by cell id, so worker count, scheduling, and
    completion order cannot change the output: a sharded sweep and its
    serial replay (``--workers 1``) are bit-identical per query — each
    row carries a SHA-256 over every query's exact result floats (and a
    completion-order hash), asserted in tests/test_vectorized.py and
    gated against tests/golden/sweep_cells.json in CI (--check-golden).

Usage:
  python benchmarks/sweep.py --scenarios engine_off,pools3_backlog \
      --seeds 4 --n 5000 --workers 8 --budget-s 300
  python benchmarks/sweep.py --check-golden tests/golden/sweep_cells.json
"""
from __future__ import annotations

import argparse
import gc
import hashlib
import json
import multiprocessing
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import numpy as np  # noqa: E402

from benchmarks.scale import (  # noqa: E402
    DAY_S,
    SEED_DAY_QUERIES,
    _pools3_autoscale,
    _pools3_specs,
)
from repro.core import Policy, SimConfig, Simulation, SLAConfig  # noqa: E402
from repro.core.query import reset_qids  # noqa: E402
from repro.core.workload import generate, scaled_patterns  # noqa: E402

#: every sweepable scenario — the four classic rows plus the fusion day
SCENARIOS = (
    "engine_off",
    "engine_on",
    "pools3_runqueue",
    "pools3_backlog",
    "pools3_fuse_cross",
)


def scenario_cfg(scenario: str, seed) -> SimConfig:
    """The SimConfig of one sweep scenario. `seed` may be an int or a
    SeedSequence (numpy's default_rng accepts both); the sweep passes
    each cell's spawned child so no two cells share RNG state."""
    if scenario in ("engine_off", "engine_on"):
        on = scenario == "engine_on"
        return SimConfig(
            policy=Policy.AUTO, vm_mode="sos", vm_chips=64,
            sos_slice_chips=16, use_calibration=False, seed=seed,
            sla=SLAConfig(vm_overload_threshold=12,
                          preempt_best_effort=on, spill_enabled=on),
        )
    if scenario in ("pools3_runqueue", "pools3_backlog", "pools3_fuse_cross"):
        backlog = scenario != "pools3_runqueue"
        fuse = scenario == "pools3_fuse_cross"
        return SimConfig(
            policy=Policy.FORCE, use_calibration=False, seed=seed,
            fuse_queries=fuse, cross_pool_fusion=fuse,
            sla=SLAConfig(vm_overload_threshold=12, preempt_best_effort=True,
                          spill_enabled=True, spill_back_enabled=backlog,
                          spill_back_low_backlog_s=5.0),
            pools=_pools3_specs(_pools3_autoscale(backlog)),
        )
    raise ValueError(f"unknown scenario {scenario!r} (expected {SCENARIOS})")


def build_cells(scenarios, n_seeds: int, n_target: int,
                master_seed: int) -> list[dict]:
    """The deterministic cell grid. Cell order — and therefore which
    SeedSequence child each cell receives — depends only on the
    (scenarios, n_seeds, n_target, master_seed) arguments, never on
    worker scheduling."""
    cells = [
        {
            "cell": f"{scenario}:n{n_target}:s{si}",
            "scenario": scenario,
            "seed_index": si,
            "n_target": n_target,
            "master_seed": master_seed,
        }
        for scenario in scenarios
        for si in range(n_seeds)
    ]
    children = np.random.SeedSequence(master_seed).spawn(len(cells))
    for cell, child in zip(cells, children):
        cell["ss"] = child
    return cells


def _fingerprint(res) -> tuple[str, str]:
    """(sorted-by-qid result hash, completion-order hash) over every
    query's exact floats — repr round-trips IEEE doubles losslessly, so
    equal hashes mean bit-identical per-query results."""
    h = hashlib.sha256()
    for q in sorted(res.queries, key=lambda q: q.qid):
        h.update(
            f"{q.qid}|{q.cost!r}|{q.chip_seconds!r}|{q.finish_time!r}|"
            f"{q.start_time!r}|{q.cluster}|{len(q.stage_trace)}|"
            f"{q.retries}|{q.preemptions}|{q.spilled}|"
            f"{q.spill_backs}\n".encode()
        )
    ho = hashlib.sha256()
    for q in res.queries:
        ho.update(f"{q.qid},".encode())
    return h.hexdigest(), ho.hexdigest()


def run_cell(cell: dict) -> dict:
    """Worker entry point: run one cell, return its merged-row dict.
    Pure function of the cell spec (including its SeedSequence child):
    safe under any worker count or completion order. Qids restart at 0
    per cell, so the fingerprints don't depend on what else ran in this
    worker process before."""
    reset_qids()
    gen_ss, sim_ss = cell["ss"].spawn(2)
    factor = cell["n_target"] / SEED_DAY_QUERIES
    t0 = time.perf_counter()
    qs = generate(horizon_s=DAY_S, seed=gen_ss,
                  patterns=scaled_patterns(factor))
    gen_s = time.perf_counter() - t0
    sim = Simulation(scenario_cfg(cell["scenario"], sim_ss))
    gc.collect()
    gc.disable()
    try:
        t0 = time.perf_counter()
        res = sim.run(qs)
        wall = time.perf_counter() - t0
    finally:
        gc.enable()
    t0 = time.perf_counter()
    sha, order_sha = _fingerprint(res)
    s = res.summary()
    imm_waits = [
        q.queue_wait or 0.0
        for q in res.queries
        if q.effective_sla is not None and q.effective_sla.short == "imm"
    ]
    accounting_s = time.perf_counter() - t0
    return {
        "cell": cell["cell"],
        "scenario": cell["scenario"],
        "seed_index": cell["seed_index"],
        "master_seed": cell["master_seed"],
        "n": len(qs),
        "wall_s": round(wall, 3),
        "gen_s": round(gen_s, 3),
        "accounting_s": round(accounting_s, 3),
        "qps": int(len(qs) / max(wall, 1e-9)),
        "stages": s["stages"],
        "total_cost": s["total_cost"],
        "violations": s["violations"],
        "preemptions": s["preemptions"],
        "spilled": s["spilled"],
        "spill_backs": s["spill_backs"],
        "fused_queries": s["fused_queries"],
        "imm_p95_wait_s": round(float(np.percentile(imm_waits, 95)), 2)
        if imm_waits else 0.0,
        "sha256": sha,
        "order_sha256": order_sha,
    }


def run_sweep(cells: list[dict], workers: int,
              budget_s: float | None = None) -> tuple[dict, float]:
    """Run the grid, sharded over `workers` forked processes (serial
    in-process when workers <= 1), and merge rows keyed by cell id.
    Returns (rows, sweep wall seconds). ``budget_s`` is a hard guard:
    blowing it raises SystemExit(1) mid-collection."""
    t0 = time.perf_counter()
    rows: dict[str, dict] = {}

    def _take(row: dict) -> None:
        rows[row["cell"]] = row
        if budget_s is not None and time.perf_counter() - t0 > budget_s:
            print(f"FAIL: sweep exceeded the {budget_s}s wall budget "
                  f"after {len(rows)}/{len(cells)} cells")
            raise SystemExit(1)

    if workers <= 1:
        for cell in cells:
            _take(run_cell(cell))
    else:
        # fork: workers inherit the loaded modules; the simulator is
        # pure Python + numpy so there are no thread-state hazards
        ctx = multiprocessing.get_context("fork")
        with ctx.Pool(workers) as pool:
            it = pool.imap_unordered(run_cell, cells)
            while len(rows) < len(cells):
                try:
                    row = (it.next() if budget_s is None
                           else it.next(timeout=max(
                               budget_s - (time.perf_counter() - t0), 0.1)))
                except multiprocessing.TimeoutError:
                    pool.terminate()
                    print(f"FAIL: sweep exceeded the {budget_s}s wall "
                          f"budget after {len(rows)}/{len(cells)} cells")
                    raise SystemExit(1)
                _take(row)
    return rows, time.perf_counter() - t0


def merge_out(out_path: Path, rows: dict, meta: dict,
              profile: bool) -> float:
    """Merge the sweep rows into the shared bench JSON, preserving every
    section other tools own (benchmarks/scale.py's `rows`/`derived`),
    and append a cross-PR trajectory entry. Returns merge wall secs."""
    t0 = time.perf_counter()
    out = {}
    if out_path.exists():
        try:
            out = json.loads(out_path.read_text())
        except (json.JSONDecodeError, OSError):
            out = {}
    sweep = out.setdefault("sweep", {})
    sweep["cells"] = {k: rows[k] for k in sorted(rows)}
    sweep["meta"] = meta
    if profile:
        sweep["profile"] = {
            "arrival_gen_s": round(sum(r["gen_s"] for r in rows.values()), 3),
            "advance_loop_s": round(sum(r["wall_s"] for r in rows.values()), 3),
            "accounting_s": round(
                sum(r["accounting_s"] for r in rows.values()), 3),
            "merge_s": None,  # patched below, after the write is timed
        }
    out.setdefault("trajectory", []).append({
        "label": meta["label"],
        "sweep_cells": meta["cells"],
        "concurrent_workers": meta["workers"],
        "sweep_wall_s": meta["wall_s"],
        "sim_queries": meta["sim_queries"],
        "agg_qps": meta["agg_qps"],
    })
    merge_s = round(time.perf_counter() - t0, 3)
    if profile:
        sweep["profile"]["merge_s"] = merge_s
    out_path.write_text(json.dumps(out, indent=2, sort_keys=True) + "\n")
    return merge_s


def check_golden(rows: dict, golden_path: Path) -> int:
    """CI drift gate: every golden cell must exist in the sweep with a
    bit-identical per-query fingerprint. Returns the number of drifts."""
    golden = json.loads(golden_path.read_text())
    drifts = 0
    for cell_id, want in golden["cells"].items():
        got = rows.get(cell_id)
        if got is None:
            print(f"DRIFT {cell_id}: missing from sweep")
            drifts += 1
            continue
        for f in ("sha256", "order_sha256", "n", "total_cost"):
            if got[f] != want[f]:
                print(f"DRIFT {cell_id}.{f}: {got[f]!r} != golden "
                      f"{want[f]!r}")
                drifts += 1
    if not drifts:
        print(f"golden check OK: {len(golden['cells'])} cells bit-identical")
    return drifts


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenarios", default=",".join(SCENARIOS),
                    help="comma-separated scenario list")
    ap.add_argument("--seeds", type=int, default=4,
                    help="seed indices 0..N-1 per scenario")
    ap.add_argument("--n", type=int, default=5000,
                    help="queries per simulated day (per cell)")
    ap.add_argument("--workers", type=int, default=None,
                    help="worker processes (default: one per cell, "
                    "capped at 20)")
    ap.add_argument("--master-seed", type=int, default=0)
    ap.add_argument("--budget-s", type=float, default=None,
                    help="hard sweep wall budget: exceed it -> exit 1")
    ap.add_argument("--out", default=str(
        Path(__file__).resolve().parents[1] / "BENCH_scale.json"))
    ap.add_argument("--label", default="sweep",
                    help="trajectory entry label (e.g. the PR number)")
    ap.add_argument("--profile", action="store_true",
                    help="record the per-phase wall breakdown "
                    "(arrival gen / advance loop / accounting / merge)")
    ap.add_argument("--check-golden", default=None,
                    help="compare cells against this golden JSON and "
                    "exit 1 on any drift")
    ap.add_argument("--write-golden", default=None,
                    help="write the cells' fingerprints as a golden JSON")
    args = ap.parse_args()

    scenarios = [s for s in args.scenarios.split(",") if s]
    cells = build_cells(scenarios, args.seeds, args.n, args.master_seed)
    workers = (min(len(cells), 20) if args.workers is None
               else args.workers)
    print(f"sweep: {len(cells)} cells "
          f"({len(scenarios)} scenarios x {args.seeds} seeds, "
          f"n={args.n}/day), {workers} workers")
    rows, wall = run_sweep(cells, workers, args.budget_s)

    sim_queries = sum(r["n"] for r in rows.values())
    meta = {
        "label": args.label,
        "master_seed": args.master_seed,
        "n_target": args.n,
        "scenarios": scenarios,
        "seeds": args.seeds,
        "cells": len(cells),
        "workers": workers,
        "wall_s": round(wall, 2),
        "sim_queries": sim_queries,
        # queries simulated per wall-second ACROSS the sweep — the
        # number the ">= 20 concurrent cells" acceptance reads, next to
        # the single-core per-cell qps inside each row
        "agg_qps": int(sim_queries / max(wall, 1e-9)),
        "budget_s": args.budget_s,
    }
    for k in sorted(rows):
        r = rows[k]
        print(f"  {k}: wall {r['wall_s']}s qps {r['qps']} "
              f"cost {r['total_cost']} sha {r['sha256'][:12]}…")
    print(f"sweep wall {meta['wall_s']}s, {meta['agg_qps']} q/s aggregate"
          + (f" (budget {args.budget_s}s: OK)" if args.budget_s else ""))

    merge_s = merge_out(Path(args.out), rows, meta, args.profile)
    print(f"merged into {args.out} ({merge_s}s)")

    if args.write_golden:
        golden = {
            "master_seed": args.master_seed,
            "n_target": args.n,
            "cells": {
                k: {f: rows[k][f]
                    for f in ("sha256", "order_sha256", "n", "total_cost")}
                for k in sorted(rows)
            },
        }
        Path(args.write_golden).write_text(
            json.dumps(golden, indent=2, sort_keys=True) + "\n")
        print(f"wrote golden {args.write_golden}")
    if args.check_golden:
        if check_golden(rows, Path(args.check_golden)):
            raise SystemExit(1)


if __name__ == "__main__":
    main()
