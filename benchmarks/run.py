"""Benchmark harness: one function per paper table/figure + the roofline
table. Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks import paper, roofline  # noqa: E402


def _run(name, fn):
    t0 = time.perf_counter()
    rows, derived = fn()
    dt_us = (time.perf_counter() - t0) * 1e6
    print(f"{name},{dt_us:.0f},{json.dumps(derived)}")
    return rows, derived


def main() -> None:
    print("name,us_per_call,derived")
    _run("table1_workloads", paper.table1_workloads)
    _run("fig5_stream", paper.fig5_stream)
    _run("fig6_exec_time", paper.fig6_exec_time)
    rows7, d7 = _run("fig7_cost", paper.fig7_cost)
    _run("sla_guarantees", paper.sla_guarantees)
    _run("sos_vs_pos_determinism", paper.sos_vs_pos_determinism)
    _run("stage_engine", paper.stage_engine)
    _run("beyond_paper", paper.beyond_paper)

    def _roofline():
        rows = roofline.roofline_rows(roofline.load_records())
        ok = [r for r in rows if r.get("status") == "ok"]
        derived = {
            "cells": len(rows),
            "ok": len(ok),
            "median_roofline_frac": round(
                sorted(r["roofline_frac"] for r in ok)[len(ok) // 2], 3
            ) if ok else None,
        }
        return rows, derived

    rows, _ = _run("roofline_table", _roofline)

    def _variants():
        vr = roofline.variant_rows()
        derived = {
            "cells_improved": len(vr),
            "max_speedup": round(max((r["speedup"] for r in vr), default=1), 2),
            "median_speedup": round(
                sorted(r["speedup"] for r in vr)[len(vr) // 2], 2
            ) if vr else 1.0,
        }
        return vr, derived

    vrows, _ = _run("perf_variants", _variants)

    # human-readable appendix
    print("\n--- fig7 detail ---")
    for k, v in rows7.items():
        print(f"  {k}: {v}")
    print("\n--- §Perf: baseline vs best measured variant ---")
    for r in vrows:
        print(f"  {r['arch']:24s} {r['shape']:12s} {r['mesh']:8s}"
              f" {r['variant']:18s} {r['baseline_s']*1e3:9.2f} ->"
              f" {r['optimized_s']*1e3:9.2f} ms  ({r['speedup']:.2f}x)")

    print("\n--- roofline table (baseline variant) ---")
    print(roofline.fmt_table(rows))


if __name__ == "__main__":
    main()
