"""The chaos day: fault-injected soak of the convergence control plane.

Runs a seeded multi-thousand-query day through the 3-pool registry with
worker deaths, provisioning stalls, and persistent slow hosts injected
(core/chaos.py), and gates on the robustness contract (docs/convergence.md):

  * every query reaches a terminal state — deaths can never strand work,
  * billing conservation holds over the whole fault-injected population
    (and REPRO_SANITIZE=1 asserts it again inside the run),
  * the recorded day REPLAYS bit-identically: same seeds => same event-
    feed fingerprint and same per-query result hash,
  * SLA degradation is graceful: the relaxed-deadline violation rate on
    the chaos day stays within `--grace` of the fault-free baseline.

`--live` adds a thread-backed smoke: a seeded LiveChaos kills real
worker threads mid-stage; the drain must return with every query
terminal and the plane's respawn/resume counters moving.

Usage:
    PYTHONPATH=src python benchmarks/chaos.py --fast --check
    PYTHONPATH=src python benchmarks/chaos.py --live --check
"""
from __future__ import annotations

import argparse
import hashlib
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.scale import (  # noqa: E402
    DAY_S,
    SEED_DAY_QUERIES,
    _pools3_autoscale,
    _pools3_specs,
    _write_bench,
)
from repro.core import Policy, SimConfig, Simulation, SLAConfig  # noqa: E402
from repro.core.chaos import ChaosConfig  # noqa: E402
from repro.core.query import reset_qids  # noqa: E402
from repro.core.workload import generate, scaled_patterns  # noqa: E402


def _day_cfg(n_target: int, seed: int, chaos: bool) -> SimConfig:
    cc = None
    if chaos:
        cc = ChaosConfig(
            seed=seed + 1_000,
            n_deaths=8,              # repeated capacity losses...
            death_pools=("vm", "spot"),
            horizon_s=DAY_S,
            stall_prob=0.4,          # ...whose replacements stall...
            slow_host_frac=0.1,      # ...on a 10%-degraded fleet
            slow_factor=1.5,
        )
    return SimConfig(
        policy=Policy.AUTO, use_calibration=False, seed=seed,
        sla=SLAConfig(vm_overload_threshold=8, preempt_best_effort=True,
                      spill_enabled=True, spill_back_enabled=True,
                      spill_back_low_backlog_s=5.0),
        pools=_pools3_specs(_pools3_autoscale(True)),
        events=True, chaos=cc,
    )


def _result_hash(res) -> str:
    """Per-query bit-identity hash (benchmarks/_rowhash.py idiom)."""
    h = hashlib.sha256()
    for q in sorted(res.queries, key=lambda q: q.qid):
        h.update(
            f"{q.qid}|{q.cost!r}|{q.chip_seconds!r}|{q.finish_time!r}|"
            f"{q.cluster}|{q.state}|{q.retries}|{q.preemptions}\n".encode()
        )
    return h.hexdigest()


def _conservation_gap(res) -> float:
    """|population billed - population traced| / billed (traces are
    shared by fused members: dedupe by identity)."""
    traces = {id(q.stage_trace): q.stage_trace
              for q in res.queries if q.stage_trace}
    traced = sum(e.cost for tr in traces.values() for e in tr)
    billed = sum(q.cost for q in res.queries)
    return abs(traced - billed) / max(abs(billed), 1e-12)


def _run_day(n_target: int, seed: int, chaos: bool) -> dict:
    factor = n_target / SEED_DAY_QUERIES
    reset_qids()  # replay contract: qids are part of the recorded day
    qs = generate(horizon_s=DAY_S, seed=seed, patterns=scaled_patterns(factor))
    t0 = time.perf_counter()
    res = Simulation(_day_cfg(n_target, seed, chaos)).run(qs)
    wall = time.perf_counter() - t0
    s = res.summary()
    non_terminal = sum(q.state != "done" for q in res.queries)
    return {
        "n": s["n"],
        "wall_s": round(wall, 2),
        "non_terminal": non_terminal,
        "violations": s["violations"],
        "violation_rate": s["violations"] / max(s["n"], 1),
        "total_cost": round(s["total_cost"], 2),
        "retries": s["retries"],
        "conservation_gap": _conservation_gap(res),
        "event_counts": dict(res.events.counts()) if res.events else {},
        "feed_fingerprint": res.events.fingerprint() if res.events else None,
        "result_hash": _result_hash(res),
    }


def run_chaos_section(n_target: int, seed: int, grace: float) -> dict:
    baseline = _run_day(n_target, seed, chaos=False)
    a = _run_day(n_target, seed, chaos=True)
    b = _run_day(n_target, seed, chaos=True)  # the replay
    deg = a["violation_rate"] - baseline["violation_rate"]
    section = {
        "baseline": baseline,
        "chaos": a,
        "replay_identical": (
            a["feed_fingerprint"] == b["feed_fingerprint"]
            and a["result_hash"] == b["result_hash"]
        ),
        "sla_degradation": round(deg, 4),
        "grace_budget": grace,
        "gate": {
            "all_terminal": a["non_terminal"] == 0,
            "conserving": a["conservation_gap"] < 1e-9,
            "faults_landed": (
                a["event_counts"].get("death", 0) > 0
                and a["event_counts"].get("replace", 0) > 0
            ),
            "graceful": deg <= grace,
        },
    }
    section["gate"]["replay_identical"] = section["replay_identical"]
    section["passed"] = all(section["gate"].values())
    return section


def run_live_smoke(seed: int = 3, n: int = 24) -> dict:
    """Thread-backed chaos: seeded mid-stage worker kills; the drain
    must return every query terminal with the plane healing behind it."""
    from repro.core.chaos import ChaosConfig as CC
    from repro.core.chaos import install_live_chaos
    from repro.core.live import LiveConfig, LiveEngine
    from repro.core.pools import PoolSpec
    from repro.core.query import Query, QueryWork
    from repro.core.sla import ServiceLevel

    reset_qids()
    eng = LiveEngine(LiveConfig(
        pools=[PoolSpec(name="vm", kind="reserved", chips=2),
               PoolSpec(name="cf", kind="elastic", chips=2, startup_s=0.05,
                        price_multiplier=10.0)],
        sla=SLAConfig(relaxed_deadline_s=10.0, poll_period_s=0.02,
                      vm_overload_threshold=1_000),
        stage_deadline_s=1.0, convergence=True, events=True,
    ))
    install_live_chaos(eng, CC(seed=seed, live_death_prob=0.12))
    t0 = time.perf_counter()
    queries = []
    for i in range(n):
        sla = (ServiceLevel.IMMEDIATE if i % 3 == 0
               else ServiceLevel.BEST_EFFORT)
        q = Query(work=QueryWork(arch="paper-default", batch=1), sla=sla,
                  submit_time=0.0)
        queries.append(q)
        eng.submit(q)
    done = eng.drain(n, timeout=120.0)
    wall = time.perf_counter() - t0
    terminal = sum(q.state in ("done", "failed") for q in queries)
    failed_with_error = all(
        q.error is not None for q in queries if q.state == "failed"
    )
    counts = dict(eng.events.counts()) if eng.events else {}
    return {
        "n": n,
        "wall_s": round(wall, 2),
        "drained": len(done),
        "terminal": terminal,
        "deaths": eng.plane.deaths,
        "replacements": eng.plane.replacements,
        "resumes": eng.plane.resumes,
        "event_counts": counts,
        "gate": {
            "all_terminal": terminal == n and len(done) == n,
            "errors_surfaced": failed_with_error,
            "chaos_fired": eng.plane.deaths > 0,
            "healed": eng.plane.replacements > 0,
        },
        "passed": (terminal == n and len(done) == n and failed_with_error
                   and eng.plane.deaths > 0 and eng.plane.replacements > 0),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--factor", type=float, default=5.5,
                    help="day size multiplier (5.5 ~= the 5k-query day)")
    ap.add_argument("--fast", action="store_true",
                    help="1/5th scale smoke run")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--grace", type=float, default=0.10,
                    help="max allowed relaxed-violation-rate increase on "
                    "the chaos day vs the fault-free baseline")
    ap.add_argument("--live", action="store_true",
                    help="also run the thread-backed live chaos smoke")
    ap.add_argument("--check", action="store_true",
                    help="fail (exit 1) unless every gate holds")
    ap.add_argument("--out", default=str(
        Path(__file__).resolve().parents[1] / "BENCH_scale.json"))
    args = ap.parse_args()
    factor = args.factor / 5 if args.fast else args.factor
    n_target = int(SEED_DAY_QUERIES * factor)

    section = run_chaos_section(n_target, args.seed, args.grace)
    if args.live:
        section["live"] = run_live_smoke()
    _write_bench(args.out, {"chaos": section})
    print(json.dumps({k: v for k, v in section.items()
                      if k in ("gate", "sla_degradation", "passed")},
                     indent=2))
    if args.check:
        ok = section["passed"] and (
            section["live"]["passed"] if args.live else True
        )
        if not ok:
            print("FAIL: chaos gate")
            raise SystemExit(1)
        print("chaos gate passed: every query terminal, conservation "
              "holds, the day replays bit-identically, degradation "
              f"{section['sla_degradation']} <= {args.grace}")


if __name__ == "__main__":
    main()
