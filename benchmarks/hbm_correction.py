"""Annotate dry-run records with a TPU-corrected HBM estimate.

The CPU backend upcasts every bf16 dot operand to f32 (verified: a bf16
matmul's compiled module contains `convert bf16->f32` fusions of the full
weight, doubling temp bytes — see EXPERIMENTS.md §Dry-run). TPU executes
bf16 natively, so `memory_analysis()` from this container OVERSTATES HBM:

  corrected = raw - 2 * bf16_static_args          (f32 copies of weights/caches)
            - bf16_resid_estimate (train only)    (f32 copies of saved carries)

Static argument bytes are exact (recomputed from the program specs and
sharding rules with a shape-only mesh — no devices needed). The residual
estimate is L x B_local/mb x S x D x 2B (the remat-saved layer inputs).
"""
from __future__ import annotations

import json
import math
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config, get_shape  # noqa: E402
from repro.data.batches import prefill_specs, train_specs  # noqa: E402
from repro.models.transformer import LM  # noqa: E402
from repro.parallel.sharding import rules_for, spec_for  # noqa: E402

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"
HBM = 16 * 1024**3


class ShapeMesh:
    def __init__(self, multi_pod: bool):
        self.shape = (
            {"pod": 2, "data": 16, "model": 16} if multi_pod else
            {"data": 16, "model": 16}
        )


def _shard_bytes(sds, axes, rules, mesh) -> int:
    spec = spec_for(sds.shape, axes, rules, mesh)
    denom = 1
    for entry in spec:
        if entry is None:
            continue
        for a in entry if isinstance(entry, tuple) else (entry,):
            denom *= mesh.shape[a]
    return math.prod(sds.shape) * sds.dtype.itemsize // denom


def static_args(arch: str, shape: str, multi_pod: bool) -> dict:
    cell = get_shape(shape)
    cfg = get_config(arch)
    model = LM(cfg)
    mesh = ShapeMesh(multi_pod)
    kind = "long" if cell.name == "long_500k" else cell.kind
    rules = rules_for(kind, multi_pod=multi_pod)
    out = {"bf16": 0, "f32": 0, "other": 0}

    def add(axes, sds):
        b = _shard_bytes(sds, axes, rules, mesh)
        key = {jnp.bfloat16: "bf16", jnp.float32: "f32"}.get(
            sds.dtype.type, "other"
        )
        out[key] += b

    if cell.kind == "train":
        pshapes = model.param_shapes(jnp.float32)
        paxes = model.param_axes()
        for _ in range(3):  # params + adam m + adam v
            jax.tree.map(
                add, paxes, pshapes, is_leaf=lambda x: isinstance(x, tuple)
            )
        for k, v in train_specs(cfg, cell).items():
            add(("batch", "seq") if v.ndim == 2 else ("batch", "seq", "embed"), v)
    else:
        pshapes = model.param_shapes(jnp.bfloat16)
        paxes = model.param_axes()
        jax.tree.map(add, paxes, pshapes, is_leaf=lambda x: isinstance(x, tuple))
        if cell.kind == "decode":
            cs = model.cache_spec(cell.global_batch, cell.seq_len,
                                  enc_len=cell.seq_len if cfg.is_encoder_decoder else None)
            cax = model.cache_axes(cs)
            jax.tree.map(add, cax, cs, is_leaf=lambda x: isinstance(x, tuple))
        else:
            for k, v in prefill_specs(cfg, cell).items():
                add(("batch", "seq") if v.ndim == 2 else ("batch", "seq", "embed"), v)
    return out


def resid_estimate(arch: str, shape: str, multi_pod: bool, microbatches: int) -> int:
    cell = get_shape(shape)
    if cell.kind != "train":
        return 0
    cfg = get_config(arch)
    shards = 32 if multi_pod else 16
    b_local = max(1, cell.global_batch // shards // max(microbatches, 1))
    layers = cfg.num_layers + (cfg.num_encoder_layers or 0)
    return layers * b_local * cell.seq_len * cfg.d_model * 2


def main():
    over_raw, over_corr = [], []
    for p in sorted(RESULTS.glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("status") != "ok" or rec.get("variant", "baseline") != "baseline":
            continue
        multi = rec["mesh"] == "2x16x16"
        st = static_args(rec["arch"], rec["shape"], multi)
        mb = rec.get("full", {}).get("microbatches") or 1
        resid = resid_estimate(rec["arch"], rec["shape"], multi, mb)
        raw = rec["full"]["per_device_bytes_estimate"]
        corrected = raw - 2 * st["bf16"] - resid
        rec["full"]["static_args_bytes"] = st
        rec["full"]["cpu_upcast_correction"] = {
            "bf16_args_f32_copies": 2 * st["bf16"],
            "train_resid_f32_copies": resid,
            "corrected_per_device_bytes": corrected,
            "fits_hbm_tpu_estimate": bool(corrected <= HBM),
        }
        p.write_text(json.dumps(rec, indent=1))
        if raw > HBM:
            over_raw.append((rec["arch"], rec["shape"], rec["mesh"]))
            if corrected > HBM:
                over_corr.append(
                    (rec["arch"], rec["shape"], rec["mesh"],
                     round(corrected / 2**30, 1))
                )
    print(f"over raw: {len(over_raw)}  over corrected: {len(over_corr)}")
    for o in over_corr:
        print("  still over:", o)


if __name__ == "__main__":
    main()
