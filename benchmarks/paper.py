"""One benchmark per paper table/figure (PixelsDB, PVLDB'25).

Each function returns (rows, derived) where rows is the table/figure data
and derived the headline numbers the paper reports.
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import Policy, generate, run_sim, stream_histogram  # noqa: E402
from repro.core.workload import TABLE1  # noqa: E402

HORIZON = 14_400.0
_CACHE: dict = {}


def _runs():
    if "runs" not in _CACHE:
        out = {}
        for name, kw in [
            ("auto_sla", dict(policy=Policy.AUTO, sla_enabled=True)),
            ("auto_nosla", dict(policy=Policy.AUTO, sla_enabled=False)),
            ("force_sla", dict(policy=Policy.FORCE, sla_enabled=True)),
        ]:
            qs = generate(horizon_s=HORIZON, seed=0)
            out[name] = run_sim(qs, **kw)
        _CACHE["runs"] = out
    return _CACHE["runs"]


def table1_workloads():
    """Table 1: datasets, workload patterns, query counts, SLA mixes."""
    qs = generate(horizon_s=HORIZON, seed=0)
    rows = []
    for spec in TABLE1:
        mine = [q for q in qs if q.source == spec.name]
        mix = {}
        for q in mine:
            mix[q.sla.short] = mix.get(q.sla.short, 0) + 1
        rows.append(
            dict(db=spec.name, size_gb=spec.db_gb, arch=spec.arch,
                 count=len(mine), sla_mix=mix)
        )
    derived = {"total_queries": sum(r["count"] for r in rows)}
    return rows, derived


def fig5_stream():
    """Fig 5: merged query stream of the five workloads."""
    qs = generate(horizon_s=HORIZON, seed=0)
    hist, edges = stream_histogram(qs, HORIZON, bins=48)
    peak = max(max(v) for v in hist.values())
    return hist, {"bins": len(edges) - 1, "peak_bin_count": peak}


def fig6_exec_time():
    """Fig 6: cumulative execution time by submitted SLA, per config."""
    rows = {}
    for name, res in _runs().items():
        rows[name] = {k: round(v, 1) for k, v in res.exec_time_by_sla().items()}
    # paper §5.2: force w/ SLA inflates relaxed/BoE exec (squeezed into VM);
    # auto w/ SLA is comparable to w/o SLA
    derived = {
        "force_rel_vs_auto_rel": round(
            rows["force_sla"]["rel"] / max(rows["auto_sla"]["rel"], 1e-9), 2
        ),
        "auto_sla_vs_nosla_imm": round(
            rows["auto_sla"]["imm"] / max(rows["auto_nosla"]["imm"], 1e-9), 2
        ),
    }
    return rows, derived


def fig7_cost():
    """Fig 7: cumulative cost by submitted SLA; headline reductions."""
    runs = _runs()
    rows = {
        name: dict(
            total=round(res.total_cost(), 2),
            **{k: round(v, 2) for k, v in res.cost_by_sla().items()},
        )
        for name, res in runs.items()
    }
    base = rows["auto_nosla"]["total"]
    derived = {
        "auto_sla_reduction": round(1 - rows["auto_sla"]["total"] / base, 3),
        "force_sla_reduction": round(1 - rows["force_sla"]["total"] / base, 3),
        "paper_auto_reduction": 0.222,
        "paper_force_reduction": 0.655,
        "imm_increase_auto": round(
            rows["auto_sla"]["imm"] / rows["auto_nosla"]["imm"] - 1, 3
        ),
        "imm_increase_force": round(
            rows["force_sla"]["imm"] / rows["auto_nosla"]["imm"] - 1, 3
        ),
    }
    return rows, derived


def sla_guarantees():
    """§4.2/§5 claim: pending-time guarantees hold in every configuration."""
    rows = {}
    for name, res in _runs().items():
        rows[name] = {
            "violations": len(res.pending_violations(300.0)),
            "max_rel_pending_s": round(
                max((q.pending_time or 0.0 for q in res.by_sla()["rel"]),
                    default=0.0), 1,
            ),
            "finished": res.summary()["finished"],
        }
    derived = {"total_violations": sum(r["violations"] for r in rows.values())}
    return rows, derived


def sos_vs_pos_determinism():
    """§3.3 vision / §5.3 lessons: SOS is deterministic, POS is not."""
    from repro.core import Query, QueryWork, ServiceLevel
    from repro.core.sla import SLAConfig

    def probe_exec(mode, n_bg):
        qs = [Query(work=QueryWork(arch="paper-default", prompt_tokens=500_000),
                    sla=ServiceLevel.IMMEDIATE, submit_time=0.0)]
        qs += [Query(work=QueryWork(arch="paper-default", prompt_tokens=2_000_000),
                     sla=ServiceLevel.IMMEDIATE, submit_time=0.0)
               for _ in range(n_bg)]
        res = run_sim(qs, vm_mode=mode, vm_chips=64, sos_slice_chips=16,
                      use_calibration=False,
                      sla=SLAConfig(vm_overload_threshold=10**9))
        return min(q.exec_time for q in res.queries)

    rows = {
        mode: {n: round(probe_exec(mode, n), 2) for n in (0, 1, 3, 6)}
        for mode in ("pos", "sos")
    }
    pos_spread = rows["pos"][6] / rows["pos"][0]
    sos_spread = rows["sos"][6] / rows["sos"][0]
    return rows, {
        "pos_slowdown_at_6": round(pos_spread, 2),
        "sos_slowdown_at_6": round(sos_spread, 2),
    }


def stage_engine():
    """Stage-level engine (core/engine.py): SOS with stage-boundary
    preemption + cross-cluster spill on vs off, on the Table-1 day."""
    import numpy as np

    from repro.core import SimConfig, Simulation
    from repro.core.sla import SLAConfig

    rows = {}
    for name, on in (("sos_plain", False), ("sos_preempt_spill", True)):
        qs = generate(horizon_s=HORIZON, seed=0)
        cfg = SimConfig(
            policy=Policy.AUTO, vm_mode="sos", vm_chips=16, sos_slice_chips=8,
            use_calibration=False,
            sla=SLAConfig(vm_overload_threshold=12, preempt_best_effort=on,
                          spill_enabled=on),
        )
        res = Simulation(cfg).run(qs)
        s = res.summary()
        waits = [
            q.queue_wait or 0.0
            for q in res.queries
            if q.effective_sla is not None and q.effective_sla.short == "imm"
        ]
        rows[name] = {
            "total_cost": s["total_cost"],
            "violations": s["violations"],
            "imm_p95_wait_s": round(float(np.percentile(waits, 95)), 2)
            if waits else 0.0,
            "stages": s["stages"],
            "preemptions": s["preemptions"],
            "spilled": s["spilled"],
        }
    derived = {
        "imm_wait_reduction": round(
            1 - rows["sos_preempt_spill"]["imm_p95_wait_s"]
            / max(rows["sos_plain"]["imm_p95_wait_s"], 1e-9), 3,
        ),
        "cost_delta_pct": round(
            100 * (rows["sos_preempt_spill"]["total_cost"]
                   / max(rows["sos_plain"]["total_cost"], 1e-9) - 1), 2,
        ),
    }
    return rows, derived


def beyond_paper():
    """Beyond-paper extensions (paper §3.3 opportunities, §5.3 lessons):
    SOS in the cost-efficient cluster + multi-query fusion."""
    import numpy as np

    base = run_sim(
        generate(horizon_s=HORIZON, seed=0), policy=Policy.AUTO, sla_enabled=False
    ).total_cost()
    rows = {}
    for name, kw in [
        ("force_pos", dict(policy=Policy.FORCE)),
        ("force_sos_fuse", dict(policy=Policy.FORCE, vm_mode="sos",
                                sos_slice_chips=1, fuse_queries=True)),
        ("auto_fuse", dict(policy=Policy.AUTO, fuse_queries=True)),
    ]:
        res = run_sim(generate(horizon_s=HORIZON, seed=0), sla_enabled=True, **kw)
        rel = res.by_sla()["rel"]
        lat = [q.latency for q in rel if q.latency is not None]
        rows[name] = {
            "total": round(res.total_cost(), 2),
            "reduction": round(1 - res.total_cost() / base, 3),
            "violations": len(res.pending_violations(300.0)),
            "rel_p95_latency_s": round(float(np.percentile(lat, 95)), 0),
        }
    derived = {
        "sos_fuse_rel_p95_speedup": round(
            rows["force_pos"]["rel_p95_latency_s"]
            / rows["force_sos_fuse"]["rel_p95_latency_s"], 2,
        ),
        "auto_fuse_reduction": rows["auto_fuse"]["reduction"],
        "force_sos_fuse_reduction": rows["force_sos_fuse"]["reduction"],
    }
    return rows, derived
