"""Per-query bit-identity fingerprint of the four classic 50k rows.

Runs each classic scale.py config and hashes every query's exact result
floats (repr round-trips IEEE doubles losslessly), so two commits can be
compared for bit-identical per-query results without storing 200k rows.

Usage: PYTHONPATH=src python benchmarks/_rowhash.py out.json [--factor 55]
"""
from __future__ import annotations

import argparse
import hashlib
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from benchmarks.scale import (  # noqa: E402
    DAY_S,
    SEED_DAY_QUERIES,
    _pools3_autoscale,
    _pools3_specs,
)
from repro.core import Policy, SimConfig, Simulation, SLAConfig  # noqa: E402
from repro.core.workload import generate, scaled_patterns  # noqa: E402


def _row_cfg(name: str) -> SimConfig:
    engine_on = name == "engine_on"
    if name in ("engine_off", "engine_on"):
        return SimConfig(
            policy=Policy.AUTO, vm_mode="sos", vm_chips=64,
            sos_slice_chips=16, use_calibration=False, seed=0,
            sla=SLAConfig(vm_overload_threshold=12,
                          preempt_best_effort=engine_on,
                          spill_enabled=engine_on),
        )
    backlog = name == "pools3_backlog"
    return SimConfig(
        policy=Policy.FORCE, use_calibration=False, seed=0,
        sla=SLAConfig(vm_overload_threshold=12, preempt_best_effort=True,
                      spill_enabled=True, spill_back_enabled=backlog,
                      spill_back_low_backlog_s=5.0),
        pools=_pools3_specs(_pools3_autoscale(backlog)),
    )


def fingerprint(name: str, factor: float) -> dict:
    qs = generate(horizon_s=DAY_S, seed=0, patterns=scaled_patterns(factor))
    n = len(qs)
    t0 = time.perf_counter()
    res = Simulation(_row_cfg(name)).run(qs)
    wall = time.perf_counter() - t0
    h = hashlib.sha256()
    total_cost = 0.0
    stages = 0
    for q in sorted(res.queries, key=lambda q: q.qid):
        h.update(
            f"{q.qid}|{q.cost!r}|{q.chip_seconds!r}|{q.finish_time!r}|"
            f"{q.start_time!r}|{q.cluster}|{len(q.stage_trace)}|"
            f"{q.retries}|{q.preemptions}|{q.spilled}|"
            f"{q.spill_backs}\n".encode()
        )
        total_cost += q.cost
        stages += len(q.stage_trace)
    # finished-order hash: the ORDER queries complete in is behavior too
    ho = hashlib.sha256()
    for q in res.queries:
        ho.update(f"{q.qid},".encode())
    return {
        "n": n,
        "sha256": h.hexdigest(),
        "order_sha256": ho.hexdigest(),
        "total_cost": round(total_cost, 4),
        "stages": stages,
        "wall_s": round(wall, 2),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("out")
    ap.add_argument("--factor", type=float, default=55.0)
    ap.add_argument("--rows", default="engine_off,engine_on,"
                    "pools3_runqueue,pools3_backlog")
    args = ap.parse_args()
    out = {}
    for name in args.rows.split(","):
        out[name] = fingerprint(name, args.factor)
        print(f"{name}: {json.dumps(out[name])}", flush=True)
    Path(args.out).write_text(json.dumps(out, indent=2, sort_keys=True) + "\n")


if __name__ == "__main__":
    main()
