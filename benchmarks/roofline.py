"""Roofline table from the dry-run records (EXPERIMENTS.md §Roofline).

Reads results/dryrun/*.json produced by ``repro.launch.dryrun`` (the only
process allowed to fake 512 devices) and derives, per (arch x shape x
mesh): the three roofline terms, the bottleneck, MODEL_FLOPS/HLO ratio,
and roofline fraction (model-flops time at peak / achievable step time).
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_config, get_shape  # noqa: E402
from repro.core.cost_model import _analytic_step, _decode_step_time  # noqa: E402
from repro.perf.hw import V5E  # noqa: E402

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def ideal_step_s(arch: str, shape: str, chips: int) -> float:
    """Analytic lower bound for the cell: max(model-flops compute time,
    minimal-bytes memory time). Decode is judged against its own memory
    roofline (weights + KV/state streamed once), not model flops."""
    cfg = get_config(arch)
    cell = get_shape(shape)
    if cell.kind == "decode":
        return _decode_step_time(cfg, cell.global_batch, cell.seq_len, chips)
    tokens = cell.global_batch * cell.seq_len
    return _analytic_step(cfg, tokens, cell.kind if cell.kind == "train" else "serve", chips)


def load_records(variant: str | None = None) -> list[dict]:
    recs = []
    for p in sorted(RESULTS.glob("*.json")):
        r = json.loads(p.read_text())
        v = r.get("variant", "baseline")
        if variant is not None and v != variant:
            continue
        if variant is None and v != "baseline":
            continue
        recs.append(r)
    return recs


def roofline_rows(recs: list[dict]) -> list[dict]:
    rows = []
    for r in recs:
        if r.get("status") == "skipped":
            rows.append(dict(arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                             status="skipped", why=r.get("skip_reason", "")))
            continue
        if r.get("status") != "ok" or "roofline" not in r:
            rows.append(dict(arch=r["arch"], shape=r["shape"],
                             mesh=r.get("mesh", "?"), status=r.get("status")))
            continue
        t = r["roofline"]["terms"]
        ideal = ideal_step_s(r["arch"], r["shape"], r["chips"])
        frac = ideal / t["step_s"] if t["step_s"] else 0.0
        rows.append(
            dict(
                arch=r["arch"], shape=r["shape"], mesh=r["mesh"], status="ok",
                compute_s=t["compute_s"], memory_s=t["memory_s"],
                collective_s=t["collective_s"], step_s=t["step_s"],
                bottleneck=t["bottleneck"],
                useful=r["roofline"]["useful_ratio"],
                roofline_frac=frac,
                fits_hbm=r["full"]["fits_hbm"],
                per_dev_gb=r["full"]["per_device_bytes_estimate"] / 2**30,
            )
        )
    return rows


def fmt_table(rows: list[dict]) -> str:
    out = [
        f"{'arch':24s} {'shape':12s} {'mesh':8s} {'comp_s':>9s} {'mem_s':>9s}"
        f" {'coll_s':>9s} {'step_s':>9s} {'bneck':>10s} {'useful':>7s}"
        f" {'RLfrac':>7s} {'fits':>5s}"
    ]
    for r in rows:
        if r.get("status") != "ok":
            out.append(
                f"{r['arch']:24s} {r['shape']:12s} {r.get('mesh','?'):8s}"
                f" -- {r.get('status')}: {r.get('why','')[:60]}"
            )
            continue
        out.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s}"
            f" {r['compute_s']:9.4f} {r['memory_s']:9.4f} {r['collective_s']:9.4f}"
            f" {r['step_s']:9.4f} {r['bottleneck']:>10s} {r['useful']:7.3f}"
            f" {r['roofline_frac']:7.3f} {str(r['fits_hbm']):>5s}"
        )
    return "\n".join(out)


def main():
    rows = roofline_rows(load_records())
    print(fmt_table(rows))
    ok = [r for r in rows if r.get("status") == "ok"]
    if ok:
        worst = sorted(ok, key=lambda r: r["roofline_frac"])[:3]
        coll = [r for r in ok if r["bottleneck"] == "collective"]
        print(f"\ncells={len(rows)} ok={len(ok)}"
              f" collective-bound={len(coll)}")
        print("worst roofline fractions:",
              [(r['arch'], r['shape'], r['mesh'], round(r['roofline_frac'], 3))
               for r in worst])


if __name__ == "__main__":
    main()


def variant_rows() -> list[dict]:
    """Baseline vs best-measured-variant per cell (the §Perf wins)."""
    base: dict[tuple, dict] = {}
    variants: dict[tuple, list[dict]] = {}
    for p in sorted(RESULTS.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("status") != "ok" or "roofline" not in r:
            continue
        key = (r["arch"], r["shape"], r["mesh"])
        if r.get("variant", "baseline") in ("baseline",):
            base[key] = r
        else:
            variants.setdefault(key, []).append(r)
    rows = []
    for key, vs in sorted(variants.items()):
        if key not in base:
            continue
        b = base[key]["roofline"]["terms"]["step_s"]

        def _fits(r):
            c = r["full"].get("cpu_upcast_correction", {})
            return r["full"]["fits_hbm"] or c.get("fits_hbm_tpu_estimate", True)

        fitting = [r for r in vs if _fits(r)] or vs
        best = min(fitting, key=lambda r: r["roofline"]["terms"]["step_s"])
        v = best["roofline"]["terms"]["step_s"]
        if v >= b * 0.999:
            continue  # only report wins
        rows.append(dict(
            arch=key[0], shape=key[1], mesh=key[2],
            variant=best["variant"],
            baseline_s=b, optimized_s=v, speedup=b / v,
        ))
    return rows
