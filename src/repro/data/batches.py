"""Input batches: ShapeDtypeStruct specs (dry-run) + synthetic data (tests).

``input_specs`` is the single source of truth for what every (arch × shape)
cell feeds its step function — weak-type-correct, shardable, no device
allocation. ``make_batch`` materializes the same structure with
deterministic synthetic data for CPU execution.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig, ShapeCell

F32 = jnp.float32
BF16 = jnp.bfloat16


def _text_len(cfg: ModelConfig, seq_len: int) -> int:
    """Text positions after reserving frontend (patch) positions."""
    if cfg.frontend == "vision_patches":
        return seq_len - cfg.frontend_tokens
    return seq_len


def train_specs(cfg: ModelConfig, cell: ShapeCell, dtype=BF16) -> dict:
    B, S = cell.global_batch, cell.seq_len
    st = _text_len(cfg, S)
    spec = {
        "tokens": jax.ShapeDtypeStruct((B, st), jnp.int32),
        "targets": jax.ShapeDtypeStruct((B, st), jnp.int32),
    }
    if cfg.frontend == "vision_patches":
        spec["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_tokens, cfg.d_model), dtype
        )
    if cfg.is_encoder_decoder:
        spec["enc_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dtype)
    return spec


def prefill_specs(cfg: ModelConfig, cell: ShapeCell, dtype=BF16) -> dict:
    B, S = cell.global_batch, cell.seq_len
    st = _text_len(cfg, S)
    spec = {"tokens": jax.ShapeDtypeStruct((B, st), jnp.int32)}
    if cfg.frontend == "vision_patches":
        spec["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_tokens, cfg.d_model), dtype
        )
    if cfg.is_encoder_decoder:
        spec["enc_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dtype)
    return spec


def batch_axes(cfg: ModelConfig, kind: str) -> dict:
    """Logical sharding axes for every input leaf."""
    ax = {
        "tokens": ("batch", "seq"),
        "targets": ("batch", "seq"),
        "patch_embeds": ("batch", "seq", "embed"),
        "enc_embeds": ("batch", "seq", "embed"),
    }
    return ax


def make_batch(
    key: jax.Array, cfg: ModelConfig, *, batch: int, seq: int, kind: str = "train"
) -> dict:
    """Deterministic synthetic batch (small sizes; CPU tests/examples)."""
    st = _text_len(cfg, seq)
    k1, k2, k3 = jax.random.split(key, 3)
    toks = jax.random.randint(k1, (batch, st + 1), 0, cfg.vocab_size)
    out = {"tokens": toks[:, :-1]}
    if kind == "train":
        out["targets"] = toks[:, 1:]
    if cfg.frontend == "vision_patches":
        out["patch_embeds"] = jax.random.normal(
            k2, (batch, cfg.frontend_tokens, cfg.d_model), F32
        )
    if cfg.is_encoder_decoder:
        out["enc_embeds"] = jax.random.normal(k3, (batch, seq, cfg.d_model), F32)
    return out


class TokenStream:
    """Deterministic, restartable, shardable synthetic token pipeline.

    Mimics a production host data loader: each host pulls only its shard
    of the global batch (by host index), and the stream position is
    checkpointable (`state()` / `seek()`), which the fault-tolerant
    training driver relies on for exact restart.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        batch: int,
        seq: int,
        *,
        seed: int = 0,
        host_index: int = 0,
        host_count: int = 1,
    ):
        assert batch % host_count == 0
        self.cfg = cfg
        self.global_batch = batch
        self.local_batch = batch // host_count
        self.seq = seq
        self.seed = seed
        self.host_index = host_index
        self.step = 0

    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def seek(self, state: dict) -> None:
        self.step = int(state["step"])
        assert int(state["seed"]) == self.seed, "stream seed mismatch on restore"

    def next(self) -> dict:
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), self.step),
            self.host_index,
        )
        self.step += 1
        return make_batch(
            key, self.cfg, batch=self.local_batch, seq=self.seq, kind="train"
        )
