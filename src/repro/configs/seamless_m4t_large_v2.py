"""seamless-m4t-large-v2 [audio] — encoder-decoder multimodal backbone.
The speech frontend is a STUB: input_specs() provides precomputed frame
embeddings (B, S_enc, d_model). [arXiv:2308.11596; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64,
    d_ff=8192, vocab_size=256206,
    is_encoder_decoder=True, num_encoder_layers=24,
    frontend="audio_frames", act="gelu",
)

REDUCED = CONFIG.replace(
    num_layers=2, num_encoder_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
)
