"""mamba2-2.7b [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    num_layers=64, d_model=2560, num_heads=0, num_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128, conv_width=4,
    tie_embeddings=True,
)

REDUCED = CONFIG.replace(
    num_layers=3, d_model=64, ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
    vocab_size=512,
)
