"""gemma2-2b [dense] — local+global alternating attention, logit softcaps.
[arXiv:2408.00118; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b", family="dense",
    num_layers=26, d_model=2304, num_heads=8, num_kv_heads=4, head_dim=256,
    d_ff=9216, vocab_size=256000,
    sliding_window=4096, local_global_pattern="lg",
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    post_block_norms=True, scale_embeddings=True, tie_embeddings=True,
    act="gelu", rope_theta=10_000.0,
)

REDUCED = CONFIG.replace(
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, sliding_window=8,
)
