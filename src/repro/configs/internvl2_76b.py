"""internvl2-76b [vlm] — InternViT + llama3-70b-class text backbone.
The vision frontend is a STUB: input_specs() provides precomputed patch
embeddings for the first frontend_tokens positions. [arXiv:2404.16821;
unverified]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256, rope_theta=500_000.0,
    frontend="vision_patches", frontend_tokens=256,
)

REDUCED = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, frontend_tokens=8,
)
