"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=6400, vocab_size=32064,
    num_experts=16, top_k=2, rope_theta=10_000.0,
)

REDUCED = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, num_experts=4,
)
