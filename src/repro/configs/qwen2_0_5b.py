"""qwen2-0.5b [dense] — GQA with QKV bias. [arXiv:2407.10671; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2, head_dim=64,
    d_ff=4864, vocab_size=151936,
    qkv_bias=True, tie_embeddings=True, rope_theta=1_000_000.0,
)

REDUCED = CONFIG.replace(
    num_layers=2, d_model=56, num_heads=7, num_kv_heads=1, head_dim=8,
    d_ff=128, vocab_size=512,
)
