"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.
Period-8 block: attention at index 4, MoE FFN on odd indices.
[arXiv:2403.19887; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=65536,
    num_experts=16, top_k=2,
    hybrid_period=8, hybrid_attn_index=4, hybrid_moe_stride=2,
    ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128, conv_width=4,
)

REDUCED = CONFIG.replace(
    num_layers=8, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, num_experts=4, ssm_state=16, ssm_head_dim=16,
    ssm_chunk=8,
)
