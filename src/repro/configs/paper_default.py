"""The paper's own workload unit: PixelsDB serves SQL analytics, not LMs;
our ML-query adaptation uses a mid-size dense LM as the default "query
engine" model for SLA scheduling examples (DESIGN.md §2)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="paper-default", family="dense",
    num_layers=16, d_model=1024, num_heads=16, num_kv_heads=8, head_dim=64,
    d_ff=4096, vocab_size=32000,
)

REDUCED = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
)
