"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    num_experts=8, top_k=2, sliding_window=4096, rope_theta=1_000_000.0,
)

REDUCED = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, num_experts=4, sliding_window=8,
)
