"""Architecture registry: one module per assigned architecture."""
from __future__ import annotations

import importlib

from ..models.config import SHAPES, ModelConfig, ShapeCell  # re-export

_MODULES = {
    "gemma2-2b": "gemma2_2b",
    "qwen2-0.5b": "qwen2_0_5b",
    "granite-8b": "granite_8b",
    "internlm2-1.8b": "internlm2_1_8b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "mamba2-2.7b": "mamba2_2_7b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "mixtral-8x7b": "mixtral_8x7b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "internvl2-76b": "internvl2_76b",
    "paper-default": "paper_default",
}

ARCHS = tuple(k for k in _MODULES if k != "paper-default")


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    return mod.REDUCED if reduced else mod.CONFIG


def get_shape(name: str) -> ShapeCell:
    return SHAPES[name]


def cells():
    """All (arch, shape) cells in the assignment matrix (40 total)."""
    for arch in ARCHS:
        for shape in SHAPES.values():
            yield arch, shape


def runnable(arch: str, shape: ShapeCell) -> tuple[bool, str]:
    """Whether a cell runs, and the reason if skipped (DESIGN.md §5)."""
    cfg = get_config(arch)
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: 500k decode state unbounded"
    return True, ""
