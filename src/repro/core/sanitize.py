"""One-switch runtime sanitizer for the engine's correctness contracts.

``REPRO_SANITIZE=1`` (or ``SimConfig.sanitize=True``) turns on, together:

  * the incremental-vs-scan backlog check and heap invariant previously
    gated on ``REPRO_DEBUG_BACKLOG`` (engine.ClusterExecutor.advance_to),
  * lock-held asserts on the live engine's guarded attributes, generated
    from the SAME ``_GUARDED_BY`` class registries the static RL001 rule
    reads (tools/reprolint) — one source of truth for both checks,
  * lock-ORDER asserts: acquisitions that descend the statically derived
    lock hierarchy (``LOCK_RANKS``, from the reprolint RL006 lock graph
    over the threaded core modules, ``lockgraph.LOCK_FILES``) raise
    before they can
    deadlock; ``tests/test_sanitize.py`` pins the table to the recomputed
    static ranks so the two cannot drift apart,
  * post-run chip-second conservation and gap/overlap-free stage-trace
    asserts over the finished population (``check_result``).

Checks raise ``SanitizeError`` (an AssertionError, so pytest and the
hypothesis suite report them natively). The switch is read once at
import; tests flip it with ``set_enabled``. All checks are observers:
with the sanitizer off NOTHING runs, and with it on results must be
bit-identical — CI's ``sanitize-smoke`` job replays the 5k-day golden
fingerprints under ``REPRO_SANITIZE=1`` to prove it.
"""
from __future__ import annotations

import os
import threading
from typing import Iterable

_ENABLED = os.environ.get("REPRO_SANITIZE", "") == "1"

#: chip-second conservation tolerance: sums of per-stage billed seconds
#: are compared to per-query totals accumulated sequentially, so only
#: float re-association across the population needs slack.
REL_TOL = 1e-9
#: trace stitching tolerance (matches tests/test_properties.py)
EPS = 1e-9


class SanitizeError(AssertionError):
    """A correctness contract was violated at runtime."""


def enabled() -> bool:
    return _ENABLED


def set_enabled(flag: bool) -> bool:
    """Flip the global switch (tests); returns the previous value."""
    global _ENABLED
    prev = _ENABLED
    _ENABLED = bool(flag)
    return prev


# --- lock-held guards, driven by the _GUARDED_BY registries ---------------

def _lock_held(lock) -> bool:
    # RLock / Condition expose _is_owned (held by THIS thread); a plain
    # Lock only knows locked() (held by someone — the best it can say).
    probe = getattr(lock, "_is_owned", None)
    if probe is not None:
        try:
            return bool(probe())
        except TypeError:
            pass
    locked = getattr(lock, "locked", None)
    return bool(locked()) if locked is not None else True


def guard(obj, attr: str) -> None:
    """Assert that one of the locks ``type(obj)._GUARDED_BY[attr]``
    declares is currently held. No-op when the sanitizer is off or the
    attribute is not in the registry — callers sprinkle ``guard(self,
    "waiting")`` at the top of ``*_locked`` helpers (which the static
    RL001 rule exempts: the RUNTIME check covers their callers)."""
    if not _ENABLED:
        return
    registry = getattr(type(obj), "_GUARDED_BY", None)
    if not registry or attr not in registry:
        return
    locks = registry[attr]
    if isinstance(locks, str):
        locks = (locks,)
    for name in locks:
        lock = getattr(obj, name, None)
        if lock is not None and _lock_held(lock):
            return
    raise SanitizeError(
        f"sanitize: {type(obj).__name__}.{attr} accessed without holding "
        f"{' or '.join(locks)} (declared in _GUARDED_BY)"
    )


# --- lock-order enforcement, from the reprolint RL006 lock graph ----------

#: the statically derived lock hierarchy: ``tools.reprolint.lockgraph``
#: ranks every lock by its longest acquisition path (outer locks rank
#: lower, nested-inner locks higher). Acquiring DOWN the hierarchy —
#: a lower-ranked lock while holding a higher-ranked one — is the ABBA
#: half of a potential deadlock, caught here before it can block.
#: Equal-rank locks carry no static nesting evidence and are left
#: unconstrained. tests/test_sanitize.py recomputes the ranks from the
#: lock graph and asserts equality, so this table cannot drift from the
#: analysis that derived it.
LOCK_RANKS = {
    "LiveExecutor._mu": 0,
    "_ModelPool._lock": 0,
    "CrossPoolFusionIndex._lock": 1,
}

_held_tls = threading.local()


def _held_stack() -> list:
    st = getattr(_held_tls, "stack", None)
    if st is None:
        st = _held_tls.stack = []
    return st


def check_lock_order(label: str) -> None:
    """Raise if acquiring ``label`` NOW would descend the static lock
    hierarchy on this thread. Called before the underlying acquire, so
    the violation surfaces as a stack trace instead of a deadlock."""
    rank = LOCK_RANKS.get(label)
    if rank is None:
        return
    for held_label, held_rank in _held_stack():
        if held_label != label and held_rank is not None and held_rank > rank:
            raise SanitizeError(
                f"sanitize: acquiring {label} (rank {rank}) while "
                f"holding {held_label} (rank {held_rank}) descends the "
                f"static lock hierarchy — the reverse nesting exists in "
                f"the code, so this order can deadlock (ABBA)"
            )


class _OrderedLock:
    """Transparent wrapper around a ``threading`` lock that enforces
    :data:`LOCK_RANKS` when the sanitizer is on. Off, each acquire costs
    one extra attribute hop and nothing else; results are bit-identical
    either way (the wrapper never reorders or blocks differently).
    ``Condition(wrapped_mu)`` works: the Condition binds the wrapper's
    ``acquire``/``release`` (order-checked) and reaches ``_is_owned`` /
    ``_release_save`` / ``_acquire_restore`` through ``__getattr__``."""

    __slots__ = ("_label", "_raw")

    def __init__(self, label: str, raw) -> None:
        object.__setattr__(self, "_label", label)
        object.__setattr__(self, "_raw", raw)

    def acquire(self, *args, **kwargs) -> bool:
        if _ENABLED:
            check_lock_order(self._label)
        got = self._raw.acquire(*args, **kwargs)
        if got and _ENABLED:
            _held_stack().append((self._label, LOCK_RANKS.get(self._label)))
        return got

    def release(self) -> None:
        if _ENABLED:
            # tolerate an enable-flip mid-hold: pop only what was pushed
            st = _held_stack()
            for i in range(len(st) - 1, -1, -1):
                if st[i][0] == self._label:
                    del st[i]
                    break
        self._raw.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __getattr__(self, name):
        return getattr(object.__getattribute__(self, "_raw"), name)


def ordered_lock(label: str, raw):
    """Wrap ``raw`` (a ``threading`` lock) so acquisitions are checked
    against the static lock hierarchy under ``REPRO_SANITIZE=1``. The
    ``label`` is the lock graph's node name, ``Class.attr``."""
    return _OrderedLock(label, raw)


# --- post-run population checks -------------------------------------------

def check_result(queries: Iterable) -> None:
    """Chip-second conservation + gap/overlap-free traces over finished
    queries. Mirrors tests/test_properties.py::_check_fusion_invariants:
    fused members share one stage trace (carried by member 0) and split
    the bill, so conservation is checked over the POPULATION — traces
    deduped by identity — while per-query exactness holds only for
    unfused queries."""
    if not _ENABLED:
        return
    qs = [q for q in queries if q is not None]
    billed_total = 0.0
    for q in qs:
        billed_total += q.chip_seconds
        tr = getattr(q, "stage_trace", None)
        if not tr:
            continue
        # stage indices contiguous from 0, stages stitched in time
        idx = [e.index for e in tr]
        if idx != list(range(len(tr))):
            raise SanitizeError(
                f"sanitize: q{q.qid} stage trace indices {idx} are not "
                f"contiguous from 0 — a stage was dropped or duplicated"
            )
        for a, b in zip(tr, tr[1:]):
            if b.start < a.finish - EPS:
                raise SanitizeError(
                    f"sanitize: q{q.qid} stage {b.index} starts at "
                    f"{b.start} before stage {a.index} finishes at "
                    f"{a.finish} — overlapping execution of one query"
                )
        if (
            getattr(q, "fused_with", 0) == 0
            and getattr(q, "members", None) is None
        ):
            trace_cs = sum(e.chip_seconds for e in tr)
            if abs(trace_cs - q.chip_seconds) > max(
                REL_TOL * abs(q.chip_seconds), REL_TOL
            ):
                raise SanitizeError(
                    f"sanitize: q{q.qid} billed {q.chip_seconds} chip-s "
                    f"but its stage trace sums to {trace_cs} — billing "
                    f"and trace disagree"
                )
    # population conservation: every billed chip-second appears in
    # exactly one stage-trace event (fused members share a trace object)
    seen: set[int] = set()
    trace_total = 0.0
    for q in qs:
        tr = getattr(q, "stage_trace", None)
        if not tr or id(tr) in seen:
            continue
        seen.add(id(tr))
        for e in tr:
            trace_total += e.chip_seconds
    if abs(trace_total - billed_total) > max(REL_TOL * abs(billed_total), REL_TOL):
        raise SanitizeError(
            f"sanitize: population billed {billed_total} chip-s but "
            f"stage traces account for {trace_total} — chip-seconds "
            f"created or destroyed"
        )
