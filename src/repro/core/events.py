"""Append-only, capped audit event feed (otter's ``log/spec.py`` /
``cloudfeeds.py`` idiom, ROADMAP item 3).

Every control-plane action — placement, preempt, spill, fusion, scale,
worker death, replacement, checkpoint resume, drift intervention — is
recorded as one immutable row:

    (seq, kind, t_s, (sorted (key, value) payload pairs))

The feed is the system's flight recorder, not its WAL: replay means
re-running the recorded day from the same config and seed and checking
the two feeds' ``fingerprint()`` (a SHA-256 over canonical JSON rows)
match bit-for-bit — benchmarks/chaos.py and the chaos-smoke CI job do
exactly that. Rows therefore never contain wall-clock time or id()s;
``t_s`` is the caller's deterministic engine clock.

The buffer is capped (a day of placements at 1M queries would otherwise
hold the whole run live): the oldest rows fall off, ``dropped`` counts
how many, and ``fingerprint()`` folds the total emitted count in so a
truncated feed can never masquerade as a complete one.

Thread-safety: ``emit`` is called from live worker threads and the
scheduler thread concurrently; one plain ``threading.Lock`` guards the
buffer (the lock is a leaf — nothing is ever called while holding it,
so it takes no rank in ``sanitize.LOCK_RANKS``).
"""
from __future__ import annotations

import hashlib
import json
import threading
from collections import deque

#: default row cap — generous for a 5k–50k query chaos day, bounded for
#: a 1M-query one (the fingerprint still covers the drop count)
DEFAULT_CAP = 200_000

#: one row: (seq, kind, t_s, payload_items)
Row = tuple


def row_json(row: Row) -> str:
    """Canonical JSON for one row. ``json.dumps`` renders floats with
    ``repr`` (shortest round-trip), so two rows serialize identically
    iff their floats are bit-identical — which is exactly the replay
    contract the fingerprint enforces."""
    seq, kind, t_s, items = row
    return json.dumps(
        [seq, kind, t_s, [[k, _jsonable(v)] for k, v in items]],
        separators=(",", ":"),
    )


def _jsonable(v):
    if isinstance(v, tuple):
        return list(v)
    return v


class EventFeed:
    """Append-only capped feed of control-plane events.

    ``emit(kind, t_s, **payload)`` is the single producer entry point;
    payload keys are sorted so emission-site dict ordering can never
    leak into the fingerprint. Readers get snapshots (``rows()``), per-
    kind tallies (``counts()``) and the replay digest (``fingerprint()``).
    """

    #: lock contract — reprolint RL001 + repro.core.sanitize read this.
    _GUARDED_BY = {
        "_rows": "_lock",
        "_seq": "_lock",
    }

    __slots__ = ("cap", "_rows", "_seq", "_lock")

    def __init__(self, cap: int = DEFAULT_CAP):
        self.cap = max(1, int(cap))
        self._rows: deque = deque(maxlen=self.cap)
        self._seq = 0
        self._lock = threading.Lock()

    def emit(self, kind: str, t_s: float, **payload) -> int:
        """Record one event at engine time ``t_s``; returns its seq."""
        # the row is composed OUTSIDE the lock: emit sits on worker hot
        # paths, the critical section is two statements
        items = tuple(sorted(payload.items()))
        with self._lock:
            seq = self._seq
            self._seq += 1
            self._rows.append((seq, kind, t_s, items))
        return seq

    def __len__(self) -> int:
        with self._lock:
            return len(self._rows)

    @property
    def total(self) -> int:
        """Rows ever emitted (>= len(self) once the cap bites)."""
        with self._lock:
            return self._seq

    @property
    def dropped(self) -> int:
        """Rows that fell off the capped buffer."""
        with self._lock:
            return self._seq - len(self._rows)

    def rows(self) -> list:
        with self._lock:
            return list(self._rows)

    def counts(self) -> dict:
        """Per-kind row tallies over the retained window."""
        out: dict = {}
        for _, kind, _, _ in self.rows():
            out[kind] = out.get(kind, 0) + 1
        return out

    def tail(self, n: int = 20) -> list:
        rows = self.rows()
        return rows[-n:]

    def fingerprint(self) -> str:
        """SHA-256 over the canonical JSON of every retained row plus
        the total emitted count — the replay identity: two runs of the
        same seeded day must produce equal fingerprints, bit-for-bit."""
        with self._lock:
            rows = list(self._rows)
            seq = self._seq
        h = hashlib.sha256()
        h.update(f"total={seq}\n".encode())
        for row in rows:
            h.update(row_json(row).encode())
            h.update(b"\n")
        return h.hexdigest()
