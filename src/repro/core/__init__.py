"""The paper's contribution: flexible performance SLAs for serverless
query processing, with SOS (stage-oriented scaling) execution."""
from .allocation import AllocationConfig, AllocationPoint, Allocator
from .clusters import (
    AutoscaleConfig,
    CostEfficientCluster,
    FaultModel,
    HighElasticCluster,
)
from .calibration import (
    CalibrationTable,
    LiveCalibrator,
    fit_dryruns,
    invalidate_default_calibration,
)
from .engine import ClusterExecutor, StageEvent
from .insights import CostExplorer, export_trace, price_menu
from .cost_model import CostModel, Stage, StagePlan
from .pools import PoolSpec, build_pool, default_pool_specs
from .query import Query, QueryWork
from .scheduler import (
    BoEScheduler,
    CrossPoolFusionIndex,
    PendingQueue,
    QueryCoordinator,
    RelaxedScheduler,
    ServiceLayer,
    fuse_queries,
    fusion_key,
    unpack_fused,
)
from .simulator import SimConfig, SimResult, Simulation, run_sim
from .sla import Policy, ServiceLevel, SLAConfig
from .workload import TABLE1, generate, scaled_patterns, stream_histogram
