"""Deterministic per-stage cost model — the property that makes SOS
suitable for flexible SLAs (paper §3.3 vision 1).

A query compiles to a chain of stages; every stage has a roofline time on
a given worker slice, derived from the same three-term model as
EXPERIMENTS.md §Roofline. Empirical calibration (core/calibration.py)
closes the loop between measurements and the scheduler: a
``CalibrationTable`` — fitted offline from dry-run JSONs or online from
measured stage walls — scales stage times (never plan structure), and
every table update invalidates the plan cache via a version check, so a
mid-run hot swap flows into quotes immediately.
"""
from __future__ import annotations

import math
from collections import OrderedDict
from dataclasses import dataclass, field
from functools import cached_property
from pathlib import Path
from typing import TYPE_CHECKING, Optional

import numpy as np

from ..configs import get_config
from ..models.config import ModelConfig
from ..perf.hw import V5E, HwSpec
from .query import QueryWork

if TYPE_CHECKING:  # pragma: no cover — import cycle guard
    from .calibration import CalibrationTable

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


@dataclass(frozen=True)
class Stage:
    name: str
    time_s: float  # on the stage's isolated worker slice
    chips: int  # worker slice size

    @property
    def chip_seconds(self) -> float:
        return self.time_s * self.chips


@dataclass(frozen=True)
class StagePlan:
    stages: tuple[Stage, ...]

    @property
    def exec_time(self) -> float:
        return self._suffix_time[0]

    @property
    def chip_seconds(self) -> float:
        return self._suffix_cs[0]

    # suffix sums make every remaining-* view O(1): the backlog signal
    # and the coordinator's quotes call them per query per event, and
    # chunked decode gives long generations hundreds of stages.
    # np.cumsum is a sequential left-to-right accumulate (np.add.accumulate,
    # not the pairwise tree np.sum uses), so these are bit-identical to the
    # old Python accumulation loop while building long plans in C.
    @cached_property
    def _suffix_time(self) -> tuple[float, ...]:
        if not self.stages:
            return (0.0,)
        acc = np.cumsum([s.time_s for s in reversed(self.stages)])
        return (*acc[::-1].tolist(), 0.0)

    @cached_property
    def _suffix_cs(self) -> tuple[float, ...]:
        if not self.stages:
            return (0.0,)
        acc = np.cumsum([s.chip_seconds for s in reversed(self.stages)])
        return (*acc[::-1].tolist(), 0.0)

    # --- stage-cursor views (engine.py runs a query as a cursor) ------
    def remaining_time(self, cursor: int = 0) -> float:
        return self._suffix_time[min(cursor, len(self.stages))]

    def remaining_chip_seconds(self, cursor: int = 0) -> float:
        return self._suffix_cs[min(cursor, len(self.stages))]


def _analytic_step(cfg: ModelConfig, tokens: int, kind: str, chips: int,
                   hw: HwSpec = V5E) -> float:
    """Analytic roofline step time for `tokens` processed on `chips`."""
    n_active = cfg.active_params()
    factor = 6 if kind == "train" else 2
    flops = factor * n_active * tokens
    # weight streaming + activations; decode is weight-bound per token
    bytes_ = 2 * n_active + tokens * cfg.d_model * 2 * max(cfg.num_layers, 1)
    compute = flops / (chips * hw.peak_flops_bf16)
    memory = bytes_ / (chips * hw.hbm_bandwidth)
    return max(compute, memory)


def _decode_step_time(cfg: ModelConfig, batch: int, context: int, chips: int,
                      hw: HwSpec = V5E) -> float:
    """One decode token for `batch` sequences at a given context length."""
    return _decode_chunk_time(cfg, batch, context, 1, chips, hw)


def _decode_chunk_time(cfg: ModelConfig, batch: int, context0: int, n: int,
                       chips: int, hw: HwSpec = V5E) -> float:
    """Exact time of `n` consecutive decode tokens whose first token
    reads a KV cache of `context0` tokens: token j is priced at context
    ``context0 + j``. Summing per token makes a generation's total
    independent of how it is chunked (chunk boundaries are a scheduling
    choice, not a cost), while later chunks correctly pay for the longer
    cache they read — the old model priced every chunk at the INITIAL
    context, systematically under-quoting long generations.

    The per-token KV walk is vectorized: the per-layer min(window,
    context) sum collapses to one ``np.minimum.outer`` over the chunk's
    contexts. All intermediates stay exact int64 (no overflow at any
    realistic model/context size) and the final per-token times are
    accumulated sequentially, so the result is bit-identical to the
    scalar reference (``_decode_chunk_time_scalar``, kept as the
    equivalence oracle for tests/test_vectorized.py)."""
    if n <= 0:
        return 0.0
    n_active = cfg.active_params()
    compute = 2 * n_active * batch / (chips * hw.peak_flops_bf16)
    ssm = 0
    if cfg.ssm_state:
        n_mamba = sum(1 for k in cfg.layer_kinds() if k == "mamba")
        ssm = n_mamba * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4
    windows = () if cfg.attention_free else tuple(cfg.window_pattern())
    kv_unit = 2 * cfg.num_kv_heads * cfg.head_dim * 2  # k+v bf16 per tok
    bw = chips * hw.hbm_bandwidth
    ctx = context0 + np.arange(n, dtype=np.int64)
    # falsy window (None or 0) = full attention over the whole context
    n_full = sum(1 for w in windows if not w)
    sliding = np.array([w for w in windows if w], dtype=np.int64)
    kv = n_full * ctx
    if sliding.size:
        kv = kv + np.minimum.outer(ctx, sliding).sum(axis=1)
    bytes_ = 2 * n_active + batch * (kv * kv_unit + ssm)
    per_token = np.maximum(compute, bytes_ / bw)
    total = 0.0
    for t in per_token.tolist():  # sequential: total must not depend on
        total += t                # numpy's pairwise summation tree
    return total


def _decode_chunk_time_scalar(cfg: ModelConfig, batch: int, context0: int,
                              n: int, chips: int, hw: HwSpec = V5E) -> float:
    """The original per-token loop — the equivalence oracle the
    vectorized `_decode_chunk_time` is locked against in tests."""
    n_active = cfg.active_params()
    compute = 2 * n_active * batch / (chips * hw.peak_flops_bf16)
    ssm = 0
    if cfg.ssm_state:
        n_mamba = sum(1 for k in cfg.layer_kinds() if k == "mamba")
        ssm = n_mamba * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4
    windows = () if cfg.attention_free else tuple(cfg.window_pattern())
    kv_unit = 2 * cfg.num_kv_heads * cfg.head_dim * 2  # k+v bf16 per tok
    bw = chips * hw.hbm_bandwidth
    total = 0.0
    for j in range(n):
        context = context0 + j
        kv = sum((min(w, context) if w else context) for w in windows)
        bytes_ = 2 * n_active + batch * (kv * kv_unit + ssm)
        total += max(compute, bytes_ / bw)
    return total


class CostModel:
    """Maps QueryWork -> StagePlan on a worker slice of `chips` chips.

    Decode is split into chunks of ``decode_chunk_tokens`` tokens (0
    disables chunking): long generations become a chain of short stages,
    so they are preemptible at chunk boundaries and a fault retries only
    the failed chunk. Plan STRUCTURE depends only on the work (never on
    `chips` or ``speed_factor``), so a mid-plan stage cursor stays valid
    when the remaining stages are re-planned for a different slice size
    or a different pool (cross-pool spill, spill-back, preemption resume).

    ``speed_factor`` models heterogeneous pool hardware relative to the
    `hw` baseline: a 0.25x pool (e.g. CPU spot) runs every stage 4x
    longer — and bills 4x the chip-seconds — on the same plan structure.

    ``calibration`` injects an explicit ``CalibrationTable``
    (core/calibration.py): its per-(arch, kind) factors scale stage
    times and its fitted ``speed_factor`` (when set) overrides the
    declared one. The table is LIVE state — any update bumps its
    version, and ``plan`` clears the plan cache on a version change, so
    a calibration hot swap flows into the very next quote. An injected
    table applies regardless of ``use_calibration``, which only gates
    the process-wide default table over ``results/dryrun``.

    ``parallel_overhead`` models the coordination tax of spreading one
    stage across a wider slice: every stage time is scaled by
    ``1 + parallel_overhead * (chips - 1)``. The pure roofline is
    exactly linear in chips (time ∝ 1/chips), which makes chip-seconds
    — and therefore cost — width-independent and the latency/cost
    frontier degenerate; a nonzero overhead restores the real trade
    (wider = faster wall time, but more billed chip-seconds), which is
    what the per-query allocator (core/allocation.py) sweeps. The
    default 0.0 keeps every existing plan bit-identical.
    """

    #: LRU bound on the plan cache: the per-query chips sweep multiplies
    #: keys per (work shape × allocation), which grew the old unbounded
    #: dict without limit on long heterogeneous days
    PLAN_CACHE_MAX = 4096

    def __init__(self, hw: HwSpec = V5E, use_calibration: bool = True,
                 decode_chunk_tokens: int = 32, speed_factor: float = 1.0,
                 calibration: Optional["CalibrationTable"] = None,
                 parallel_overhead: float = 0.0):
        if speed_factor <= 0:
            raise ValueError(f"speed_factor must be > 0, got {speed_factor}")
        if parallel_overhead < 0:
            raise ValueError(
                f"parallel_overhead must be >= 0, got {parallel_overhead}"
            )
        self.hw = hw
        self.use_calibration = use_calibration
        self.decode_chunk_tokens = decode_chunk_tokens
        self.speed_factor = speed_factor
        self.calibration = calibration
        self.parallel_overhead = parallel_overhead
        # key -> (table version the plan was computed under, plan);
        # entries are version-tagged so a plan computed concurrently
        # with a hot swap can never be served under the NEW version.
        # LRU-bounded: the chips axis in the key means an allocator
        # sweep creates one entry per (work shape, width).
        self._plan_cache: OrderedDict[tuple, tuple[int, StagePlan]] = OrderedDict()
        self._cal_version = -1
        self.plan_cache_hits = 0
        self.plan_cache_misses = 0

    def _table(self) -> Optional["CalibrationTable"]:
        if self.calibration is not None:
            return self.calibration
        if self.use_calibration:
            from .calibration import default_table

            return default_table()
        return None

    def set_calibration(self, table: Optional["CalibrationTable"]) -> None:
        """Swap the injected table (None reverts to the default/none).
        Safe at any stage boundary: calibration scales times, never plan
        structure, so mid-plan stage cursors stay valid."""
        self.calibration = table
        self.invalidate_cache()

    def invalidate_cache(self) -> None:
        self._plan_cache.clear()
        self._cal_version = -1

    def plan_cache_stats(self) -> dict:
        """Hit/miss counters (and current size) of the LRU plan cache —
        what the scale benchmark asserts to show the allocator's chips
        sweep stays cached instead of re-planning per query."""
        return {
            "hits": self.plan_cache_hits,
            "misses": self.plan_cache_misses,
            "size": len(self._plan_cache),
            "max": self.PLAN_CACHE_MAX,
        }

    def plan_version(self) -> int:
        """The active calibration table's version (0 when uncalibrated)
        — what plan-derived caches (the executors' static quotes, the
        incremental backlog's waiting sums) validate against so a hot
        swap invalidates them exactly like the plan cache itself."""
        cal = self.calibration
        if cal is not None:
            return cal.version
        if not self.use_calibration:  # hot path: no table can exist
            return 0
        table = self._table()
        return table.version if table is not None else 0

    @property
    def effective_speed_factor(self) -> float:
        """The speed quotes are made at: the table's fitted value when
        one exists, the declared constant otherwise."""
        t = self._table()
        if t is not None and t.speed_factor is not None:
            return t.speed_factor
        return self.speed_factor

    def _cal(self, arch: str, kind: str) -> float:
        t = self._table()
        cal = t.factor(arch, kind) if t is not None else 1.0
        return cal / self.effective_speed_factor

    def plan(self, work: QueryWork, chips: int) -> StagePlan:
        # versioned cache: a calibration update (hot swap, re-fit,
        # default-table invalidation) must reach the next plan() call —
        # the old cache never invalidated, so updates silently no-opped
        ver = self.plan_version()
        if ver != self._cal_version:
            self._plan_cache.clear()
            self._cal_version = ver
        key = (work.arch, work.kind, work.batch, work.prompt_tokens,
               work.output_tokens, work.train_steps, work.seq_len, chips)
        cached = self._plan_cache.get(key)
        if cached is not None and cached[0] == ver:
            self.plan_cache_hits += 1
            self._plan_cache.move_to_end(key)
            return cached[1]
        self.plan_cache_misses += 1
        cfg = get_config(work.arch)
        cal = self._cal(work.arch, work.kind)
        if self.parallel_overhead:
            # the parallelism tax composes with calibration exactly like
            # the speed factor: it scales times, never plan structure
            cal = cal * (1.0 + self.parallel_overhead * (chips - 1))
        stages: list[Stage] = []
        if work.kind == "train":
            t = _analytic_step(cfg, work.batch * work.seq_len, "train", chips)
            stages.append(Stage("train_steps", cal * t * work.train_steps, chips))
        else:
            tp = _analytic_step(
                cfg, work.batch * work.prompt_tokens, "serve", chips
            )
            stages.append(Stage("prefill", cal * tp, chips))
            if work.output_tokens:
                chunk = self.decode_chunk_tokens or work.output_tokens
                done = 0
                while done < work.output_tokens:
                    # each chunk pays for the KV cache grown by the
                    # chunks before it (token-exact, so chunking never
                    # changes the total). Context depends only on the
                    # work, so plan STRUCTURE stays chips/speed-
                    # independent and cursors survive pool hops.
                    n = min(chunk, work.output_tokens - done)
                    t_chunk = _decode_chunk_time(
                        cfg, work.batch, work.prompt_tokens + done, n, chips
                    )
                    stages.append(
                        Stage(f"decode[{done}:{done + n}]", cal * t_chunk, chips)
                    )
                    done += n
        out = StagePlan(tuple(stages))
        self._plan_cache[key] = (ver, out)
        if len(self._plan_cache) > self.PLAN_CACHE_MAX:
            self._plan_cache.popitem(last=False)
        return out

    def exec_time(self, work: QueryWork, chips: int) -> float:
        return self.plan(work, chips).exec_time

    def chip_seconds(self, work: QueryWork, chips: int) -> float:
        return self.plan(work, chips).chip_seconds
