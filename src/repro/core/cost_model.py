"""Deterministic per-stage cost model — the property that makes SOS
suitable for flexible SLAs (paper §3.3 vision 1).

A query compiles to a chain of stages; every stage has a roofline time on
a given worker slice, derived from the same three-term model as
EXPERIMENTS.md §Roofline. When a dry-run JSON for the (arch, shape) exists
in results/dryrun/, an empirical calibration factor (compiled HLO terms /
analytic terms) is applied, closing the loop between the compiled
artifacts and the scheduler simulation.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from functools import cached_property, lru_cache
from pathlib import Path
from typing import Optional

from ..configs import get_config
from ..models.config import ModelConfig
from ..perf.hw import V5E, HwSpec
from .query import QueryWork

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


@dataclass(frozen=True)
class Stage:
    name: str
    time_s: float  # on the stage's isolated worker slice
    chips: int  # worker slice size

    @property
    def chip_seconds(self) -> float:
        return self.time_s * self.chips


@dataclass(frozen=True)
class StagePlan:
    stages: tuple[Stage, ...]

    @property
    def exec_time(self) -> float:
        return self._suffix_time[0]

    @property
    def chip_seconds(self) -> float:
        return self._suffix_cs[0]

    # suffix sums make every remaining-* view O(1): the backlog signal
    # and the coordinator's quotes call them per query per event, and
    # chunked decode gives long generations hundreds of stages
    @cached_property
    def _suffix_time(self) -> tuple[float, ...]:
        acc = [0.0]
        for s in reversed(self.stages):
            acc.append(acc[-1] + s.time_s)
        return tuple(reversed(acc))

    @cached_property
    def _suffix_cs(self) -> tuple[float, ...]:
        acc = [0.0]
        for s in reversed(self.stages):
            acc.append(acc[-1] + s.chip_seconds)
        return tuple(reversed(acc))

    # --- stage-cursor views (engine.py runs a query as a cursor) ------
    def remaining_time(self, cursor: int = 0) -> float:
        return self._suffix_time[min(cursor, len(self.stages))]

    def remaining_chip_seconds(self, cursor: int = 0) -> float:
        return self._suffix_cs[min(cursor, len(self.stages))]


@lru_cache(maxsize=None)
def _calibration(arch: str, kind: str) -> float:
    """HLO-derived step time / analytic step time, from dry-run records."""
    shape = {"serve": "prefill_32k", "train": "train_4k"}[kind]
    path = RESULTS / f"{arch}__{shape}__16x16.json"
    if not path.exists():
        return 1.0
    try:
        rec = json.loads(path.read_text())
        terms = rec["roofline"]["terms"]
        cfg = get_config(arch)
        cell_tokens = {"prefill_32k": 32 * 32768, "train_4k": 256 * 4096}[shape]
        an = _analytic_step(cfg, cell_tokens, kind, chips=rec["chips"])
        return max(0.25, min(20.0, terms["step_s"] / an)) if an else 1.0
    except Exception:
        return 1.0


def _analytic_step(cfg: ModelConfig, tokens: int, kind: str, chips: int,
                   hw: HwSpec = V5E) -> float:
    """Analytic roofline step time for `tokens` processed on `chips`."""
    n_active = cfg.active_params()
    factor = 6 if kind == "train" else 2
    flops = factor * n_active * tokens
    # weight streaming + activations; decode is weight-bound per token
    bytes_ = 2 * n_active + tokens * cfg.d_model * 2 * max(cfg.num_layers, 1)
    compute = flops / (chips * hw.peak_flops_bf16)
    memory = bytes_ / (chips * hw.hbm_bandwidth)
    return max(compute, memory)


def _decode_step_time(cfg: ModelConfig, batch: int, context: int, chips: int,
                      hw: HwSpec = V5E) -> float:
    """One decode token for `batch` sequences at a given context length."""
    n_active = cfg.active_params()
    flops = 2 * n_active * batch
    kv = 0
    for w in cfg.window_pattern():
        if cfg.attention_free:
            break
        eff = min(w, context) if w else context
        kv += 2 * eff * cfg.num_kv_heads * cfg.head_dim * 2  # k+v bf16
    ssm = 0
    if cfg.ssm_state:
        n_mamba = sum(1 for k in cfg.layer_kinds() if k == "mamba")
        ssm = n_mamba * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4
    bytes_ = 2 * n_active + batch * (kv + ssm)
    compute = flops / (chips * hw.peak_flops_bf16)
    memory = bytes_ / (chips * hw.hbm_bandwidth)
    return max(compute, memory)


class CostModel:
    """Maps QueryWork -> StagePlan on a worker slice of `chips` chips.

    Decode is split into chunks of ``decode_chunk_tokens`` tokens (0
    disables chunking): long generations become a chain of short stages,
    so they are preemptible at chunk boundaries and a fault retries only
    the failed chunk. Plan STRUCTURE depends only on the work (never on
    `chips` or ``speed_factor``), so a mid-plan stage cursor stays valid
    when the remaining stages are re-planned for a different slice size
    or a different pool (cross-pool spill, spill-back, preemption resume).

    ``speed_factor`` models heterogeneous pool hardware relative to the
    `hw` baseline: a 0.25x pool (e.g. CPU spot) runs every stage 4x
    longer — and bills 4x the chip-seconds — on the same plan structure.
    """

    def __init__(self, hw: HwSpec = V5E, use_calibration: bool = True,
                 decode_chunk_tokens: int = 32, speed_factor: float = 1.0):
        if speed_factor <= 0:
            raise ValueError(f"speed_factor must be > 0, got {speed_factor}")
        self.hw = hw
        self.use_calibration = use_calibration
        self.decode_chunk_tokens = decode_chunk_tokens
        self.speed_factor = speed_factor
        self._plan_cache: dict[tuple, StagePlan] = {}

    def _cal(self, arch: str, kind: str) -> float:
        cal = _calibration(arch, kind) if self.use_calibration else 1.0
        return cal / self.speed_factor

    def plan(self, work: QueryWork, chips: int) -> StagePlan:
        key = (work.arch, work.kind, work.batch, work.prompt_tokens,
               work.output_tokens, work.train_steps, work.seq_len, chips)
        cached = self._plan_cache.get(key)
        if cached is not None:
            return cached
        cfg = get_config(work.arch)
        cal = self._cal(work.arch, work.kind)
        stages: list[Stage] = []
        if work.kind == "train":
            t = _analytic_step(cfg, work.batch * work.seq_len, "train", chips)
            stages.append(Stage("train_steps", cal * t * work.train_steps, chips))
        else:
            tp = _analytic_step(
                cfg, work.batch * work.prompt_tokens, "serve", chips
            )
            stages.append(Stage("prefill", cal * tp, chips))
            if work.output_tokens:
                td = _decode_step_time(
                    cfg, work.batch, work.prompt_tokens, chips
                )
                chunk = self.decode_chunk_tokens or work.output_tokens
                done = 0
                while done < work.output_tokens:
                    n = min(chunk, work.output_tokens - done)
                    stages.append(
                        Stage(f"decode[{done}:{done + n}]", cal * td * n, chips)
                    )
                    done += n
        out = StagePlan(tuple(stages))
        self._plan_cache[key] = out
        return out

    def exec_time(self, work: QueryWork, chips: int) -> float:
        return self.plan(work, chips).exec_time

    def chip_seconds(self, work: QueryWork, chips: int) -> float:
        return self.plan(work, chips).chip_seconds
