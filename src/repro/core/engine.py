"""Stage-level execution engine: StagePlan is the unit of scheduling.

The paper's SOS argument (§4.3/§5.3) is that each query *stage* runs on an
isolated slice with a deterministic cost — that property is what makes
pending-time SLAs and admission-time price quotes tractable. This module
makes the runtime honor it: a running query is a cursor over its
``StagePlan`` (``Query.stage_cursor``), and both clusters drive execution
through one ``ClusterExecutor`` base whose core is a heap of predicted
per-stage completion times.

Heap discipline: every running stage has exactly one *valid* heap entry;
entries are lazily invalidated by bumping ``_Run.epoch`` whenever a
prediction changes (processor-sharing rate changes, preemption, spill),
so reschedules are O(log n) pushes and stale entries are skipped on pop.
This replaces the O(n) list scans the clusters used to do per event and
the ``last_completion_push`` dedupe hack the simulator needed on top.

Hot-path complexity (the 1M-query-day requirement, benchmarks/scale.py):
every per-event query is O(1) —

  * ``predicted_backlog_cs`` is an incrementally maintained counter, not
    an O(running + waiting) scan. Each run's current-stage prediction is
    stored as the pair ``(t_finish * burn, burn)`` so the remaining
    chip-seconds at time ``now`` are ``sum(t_finish*burn) - now *
    sum(burn)`` — time-parametric, no decay bookkeeping to settle, and
    each retired run removes exactly the terms it added. Waiting queries
    and unstarted stages contribute version-tracked static sums. The
    old scan survives as ``predicted_backlog_scan_cs`` and a debug mode
    (``DEBUG_BACKLOG`` / ``check_backlog_invariant``) asserts the two
    agree after every advance — the hypothesis suite runs with it on.
  * quotes read a per-pool static cache (remaining exec time +
    chip-seconds at the pool's slice) keyed by the work shape and stage
    cursor, invalidated off ``CalibrationTable.version`` and the pool's
    ``load_epoch`` (bumped when capacity changes), so the coordinator's
    all-pools quote loop re-plans only when planning inputs change.
  * ``waiting`` is a ``WaitingQueue``: still a list (external code may
    append to it directly), but every mutation keeps per-service-level
    FIFO lanes and counts in sync, so the SOS priority pop selects its
    candidate in O(1) (the dense-list removal is a C memmove) and the
    displacing-waiter check is O(1) instead of an O(waiting) scan.

Stage boundaries are where policy acts:
  * preemption — a BEST_EFFORT query marked ``preempt_requested`` stops
    at its next boundary and re-enters the waiting queue with its cursor
    (and billed chip-seconds) intact;
  * cross-cluster spill — the coordinator may hand the remaining stages
    of a VM query to the elastic cluster (re-planned for the elastic
    slice size, billed at the elastic rate from that stage on);
  * fault recovery — the fault model is sampled per stage, so a retry
    re-runs (and re-bills) only the failed stage.
"""
from __future__ import annotations

import heapq
import itertools
import math
import os
from collections import deque
from typing import Callable, NamedTuple, Optional

import numpy as np

from . import sanitize
from .cost_model import CostModel, Stage, StagePlan
from .query import Query
from .sla import ServiceLevel

#: when true, every ``advance_to`` re-derives the backlog with the full
#: O(running + waiting) scan and asserts it matches the incremental
#: counter — the equivalence lock the hypothesis suite runs under.
#: ``REPRO_SANITIZE=1`` (core/sanitize.py) implies it per-pool via the
#: executor's ``sanitize`` flag without flipping this global.
DEBUG_BACKLOG = os.environ.get("REPRO_DEBUG_BACKLOG", "") == "1"

_BOE = int(ServiceLevel.BEST_EFFORT)


class StageEvent(NamedTuple):
    """One completed stage execution — the per-stage trace record.
    A NamedTuple, not a dataclass: a 1M-query day creates millions of
    these and tuple construction is several times cheaper."""

    qid: int
    stage: str
    index: int  # position in the query's StagePlan
    cluster: str
    start: float
    finish: float
    chips: int
    chip_seconds: float  # billed (includes retry re-runs / speculation)
    cost: float
    retries: int


def account_stage(
    q: Query,
    stage: str,
    cluster: str,
    start: float,
    finish: float,
    chips: int,
    billed_cs: float,
    price_per_chip_s: float,
    retries: int = 0,
) -> StageEvent:
    """Record one completed stage on the query: bill the chip-seconds,
    add the cost, append the trace event, advance the cursor. Both the
    simulated executors and the live engine (core/live.py) account
    through this one helper, so live billing is the same per-stage
    arithmetic the simulator's conservation tests lock down."""
    cost = billed_cs * price_per_chip_s
    q.chip_seconds += billed_cs
    q.cost += cost
    ev = StageEvent(q.qid, stage, q.stage_cursor, cluster, start, finish,
                    chips, billed_cs, cost, retries)
    q.stage_trace.append(ev)
    q.stage_cursor += 1
    return ev


class _Run:
    """Execution state of the CURRENT stage of one admitted query."""

    __slots__ = (
        "query", "plan", "chips", "remaining", "rate", "last_update",
        "epoch", "active", "stage_start", "billed_cs", "stage_retries",
        "preempt_requested",
        # incremental-backlog terms this run currently contributes
        # (engine-private; see ClusterExecutor._bl_* helpers)
        "bl_state", "bl_cur", "bl_tf_burn", "bl_burn", "bl_unstarted",
        "bl_token", "plan_ver",
    )

    def __init__(self, query: Query, plan: StagePlan, chips: int):
        self.query = query
        self.plan = plan
        self.chips = chips
        self.remaining = 0.0  # work left in this stage (units set by rate)
        self.rate = 1.0  # work units consumed per second
        self.last_update = 0.0
        self.epoch = 0  # bumped on every (re)prediction
        self.active = True
        self.stage_start = 0.0
        self.billed_cs = 0.0
        self.stage_retries = 0
        self.preempt_requested = False
        self.bl_state = 0  # 0 = no terms, 1 = future (unstarted), 2 = active
        self.bl_cur = 0.0
        self.bl_tf_burn = 0.0
        self.bl_burn = 0.0
        self.bl_unstarted = 0.0
        self.bl_token = 0
        self.plan_ver = -1


class WaitingQueue(list):
    """``pool.waiting``: still a list — external code (tests, policy
    snapshots) may read or append to it directly — but every mutation
    also maintains per-service-level FIFO lanes and counts, and fires
    the owner's hooks (incremental backlog, cross-pool fusion index).
    ``pop_best`` replaces the SOS slice-handoff's O(n) min scan."""

    __slots__ = ("_owner", "_seq", "_lanes", "_live", "_by_seq", "counts")

    def __init__(self, owner: "ClusterExecutor"):
        super().__init__()
        self._owner = owner
        self._seq = itertools.count()
        # lanes hold seqs, resolved through _by_seq at pop time: the
        # indirection is what lets `replace` keep a lane slot while
        # swapping the query occupying it
        self._lanes: tuple[deque, ...] = (deque(), deque(), deque())
        self._live: dict[Query, int] = {}  # query -> its live lane seq
        self._by_seq: dict[int, Query] = {}  # lane seq -> current query
        self.counts = [0, 0, 0]  # waiting queries per service level

    # --- internal bookkeeping ----------------------------------------
    def _track(self, q: Query) -> None:
        seq = next(self._seq)
        self._live[q] = seq
        self._by_seq[seq] = q
        lvl = q.current_sla  # IntEnum: indexes lanes/counts directly
        self._lanes[lvl].append(seq)
        self.counts[lvl] += 1
        self._owner._wait_added(q)

    def _untrack(self, q: Query) -> None:
        seq = self._live.pop(q)
        del self._by_seq[seq]
        lvl = q.current_sla
        self.counts[lvl] -= 1
        # reclaim dead entries at the lane head: FIFO pools (elastic,
        # POS) drain via pop(0) and never visit pop_best's lazy cleanup,
        # so without this sweep their lanes would grow one dead cell per
        # query forever. Amortized O(1): each entry is swept once.
        lane = self._lanes[lvl]
        by_seq = self._by_seq
        while lane and lane[0] not in by_seq:
            lane.popleft()
        self._owner._wait_removed(q)

    # --- list mutators, kept in sync ---------------------------------
    def append(self, q: Query) -> None:
        super().append(q)
        self._track(q)

    def extend(self, qs) -> None:
        for q in qs:
            self.append(q)

    def insert(self, i: int, q: Query) -> None:
        super().insert(i, q)
        self._track(q)

    def remove(self, q: Query) -> None:
        super().remove(q)
        self._untrack(q)

    def pop(self, i: int = -1) -> Query:
        q = super().pop(i)
        self._untrack(q)
        return q

    def clear(self) -> None:
        while self:
            self.pop()

    def peek_best(self) -> Query:
        """The query ``pop_best`` would return, without removing it —
        variable-width admission must price the head's slice before
        committing to start it."""
        by_seq = self._by_seq
        for lane in self._lanes:
            while lane:
                q = by_seq.get(lane[0])
                if q is None:
                    lane.popleft()  # stale: removed through another path
                    continue
                return q
        raise IndexError("peek_best from an empty waiting queue")

    # --- priority pop (SOS slice handoff) ----------------------------
    def pop_best(self) -> Query:
        """Earliest-enqueued query of the most urgent waiting level —
        exactly ``min(waiting, key=(sla, insertion index))``. Candidate
        selection is O(1) from the lanes; the dense-list removal below
        is an O(queue) C-level memmove (kept: the list API is what
        external code and the scan paths read)."""
        by_seq = self._by_seq
        for lane in self._lanes:
            while lane:
                q = by_seq.get(lane[0])
                if q is None:
                    lane.popleft()  # stale: removed through another path
                    continue
                lane.popleft()
                list.remove(self, q)
                self._untrack(q)
                return q
        raise IndexError("pop_best from an empty waiting queue")


class ClusterExecutor:
    """Base for both clusters: admission + per-stage completion queue.

    Subclasses implement ``_admit`` (capacity policy), ``_plan_chips``
    (slice sizing) and may override ``_stage_work`` (fault sampling),
    ``_run_rate``/``_rates_changed`` (processor sharing) and
    ``_continue_run`` (stage-boundary preemption/spill policy).

    As a POOL in the coordinator's registry, an executor also answers
    placement questions: ``quote(q)`` prices the query's remaining
    stages at the pool's current load, ``predicted_backlog_cs`` is the
    incrementally-maintained chip-seconds committed to the pool (the
    backlog-driven autoscale signal), and ``rehome`` — wired by the
    coordinator — may move a query to another pool at any stage
    boundary (spill, spill-back).
    """

    name = "?"
    #: "reserved" pools are bounded and cheap (the cost-efficient tier);
    #: "elastic" pools are unbounded burst capacity at a premium price.
    pool_kind = "reserved"
    #: whether the simulator must `tick` this pool on events that are
    #: not its own (only pools with time-decaying policy signals —
    #: backlog-triggered autoscale, injected chaos — need it)
    needs_tick = False
    #: audit event feed (core/events.py), attached by the simulation /
    #: live engine when event recording is on; None costs nothing
    events = None
    #: injected fault schedule (core/chaos.py PoolChaos) and its next
    #: due death — wired by chaos.wire_sim_chaos on reserved pools
    _chaos = None
    _chaos_next = math.inf

    def __init__(
        self,
        cost_model: Optional[CostModel] = None,
        fault=None,
        rng: Optional[np.random.Generator] = None,
        price_per_chip_s: float = 0.0,
    ):
        self.cost_model = cost_model or CostModel()
        self.fault = fault
        self.rng = rng or np.random.default_rng(0)
        self.price_per_chip_s = price_per_chip_s
        #: one-switch runtime sanitizer (core/sanitize.py): when set,
        #: every advance_to re-checks the backlog and heap invariants,
        #: exactly as DEBUG_BACKLOG does globally. Observers only —
        #: results are bit-identical either way.
        self.sanitize = sanitize.enabled()
        # insertion-ordered for deterministic iteration, O(1) removal
        self.running: dict[_Run, None] = {}
        self.waiting: list[Query] = WaitingQueue(self)
        self._heap: list[tuple[float, int, _Run, int]] = []
        self._seq = itertools.count()
        self.stages_completed = 0
        #: bumped whenever the pool's planning inputs change (capacity /
        #: slice size); static-quote cache entries are validated against
        #: it together with the calibration version
        self.load_epoch = 0
        self._quote_cache: dict[tuple, tuple] = {}
        #: per-query width chooser (core/allocation.py), attached by
        #: build_pool when the pool's spec carries AllocationConfig;
        #: None keeps the pool's fixed slice sizing
        self.allocator = None
        #: runs currently flagged for stage-boundary preemption — lets
        #: the per-admission preempt bookkeeping skip its O(running)
        #: scan whenever flags already match the waiting IMMEDIATEs
        self._flagged: set[_Run] = set()
        #: cross-pool fusion index hook (scheduler.CrossPoolFusionIndex),
        #: wired by the coordinator when placement-time fusion is on;
        #: told about every waiting-queue add/remove
        self.wait_observer = None
        #: stage-boundary re-placement hook, wired by the coordinator:
        #: (query, now) -> target pool, or None to keep the query here
        self.rehome: Optional[Callable[[Query, float], Optional["ClusterExecutor"]]] = None
        #: observation hook called after every completed stage with
        #: (query, planned_stage, event) — how a calibration loop reads
        #: this pool's predicted-vs-actual stage walls without touching
        #: the accounting path (core/calibration.py, benchmarks)
        self.stage_observer: Optional[Callable[[Query, Stage, StageEvent], None]] = None
        # --- incremental backlog counter (predicted_backlog_cs) -------
        self._bl_wait_map: dict[int, float] = {}  # qid -> remaining cs
        self._bl_wait_cs = 0.0
        self._bl_unstarted_cs = 0.0
        self._bl_tf_burn = 0.0  # sum over started runs: t_finish * burn
        self._bl_burn = 0.0  # sum over started runs: burn (cs per second)
        self._bl_future: list[tuple[float, int, _Run]] = []  # startup leads
        self._bl_future_cs = 0.0
        self._bl_now = 0.0  # latest time this pool has observed
        self._bl_ver = -1  # calibration version the wait sums were built at
        #: earliest time a backlog-triggered autoscale verdict can change
        #: passively (clusters.CostEfficientCluster.tick); any backlog
        #: mutation resets it to 0 = "re-evaluate at the next event"
        self._as_next_eval = 0.0

    # --- queue state the coordinator watches -------------------------
    @property
    def run_queue_len(self) -> int:
        return len(self.running) + len(self.waiting)

    @property
    def idle(self) -> bool:
        return self.run_queue_len == 0

    def has_displacing_waiter(self, q: Query) -> bool:
        """Whether a waiting non-BEST_EFFORT query at least as urgent as
        `q` has no slice (the spill trigger) — O(1) from the waiting
        queue's per-level counts instead of an O(waiting) scan."""
        counts = self.waiting.counts
        lvl = int(q.current_sla)
        return any(counts[l] for l in range(lvl + 1) if l != _BOE)

    # --- placement interface (the coordinator's registry view) -------
    def effective_chips(self, q: Query) -> int:
        """The slice size EVERY planning path uses for this query on this
        pool — quotes, spill thresholds, and execution must all plan with
        the same chips, so they share this one accessor."""
        return self._plan_chips(q)

    def has_capacity(self) -> bool:
        """Whether a newly submitted query would start immediately."""
        return True

    def _queue_delay_estimate(self, q: Query, now: Optional[float]) -> float:
        """Estimated wait before the query's first remaining stage runs."""
        return 0.0

    def _static_quote(self, q: Query) -> tuple[float, float, float]:
        """(remaining exec seconds, remaining chip-seconds, cost) of the
        query's remaining stages on this pool's slice — the load-free
        half of a quote, cached per (work shape, stage cursor) and
        invalidated off the calibration version + the pool's load epoch.
        The coordinator's per-query all-pools quote loop reads this, so
        routing re-plans only when a planning input actually changed."""
        w = q.work
        # the service level is a planning input once an allocator sizes
        # slices per level; without one it only widens cache granularity
        key = (w.arch, w.kind, w.batch, w.prompt_tokens, w.output_tokens,
               w.train_steps, w.seq_len, q.stage_cursor, q.current_sla)
        ver = (self.cost_model.plan_version(), self.load_epoch)
        hit = self._quote_cache.get(key)
        if hit is not None and hit[0] == ver:
            return hit[1]
        plan = self.cost_model.plan(w, self.effective_chips(q))
        cs = plan.remaining_chip_seconds(q.stage_cursor)
        out = (plan.remaining_time(q.stage_cursor), cs,
               cs * self.price_per_chip_s)
        if len(self._quote_cache) > 4096:  # unbounded work variety guard
            self._quote_cache.clear()
        self._quote_cache[key] = (ver, out)
        return out

    def remaining_exec_s(self, q: Query) -> float:
        """Remaining execution seconds on this pool's slice (cached) —
        what the spill/spill-back worth-the-hop thresholds compare."""
        return self._static_quote(q)[0]

    def quote_cost(self, q: Query) -> float:
        """The cost half of `quote` alone — O(1), no queue-state walk.
        Placement paths that only compare prices use this so a saturated
        pool's backlog walk is never computed just to be discarded."""
        return self._static_quote(q)[2]

    def quote(self, q: Query, now: Optional[float] = None) -> dict:
        """Latency/cost quote for the query's REMAINING stages
        (q.stage_cursor onward) at the pool's current load. A preempted
        or spill-candidate query is priced for what's left, never for
        work it already ran."""
        exec_s, _, cost = self._static_quote(q)
        return {
            "latency_s": self._queue_delay_estimate(q, now) + exec_s,
            "cost": cost,
        }

    def _run_cs_factor(self, run: _Run) -> float:  # reprolint: disable=RL102 -- mode-dependent dimension: chip_s per work unit, where a work unit is wall-seconds (SOS) or chip-seconds (POS)
        """Chip-seconds per work unit of this run (base: work is
        wall-seconds on an isolated slice of `run.chips`)."""
        return float(run.chips)

    def _run_remaining_cs(self, run: _Run, now: Optional[float]) -> float:
        """Chip-seconds left in the run's CURRENT stage (scan path)."""
        elapsed = 0.0 if now is None else max(now - run.last_update, 0.0)
        return max(run.remaining - elapsed * run.rate, 0.0) * run.chips

    # --- incremental backlog maintenance ------------------------------
    def _wait_added(self, q: Query) -> None:
        cs = self._static_quote(q)[1]
        self._bl_wait_map[q.qid] = cs
        self._bl_wait_cs += cs
        self._as_next_eval = 0.0
        if self.wait_observer is not None:
            self.wait_observer.add(self, q)

    def _wait_removed(self, q: Query) -> None:
        self._as_next_eval = 0.0
        self._bl_wait_cs -= self._bl_wait_map.pop(q.qid, 0.0)
        if not self._bl_wait_map:
            self._bl_wait_cs = 0.0  # pin float drift to zero when empty
        if self.wait_observer is not None:
            self.wait_observer.discard(q)

    def _bl_rebuild_wait(self) -> None:
        """Re-derive the waiting sums (calibration version bumped, or a
        POS pool's plan chips changed) — amortized O(1): only runs when
        a planning input changes, never per event."""
        self._as_next_eval = 0.0
        self._bl_wait_map.clear()
        self._bl_wait_cs = 0.0
        for q in self.waiting:
            cs = self._static_quote(q)[1]
            self._bl_wait_map[q.qid] = cs
            self._bl_wait_cs += cs

    def _bl_retract_run(self, run: _Run) -> None:
        if run.bl_state == 2:
            self._bl_tf_burn -= run.bl_tf_burn
            self._bl_burn -= run.bl_burn
        elif run.bl_state == 1:
            self._bl_future_cs -= run.bl_cur
        run.bl_state = 0

    def _bl_retire_run(self, run: _Run) -> None:
        self._as_next_eval = 0.0
        self._bl_retract_run(run)
        self._bl_unstarted_cs -= run.bl_unstarted
        run.bl_unstarted = 0.0
        self._flagged.discard(run)
        if not self.running:
            # no runs left: pin the run-side aggregates to exactly zero
            # so float drift can never accumulate across a long day
            self._bl_tf_burn = 0.0
            self._bl_burn = 0.0
            self._bl_unstarted_cs = 0.0
            self._bl_future_cs = 0.0
            self._bl_future.clear()

    def _bl_sync(self, now: Optional[float]) -> None:
        ver = self.cost_model.plan_version()
        if ver != self._bl_ver:
            self._bl_ver = ver
            self._bl_rebuild_wait()
        if now is not None and now > self._bl_now:
            self._bl_now = now
        fut = self._bl_future
        while fut and fut[0][0] <= self._bl_now + 1e-9:
            _, _, token, run = heapq.heappop(fut)
            if run.bl_state == 1 and run.bl_token == token:
                # the startup lead has elapsed: the run's current stage
                # now decays like any started run
                self._bl_future_cs -= run.bl_cur
                self._bl_tf_burn += run.bl_tf_burn
                self._bl_burn += run.bl_burn
                run.bl_state = 2

    def predicted_backlog_cs(self, now: Optional[float] = None) -> float:
        """Predicted chip-seconds committed to this pool: the running
        stages' remaining work (the same predictions the stage heap
        holds), every running query's unstarted stages, and every
        waiting query's remaining plan — the backlog-driven autoscale
        signal. O(1): maintained incrementally at submit / admit /
        stage-begin / finish / preempt / spill / rehome, with the old
        full scan kept as ``predicted_backlog_scan_cs`` and asserted
        equivalent in debug mode (``check_backlog_invariant``)."""
        self._bl_sync(now)
        t = self._bl_now if now is None else now
        run_cs = self._bl_tf_burn - t * self._bl_burn
        if run_cs < 0.0:
            run_cs = 0.0
        return run_cs + self._bl_future_cs + self._bl_unstarted_cs + self._bl_wait_cs

    def predicted_backlog_scan_cs(self, now: Optional[float] = None) -> float:
        """The original O(running + waiting) backlog recompute — the
        debug-mode reference the incremental counter is locked against."""
        total = 0.0
        for run in self.running:
            total += self._run_remaining_cs(run, now)
            total += run.plan.remaining_chip_seconds(run.query.stage_cursor + 1)
        for q in self.waiting:
            plan = self.cost_model.plan(q.work, self._plan_chips(q))
            total += plan.remaining_chip_seconds(q.stage_cursor)
        return total

    def check_backlog_invariant(self, now: Optional[float] = None) -> None:
        """Assert incremental backlog == full scan (debug/test hook)."""
        inc = self.predicted_backlog_cs(now)
        scan = self.predicted_backlog_scan_cs(now)
        assert math.isclose(inc, scan, rel_tol=1e-9, abs_tol=1e-6), (
            f"{self.name}: incremental backlog {inc!r} != scan {scan!r} "
            f"at now={now!r}"
        )

    def drain_time_s(self, now: Optional[float] = None) -> float:
        """Seconds to drain the predicted backlog at current capacity
        (elastic pools drain in parallel: effectively zero)."""
        return 0.0

    def tick(self, now: float) -> None:
        """Cheap per-event bookkeeping for a pool with NO completions due
        at `now`. Base pools have no time-driven policy between their own
        events; autoscaled reserved pools re-evaluate the backlog trigger
        (its drain-time signal decays continuously) — see
        CostEfficientCluster.tick."""

    def tick_due(self, now: float) -> bool:
        """Whether `tick` would act at `now` (the simulator's idle-event
        fast path skips the pool pass when no tick is due anywhere)."""
        return False

    def next_tick_time(self) -> float:
        """Earliest future time `tick` could act — lets the simulator's
        poll fast-forward skip straight past an idle pool (inf = this
        pool never acts between its own events)."""
        return math.inf

    def check_heap_invariant(self) -> None:
        """Test/debug hook: every running stage has exactly one VALID
        heap entry, and no valid entry refers to a retired run."""
        valid: dict[int, int] = {}
        for _, _, run, epoch in self._heap:
            if run.active and epoch == run.epoch:
                valid[id(run)] = valid.get(id(run), 0) + 1
        running_ids = {id(r) for r in self.running}
        assert set(valid) == running_ids, (
            f"{self.name}: valid heap entries {len(valid)} != "
            f"running {len(running_ids)}"
        )
        assert all(v == 1 for v in valid.values()), (
            f"{self.name}: duplicate valid heap entries: {valid}"
        )

    # --- subclass hooks ----------------------------------------------
    def _admit(self, now: float) -> None:
        raise NotImplementedError

    def _plan_chips(self, q: Query) -> int:
        raise NotImplementedError

    def _stage_work(self, stage: Stage, q: Query) -> tuple[float, float, int]:
        """(work units, billed chip-seconds, retries) for one stage run.
        Default: wall-seconds at rate 1, fault model sampled per stage."""
        if self.fault is None:
            return stage.time_s, stage.chip_seconds, 0
        return self.fault.stage_execution(
            stage.time_s, stage.chips, self.rng, q
        )

    def _run_rate(self, run: _Run) -> float:
        return 1.0

    def _rates_changed(self, now: float) -> None:
        """Concurrency changed — subclasses with shared rates reschedule."""

    def _sync(self, now: float) -> None:
        """Advance run bookkeeping to `now` (shared-rate subclasses)."""

    def _continue_run(self, run: _Run, now: float) -> bool:
        """Stage-boundary policy: return False to withhold the next stage
        (the run is retired; the query was re-routed or re-queued).
        Base behavior: ask the coordinator's `rehome` hook whether the
        query should continue on another pool — a reserved pool spills
        to an elastic one under overload, an elastic pool hands a
        spilled query back once the reserved backlog clears."""
        if self.rehome is None:
            return True
        target = self.rehome(run.query, now)
        if target is None or target is self:
            return True
        self._handoff(run.query, target, now)
        return False

    def _handoff(self, q: Query, target: "ClusterExecutor", now: float) -> None:
        """Move a query to another pool at a stage boundary. The stage
        cursor stays valid because plan STRUCTURE is pool-independent;
        remaining stages are re-planned (and re-priced) on the target."""
        if target.pool_kind == "elastic" and self.pool_kind == "reserved":
            q.spilled = True
            q.state = "spilled"
            kind = "spill"
        else:
            q.spill_backs += 1
            q.state = "spilled-back"
            kind = "spill_back"
        if self.events is not None:
            self.events.emit(
                kind, now, qid=q.qid, src=self.name, dst=target.name,
                cursor=q.stage_cursor,
            )
        target.submit(q, now)

    def withdraw(self, q: Query) -> bool:
        """Remove a WAITING query from this pool (placement-time fusion
        pulls compatible waiters out of their pools before merging).
        Returns False when the query is no longer waiting here."""
        try:
            self.waiting.remove(q)
        except ValueError:
            return False
        self._waiter_withdrawn(q)
        return True

    def _waiter_withdrawn(self, q: Query) -> None:
        """Hook after a waiter is pulled by fusion: subclasses whose
        policy state derives from the waiting queue (stage-boundary
        preemption flags) re-derive it here — the old per-event
        rederivation would otherwise leave a stale flag that preempts a
        run nobody is waiting for."""

    # --- heap machinery ----------------------------------------------
    def _push(self, run: _Run, now: float) -> None:
        run.epoch += 1
        t = now + max(run.remaining, 0.0) / run.rate
        heapq.heappush(self._heap, (t, next(self._seq), run, run.epoch))
        # incremental backlog: replace this run's prediction terms with
        # the ones implied by the entry just pushed (identical floats);
        # the retract is inlined — this runs once per stage begin/re-rate
        self._as_next_eval = 0.0
        st = run.bl_state
        if st == 2:
            self._bl_tf_burn -= run.bl_tf_burn
            self._bl_burn -= run.bl_burn
        elif st == 1:
            self._bl_future_cs -= run.bl_cur
        run.bl_state = 0
        burn = run.rate * self._run_cs_factor(run)
        run.bl_tf_burn = t * burn
        run.bl_burn = burn
        if run.last_update > self._bl_now + 1e-9:
            # not started yet (elastic startup lead): the scan counts the
            # full stage work until `now` reaches the start time
            run.bl_state = 1
            run.bl_cur = max(run.remaining, 0.0) * self._run_cs_factor(run)
            run.bl_token = run.epoch
            self._bl_future_cs += run.bl_cur
            heapq.heappush(
                self._bl_future,
                (run.last_update, next(self._seq), run.bl_token, run),
            )
        else:
            run.bl_state = 2
            self._bl_tf_burn += run.bl_tf_burn
            self._bl_burn += run.bl_burn

    def _prune(self) -> None:
        h = self._heap
        while h and (not h[0][2].active or h[0][3] != h[0][2].epoch):
            heapq.heappop(h)

    def next_event_time(self) -> Optional[float]:
        """Earliest valid predicted stage completion (absolute time)."""
        self._prune()
        return self._heap[0][0] if self._heap else None

    # --- lifecycle ----------------------------------------------------
    def submit(self, q: Query, now: float) -> None:
        q.cluster = self.name
        self.waiting.append(q)
        self._admit(now)

    def _start_run(self, q: Query, now: float) -> _Run:
        chips = self._plan_chips(q)
        plan = self.cost_model.plan(q.work, chips)
        run = _Run(q, plan, chips)
        run.plan_ver = self.cost_model.plan_version()
        if q.start_time is None:
            q.start_time = now
        q.state = "running"
        self.running[run] = None
        self._begin_stage(run, now)
        return run

    def _begin_stage(self, run: _Run, now: float) -> None:
        # re-read the plan at every stage boundary: a calibration hot
        # swap (versioned CostModel cache) must flow into the stages not
        # yet begun. Structure is calibration-invariant, so the cursor
        # stays valid; the version check makes the no-update case a
        # single integer compare instead of a plan-cache lookup.
        ver = self.cost_model.plan_version()
        if ver != run.plan_ver:
            run.plan = self.cost_model.plan(run.query.work, run.chips)
            run.plan_ver = ver
        stage = run.plan.stages[run.query.stage_cursor]
        work, billed, retries = self._stage_work(stage, run.query)
        run.stage_start = now
        run.remaining = work
        run.last_update = now
        run.rate = self._run_rate(run)
        run.billed_cs = billed
        run.stage_retries = retries
        unstarted = run.plan._suffix_cs[run.query.stage_cursor + 1]
        self._bl_unstarted_cs += unstarted - run.bl_unstarted
        run.bl_unstarted = unstarted
        self._push(run, now)

    def advance_to(self, now: float) -> list[Query]:
        """Process every stage completion due by `now`; returns queries
        that finished their final stage (stamped with the exact per-stage
        completion time, not the event-processing time)."""
        finished: list[Query] = []
        h = self._heap
        due = now + 1e-9
        pop = heapq.heappop
        finish = self._finish_stage  # bound once: this loop is the
        while h:                     # single hottest line in a 1M-day
            e = h[0]
            run = e[2]
            if not run.active or e[3] != run.epoch:
                pop(h)  # stale entry (epoch invalidation)
                continue
            if e[0] > due:
                break
            pop(h)
            finish(run, e[0], finished)
        # completion branches admit at their exact finish times; a
        # trailing pass only matters for pools with time-driven policy
        # (autoscale trigger re-evaluation at this event's `now`)
        if self.needs_tick:
            self._admit(now)
        if DEBUG_BACKLOG or self.sanitize:
            self.check_backlog_invariant(now)
            if self.sanitize:
                self.check_heap_invariant()
        return finished

    #: subclasses with shared-rate dynamics (POS) set this so the hot
    #: SOS/elastic path skips the no-op _sync/_rates_changed dispatches
    _shared_rates = False

    def _finish_stage(self, run: _Run, t: float, finished: list[Query]) -> None:
        if self._shared_rates:
            self._sync(t)
        q = run.query
        stage = run.plan.stages[q.stage_cursor]
        ev = account_stage(
            q, stage.name, self.name, run.stage_start, t, run.chips,
            run.billed_cs, self.price_per_chip_s, run.stage_retries,
        )
        self.stages_completed += 1
        if self.stage_observer is not None:
            self.stage_observer(q, stage, ev)
        if q.stage_cursor >= len(run.plan.stages):
            run.active = False
            del self.running[run]
            self._bl_retire_run(run)
            q.finish_time = t
            q.state = "done"
            finished.append(q)
            if self._shared_rates:
                self._rates_changed(t)
            self._admit(t)
        elif not self._continue_run(run, t):
            run.active = False
            del self.running[run]
            self._bl_retire_run(run)
            if self._shared_rates:
                self._rates_changed(t)
            self._admit(t)
        else:
            self._begin_stage(run, t)
