"""Stage-level execution engine: StagePlan is the unit of scheduling.

The paper's SOS argument (§4.3/§5.3) is that each query *stage* runs on an
isolated slice with a deterministic cost — that property is what makes
pending-time SLAs and admission-time price quotes tractable. This module
makes the runtime honor it: a running query is a cursor over its
``StagePlan`` (``Query.stage_cursor``), and both clusters drive execution
through one ``ClusterExecutor`` base whose core is a heap of predicted
per-stage completion times.

Heap discipline: every running stage has exactly one *valid* heap entry;
entries are lazily invalidated by bumping ``_Run.epoch`` whenever a
prediction changes (processor-sharing rate changes, preemption, spill),
so reschedules are O(log n) pushes and stale entries are skipped on pop.
This replaces the O(n) list scans the clusters used to do per event and
the ``last_completion_push`` dedupe hack the simulator needed on top.

Stage boundaries are where policy acts:
  * preemption — a BEST_EFFORT query marked ``preempt_requested`` stops
    at its next boundary and re-enters the waiting queue with its cursor
    (and billed chip-seconds) intact;
  * cross-cluster spill — the coordinator may hand the remaining stages
    of a VM query to the elastic cluster (re-planned for the elastic
    slice size, billed at the elastic rate from that stage on);
  * fault recovery — the fault model is sampled per stage, so a retry
    re-runs (and re-bills) only the failed stage.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from .cost_model import CostModel, Stage, StagePlan
from .query import Query


@dataclass(frozen=True)
class StageEvent:
    """One completed stage execution — the per-stage trace record."""

    qid: int
    stage: str
    index: int  # position in the query's StagePlan
    cluster: str
    start: float
    finish: float
    chips: int
    chip_seconds: float  # billed (includes retry re-runs / speculation)
    cost: float
    retries: int


def account_stage(
    q: Query,
    *,
    stage: str,
    cluster: str,
    start: float,
    finish: float,
    chips: int,
    billed_cs: float,
    price_per_chip_s: float,
    retries: int = 0,
) -> StageEvent:
    """Record one completed stage on the query: bill the chip-seconds,
    add the cost, append the trace event, advance the cursor. Both the
    simulated executors and the live engine (core/live.py) account
    through this one helper, so live billing is the same per-stage
    arithmetic the simulator's conservation tests lock down."""
    cost = billed_cs * price_per_chip_s
    q.chip_seconds += billed_cs
    q.cost += cost
    ev = StageEvent(
        qid=q.qid, stage=stage, index=q.stage_cursor, cluster=cluster,
        start=start, finish=finish, chips=chips, chip_seconds=billed_cs,
        cost=cost, retries=retries,
    )
    q.stage_trace.append(ev)
    q.stage_cursor += 1
    return ev


class _Run:
    """Execution state of the CURRENT stage of one admitted query."""

    __slots__ = (
        "query", "plan", "chips", "remaining", "rate", "last_update",
        "epoch", "active", "stage_start", "billed_cs", "stage_retries",
        "preempt_requested",
    )

    def __init__(self, query: Query, plan: StagePlan, chips: int):
        self.query = query
        self.plan = plan
        self.chips = chips
        self.remaining = 0.0  # work left in this stage (units set by rate)
        self.rate = 1.0  # work units consumed per second
        self.last_update = 0.0
        self.epoch = 0  # bumped on every (re)prediction
        self.active = True
        self.stage_start = 0.0
        self.billed_cs = 0.0
        self.stage_retries = 0
        self.preempt_requested = False


class ClusterExecutor:
    """Base for both clusters: admission + per-stage completion queue.

    Subclasses implement ``_admit`` (capacity policy), ``_plan_chips``
    (slice sizing) and may override ``_stage_work`` (fault sampling),
    ``_run_rate``/``_rates_changed`` (processor sharing) and
    ``_continue_run`` (stage-boundary preemption/spill policy).

    As a POOL in the coordinator's registry, an executor also answers
    placement questions: ``quote(q)`` prices the query's remaining
    stages at the pool's current load, ``predicted_backlog_s`` sums the
    chip-seconds already committed to the pool (the backlog-driven
    autoscale signal), and ``rehome`` — wired by the coordinator — may
    move a query to another pool at any stage boundary (spill,
    spill-back).
    """

    name = "?"
    #: "reserved" pools are bounded and cheap (the cost-efficient tier);
    #: "elastic" pools are unbounded burst capacity at a premium price.
    pool_kind = "reserved"

    def __init__(
        self,
        cost_model: Optional[CostModel] = None,
        fault=None,
        rng: Optional[np.random.Generator] = None,
        price_per_chip_s: float = 0.0,
    ):
        self.cost_model = cost_model or CostModel()
        self.fault = fault
        self.rng = rng or np.random.default_rng(0)
        self.price_per_chip_s = price_per_chip_s
        # insertion-ordered for deterministic iteration, O(1) removal
        self.running: dict[_Run, None] = {}
        self.waiting: list[Query] = []
        self._heap: list[tuple[float, int, _Run, int]] = []
        self._seq = itertools.count()
        self.stages_completed = 0
        #: stage-boundary re-placement hook, wired by the coordinator:
        #: (query, now) -> target pool, or None to keep the query here
        self.rehome: Optional[Callable[[Query, float], Optional["ClusterExecutor"]]] = None
        #: observation hook called after every completed stage with
        #: (query, planned_stage, event) — how a calibration loop reads
        #: this pool's predicted-vs-actual stage walls without touching
        #: the accounting path (core/calibration.py, benchmarks)
        self.stage_observer: Optional[Callable[[Query, Stage, StageEvent], None]] = None

    # --- queue state the coordinator watches -------------------------
    @property
    def run_queue_len(self) -> int:
        return len(self.running) + len(self.waiting)

    @property
    def idle(self) -> bool:
        return self.run_queue_len == 0

    # --- placement interface (the coordinator's registry view) -------
    def effective_chips(self, q: Query) -> int:
        """The slice size EVERY planning path uses for this query on this
        pool — quotes, spill thresholds, and execution must all plan with
        the same chips, so they share this one accessor."""
        return self._plan_chips(q)

    def has_capacity(self) -> bool:
        """Whether a newly submitted query would start immediately."""
        return True

    def _queue_delay_estimate(self, q: Query, now: Optional[float]) -> float:
        """Estimated wait before the query's first remaining stage runs."""
        return 0.0

    def quote_cost(self, q: Query) -> float:
        """The cost half of `quote` alone — O(1), no queue-state walk.
        Placement paths that only compare prices use this so a saturated
        pool's backlog walk is never computed just to be discarded."""
        plan = self.cost_model.plan(q.work, self.effective_chips(q))
        return plan.remaining_chip_seconds(q.stage_cursor) * self.price_per_chip_s

    def quote(self, q: Query, now: Optional[float] = None) -> dict:
        """Latency/cost quote for the query's REMAINING stages
        (q.stage_cursor onward) at the pool's current load. A preempted
        or spill-candidate query is priced for what's left, never for
        work it already ran."""
        plan = self.cost_model.plan(q.work, self.effective_chips(q))
        return {
            "latency_s": self._queue_delay_estimate(q, now)
            + plan.remaining_time(q.stage_cursor),
            "cost": self.quote_cost(q),
        }

    def _run_remaining_cs(self, run: _Run, now: Optional[float]) -> float:
        """Chip-seconds left in the run's CURRENT stage (base: work is
        wall-seconds at rate 1 on an isolated slice of `run.chips`)."""
        elapsed = 0.0 if now is None else max(now - run.last_update, 0.0)
        return max(run.remaining - elapsed * run.rate, 0.0) * run.chips

    def predicted_backlog_s(self, now: Optional[float] = None) -> float:
        """Predicted chip-seconds committed to this pool: the running
        stages' remaining work (the same predictions the stage heap
        holds), every running query's unstarted stages, and every
        waiting query's remaining plan. This is the backlog-driven
        autoscale signal — a single huge waiting query is a large
        backlog long before it is a long run queue."""
        total = 0.0
        for run in self.running:
            total += self._run_remaining_cs(run, now)
            total += run.plan.remaining_chip_seconds(run.query.stage_cursor + 1)
        for q in self.waiting:
            plan = self.cost_model.plan(q.work, self._plan_chips(q))
            total += plan.remaining_chip_seconds(q.stage_cursor)
        return total

    def drain_time_s(self, now: Optional[float] = None) -> float:
        """Seconds to drain the predicted backlog at current capacity
        (elastic pools drain in parallel: effectively zero)."""
        return 0.0

    def check_heap_invariant(self) -> None:
        """Test/debug hook: every running stage has exactly one VALID
        heap entry, and no valid entry refers to a retired run."""
        valid: dict[int, int] = {}
        for _, _, run, epoch in self._heap:
            if run.active and epoch == run.epoch:
                valid[id(run)] = valid.get(id(run), 0) + 1
        running_ids = {id(r) for r in self.running}
        assert set(valid) == running_ids, (
            f"{self.name}: valid heap entries {len(valid)} != "
            f"running {len(running_ids)}"
        )
        assert all(v == 1 for v in valid.values()), (
            f"{self.name}: duplicate valid heap entries: {valid}"
        )

    # --- subclass hooks ----------------------------------------------
    def _admit(self, now: float) -> None:
        raise NotImplementedError

    def _plan_chips(self, q: Query) -> int:
        raise NotImplementedError

    def _stage_work(self, stage: Stage, q: Query) -> tuple[float, float, int]:
        """(work units, billed chip-seconds, retries) for one stage run.
        Default: wall-seconds at rate 1, fault model sampled per stage."""
        if self.fault is None:
            return stage.time_s, stage.chip_seconds, 0
        return self.fault.stage_execution(
            stage.time_s, stage.chips, self.rng, q
        )

    def _run_rate(self, run: _Run) -> float:
        return 1.0

    def _rates_changed(self, now: float) -> None:
        """Concurrency changed — subclasses with shared rates reschedule."""

    def _sync(self, now: float) -> None:
        """Advance run bookkeeping to `now` (shared-rate subclasses)."""

    def _continue_run(self, run: _Run, now: float) -> bool:
        """Stage-boundary policy: return False to withhold the next stage
        (the run is retired; the query was re-routed or re-queued).
        Base behavior: ask the coordinator's `rehome` hook whether the
        query should continue on another pool — a reserved pool spills
        to an elastic one under overload, an elastic pool hands a
        spilled query back once the reserved backlog clears."""
        if self.rehome is None:
            return True
        target = self.rehome(run.query, now)
        if target is None or target is self:
            return True
        self._handoff(run.query, target, now)
        return False

    def _handoff(self, q: Query, target: "ClusterExecutor", now: float) -> None:
        """Move a query to another pool at a stage boundary. The stage
        cursor stays valid because plan STRUCTURE is pool-independent;
        remaining stages are re-planned (and re-priced) on the target."""
        if target.pool_kind == "elastic" and self.pool_kind == "reserved":
            q.spilled = True
            q.state = "spilled"
        else:
            q.spill_backs += 1
            q.state = "spilled-back"
        target.submit(q, now)

    # --- heap machinery ----------------------------------------------
    def _push(self, run: _Run, now: float) -> None:
        run.epoch += 1
        t = now + max(run.remaining, 0.0) / run.rate
        heapq.heappush(self._heap, (t, next(self._seq), run, run.epoch))

    def _prune(self) -> None:
        h = self._heap
        while h and (not h[0][2].active or h[0][3] != h[0][2].epoch):
            heapq.heappop(h)

    def next_event_time(self) -> Optional[float]:
        """Earliest valid predicted stage completion (absolute time)."""
        self._prune()
        return self._heap[0][0] if self._heap else None

    # --- lifecycle ----------------------------------------------------
    def submit(self, q: Query, now: float) -> None:
        q.cluster = self.name
        self.waiting.append(q)
        self._admit(now)

    def _start_run(self, q: Query, now: float) -> _Run:
        chips = self._plan_chips(q)
        plan = self.cost_model.plan(q.work, chips)
        run = _Run(q, plan, chips)
        if q.start_time is None:
            q.start_time = now
        q.state = "running"
        self.running[run] = None
        self._begin_stage(run, now)
        return run

    def _begin_stage(self, run: _Run, now: float) -> None:
        # re-read the plan at every stage boundary: a calibration hot
        # swap (versioned CostModel cache) must flow into the stages not
        # yet begun. Structure is calibration-invariant, so the cursor
        # stays valid; with no update this is a cache hit returning the
        # same object.
        run.plan = self.cost_model.plan(run.query.work, run.chips)
        stage = run.plan.stages[run.query.stage_cursor]
        work, billed, retries = self._stage_work(stage, run.query)
        run.stage_start = now
        run.remaining = work
        run.last_update = now
        run.rate = self._run_rate(run)
        run.billed_cs = billed
        run.stage_retries = retries
        self._push(run, now)

    def advance_to(self, now: float) -> list[Query]:
        """Process every stage completion due by `now`; returns queries
        that finished their final stage (stamped with the exact per-stage
        completion time, not the event-processing time)."""
        finished: list[Query] = []
        while True:
            self._prune()
            if not self._heap or self._heap[0][0] > now + 1e-9:
                break
            t, _, run, _ = heapq.heappop(self._heap)
            self._finish_stage(run, t, finished)
        self._admit(now)
        return finished

    def _finish_stage(self, run: _Run, t: float, finished: list[Query]) -> None:
        self._sync(t)
        q = run.query
        stage = run.plan.stages[q.stage_cursor]
        ev = account_stage(
            q, stage=stage.name, cluster=self.name, start=run.stage_start,
            finish=t, chips=run.chips, billed_cs=run.billed_cs,
            price_per_chip_s=self.price_per_chip_s,
            retries=run.stage_retries,
        )
        self.stages_completed += 1
        if self.stage_observer is not None:
            self.stage_observer(q, stage, ev)
        if q.stage_cursor >= len(run.plan.stages):
            run.active = False
            del self.running[run]
            q.finish_time = t
            q.state = "done"
            finished.append(q)
            self._rates_changed(t)
            self._admit(t)
        elif not self._continue_run(run, t):
            run.active = False
            del self.running[run]
            self._rates_changed(t)
            self._admit(t)
        else:
            self._begin_stage(run, t)
