"""Stage-level execution engine: StagePlan is the unit of scheduling.

The paper's SOS argument (§4.3/§5.3) is that each query *stage* runs on an
isolated slice with a deterministic cost — that property is what makes
pending-time SLAs and admission-time price quotes tractable. This module
makes the runtime honor it: a running query is a cursor over its
``StagePlan`` (``Query.stage_cursor``), and both clusters drive execution
through one ``ClusterExecutor`` base whose core is a heap of predicted
per-stage completion times.

Heap discipline: every running stage has exactly one *valid* heap entry;
entries are lazily invalidated by bumping ``_Run.epoch`` whenever a
prediction changes (processor-sharing rate changes, preemption, spill),
so reschedules are O(log n) pushes and stale entries are skipped on pop.
This replaces the O(n) list scans the clusters used to do per event and
the ``last_completion_push`` dedupe hack the simulator needed on top.

Stage boundaries are where policy acts:
  * preemption — a BEST_EFFORT query marked ``preempt_requested`` stops
    at its next boundary and re-enters the waiting queue with its cursor
    (and billed chip-seconds) intact;
  * cross-cluster spill — the coordinator may hand the remaining stages
    of a VM query to the elastic cluster (re-planned for the elastic
    slice size, billed at the elastic rate from that stage on);
  * fault recovery — the fault model is sampled per stage, so a retry
    re-runs (and re-bills) only the failed stage.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass
from typing import Optional

import numpy as np

from .cost_model import CostModel, Stage, StagePlan
from .query import Query


@dataclass(frozen=True)
class StageEvent:
    """One completed stage execution — the per-stage trace record."""

    qid: int
    stage: str
    index: int  # position in the query's StagePlan
    cluster: str
    start: float
    finish: float
    chips: int
    chip_seconds: float  # billed (includes retry re-runs / speculation)
    cost: float
    retries: int


class _Run:
    """Execution state of the CURRENT stage of one admitted query."""

    __slots__ = (
        "query", "plan", "chips", "remaining", "rate", "last_update",
        "epoch", "active", "stage_start", "billed_cs", "stage_retries",
        "preempt_requested",
    )

    def __init__(self, query: Query, plan: StagePlan, chips: int):
        self.query = query
        self.plan = plan
        self.chips = chips
        self.remaining = 0.0  # work left in this stage (units set by rate)
        self.rate = 1.0  # work units consumed per second
        self.last_update = 0.0
        self.epoch = 0  # bumped on every (re)prediction
        self.active = True
        self.stage_start = 0.0
        self.billed_cs = 0.0
        self.stage_retries = 0
        self.preempt_requested = False


class ClusterExecutor:
    """Base for both clusters: admission + per-stage completion queue.

    Subclasses implement ``_admit`` (capacity policy), ``_plan_chips``
    (slice sizing) and may override ``_stage_work`` (fault sampling),
    ``_run_rate``/``_rates_changed`` (processor sharing) and
    ``_continue_run`` (stage-boundary preemption/spill policy).
    """

    name = "?"

    def __init__(
        self,
        cost_model: Optional[CostModel] = None,
        fault=None,
        rng: Optional[np.random.Generator] = None,
        price_per_chip_s: float = 0.0,
    ):
        self.cost_model = cost_model or CostModel()
        self.fault = fault
        self.rng = rng or np.random.default_rng(0)
        self.price_per_chip_s = price_per_chip_s
        # insertion-ordered for deterministic iteration, O(1) removal
        self.running: dict[_Run, None] = {}
        self.waiting: list[Query] = []
        self._heap: list[tuple[float, int, _Run, int]] = []
        self._seq = itertools.count()
        self.stages_completed = 0

    # --- queue state the coordinator watches -------------------------
    @property
    def run_queue_len(self) -> int:
        return len(self.running) + len(self.waiting)

    @property
    def idle(self) -> bool:
        return self.run_queue_len == 0

    # --- subclass hooks ----------------------------------------------
    def _admit(self, now: float) -> None:
        raise NotImplementedError

    def _plan_chips(self, q: Query) -> int:
        raise NotImplementedError

    def _stage_work(self, stage: Stage, q: Query) -> tuple[float, float, int]:
        """(work units, billed chip-seconds, retries) for one stage run.
        Default: wall-seconds at rate 1, fault model sampled per stage."""
        if self.fault is None:
            return stage.time_s, stage.chip_seconds, 0
        return self.fault.stage_execution(
            stage.time_s, stage.chips, self.rng, q
        )

    def _run_rate(self, run: _Run) -> float:
        return 1.0

    def _rates_changed(self, now: float) -> None:
        """Concurrency changed — subclasses with shared rates reschedule."""

    def _sync(self, now: float) -> None:
        """Advance run bookkeeping to `now` (shared-rate subclasses)."""

    def _continue_run(self, run: _Run, now: float) -> bool:
        """Stage-boundary policy: return False to withhold the next stage
        (the run is retired; the query was re-routed or re-queued)."""
        return True

    # --- heap machinery ----------------------------------------------
    def _push(self, run: _Run, now: float) -> None:
        run.epoch += 1
        t = now + max(run.remaining, 0.0) / run.rate
        heapq.heappush(self._heap, (t, next(self._seq), run, run.epoch))

    def _prune(self) -> None:
        h = self._heap
        while h and (not h[0][2].active or h[0][3] != h[0][2].epoch):
            heapq.heappop(h)

    def next_event_time(self) -> Optional[float]:
        """Earliest valid predicted stage completion (absolute time)."""
        self._prune()
        return self._heap[0][0] if self._heap else None

    # --- lifecycle ----------------------------------------------------
    def submit(self, q: Query, now: float) -> None:
        q.cluster = self.name
        self.waiting.append(q)
        self._admit(now)

    def _start_run(self, q: Query, now: float) -> _Run:
        chips = self._plan_chips(q)
        plan = self.cost_model.plan(q.work, chips)
        run = _Run(q, plan, chips)
        if q.start_time is None:
            q.start_time = now
        q.state = "running"
        self.running[run] = None
        self._begin_stage(run, now)
        return run

    def _begin_stage(self, run: _Run, now: float) -> None:
        stage = run.plan.stages[run.query.stage_cursor]
        work, billed, retries = self._stage_work(stage, run.query)
        run.stage_start = now
        run.remaining = work
        run.last_update = now
        run.rate = self._run_rate(run)
        run.billed_cs = billed
        run.stage_retries = retries
        self._push(run, now)

    def advance_to(self, now: float) -> list[Query]:
        """Process every stage completion due by `now`; returns queries
        that finished their final stage (stamped with the exact per-stage
        completion time, not the event-processing time)."""
        finished: list[Query] = []
        while True:
            self._prune()
            if not self._heap or self._heap[0][0] > now + 1e-9:
                break
            t, _, run, _ = heapq.heappop(self._heap)
            self._finish_stage(run, t, finished)
        self._admit(now)
        return finished

    def _finish_stage(self, run: _Run, t: float, finished: list[Query]) -> None:
        self._sync(t)
        q = run.query
        stage = run.plan.stages[q.stage_cursor]
        cost = run.billed_cs * self.price_per_chip_s
        q.chip_seconds += run.billed_cs
        q.cost += cost
        q.stage_trace.append(StageEvent(
            qid=q.qid, stage=stage.name, index=q.stage_cursor,
            cluster=self.name, start=run.stage_start, finish=t,
            chips=run.chips, chip_seconds=run.billed_cs, cost=cost,
            retries=run.stage_retries,
        ))
        self.stages_completed += 1
        q.stage_cursor += 1
        if q.stage_cursor >= len(run.plan.stages):
            run.active = False
            del self.running[run]
            q.finish_time = t
            q.state = "done"
            finished.append(q)
            self._rates_changed(t)
            self._admit(t)
        elif not self._continue_run(run, t):
            run.active = False
            del self.running[run]
            self._rates_changed(t)
            self._admit(t)
        else:
            self._begin_stage(run, t)
