"""Queries: the unit of work of the serverless ML-query service.

In PixelsDB a query is SQL over object storage; in this TPU adaptation a
query is an analytical ML job against one of the registered architectures
(DESIGN.md §2): a batched inference request (prefill + N decode tokens)
or a fixed number of training steps.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Optional

from .sla import ServiceLevel

_qid = itertools.count()


def reset_qids() -> None:
    """Restart qid assignment from 0. Qids come from a process-global
    counter, so two identical simulated days in one process get
    different qids; a harness that fingerprints per-query results
    across process shards (benchmarks/sweep.py) resets the counter at
    each cell start so qids — and therefore the fingerprints — depend
    only on the cell, not on what ran before it in the same process."""
    global _qid
    _qid = itertools.count()


@dataclass(slots=True)
class QueryWork:
    """Work descriptor, independent of where it runs."""

    arch: str = "paper-default"
    kind: str = "serve"  # serve | train
    batch: int = 1
    prompt_tokens: int = 1024
    output_tokens: int = 64
    train_steps: int = 0
    seq_len: int = 4096  # train sequence length

    @property
    def total_tokens(self) -> int:
        if self.kind == "train":
            return self.train_steps * self.batch * self.seq_len
        return self.batch * (self.prompt_tokens + self.output_tokens)


@dataclass(eq=False, slots=True)
class Query:
    """eq=False: queries are identities, not values — two queries with
    the same work are still distinct units of billing, queue membership
    is an O(1) identity check, and a query can key the fusion index /
    waiting-lane maps directly. slots=True: a 1M-query day allocates a
    million of these; slotted instances are ~4x smaller and faster."""

    work: QueryWork
    sla: ServiceLevel
    submit_time: float
    source: str = ""  # workload pattern name (Table 1)
    latency_target_s: Optional[float] = None  # execution-time SLA (beyond-paper)
    qid: int = field(default_factory=lambda: next(_qid))

    # lifecycle (filled by the runtime)
    effective_sla: Optional[ServiceLevel] = None  # after w/o-SLA rewrite
    dequeue_time: Optional[float] = None
    start_time: Optional[float] = None
    finish_time: Optional[float] = None
    cluster: Optional[str] = None  # "vm" (cost-efficient) | "cf" (elastic)
    chip_seconds: float = 0.0
    cost: float = 0.0
    retries: int = 0
    #: live engine only: the error string when execution raised
    #: (state == "failed"); the simulator's fault model retries instead
    error: Optional[str] = None

    # stage-level engine state (core/engine.py): a running query is a
    # cursor over its StagePlan; the cursor survives preemption and
    # cross-cluster spill, so completed stages are never re-run.
    stage_cursor: int = 0  # next stage index to execute
    state: str = "pending"  # pending|running|preempted|spilled|spilled-back|done|failed
    preemptions: int = 0
    spilled: bool = False
    spill_backs: int = 0  # returns from an elastic pool to a reserved one
    stage_trace: list = field(default_factory=list)  # StageEvent records

    # multi-query fusion (scheduler.fuse_queries / cross-pool placement)
    #: on a MERGED query: the member queries it was fused from
    members: Optional[list] = None
    #: on a member after unpack: size of the fused group it ran in
    #: (0 = ran alone) — what benchmark fusion rates are computed from
    fused_with: int = 0

    @property
    def current_sla(self) -> ServiceLevel:
        """The level the runtime acts on: the w/o-SLA rewrite when one
        has been applied, the submitted level otherwise."""
        return self.effective_sla if self.effective_sla is not None else self.sla

    @property
    def pending_time(self) -> Optional[float]:
        """Time in the SLA pending queue (what the guarantee covers)."""
        if self.dequeue_time is None:
            return None
        return self.dequeue_time - self.submit_time

    @property
    def queue_wait(self) -> Optional[float]:
        """Cluster admission wait (after SLA dequeue, before execution)."""
        if self.start_time is None or self.dequeue_time is None:
            return None
        return self.start_time - self.dequeue_time

    @property
    def exec_time(self) -> Optional[float]:
        if self.finish_time is None or self.start_time is None:
            return None
        return self.finish_time - self.start_time

    @property
    def latency(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.submit_time

    def __repr__(self) -> str:  # compact traces
        return (
            f"Q{self.qid}[{self.sla.short} {self.work.arch}"
            f" {self.work.kind} t={self.submit_time:.0f}]"
        )
