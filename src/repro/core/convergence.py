"""Convergence control plane for the pool registry (otter-style,
ROADMAP item 3).

The shape is inverted from the old reactive autoscale: every pool
carries a ``desired_chips`` that POLICIES mutate — the backlog/run-queue
trigger (refactored out of ``clusters.CostEfficientCluster`` with
bit-identical watermark math), cron-style schedules, and external hooks
— and a CONVERGENCE step drives observed capacity toward desired:

  * simulated pools: worker deaths (core/chaos.py) drop ``chips`` below
    ``desired_chips``; ``PoolConverger.heal`` schedules the replacement
    through the pool's normal provisioning delay, with deterministic
    seeded exponential backoff when provisioning itself stalls.
  * live pools: ``ConvergencePlane.step_live`` (scheduler thread)
    respawns dead ``LiveReservedPool`` worker threads, decays the
    pool's calibration confidence so the replacement re-learns in a few
    stages (core/calibration.py ``LiveCalibrator.decay``), and resumes
    a lost in-flight query from its ``DecodeCheckpoint`` on a healthy
    slice — only the lost stage is re-run and re-billed.

Every action lands in the audit feed (core/events.py) when one is
attached. Policies are evaluated in list order and the LAST non-None
target wins, so appended schedule/hook policies override the reactive
trigger for the ticks they fire on.

This module holds no locks of its own: simulated pools are
single-threaded, and the live plane runs only on the engine's scheduler
thread (reprolint's lock graph scans this file — see
tools/reprolint/lockgraph.py — and must find it lock-free).

See docs/convergence.md for the policy surface and replay recipe.
"""
from __future__ import annotations

import math
from typing import Callable, Optional


class ConvergencePolicy:
    """One desired-capacity input. ``desired(pool, now)`` returns a chip
    target or None (no opinion this tick); ``next_fire_s(now)`` is the
    next time the policy needs an evaluation regardless of traffic
    (``inf`` = purely event/load-driven)."""

    __slots__ = ()

    def desired(self, pool, now: float) -> Optional[int]:
        raise NotImplementedError

    def next_fire_s(self, now: float) -> float:
        return math.inf


class BacklogTriggerPolicy(ConvergencePolicy):
    """The reactive trigger, lifted verbatim from the old
    ``CostEfficientCluster._schedule_autoscale``: hot/cold watermarks on
    either the predicted backlog drain time or the run-queue length
    (``AutoscaleConfig.trigger``). The float math is IDENTICAL, so
    legacy autoscale configs replay bit-for-bit through the plane."""

    __slots__ = ()

    def desired(self, pool, now: float) -> Optional[int]:
        a = pool.autoscale
        if a.trigger == "backlog":
            drain_s = pool.drain_time_s(now)
            # scale out only when queued work exists — a long RUNNING
            # stage inflates the backlog but new slices can't help it —
            # and never scale IN over the head of a queue
            hot = drain_s >= a.backlog_high_s and bool(pool.waiting)
            cold = drain_s <= a.backlog_low_s and not pool.waiting
        else:
            hot = pool.run_queue_len >= a.high_watermark
            cold = pool.run_queue_len <= a.low_watermark
        if hot and pool.chips < a.max_chips:
            return min(a.max_chips, pool.chips + a.step_chips)
        if cold and pool.chips > a.min_chips:
            return max(a.min_chips, pool.chips - a.step_chips)
        return None


class SchedulePolicy(ConvergencePolicy):
    """Cron-style scheduled capacity (otter's scheduled scaling
    policies): fire ``chips`` at ``offset_s + k * period_s`` for
    ``k = 0.. while t <= horizon_s``, or at an explicit firing list.
    When several firings are due at one evaluation, the latest wins.

    Rides the pool's autoscale tick: the owning pool needs
    ``autoscale.enabled=True`` (neutralize the reactive trigger with
    out-of-reach watermarks if pure scheduling is wanted)."""

    __slots__ = ("entries", "_idx")

    def __init__(
        self,
        entries: Optional[list] = None,
        *,
        period_s: Optional[float] = None,
        offset_s: float = 0.0,
        chips: Optional[int] = None,
        horizon_s: float = 86_400.0,
    ):
        if entries is None:
            if period_s is None or chips is None:
                raise ValueError(
                    "SchedulePolicy needs either explicit entries or "
                    "(period_s, chips)"
                )
            if period_s <= 0:
                raise ValueError(f"period_s must be > 0, got {period_s}")
            entries = []
            t_s = offset_s
            while t_s <= horizon_s:
                entries.append((t_s, chips))
                t_s += period_s
        #: one-shot firings [(fire time s, chips)], consumed in order
        self.entries: list = sorted(entries)
        self._idx = 0

    def next_fire_s(self, now: float) -> float:
        if self._idx < len(self.entries):
            return self.entries[self._idx][0]
        return math.inf

    def desired(self, pool, now: float) -> Optional[int]:
        target = None
        while (
            self._idx < len(self.entries)
            and self.entries[self._idx][0] <= now + 1e-9
        ):
            target = self.entries[self._idx][1]
            self._idx += 1
        return target


class HookPolicy(ConvergencePolicy):
    """External scale hook (otter's webhook policy idiom): an injected
    ``fn(pool, now) -> Optional[int]`` — an operator override, an
    experiment harness, a remote controller. Purely opinion: the
    convergence step still owns delays, backoff, and events."""

    __slots__ = ("fn",)

    def __init__(self, fn: Callable):
        self.fn = fn

    def desired(self, pool, now: float) -> Optional[int]:
        return self.fn(pool, now)


class PoolConverger:
    """Per-pool convergence state for SIMULATED executors: evaluates the
    policy list into ``pool.desired_chips``, schedules the capacity
    change through the pool's provisioning delay (with seeded
    exponential backoff on injected provisioning failures), and heals
    death-induced divergence back to desired."""

    __slots__ = ("policies", "next_fire_s")

    def __init__(self, policies: Optional[list] = None):
        self.policies: list = (
            policies if policies is not None else [BacklogTriggerPolicy()]
        )
        #: earliest scheduled (traffic-independent) policy firing —
        #: drives the pool's tick_due / next_tick_time
        self.next_fire_s = math.inf

    def add_policy(self, policy: ConvergencePolicy) -> None:
        self.policies.append(policy)
        self.next_fire_s = min(self.next_fire_s, policy.next_fire_s(0.0))

    def evaluate(self, pool, now: float) -> Optional[int]:
        """One policy pass (the old ``_schedule_autoscale`` body):
        last non-None target wins, ``desired_chips`` records it, and —
        exactly like the legacy trigger — a pending capacity change
        blocks scheduling another until it lands."""
        target = None
        for pol in self.policies:
            t = pol.desired(pool, now)
            if t is not None:
                target = t
        nxt = math.inf
        for pol in self.policies:
            t_s = pol.next_fire_s(now)
            if t_s < nxt:
                nxt = t_s
        self.next_fire_s = nxt
        if target is not None:
            pool.desired_chips = target
        if target is not None and not pool._pending_scale:
            self._schedule_change(pool, now, target)
        return target

    def heal(self, pool, now: float) -> bool:
        """Drive observed capacity back to desired after a worker death
        (core/chaos.py dropped ``pool.chips``). Replacement capacity
        goes through the same provisioning delay (+ backoff) a scale-out
        would — dead capacity is not free to restore."""
        if pool._pending_scale or pool.chips >= pool.desired_chips:
            return False
        self._schedule_change(pool, now, pool.desired_chips, kind="replace")
        return True

    def _schedule_change(
        self, pool, now: float, target: int, kind: str = "scale"
    ) -> None:
        a = pool.autoscale
        base_s = (
            a.scale_delay_s
            if target > pool.chips
            else (
                a.scale_in_delay_s
                if a.scale_in_delay_s is not None
                else a.scale_delay_s
            )
        )
        delay_s = self._provision_delay_s(pool, now, base_s)
        pool._pending_scale.append((now + delay_s, target))
        if pool.events is not None:
            pool.events.emit(
                kind, now, pool=pool.name, from_chips=pool.chips,
                to_chips=target, at_s=now + delay_s,
            )

    def _provision_delay_s(self, pool, now: float, base_s: float) -> float:
        """Provisioning latency with injected stalls: each seeded failed
        attempt (pool's chaos schedule, core/chaos.py) adds a retry wait
        of ``min(cap_s, backoff_base_s * 2**k)`` — deterministic
        exponential backoff, every retry audited."""
        ch = getattr(pool, "_chaos", None)
        if ch is None:
            return base_s
        total_s = base_s
        for k in range(ch.draw_provision_failures()):
            b_s = ch.backoff_s(k)
            total_s += b_s
            if pool.events is not None:
                pool.events.emit(
                    "provision_retry", now, pool=pool.name,
                    attempt=k + 1, backoff_s=b_s,
                )
        return total_s


class ConvergencePlane:
    """LIVE-side convergence (runs ONLY on the engine's scheduler
    thread — it holds no locks; everything it calls takes its own):

      * respawn dead ``LiveReservedPool`` worker threads back to the
        pool's desired worker count;
      * decay the pool's calibration confidence so the replacement
        re-learns the pool speed in a few stages instead of from
        scratch;
      * resume a reaped in-flight query from its ``DecodeCheckpoint``
        on a healthy slice (``max_resumes`` per query), re-billing only
        the lost stage.
    """

    __slots__ = ("engine", "_resumes", "deaths", "replacements", "resumes")

    def __init__(self, engine):
        self.engine = engine
        self._resumes: dict = {}  # qid -> resume count
        self.deaths = 0
        self.replacements = 0
        self.resumes = 0

    def step_live(self, now_s: float) -> None:
        """One convergence pass: detect and replace dead workers."""
        eng = self.engine
        for pool in eng.pools:
            respawn = getattr(pool, "respawn_workers", None)
            if respawn is None:
                continue
            n = respawn()
            if not n:
                continue
            self.deaths += n
            self.replacements += n
            if eng.events is not None:
                eng.events.emit("death", now_s, pool=pool.name, workers=n)
                eng.events.emit("replace", now_s, pool=pool.name, workers=n)
            if eng.calibrator is not None:
                # the replacement host inherits the pool EWMA at reduced
                # confidence and re-learns in a few stages (PR-4
                # follow-up; see docs/calibration.md)
                eng.calibrator.decay(pool.name)

    def try_resume(self, q, now_s: float) -> bool:
        """Resume a stale RUNNING query on a healthy slice. The stage
        cursor and billing already accrued live on the Query; decode
        state comes from the engine's checkpoint store — so the re-run
        re-bills ONLY the stage the dead worker lost. False when the
        resume budget is spent or mid-decode state is missing (the
        caller fails the query instead)."""
        eng = self.engine
        if self._resumes.get(q.qid, 0) >= eng.cfg.max_resumes:
            return False
        if q.stage_cursor > 0 and not eng._has_ckpt(q.qid):
            return False
        # the dead worker's placement will never release itself: force
        # every pool to forget the qid (token-gated, so if the worker
        # is merely wedged — not dead — its eventual release is a no-op
        # and its stage loop stops at the ownership check)
        for pool in eng.pools:
            pool.force_release(q.qid)
        self._resumes[q.qid] = self._resumes.get(q.qid, 0) + 1
        self.resumes += 1
        q.state = "preempted"  # re-enters a waiting queue at its cursor
        with eng._lock:
            eng.coordinator.route(q, now_s)
        if eng.events is not None:
            eng.events.emit(
                "resume", now_s, qid=q.qid, cursor=q.stage_cursor
            )
        return True
