"""Discrete-event simulation of the full serverless query service.

Events: query arrivals, scheduler polls, and STAGE completions. Both
clusters are ClusterExecutors (core/engine.py): each keeps one heap of
predicted per-stage finish times, and the simulator simply wakes at the
earliest predicted stage event — no per-cluster completion dedupe is
needed because stale heap entries are epoch-invalidated inside the
executors and `advance_to` is idempotent.

Query execution times come from the deterministic stage cost model
(grounded in the dry-run roofline, DESIGN.md §6), so the simulation and
the compiled artifacts share one source of truth.
"""
from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass, field
from typing import Iterable, Optional

import numpy as np

from .clusters import AutoscaleConfig, FaultModel
from .engine import StageEvent
from .insights import cluster_shares
from .pools import PoolSpec, build_pool, default_pool_specs
from .query import Query
from .scheduler import QueryCoordinator, ServiceLayer
from .sla import Policy, ServiceLevel, SLAConfig


@dataclass
class SimConfig:
    policy: Policy = Policy.AUTO
    sla_enabled: bool = True
    sla: SLAConfig = field(default_factory=SLAConfig)
    vm_chips: int = 4  # small reserved slice (paper: one m5.8xlarge)
    vm_mode: str = "pos"  # paper's current impl: POS (Trino) in the VM
    interference_alpha: float = 0.5
    sos_slice_chips: int = 32
    cf_startup_s: float = 2.0
    elastic_price_multiplier: float = 10.0  # paper: CF is 9-24x spot VM
    seed: int = 0
    use_calibration: bool = True
    fault: FaultModel = field(default_factory=FaultModel)
    autoscale: AutoscaleConfig = field(default_factory=AutoscaleConfig)
    fuse_queries: bool = False  # beyond-paper: multi-query batch fusion
    horizon_s: Optional[float] = None  # stop collecting after this time
    #: decode stages are chunked to at most this many tokens, making long
    #: generations preemptible/retryable at chunk granularity (0 = off)
    decode_chunk_tokens: int = 32
    #: executor registry: a list of PoolSpecs, one pool each. None builds
    #: the paper's vm/cf pair from the legacy knobs above — bit-for-bit
    #: the PR-1 two-cluster simulator.
    pools: Optional[list[PoolSpec]] = None
    #: per-pool fitted CalibrationTables keyed by pool name, injected
    #: into each pool's CostModel (core/calibration.py). Pools absent
    #: from the dict fall back to PoolSpec.dryrun_dir (fitted at build
    #: time) or the declared constants.
    calibrations: Optional[dict] = None


@dataclass
class SimResult:
    queries: list[Query]
    cfg: SimConfig

    def by_sla(self) -> dict[str, list[Query]]:
        out: dict[str, list[Query]] = {"imm": [], "rel": [], "boe": []}
        for q in self.queries:
            out[q.sla.short].append(q)
        return out

    def total_cost(self) -> float:
        return sum(q.cost for q in self.queries)

    def cost_by_sla(self) -> dict[str, float]:
        return {k: sum(q.cost for q in v) for k, v in self.by_sla().items()}

    def exec_time_by_sla(self) -> dict[str, float]:
        return {
            k: sum(q.exec_time or 0.0 for q in v) for k, v in self.by_sla().items()
        }

    def pending_violations(self, deadline_s: float) -> list[Query]:
        return [
            q
            for q in self.queries
            if q.effective_sla is ServiceLevel.RELAXED
            and q.pending_time is not None
            and q.pending_time > deadline_s + 1e-6
        ]

    def stage_events(self) -> list[StageEvent]:
        """The per-stage execution trace, ordered by completion time."""
        evs = [e for q in self.queries for e in q.stage_trace]
        evs.sort(key=lambda e: (e.finish, e.qid, e.index))
        return evs

    def cumulative(self, attr: str = "cost") -> dict[str, tuple[list, list]]:
        """Per-SLA (times, cumulative-values) for Fig 6/7-style curves."""
        out = {}
        for k, qs in self.by_sla().items():
            qs = [q for q in qs if q.finish_time is not None]
            qs.sort(key=lambda q: q.finish_time)
            ts, acc, tot = [], [], 0.0
            for q in qs:
                tot += getattr(q, attr) if attr == "cost" else (q.exec_time or 0.0)
                ts.append(q.finish_time)
                acc.append(tot)
            out[k] = (ts, acc)
        return out

    def summary(self) -> dict:
        by = self.by_sla()
        deadline = self.cfg.sla.relaxed_deadline_s
        cluster_share = cluster_shares(self.queries)
        out = {
            "n": len(self.queries),
            "finished": sum(q.finish_time is not None for q in self.queries),
            "total_cost": round(self.total_cost(), 2),
            "cost_by_sla": {k: round(v, 2) for k, v in self.cost_by_sla().items()},
            "exec_by_sla": {
                k: round(v, 1) for k, v in self.exec_time_by_sla().items()
            },
            "cluster_share": cluster_share,
            "violations": len(self.pending_violations(deadline)),
            "max_rel_pending": max(
                (q.pending_time or 0.0 for q in by["rel"]), default=0.0
            ),
            "mean_imm_pending": float(
                np.mean([q.pending_time or 0.0 for q in by["imm"]])
            )
            if by["imm"]
            else 0.0,
            "stages": sum(len(q.stage_trace) for q in self.queries),
            "preemptions": sum(q.preemptions for q in self.queries),
            "spilled": sum(q.spilled for q in self.queries),
            "spill_backs": sum(q.spill_backs for q in self.queries),
            "retries": sum(q.retries for q in self.queries),
        }
        if "vm" in cluster_share:  # legacy key, derived, only when real
            out["vm_share"] = cluster_share["vm"]
        return out


class Simulation:
    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        specs = cfg.pools
        if specs is None:
            specs = default_pool_specs(
                vm_chips=cfg.vm_chips,
                vm_mode=cfg.vm_mode,
                interference_alpha=cfg.interference_alpha,
                sos_slice_chips=cfg.sos_slice_chips,
                cf_startup_s=cfg.cf_startup_s,
                elastic_price_multiplier=cfg.elastic_price_multiplier,
                autoscale=cfg.autoscale,
            )
        # all pools share one rng: fault sampling depends only on the
        # seed and the stage execution order, not on pool membership
        self.pools = [
            build_pool(
                spec,
                use_calibration=cfg.use_calibration,
                decode_chunk_tokens=cfg.decode_chunk_tokens,
                fault=cfg.fault,
                rng=rng,
                sla=cfg.sla,
                calibration=(cfg.calibrations or {}).get(spec.name),
            )
            for spec in specs
        ]
        self.coordinator = QueryCoordinator(
            self.pools, policy=cfg.policy, cfg=cfg.sla
        )
        self.coordinator.wire_rehoming()
        self.vm = self.coordinator.vm
        self.cf = self.coordinator.cf
        self.service = ServiceLayer(
            self.coordinator, cfg.sla, cfg.sla_enabled, fuse=cfg.fuse_queries
        )

    def run(self, queries: Iterable[Query]) -> SimResult:
        cfg = self.cfg
        arrivals = sorted(queries, key=lambda q: q.submit_time)
        finished: list[Query] = []
        counter = itertools.count()
        events: list[tuple[float, int, str]] = []

        def push(t: float, kind: str) -> None:
            heapq.heappush(events, (t, next(counter), kind))

        for q in arrivals:
            push(q.submit_time, "arrival")
        if arrivals:
            push(arrivals[0].submit_time, "poll")
        ai = 0
        # earliest scheduled stage wake-up; a new push happens only when a
        # strictly earlier stage completion appears, so the heap never
        # floods with duplicates (this replaces the old per-cluster
        # last_completion_push dedupe).
        stage_wake = math.inf

        while events:
            now, _, kind = heapq.heappop(events)
            if kind == "stage" and now >= stage_wake - 1e-12:
                stage_wake = math.inf
            elif kind == "arrival":
                while ai < len(arrivals) and arrivals[ai].submit_time <= now + 1e-9:
                    self.service.submit(arrivals[ai], now)
                    ai += 1
            elif kind == "poll":
                self.service.poll(now)
                if (
                    ai < len(arrivals)
                    or self.service.pending
                    or any(p.run_queue_len for p in self.pools)
                ):
                    push(now + cfg.sla.poll_period_s, "poll")
            # drain every stage completion due by now (exact per-stage
            # finish times are stamped inside the executors); a pool's
            # advance may re-home a query onto an earlier pool (spill /
            # spill-back), whose next stage lands in `nxts` below
            for pool in self.pools:
                finished.extend(pool.advance_to(now))
            nxts = [
                t
                for t in (p.next_event_time() for p in self.pools)
                if t is not None
            ]
            if nxts:
                t = max(min(nxts), now)
                if t < stage_wake - 1e-12:
                    push(t, "stage")
                    stage_wake = t

        # unpack fused queries: members share times; cost splits by tokens
        expanded: list[Query] = []
        for q in finished:
            members = getattr(q, "members", None)
            if not members:
                expanded.append(q)
                continue
            tot = sum(m.work.total_tokens for m in members)
            for i, m in enumerate(members):
                share = m.work.total_tokens / max(tot, 1)
                m.start_time = q.start_time
                m.finish_time = q.finish_time
                m.cluster = q.cluster
                m.state = q.state
                m.chip_seconds = q.chip_seconds * share
                m.cost = q.cost * share
                if i == 0:  # the fused run's stage trace and engine
                    m.stage_trace = q.stage_trace  # counters live on one
                    m.retries = q.retries  # member so summaries stay exact
                    m.preemptions = q.preemptions
                    m.spilled = q.spilled
                    m.spill_backs = q.spill_backs
                expanded.append(m)
        return SimResult(expanded, cfg)


def run_sim(queries: list[Query], **kw) -> SimResult:
    cfg = SimConfig(**kw)
    return Simulation(cfg).run(queries)
