"""Discrete-event simulation of the full serverless query service.

Events: query arrivals, scheduler polls, and STAGE completions. Both
clusters are ClusterExecutors (core/engine.py): each keeps one heap of
predicted per-stage finish times, and the simulator simply wakes at the
earliest predicted stage event — no per-cluster completion dedupe is
needed because stale heap entries are epoch-invalidated inside the
executors and `advance_to` is idempotent.

Query execution times come from the deterministic stage cost model
(grounded in the dry-run roofline, DESIGN.md §6), so the simulation and
the compiled artifacts share one source of truth.
"""
from __future__ import annotations

import heapq
import itertools
import math
import os
from dataclasses import dataclass, field
from typing import Iterable, Optional

import numpy as np

from . import sanitize
from .chaos import ChaosConfig, wire_sim_chaos
from .clusters import AutoscaleConfig, FaultModel
from .engine import StageEvent
from .events import EventFeed
from .insights import cluster_shares
from .pools import PoolSpec, build_pool, default_pool_specs
from .query import Query
from .scheduler import QueryCoordinator, ServiceLayer, unpack_fused
from .sla import Policy, ServiceLevel, SLAConfig


@dataclass
class SimConfig:
    policy: Policy = Policy.AUTO
    sla_enabled: bool = True
    sla: SLAConfig = field(default_factory=SLAConfig)
    vm_chips: int = 4  # small reserved slice (paper: one m5.8xlarge)
    vm_mode: str = "pos"  # paper's current impl: POS (Trino) in the VM
    interference_alpha: float = 0.5
    sos_slice_chips: int = 32
    cf_startup_s: float = 2.0
    elastic_price_multiplier: float = 10.0  # paper: CF is 9-24x spot VM
    seed: int = 0
    use_calibration: bool = True
    fault: FaultModel = field(default_factory=FaultModel)
    autoscale: AutoscaleConfig = field(default_factory=AutoscaleConfig)
    fuse_queries: bool = False  # beyond-paper: multi-query batch fusion
    #: placement-time fusion ACROSS pools (docs/fusion.md): the
    #: coordinator indexes every pool's waiting queue and merges
    #: compatible waiters into each newly placed query. Only meaningful
    #: with fuse_queries=True; off, runs are bit-identical to within-
    #: pool (pending-queue) fusion alone.
    cross_pool_fusion: bool = False
    fuse_max: int = 8  # max queries per fused batch (both fusion layers)
    horizon_s: Optional[float] = None  # stop collecting after this time
    #: decode stages are chunked to at most this many tokens, making long
    #: generations preemptible/retryable at chunk granularity (0 = off)
    decode_chunk_tokens: int = 32
    #: executor registry: a list of PoolSpecs, one pool each. None builds
    #: the paper's vm/cf pair from the legacy knobs above — bit-for-bit
    #: the PR-1 two-cluster simulator.
    pools: Optional[list[PoolSpec]] = None
    #: per-pool fitted CalibrationTables keyed by pool name, injected
    #: into each pool's CostModel (core/calibration.py). Pools absent
    #: from the dict fall back to PoolSpec.dryrun_dir (fitted at build
    #: time) or the declared constants.
    calibrations: Optional[dict] = None
    #: disable the batched event drain and run the original one-event-
    #: per-heap-pop loop — the equivalence oracle tests/test_vectorized.py
    #: locks the drain against (also: REPRO_SCALAR_CORE=1)
    scalar_core: bool = False
    #: one-switch runtime sanitizer (core/sanitize.py): per-advance
    #: backlog/heap invariant checks plus post-run chip-second
    #: conservation and trace-stitching asserts. None defers to the
    #: REPRO_SANITIZE=1 environment switch; results are bit-identical
    #: with the sanitizer on or off (CI's sanitize-smoke proves it).
    sanitize: Optional[bool] = None
    #: fault-injection harness (core/chaos.py): seeded worker deaths,
    #: provisioning stalls, persistent slow hosts. Implies an event
    #: feed — the chaos replay gate compares feed fingerprints.
    chaos: Optional[ChaosConfig] = None
    #: record every control-plane action into an EventFeed
    #: (core/events.py), returned on SimResult.events
    events: bool = False
    #: extra convergence policies per pool name (core/convergence.py
    #: SchedulePolicy / HookPolicy), appended after the reactive
    #: trigger — the pool needs autoscale.enabled for them to tick
    convergence_policies: Optional[dict] = None


@dataclass
class SimResult:
    queries: list[Query]
    cfg: SimConfig
    #: calibrated admission-control interventions (QueryCoordinator):
    #: quotes repriced at measured speed / pools routed around because
    #: their drift gate tripped — 0 when no pool armed a drift bound
    drift_reprices: int = 0
    drift_rejects: int = 0
    #: the run's audit feed (core/events.py) when SimConfig.events or
    #: chaos was on — replay gate: same cfg+seed => same fingerprint()
    events: Optional[EventFeed] = None

    def by_sla(self) -> dict[str, list[Query]]:
        out: dict[str, list[Query]] = {"imm": [], "rel": [], "boe": []}
        for q in self.queries:
            out[q.sla.short].append(q)
        return out

    def total_cost(self) -> float:
        return sum(q.cost for q in self.queries)

    def cost_by_sla(self) -> dict[str, float]:
        return {k: sum(q.cost for q in v) for k, v in self.by_sla().items()}

    def exec_time_by_sla(self) -> dict[str, float]:
        return {
            k: sum(q.exec_time or 0.0 for q in v) for k, v in self.by_sla().items()
        }

    def pending_violations(self, deadline_s: float) -> list[Query]:
        return [
            q
            for q in self.queries
            if q.effective_sla is ServiceLevel.RELAXED
            and q.pending_time is not None
            and q.pending_time > deadline_s + 1e-6
        ]

    def stage_events(self) -> list[StageEvent]:
        """The per-stage execution trace, ordered by completion time."""
        evs = [e for q in self.queries for e in q.stage_trace]
        evs.sort(key=lambda e: (e.finish, e.qid, e.index))
        return evs

    def cumulative(self, attr: str = "cost") -> dict[str, tuple[list, list]]:
        """Per-SLA (times, cumulative-values) for Fig 6/7-style curves."""
        out = {}
        for k, qs in self.by_sla().items():
            qs = [q for q in qs if q.finish_time is not None]
            qs.sort(key=lambda q: q.finish_time)
            ts, acc, tot = [], [], 0.0
            for q in qs:
                tot += getattr(q, attr) if attr == "cost" else (q.exec_time or 0.0)
                ts.append(q.finish_time)
                acc.append(tot)
            out[k] = (ts, acc)
        return out

    def summary(self) -> dict:
        by = self.by_sla()
        deadline = self.cfg.sla.relaxed_deadline_s
        cluster_share = cluster_shares(self.queries)
        out = {
            "n": len(self.queries),
            "finished": sum(q.finish_time is not None for q in self.queries),
            "total_cost": round(self.total_cost(), 2),
            "cost_by_sla": {k: round(v, 2) for k, v in self.cost_by_sla().items()},
            "exec_by_sla": {
                k: round(v, 1) for k, v in self.exec_time_by_sla().items()
            },
            "cluster_share": cluster_share,
            "violations": len(self.pending_violations(deadline)),
            "max_rel_pending": max(
                (q.pending_time or 0.0 for q in by["rel"]), default=0.0
            ),
            "mean_imm_pending": float(
                np.mean([q.pending_time or 0.0 for q in by["imm"]])
            )
            if by["imm"]
            else 0.0,
            "stages": sum(len(q.stage_trace) for q in self.queries),
            "fused_queries": sum(q.fused_with > 1 for q in self.queries),
            "preemptions": sum(q.preemptions for q in self.queries),
            "spilled": sum(q.spilled for q in self.queries),
            "spill_backs": sum(q.spill_backs for q in self.queries),
            "retries": sum(q.retries for q in self.queries),
            "drift_reprices": self.drift_reprices,
            "drift_rejects": self.drift_rejects,
        }
        if "vm" in cluster_share:  # legacy key, derived, only when real
            out["vm_share"] = cluster_share["vm"]
        return out


class Simulation:
    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        specs = cfg.pools
        if specs is None:
            specs = default_pool_specs(
                vm_chips=cfg.vm_chips,
                vm_mode=cfg.vm_mode,
                interference_alpha=cfg.interference_alpha,
                sos_slice_chips=cfg.sos_slice_chips,
                cf_startup_s=cfg.cf_startup_s,
                elastic_price_multiplier=cfg.elastic_price_multiplier,
                autoscale=cfg.autoscale,
            )
        # all pools share one rng: fault sampling depends only on the
        # seed and the stage execution order, not on pool membership
        self.pools = [
            build_pool(
                spec,
                use_calibration=cfg.use_calibration,
                decode_chunk_tokens=cfg.decode_chunk_tokens,
                fault=cfg.fault,
                rng=rng,
                sla=cfg.sla,
                calibration=(cfg.calibrations or {}).get(spec.name),
            )
            for spec in specs
        ]
        # explicit SimConfig.sanitize overrides the env snapshot the
        # executors were built with; None keeps REPRO_SANITIZE's word
        if cfg.sanitize is not None:
            for pool in self.pools:
                pool.sanitize = cfg.sanitize
        self.coordinator = QueryCoordinator(
            self.pools, policy=cfg.policy, cfg=cfg.sla,
            cross_pool_fusion=cfg.fuse_queries and cfg.cross_pool_fusion,
            fuse_max=cfg.fuse_max,
        )
        self.coordinator.wire_rehoming()
        # drift-gated pools feed their own measured stage walls into the
        # table's admission-control EWMA (the sim-side counterpart of
        # LiveCalibrator.observe); a pool with an observer already set
        # keeps it — external calibration loops read the same hook
        for pool in self.pools:
            table = pool.cost_model.calibration
            if (
                table is not None
                and table.drift_bound is not None
                and pool.stage_observer is None
            ):
                def _observe_drift(q, stage, ev, _table=table):
                    _table.observe_drift(stage.time_s, ev.finish - ev.start)

                pool.stage_observer = _observe_drift
        # --- convergence / chaos / audit wiring (ROADMAP item 3) ------
        self.feed: Optional[EventFeed] = None
        if cfg.events or cfg.chaos is not None:
            self.feed = EventFeed()
            for pool in self.pools:
                pool.events = self.feed
            self.coordinator.events = self.feed
        if cfg.convergence_policies:
            for name, policies in sorted(cfg.convergence_policies.items()):
                pool = next(
                    (p for p in self.pools if p.name == name), None
                )
                if pool is None:
                    raise ValueError(
                        f"convergence_policies names unknown pool {name!r}"
                    )
                if not hasattr(pool, "converger"):
                    raise ValueError(
                        f"pool {name!r} ({pool.pool_kind}) has no "
                        "convergence plane — policies drive reserved "
                        "capacity only"
                    )
                for pol in policies:
                    pool.converger.add_policy(pol)
        if cfg.chaos is not None:
            # per-pool seeded death/stall schedules + slow-host faults;
            # must precede run(): needs_tick is snapshotted there
            wire_sim_chaos(self.pools, cfg.chaos)
        self.vm = self.coordinator.vm
        self.cf = self.coordinator.cf
        self.service = ServiceLayer(
            self.coordinator, cfg.sla, cfg.sla_enabled,
            fuse=cfg.fuse_queries, fuse_max=cfg.fuse_max,
        )

    def _poll_fast_forward(self, now: float, period: float,
                           pool_bound: float, arrivals: list[Query],
                           ai: int, tick_pools: list) -> float:
        """Next poll time after a NO-OP poll: a poll that moved nothing
        stays a no-op until something observable changes — the next
        arrival, the relaxed head entering its deadline window, any
        pool's next scheduled stage completion (`pool_bound`), or a due
        autoscale action. Skip the chain to the first grid point that
        could act, stepping by repeated addition so the grid times are
        float-identical to the un-skipped 1-per-period chain."""
        t_next = now + period
        t_act = pool_bound
        if ai < len(arrivals) and arrivals[ai].submit_time < t_act:
            t_act = arrivals[ai].submit_time
        rq = self.service.relaxed.q
        if rq:
            sla = self.cfg.sla
            t_dl = (rq.head().submit_time
                    + sla.relaxed_deadline_s * sla.deadline_slack)
            if t_dl < t_act:
                t_act = t_dl
        for p in tick_pools:
            # pending scale / backlog re-eval / scheduled policy firing
            # / chaos death — the pool knows its own earliest action
            t_tick = p.next_tick_time()
            if t_tick < t_act:
                t_act = t_tick
        if t_act is math.inf:
            return t_next
        limit = t_act - 1e-9
        while t_next < limit:
            t_next += period
        return t_next

    def run(self, queries: Iterable[Query]) -> SimResult:
        cfg = self.cfg
        arrivals = sorted(queries, key=lambda q: q.submit_time)
        finished: list[Query] = []
        counter = itertools.count()
        events: list[tuple[float, int, str]] = []
        # the event loop runs millions of iterations on a 1M-query day:
        # bind the hot names locally and peek pool heaps inline (the
        # equivalent of next_event_time without two function calls per
        # pool per event)
        heappush, heappop = heapq.heappush, heapq.heappop
        pools = self.pools
        # pools with time-driven policy work between their own events
        # (autoscale is fixed at construction time)
        tick_pools = [p for p in pools if p.needs_tick]
        submit, poll = self.service.submit, self.service.poll
        poll_period = cfg.sla.poll_period_s
        n_arrivals = len(arrivals)
        scalar_core = (cfg.scalar_core
                       or os.environ.get("REPRO_SCALAR_CORE", "") == "1")

        def push(t: float, kind: str) -> None:
            heappush(events, (t, next(counter), kind))

        for q in arrivals:
            push(q.submit_time, "arrival")
        if arrivals:
            push(arrivals[0].submit_time, "poll")
        ai = 0
        # earliest scheduled stage wake-up; a new push happens only when a
        # strictly earlier stage completion appears, so the heap never
        # floods with duplicates (this replaces the old per-cluster
        # last_completion_push dedupe).
        stage_wake = math.inf

        while events:
            now, _, kind = heappop(events)
            moved = True
            reschedule_poll = False
            if kind == "stage" and now >= stage_wake - 1e-12:
                stage_wake = math.inf
            elif kind == "arrival":
                moved = False
                while ai < n_arrivals and arrivals[ai].submit_time <= now + 1e-9:
                    submit(arrivals[ai], now)
                    ai += 1
                    moved = True
            elif kind == "poll":
                moved = poll(now) > 0
                # keep polling only while something could still enter a
                # pending queue: polls act on the SLA queues alone, so
                # once they are empty and no arrival remains, no future
                # poll can ever do anything (pools drain on stage wakes)
                reschedule_poll = ai < n_arrivals or self.service.pending
            if not moved:
                # nothing entered the system this event: pool heaps are
                # exactly as the previous event left them, so the wake
                # already scheduled still stands. Only a pool with a due
                # time-driven policy action (pending capacity change,
                # backlog-trigger crossing) still needs its tick pass.
                tick_hit = False
                for p in tick_pools:
                    if p.tick_due(now):
                        tick_hit = True
                        break
                if not tick_hit:
                    if reschedule_poll:
                        push(self._poll_fast_forward(
                            now, poll_period, stage_wake, arrivals, ai,
                            tick_pools), "poll")
                    continue
            # drain every stage completion due by now (exact per-stage
            # finish times are stamped inside the executors); a pool's
            # advance may re-home a query onto ANY pool (spill /
            # spill-back), so the next-wake minimum is re-read from every
            # heap after the advances. Pools with nothing due get the
            # O(1) `tick` (apply a due capacity change, re-evaluate the
            # decaying backlog trigger) — state that admits work only
            # changes at a pool's own events, so skipping the full
            # advance is behavior-preserving.
            #
            # BATCHED DRAIN: after the advance pass, the next stage wake
            # `t` is often provably the very next event the outer heap
            # would deliver — no arrival, poll, or earlier stage event
            # can land before it (`t` is strictly below every entry in
            # `events`, and a push here would only be popped right back).
            # In that case the push+pop round trip through the event
            # heap is elided and the advance pass reruns directly at
            # `t`, so a run of pure stage-completion clusters is
            # processed in one batched inner loop. Entries in `events`
            # are totally ordered by (time, counter), so eliding an
            # entry that would be the heap minimum — and would be popped
            # before any later push — cannot reorder anything else: the
            # event sequence, and therefore every float, is bit-identical
            # to the scalar loop (cfg.scalar_core, the oracle
            # tests/test_vectorized.py asserts against).
            while True:
                due = now + 1e-9
                advanced = False
                nxt = math.inf
                for pool in pools:
                    h = pool._heap
                    while h:  # inline prune + peek
                        e = h[0]
                        if e[2].active and e[3] == e[2].epoch:
                            break
                        heappop(h)
                    if h and h[0][0] <= due:
                        finished.extend(pool.advance_to(now))
                        advanced = True
                    else:
                        if pool.needs_tick:
                            pool.tick(now)
                            while h:  # a tick may admit (pending scale)
                                e = h[0]
                                if e[2].active and e[3] == e[2].epoch:
                                    break
                                heappop(h)
                        if h and h[0][0] < nxt:
                            nxt = h[0][0]
                if advanced:
                    # an advance may have re-homed work onto ANY pool
                    # (and changed its own heap): re-read every head
                    nxt = math.inf
                    for pool in pools:
                        h = pool._heap
                        while h:
                            e = h[0]
                            if e[2].active and e[3] == e[2].epoch:
                                break
                            heappop(h)
                        if h and h[0][0] < nxt:
                            nxt = h[0][0]
                if scalar_core or reschedule_poll or nxt is math.inf:
                    # a pending poll push would change events[0] (and the
                    # poll fast-forward reads the stage_wake set below),
                    # so poll iterations always go through the heap
                    break
                t = nxt if nxt > now else now
                if t >= stage_wake - 1e-12 or (events and t >= events[0][0]):
                    # an earlier-or-equal event is already scheduled:
                    # the outer loop must deliver it first
                    break
                # elide the (t, "stage") push + its immediate pop:
                # mirrors `stage_wake = t` at push then the reset to inf
                # when the event fires
                stage_wake = math.inf
                now = t
            if nxt is not math.inf:
                t = nxt if nxt > now else now
                if t < stage_wake - 1e-12:
                    heappush(events, (t, next(counter), "stage"))
                    stage_wake = t
            if reschedule_poll:
                if moved:
                    push(now + poll_period, "poll")
                else:
                    push(self._poll_fast_forward(
                        now, poll_period, stage_wake, arrivals, ai,
                        tick_pools), "poll")

        if cfg.chaos is not None:
            # convergence epilogue: a death near the end of the day can
            # leave waiters behind capacity whose replacement lands
            # after the last heap event — keep ticking (heal, apply
            # pending scale) and draining until every pool is empty, so
            # the chaos acceptance bar ("every query terminal") holds.
            guard = 0
            while True:
                nxt = math.inf
                for pool in pools:
                    h = pool._heap
                    while h:
                        e = h[0]
                        if e[2].active and e[3] == e[2].epoch:
                            break
                        heappop(h)
                    if h and h[0][0] < nxt:
                        nxt = h[0][0]
                    if pool.run_queue_len:
                        t_tick = pool.next_tick_time()
                        if t_tick < nxt:
                            nxt = t_tick
                if nxt is math.inf:
                    break
                now = nxt if nxt > now else now
                for pool in pools:
                    if pool.tick_due(now):
                        pool.tick(now)
                    finished.extend(pool.advance_to(now))
                guard += 1
                if guard > 10_000_000:
                    raise RuntimeError(
                        "chaos epilogue made no progress — a pool is "
                        "wedged below its admission width"
                    )

        # unpack fused queries: members share times; cost splits by
        # tokens with an exact-sum repair (scheduler.unpack_fused)
        expanded: list[Query] = []
        for q in finished:
            expanded.extend(unpack_fused(q))
        if cfg.sanitize or (cfg.sanitize is None and sanitize.enabled()):
            # post-run conservation + trace-stitching asserts over the
            # unpacked population (fused members share one trace object;
            # check_result dedups by identity)
            sanitize.check_result(expanded)
        return SimResult(
            expanded, cfg,
            drift_reprices=self.coordinator.drift_reprices,
            drift_rejects=self.coordinator.drift_rejects,
            events=self.feed,
        )


def run_sim(queries: list[Query], **kw) -> SimResult:
    cfg = SimConfig(**kw)
    return Simulation(cfg).run(queries)
