"""Cost visibility + price estimation — the user-facing features the
paper's user study ranked alongside flexible SLAs (Q6: absolute
performance-price estimates, 67.9% would use; Q7: historical cost
analysis, 69.7% — §3.2/Fig 1) and the PixelsDB Web UI exposes via
brushing-and-linking. Programmatic equivalents over Query traces.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Callable, Iterable, Optional

import numpy as np

from .cost_model import CostModel
from .query import Query, QueryWork
from .sla import ServiceLevel


# ---------------------------------------------------------------------------
# Q6: absolute performance-price menu per service level
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Quote:
    sla: str
    est_pending_s: float  # worst-case pending under the level's guarantee
    est_exec_s: float
    est_cost: float
    pool: str = ""  # registry pool backing the estimate

    def as_dict(self) -> dict:
        return {
            "sla": self.sla,
            "est_pending_s": round(self.est_pending_s, 2),
            "est_exec_s": round(self.est_exec_s, 2),
            "est_cost": round(self.est_cost, 4),
            "pool": self.pool,
        }


@dataclass(frozen=True)
class _PoolRow:
    """One pool's (exec time, cost) for the work — the raw frontier."""

    name: str
    kind: str  # reserved | elastic
    exec_s: float
    cost: float


def _menu_from_rows(rows: list[_PoolRow], relaxed_deadline_s: float) -> list[Quote]:
    """Fold per-pool rows into the three-level menu. Immediate may land
    on the burst tier under load, so it is priced at the WORST elastic
    cost while quoting the fastest execution anywhere; relaxed/BoE run
    on the cheapest cost-efficient pool."""
    elastic = [r for r in rows if r.kind == "elastic"] or rows
    reserved = [r for r in rows if r.kind == "reserved"] or rows
    imm_price = max(elastic, key=lambda r: r.cost)
    imm_exec = min(rows, key=lambda r: r.exec_s)
    cheap = min(reserved, key=lambda r: r.cost)
    return [
        Quote("immediate", 0.0, imm_exec.exec_s, imm_price.cost,
              pool=imm_price.name),
        Quote("relaxed", relaxed_deadline_s, cheap.exec_s, cheap.cost,
              pool=cheap.name),
        Quote("best_effort", float("inf"), cheap.exec_s, cheap.cost,
              pool=cheap.name),
    ]


def price_menu(
    work: QueryWork,
    *,
    pools: Optional[Iterable] = None,
    cost_model: Optional[CostModel] = None,
    calibration=None,
    vm_chips: int = 4,
    cf_chips: int = 32,
    vm_price_per_chip_s: float = 1.2 / 3600,
    cf_multiplier: float = 10.0,
    relaxed_deadline_s: float = 300.0,
) -> list[Quote]:
    """The menu a user sees before choosing a service level: each level's
    worst-case pending time, estimated execution time, and price. Made
    possible by the deterministic SOS cost model (paper §3.3 vision 1).

    With ``pools`` — any executor registry, simulated (build_pool) or
    live (LiveEngine.pools) — the frontier is quoted per pool: each
    pool's own cost model, slice sizing (``effective_chips``) and unit
    price produce one row, and ``Quote.pool`` names the pool backing
    each level's PRICE (the immediate row's exec time is the fastest
    pool's, which may be a different pool). Without it, the legacy
    vm/cf knob pair prices the same rows as before — identical
    estimates whenever the elastic pool is the faster one (true for the
    default knobs: cf_chips > vm_chips)."""
    if calibration is not None and (pools is not None or cost_model is not None):
        raise ValueError(
            "calibration only corrects the legacy knob pair — registry "
            "pools (and explicit cost models) carry their own calibrated "
            "models; a silently-ignored calibration would quote "
            "uncorrected prices"
        )
    if pools is not None:
        pools = list(pools)
        if not pools:
            raise ValueError("price_menu needs at least one pool")

        def rows_at(level: ServiceLevel) -> list[_PoolRow]:
            probe = Query(work=work, sla=level, submit_time=0.0)
            rows = []
            for p in pools:
                chips = p.effective_chips(probe)
                plan = p.cost_model.plan(work, chips)
                rows.append(_PoolRow(
                    name=p.name,
                    kind=p.pool_kind,
                    exec_s=plan.exec_time,
                    cost=plan.chip_seconds * p.price_per_chip_s,
                ))
            return rows

        if not any(getattr(p, "allocator", None) is not None for p in pools):
            # fixed-knob registry: one probe prices every level —
            # byte-identical to the pre-allocator menu
            return _menu_from_rows(
                rows_at(ServiceLevel.BEST_EFFORT), relaxed_deadline_s
            )
        # per-query allocation: each level's row set is priced at the
        # width the allocator would actually buy for THAT level, so the
        # menu can no longer disagree with execution (the old single
        # BEST_EFFORT probe quoted every level at the cost-optimal width)
        imm = _menu_from_rows(
            rows_at(ServiceLevel.IMMEDIATE), relaxed_deadline_s
        )[0]
        rel = _menu_from_rows(
            rows_at(ServiceLevel.RELAXED), relaxed_deadline_s
        )[1]
        boe = _menu_from_rows(
            rows_at(ServiceLevel.BEST_EFFORT), relaxed_deadline_s
        )[2]
        return [imm, rel, boe]
    # legacy knob pair: an explicit CalibrationTable corrects both rows
    # (registry pools carry their own calibrated models instead)
    cm = cost_model or CostModel(calibration=calibration)
    rows = [
        _PoolRow("vm", "reserved", cm.exec_time(work, vm_chips),
                 cm.chip_seconds(work, vm_chips) * vm_price_per_chip_s),
        _PoolRow("cf", "elastic", cm.exec_time(work, cf_chips),
                 cm.chip_seconds(work, cf_chips) * vm_price_per_chip_s * cf_multiplier),
    ]
    return _menu_from_rows(rows, relaxed_deadline_s)


# ---------------------------------------------------------------------------
# Q7: historical cost visibility (brushing-and-linking equivalent)
# ---------------------------------------------------------------------------

def cluster_shares(
    queries: Iterable[Query], ndigits: Optional[int] = None
) -> dict[str, float]:
    """Per-pool placement shares over ``q.cluster`` (unplaced -> "?") —
    the registry-shaped replacement for the hardcoded ``q.cluster ==
    "vm"`` share, shared by CostExplorer.aggregate and
    SimResult.summary."""
    qs = list(queries)
    counts: dict[str, int] = {}
    for q in qs:
        counts[q.cluster or "?"] = counts.get(q.cluster or "?", 0) + 1
    n = max(1, len(qs))
    return {
        name: (round(c / n, ndigits) if ndigits is not None else c / n)
        for name, c in sorted(counts.items())
    }

class CostExplorer:
    """Filter/aggregate finished queries the way the Web UI's linked
    views do: brush on any dimension, read the aggregates."""

    def __init__(self, queries: Iterable[Query]):
        self.queries = [q for q in queries if q.finish_time is not None]

    def brush(self, **filters) -> "CostExplorer":
        """Filter by exact attribute values (sla, cluster, source) or
        callable predicates, e.g. brush(cluster="cf", source="dashboard")
        or brush(cost=lambda c: c > 1.0)."""
        out = self.queries
        for key, want in filters.items():
            if callable(want):
                out = [q for q in out if want(getattr(q, key))]
            elif key == "sla":
                want_lvl = (
                    want if isinstance(want, ServiceLevel)
                    else ServiceLevel[want.upper()]
                    if isinstance(want, str) and want.upper() in ServiceLevel.__members__
                    else want
                )
                out = [
                    q for q in out
                    if q.sla is want_lvl or q.sla.short == str(want)
                ]
            else:
                out = [q for q in out if getattr(q, key) == want]
        e = CostExplorer([])
        e.queries = list(out)
        return e

    def aggregate(self) -> dict:
        qs = self.queries
        if not qs:
            return {"n": 0, "total_cost": 0.0}
        costs = np.array([q.cost for q in qs])
        execs = np.array([q.exec_time or 0.0 for q in qs])
        pend = np.array([q.pending_time or 0.0 for q in qs])
        # per-pool placement shares: an N-pool registry has no special
        # "vm" — the old hardcoded `q.cluster == "vm"` share read 0 for
        # any registry without that name
        cluster_share = cluster_shares(qs, ndigits=3)
        out = {
            "n": len(qs),
            "total_cost": round(float(costs.sum()), 4),
            "mean_cost": round(float(costs.mean()), 4),
            "p95_cost": round(float(np.percentile(costs, 95)), 4),
            "total_exec_s": round(float(execs.sum()), 1),
            "p95_exec_s": round(float(np.percentile(execs, 95)), 2),
            "p95_pending_s": round(float(np.percentile(pend, 95)), 2),
            "cluster_share": cluster_share,
        }
        if "vm" in cluster_share:  # legacy key, derived, only when real
            out["vm_share"] = cluster_share["vm"]
        return out

    def by(self, attr: str) -> dict[str, dict]:
        """Group-by + aggregate (the "linking" half)."""
        groups: dict[str, list[Query]] = {}
        for q in self.queries:
            val = getattr(q, attr)
            key = val.short if isinstance(val, ServiceLevel) else str(val)
            groups.setdefault(key, []).append(q)
        return {k: CostExplorer(v).aggregate() for k, v in sorted(groups.items())}

    def top(self, n: int = 10, key: str = "cost") -> list[Query]:
        return sorted(self.queries, key=lambda q: -getattr(q, key))[:n]


# ---------------------------------------------------------------------------
# Observability: structured trace export
# ---------------------------------------------------------------------------

def export_trace(queries: Iterable[Query], path: str) -> int:
    """JSONL query trace (one record per query) for offline analysis."""
    n = 0
    with open(path, "w") as f:
        for q in queries:
            f.write(json.dumps({
                "qid": q.qid,
                "source": q.source,
                "arch": q.work.arch,
                "sla": q.sla.short,
                "effective_sla": q.effective_sla.short if q.effective_sla else None,
                "submit": q.submit_time,
                "dequeue": q.dequeue_time,
                "start": q.start_time,
                "finish": q.finish_time,
                "cluster": q.cluster,
                "chip_seconds": round(q.chip_seconds, 4),
                "cost": round(q.cost, 6),
                "retries": q.retries,
                "stages": len(q.stage_trace),
                "preemptions": q.preemptions,
                "spilled": q.spilled,
                "spill_backs": q.spill_backs,
            }) + "\n")
            n += 1
    return n
