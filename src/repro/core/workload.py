"""CAB-like workload generation (paper §5.1, Table 1, Fig. 5).

Five patterns merged on one timeline simulate an organization's cloud
data warehouse. The paper's SQL-over-GB datasets map to ML-query work
sizes (DESIGN.md §2): dataset GB -> tokens scanned, per-pattern model
architecture. Counts, dataset sizes, and SLA mixes follow Table 1:

  db   size  pattern          #q   SLA mix
  db1  10GB  dashboard        720  Rel:Imm = 3:1
  db2  30GB  manual ad-hoc     34  Imm
  db3  30GB  manual daily      87  Imm:Rel = 2:1
  db4 100GB  off-peak          22  BoE
  db5 100GB  regular report    48  Rel
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Optional

import numpy as np

from .query import Query, QueryWork
from .sla import ServiceLevel

#: tokens an ML query "scans" per GB of the paper's dataset scale
TOKENS_PER_GB = 98_304


@dataclass(frozen=True)
class PatternSpec:
    name: str
    db_gb: int
    count: int
    sla_cycle: tuple[ServiceLevel, ...]  # applied round-robin (Table 1 ratios)
    arch: str
    timing: str  # periodic | work_hours | daily_batch | off_peak | spread
    batch: int = 1
    output_tokens: int = 32


TABLE1: tuple[PatternSpec, ...] = (
    PatternSpec(
        "dashboard", 10, 720,
        (ServiceLevel.RELAXED,) * 3 + (ServiceLevel.IMMEDIATE,),
        arch="qwen2-0.5b", timing="periodic", output_tokens=16,
    ),
    PatternSpec(
        "manual_adhoc", 30, 34,
        (ServiceLevel.IMMEDIATE,),
        arch="internlm2-1.8b", timing="work_hours", output_tokens=64,
    ),
    PatternSpec(
        "manual_daily", 30, 87,
        (ServiceLevel.IMMEDIATE,) * 2 + (ServiceLevel.RELAXED,),
        arch="granite-8b", timing="work_hours", output_tokens=64,
    ),
    PatternSpec(
        "off_peak", 100, 22,
        (ServiceLevel.BEST_EFFORT,),
        arch="mixtral-8x7b", timing="off_peak", batch=4, output_tokens=128,
    ),
    PatternSpec(
        "regular_report", 100, 48,
        (ServiceLevel.RELAXED,),
        arch="phi3.5-moe-42b-a6.6b", timing="spread", batch=2, output_tokens=128,
    ),
)


def scaled_patterns(
    factor: float, patterns: tuple[PatternSpec, ...] = TABLE1
) -> tuple[PatternSpec, ...]:
    """Table 1 with query counts scaled by `factor` (SLA mixes and timing
    shapes preserved) — the organization-of-N-users knob for scale runs."""
    return tuple(
        replace(p, count=max(1, int(round(p.count * factor)))) for p in patterns
    )


def _arrival_times(
    spec: PatternSpec, horizon: float, rng: np.random.Generator
) -> np.ndarray:
    n = spec.count
    if spec.timing == "periodic":
        # dashboards refresh in synchronized rounds -> bursty spikes
        rounds = max(1, n // 12)
        starts = np.linspace(0, horizon, rounds, endpoint=False)
        per = int(math.ceil(n / rounds))
        times = (starts[:, None] + rng.uniform(0, 5.0, (rounds, per))).ravel()[:n]
        return times
    if spec.timing == "work_hours":  # two Gaussian bursts (morning/afternoon)
        centers = rng.choice([0.35, 0.65], size=n)
        return np.clip(rng.normal(centers, 0.08) * horizon, 0, horizon * 0.999)
    if spec.timing == "off_peak":  # night window
        return rng.uniform(0.82, 0.98, n) * horizon
    if spec.timing == "daily_batch":
        return np.full(n, 0.30 * horizon) + rng.uniform(0, 60, n)
    # spread: low-rate Poisson across the day
    return np.sort(rng.uniform(0, horizon, n))


def generate(
    horizon_s: float = 14_400.0,  # a compressed "day" (4h), configurable
    seed: int = 0,
    patterns: tuple[PatternSpec, ...] = TABLE1,
    tokens_per_gb: int = TOKENS_PER_GB,
) -> list[Query]:
    """The merged query stream (Fig. 5).

    Generation is vectorized per pattern: one rng draw per pattern
    (`_arrival_times`), one shared `QueryWork` per pattern (works are
    value-compared and never mutated, so every query of a pattern can
    reference the same instance — on a 1M-query day this removes a
    million identical dataclass constructions), and the SLA round-robin
    is materialized as one repeated list instead of an i%k per query.
    Query objects (identity-keyed, mutated by the run) are still built
    one per query, in the same order as the original per-query loop, so
    qids and float submit times are bit-identical."""
    rng = np.random.default_rng(seed)
    queries: list[Query] = []
    for spec in patterns:
        times = np.sort(_arrival_times(spec, horizon_s, rng)).tolist()
        prompt = spec.db_gb * tokens_per_gb // max(spec.batch, 1)
        work = QueryWork(
            arch=spec.arch,
            kind="serve",
            batch=spec.batch,
            prompt_tokens=int(prompt),
            output_tokens=spec.output_tokens,
        )
        n = len(times)
        cycle = list(spec.sla_cycle)
        slas = cycle * (n // len(cycle) + 1)  # == sla_cycle[i % k] per i
        name = spec.name
        queries.extend(
            Query(work=work, sla=sla, submit_time=t, source=name)
            for t, sla in zip(times, slas)
        )
    queries.sort(key=lambda q: q.submit_time)
    return queries


def stream_histogram(queries: list[Query], horizon_s: float, bins: int = 48):
    """Fig 5-style arrival histogram per pattern."""
    edges = np.linspace(0, horizon_s, bins + 1)
    out = {}
    for name in sorted({q.source for q in queries}):
        ts = [q.submit_time for q in queries if q.source == name]
        hist, _ = np.histogram(ts, bins=edges)
        out[name] = hist.tolist()
    return out, edges.tolist()
