"""LIVE execution backend: the same ServiceLayer / schedulers /
QueryCoordinator drive real jitted JAX work on this host, over the same
PoolSpec registry the simulator uses (core/pools.py).

The simulator (simulator.py) answers "what would this schedule cost on a
TPU fleet"; the live engine proves the scheduling layer is a real
runtime, not a model. A live pool is thread-backed hardware:

  kind="reserved" -> one serialized worker thread per chip (the
                     interference-free cost-efficient tier)
  kind="elastic"  -> a task pool of up to `chips` threads, each task
                     preceded by a provisioning sleep of `startup_s`

A running query executes its StagePlan chunk-by-chunk through the jitted
model — a prefill stage, then at most ``decode_chunk_tokens`` decode
steps per stage — and its decode state (KV cache + last token; the stage
cursor lives on the Query) is checkpointed at EVERY stage boundary. That
makes the stage-boundary policies exact on real work: an IMMEDIATE
arrival preempts a running BEST_EFFORT query at its next chunk, overload
spills the remaining chunks to an elastic pool, and spill-back returns
them — in all cases the resumed query re-runs nothing, and billing flows
through the same ``account_stage`` arithmetic as the simulator (measured
wall-seconds on a 1-chip worker, at the pool's price).

Placement is the coordinator's: every routing / spill / spill-back
decision reads ``pool.quote(q)``, never a hardcoded vm/cf branch.
Used by examples/serve_sla.py, tests/test_live.py, tests/test_system.py.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field, replace
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..models.transformer import LM
from . import sanitize
from .convergence import ConvergencePlane
from .cost_model import CostModel
from .engine import ClusterExecutor, account_stage
from .events import EventFeed
from .pools import (
    PoolSpec,
    build_live_pool,
    default_live_pool_specs,
    fit_spec_calibration,
)
from .query import Query, QueryWork
from .scheduler import QueryCoordinator, ServiceLayer, unpack_fused
from .sla import Policy, ServiceLevel, SLAConfig


def _prompt_inputs(cfg, batch: int, prompt_tokens: int, seed: int):
    """Prompt batch + frontend/encoder kwargs for one prefill call. The
    SHAPES depend only on (arch, batch, prompt_tokens) — the warm-up and
    every billed prefill must trace identically."""
    toks = jax.random.randint(
        jax.random.PRNGKey(seed), (batch, prompt_tokens), 0, cfg.vocab_size
    )
    kw = {}
    if cfg.is_encoder_decoder:
        kw["enc_embeds"] = jnp.zeros(
            (batch, prompt_tokens, cfg.d_model), jnp.float32
        )
    if cfg.frontend == "vision_patches":
        kw["frontend_embeds"] = jnp.zeros(
            (batch, cfg.frontend_tokens, cfg.d_model), jnp.float32
        )
    return toks, kw


@dataclass(frozen=True)
class _LiveModel:
    """One arch's jitted entry points. ``prefill(params, toks, kw)``
    returns (next token, decode cache); ``decode(params, cache, tok)``
    returns (next token, new cache). Greedy sampling is inside the jit,
    so one stage is exactly one compiled call per token."""

    cfg: Any
    params: dict
    prefill: Any
    decode: Any


class _ModelPool:
    """Jitted reduced models shared by every live pool, warmed OUTSIDE
    the billed window: the first ``ensure`` for an (arch, batch) shape
    runs one throwaway prefill + decode step and blocks until compiled,
    so no stage wall-clock ever includes XLA compile time (the
    first-query billing skew of the old engine). Compile seconds are
    recorded per shape in ``compile_s`` for observability."""

    #: lock contract — enforced statically by reprolint RL001 and at
    #: runtime by repro.core.sanitize (REPRO_SANITIZE=1); one registry
    #: feeds both, so the checks cannot drift apart.
    _GUARDED_BY = {
        "_models": "_lock",
        "_warm": "_lock",
        "compile_s": "_lock",
    }

    def __init__(self, prompt_tokens: int, decode_tokens: int):
        self.prompt_tokens = prompt_tokens
        self.decode_tokens = decode_tokens
        self._models: dict[str, _LiveModel] = {}
        self._warm: set[tuple[str, int]] = set()
        self.compile_s: dict[tuple[str, int], float] = {}
        self._lock = sanitize.ordered_lock(
            "_ModelPool._lock", threading.Lock()
        )

    @property
    def kv_len(self) -> int:
        return self.prompt_tokens + self.decode_tokens + 8

    def _build(self, arch: str) -> _LiveModel:
        cfg = get_config(arch, reduced=True)
        model = LM(cfg)
        params = model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
        kv_len = self.kv_len

        @jax.jit
        def prefill(params, toks, kw):
            logits, cache = model.prefill(
                params, toks, kv_len=kv_len, dtype=jnp.float32, **kw
            )
            return jnp.argmax(logits, -1)[:, None], cache

        @jax.jit
        def decode(params, cache, tok):
            logits, cache = model.decode_step(
                params, cache, tok, dtype=jnp.float32
            )
            return jnp.argmax(logits, -1)[:, None], cache

        return _LiveModel(cfg=model.cfg, params=params,
                          prefill=prefill, decode=decode)

    def ensure(self, arch: str, batch: int) -> _LiveModel:
        """Return the arch's entry points, compiled for this batch."""
        with self._lock:
            lm = self._models.get(arch)
            if lm is None:
                lm = self._models[arch] = self._build(arch)
            key = (arch, batch)
            if key in self._warm:
                return lm
            t0 = time.monotonic()
            toks, kw = _prompt_inputs(lm.cfg, batch, self.prompt_tokens, 0)
            tok, cache = lm.prefill(lm.params, toks, kw)
            if self.decode_tokens:
                tok, cache = lm.decode(lm.params, cache, tok)
            jax.block_until_ready(tok)
            self.compile_s[key] = time.monotonic() - t0
            self._warm.add(key)
            return lm


@dataclass
class DecodeCheckpoint:
    """Decode state captured at a stage boundary — what makes live
    preemption / spill / spill-back EXACT: a resumed query replays
    nothing, it decodes onward from here. The stage cursor (and the
    billing already accrued) live on the Query itself; the checkpoint
    is host-shared, so remaining chunks can resume on any pool."""

    cache: Any  # the model's decode KV-cache pytree
    tok: Any  # last sampled token, (batch, 1) int32
    decoded: int  # decode tokens already produced


class LiveExecutor(ClusterExecutor):
    """Thread-backed sibling of the simulated executors: the same
    placement interface the coordinator's registry reads (quote /
    effective_chips / run_queue_len / has_capacity / rehome), but stages
    execute real jitted model work and are billed from MEASURED wall
    time through the same ``account_stage`` arithmetic.

    One "chip" is one host worker thread. All queue state is guarded by
    ``_mu`` — counters are moved inside one critical section per
    transition, so ``run_queue_len`` can never transiently under- or
    over-count (the old engine's unlocked ``_vm_busy`` race)."""

    #: holding ``_cv`` implies holding ``_mu`` (the Condition wraps it);
    #: reprolint RL001 + repro.core.sanitize both read this registry.
    _GUARDED_BY = {
        "running": ("_mu", "_cv"),
        "waiting": ("_mu", "_cv"),
        "stages_completed": ("_mu", "_cv"),
    }

    def __init__(self, spec: PoolSpec, engine: "LiveEngine"):
        price = (
            spec.price_per_chip_hour / 3600.0
            if spec.price_per_chip_hour is not None
            else engine.cfg.vm_price * spec.price_multiplier
        )
        # offline per-pool fit: the same resolution build_pool uses
        table = fit_spec_calibration(spec)
        super().__init__(
            cost_model=CostModel(
                use_calibration=False,
                decode_chunk_tokens=engine.cfg.decode_chunk_tokens,
                speed_factor=spec.speed_factor,
                calibration=table,
                parallel_overhead=spec.parallel_overhead,
            ),
            price_per_chip_s=price,
        )
        self.name = spec.name
        self.spec = spec
        self.engine = engine
        if spec.allocation is not None:
            from .allocation import Allocator

            self.allocator = Allocator(self.cost_model, spec.allocation)
        self._mu = sanitize.ordered_lock(
            "LiveExecutor._mu", threading.RLock()
        )
        self._cv = threading.Condition(self._mu)
        # qid -> (Query, placement token). The token is unique per
        # placement, so releasing an old placement can never clobber a
        # newer one (a query may hop away and back between pools faster
        # than the old worker's cleanup runs).
        self.running: dict[int, tuple[Query, object]] = {}
        self.waiting: list[Query] = []

    # --- registry interface (what the coordinator reads) --------------
    def _plan_chips(self, q: Query) -> int:
        if self.allocator is not None:
            # live pools honor the allocated width for quoting and
            # billing; execution still occupies one worker thread, the
            # width scales the billed chip-seconds like the simulator
            w = self.allocator.choose(q.work, q.current_sla)
            return max(1, min(w, self.spec.chips))
        return 1  # one worker thread per running query

    @property
    def run_queue_len(self) -> int:
        with self._mu:
            return len(self.running) + len(self.waiting)

    def predicted_backlog_cs(self, now: Optional[float] = None) -> float:
        """Predicted chip-seconds committed here, from the same cost
        model the quotes use (live stage walls are unknown upfront)."""
        with self._mu:
            qs = [q for q, _ in self.running.values()] + list(self.waiting)
        return sum(
            self.cost_model.plan(q.work, self._plan_chips(q))
            .remaining_chip_seconds(q.stage_cursor)
            for q in qs
        )

    def has_displacing_waiter(self, q: Query) -> bool:
        # live pools mutate `waiting` from worker threads: take a locked
        # snapshot scan instead of the sim's per-level counts
        with self._mu:
            return any(
                w.current_sla is not ServiceLevel.BEST_EFFORT
                and w.current_sla <= q.current_sla
                for w in self.waiting
            )

    def withdraw(self, q: Query) -> bool:
        """Claim a waiting query for placement-time fusion. Locked and
        authoritative: False means a worker (or another fusion) already
        took it, and the caller must not fuse it."""
        with self._cv:
            try:
                self.waiting.remove(q)
            except ValueError:
                return False
            if self.wait_observer is not None:
                self.wait_observer.discard(q)
            return True

    # --- lifecycle -----------------------------------------------------
    def start(self) -> None:
        """Begin consuming work (called after the coordinator wires
        rehoming, so no stage boundary ever misses its policy hook)."""

    def stop(self) -> None:
        raise NotImplementedError

    def submit(self, q: Query, now: float) -> None:
        raise NotImplementedError

    def _release(self, q: Query, token: object) -> None:
        """Drop this placement's `running` entry — a no-op when a newer
        placement already owns the qid."""
        with self._cv:
            cur = self.running.get(q.qid)
            if cur is not None and cur[1] is token:
                del self.running[q.qid]
            self._cv.notify_all()

    def force_release(self, qid: int) -> None:
        """Unconditionally forget a qid's placement (convergence plane:
        the owning worker is dead and will never release it). Token-less
        ON PURPOSE — the caller asserts the placement is lost; if the
        worker was merely wedged, its stage loop stops at the ownership
        check and its eventual ``_release`` is a no-op."""
        with self._cv:
            self.running.pop(qid, None)
            self._cv.notify_all()

    # --- the stage loop ------------------------------------------------
    def _execute(self, q: Query, token: object) -> None:
        """Run q's remaining stages on this pool. Returns when q
        finishes, fails, is preempted (re-queued here), or is re-homed.
        ANY exception surfaces as q.state == "failed" — nothing is
        swallowed, and drain() counts the failure immediately."""
        eng = self.engine
        try:
            lm = eng.models.ensure(q.work.arch, max(1, q.work.batch))
            chips = self._plan_chips(q)
            plan = self.cost_model.plan(q.work, chips)
            if q.start_time is None:
                q.start_time = eng.now()
            eng._note_beat(q)  # heartbeat BEFORE q is visibly "running"
            q.state = "running"
            q.cluster = self.name
            while q.stage_cursor < len(plan.stages):
                if eng._stop.is_set():
                    return  # shutdown: abandon between chunks, so a
                    # timed-out drain never waits out a deep backlog
                with self._mu:
                    cur = self.running.get(q.qid)
                if cur is None or cur[1] is not token or q.state == "failed":
                    return  # reaped / force-released: a resume (or the
                    # reaper's _fail) owns this query now
                stage = plan.stages[q.stage_cursor]
                start = eng.now()
                self._run_stage_work(lm, q)
                finish = eng.now()
                account_stage(
                    q, stage=stage.name, cluster=self.name, start=start,
                    finish=finish, chips=chips,
                    billed_cs=(finish - start) * chips,
                    price_per_chip_s=self.price_per_chip_s,
                )
                eng._note_beat(q)  # stage-boundary progress heartbeat
                with self._mu:  # workers finish stages concurrently
                    self.stages_completed += 1
                if eng.calibrator is not None:
                    # live calibration loop: feed the measured stage wall
                    # and hot-swap the fitted correction at this stage
                    # boundary — structure is calibration-invariant, so
                    # the plan below stays index-compatible
                    eng.calibrator.observe(
                        self, q.work, q.stage_cursor - 1, chips, finish - start
                    )
                    eng.calibrator.maybe_apply(self)
                if q.stage_cursor >= len(plan.stages):
                    eng._finish(q)
                    return
                if self._boundary_stop(q, token):
                    return
        except Exception as err:  # noqa: BLE001 — surfaced, not swallowed
            eng._fail(q, err)

    def _run_stage_work(self, lm: _LiveModel, q: Query) -> None:
        """Execute the real JAX work of stage ``q.stage_cursor`` and
        checkpoint the resulting decode state. Chunk boundaries follow
        CostModel.plan exactly: stage 0 is prefill, stage i > 0 is the
        next <= decode_chunk_tokens decode steps."""
        eng = self.engine
        batch = max(1, q.work.batch)
        if q.stage_cursor == 0:
            toks, kw = _prompt_inputs(
                lm.cfg, batch, q.work.prompt_tokens, seed=q.qid
            )
            tok, cache = lm.prefill(lm.params, toks, kw)
            jax.block_until_ready(tok)
            eng._save_ckpt(q, DecodeCheckpoint(cache, tok, 0))
            return
        ck = eng._load_ckpt(q)
        chunk = self.cost_model.decode_chunk_tokens or q.work.output_tokens
        n = min(chunk, q.work.output_tokens - ck.decoded)
        cache, tok = ck.cache, ck.tok
        for _ in range(n):
            tok, cache = lm.decode(lm.params, cache, tok)
        jax.block_until_ready(tok)
        eng._save_ckpt(q, DecodeCheckpoint(cache, tok, ck.decoded + n))

    def _boundary_stop(self, q: Query, token: object) -> bool:
        """Stage-boundary policy, mirroring the simulator's
        ``_continue_run``: preempt first, then the coordinator's rehome
        hook (spill / spill-back). True = q stops executing here."""
        if self._should_preempt(q):
            q.preemptions += 1
            q.state = "preempted"
            with self._cv:
                # one critical section: leave `running` and re-enter
                # `waiting`, so run_queue_len never double-counts
                cur = self.running.get(q.qid)
                if cur is not None and cur[1] is token:
                    del self.running[q.qid]
                self.waiting.append(q)  # resumes at stage_cursor
                if self.wait_observer is not None:
                    self.wait_observer.add(self, q)  # no-op: cursor > 0
                self._cv.notify_all()
            return True
        if self.rehome is not None:
            now = self.engine.now()
            target = self.rehome(q, now)
            if target is not None and target is not self:
                self._handoff(q, target, now)
                return True
        return False

    def _should_preempt(self, q: Query) -> bool:
        return False  # reserved pools override


class LiveReservedPool(LiveExecutor):
    """Serialized worker thread(s): `spec.chips` threads, each running
    one query's stages at a time — the interference-free SOS tier."""

    pool_kind = "reserved"

    def __init__(self, spec: PoolSpec, engine: "LiveEngine"):
        super().__init__(spec, engine)
        self.workers = max(1, spec.chips)
        self._preempt = (
            engine.cfg.sla.preempt_best_effort
            if spec.preempt_best_effort is None
            else spec.preempt_best_effort
        )
        self._threads = [
            threading.Thread(
                target=self._worker, name=f"live-{self.name}-{i}", daemon=True
            )
            for i in range(self.workers)
        ]

    def start(self) -> None:
        for t in self._threads:
            t.start()

    def stop(self) -> None:
        with self._cv:
            self._cv.notify_all()
        for t in self._threads:
            t.join(timeout=10.0)

    def has_capacity(self) -> bool:
        with self._mu:
            return not self.waiting and len(self.running) < self.workers

    def drain_time_s(self, now: Optional[float] = None) -> float:
        return self.predicted_backlog_cs(now) / self.workers

    def _queue_delay_estimate(self, q: Query, now: Optional[float]) -> float:
        return 0.0 if self.has_capacity() else self.drain_time_s(now)

    def submit(self, q: Query, now: float) -> None:
        q.cluster = self.name
        with self._cv:
            self.waiting.append(q)
            if self.wait_observer is not None:  # shared fusion index
                self.wait_observer.add(self, q)
            self._cv.notify_all()

    def _pop_waiting_locked(self) -> Query:
        # static RL001 exempts *_locked helpers; the runtime guard
        # covers their CALLERS instead (REPRO_SANITIZE=1)
        sanitize.guard(self, "waiting")
        # slice handoff mirrors the simulator: IMMEDIATE first, FIFO
        # within a level — a resumed preempted query keeps its place
        best = min(
            range(len(self.waiting)),
            key=lambda i: (int(self.waiting[i].current_sla), i),
        )
        q = self.waiting.pop(best)
        if self.wait_observer is not None:
            self.wait_observer.discard(q)
        return q

    def _worker(self) -> None:
        stop = self.engine._stop
        while not stop.is_set():
            with self._cv:
                if not self.waiting:
                    self._cv.wait(timeout=0.05)
                    continue
                q = self._pop_waiting_locked()
                token = object()
                self.running[q.qid] = (q, token)
            try:
                self._execute(q, token)
            finally:
                self._release(q, token)

    def _should_preempt(self, q: Query) -> bool:
        """An IMMEDIATE waiter bumps a running BEST_EFFORT query at this
        chunk boundary (chip-seconds already billed stay billed)."""
        if not self._preempt or q.current_sla is not ServiceLevel.BEST_EFFORT:
            return False
        with self._mu:
            return any(
                w.current_sla is ServiceLevel.IMMEDIATE for w in self.waiting
            )

    def respawn_workers(self) -> int:
        """Replace dead worker threads (convergence plane — called only
        from the engine's scheduler thread; ``_threads`` is touched by
        no other thread after ``start``). Returns the number replaced."""
        if self.engine._stop.is_set():
            return 0
        n = 0
        for i, t in enumerate(self._threads):
            if t.is_alive():
                continue
            nt = threading.Thread(
                target=self._worker, name=f"{t.name}r", daemon=True
            )
            self._threads[i] = nt
            nt.start()
            n += 1
        return n


class LiveElasticPool(LiveExecutor):
    """Burst tier: up to `spec.chips` concurrent tasks, each preceded by
    a provisioning sleep of `spec.startup_s` (not billed — provisioning
    is the provider's cost, the premium unit price is the customer's)."""

    pool_kind = "elastic"

    def __init__(self, spec: PoolSpec, engine: "LiveEngine"):
        super().__init__(spec, engine)
        self.startup_s = spec.startup_s
        self.workers = max(1, spec.chips)
        self._exec = ThreadPoolExecutor(
            max_workers=self.workers,
            thread_name_prefix=f"live-{spec.name}",
        )

    def stop(self) -> None:
        # queued-but-unstarted tasks are dropped; started ones abandon
        # at their next chunk boundary (_execute checks engine._stop)
        self._exec.shutdown(wait=True, cancel_futures=True)

    def _queue_delay_estimate(self, q: Query, now: Optional[float]) -> float:
        """Unlike the sim's unbounded burst tier, the live pool runs at
        most ``workers`` concurrent tasks — a saturated pool must quote
        the predicted drain of the work already committed to it, not
        just the provisioning sleep, or it under-quotes latency exactly
        when it is overloaded."""
        with self._mu:
            saturated = len(self.running) >= self.workers
        if not saturated:
            return self.startup_s
        return self.startup_s + self.predicted_backlog_cs(now) / self.workers

    def submit(self, q: Query, now: float) -> None:
        q.cluster = self.name
        token = object()
        with self._mu:
            self.running[q.qid] = (q, token)  # provisioning is committed
        try:
            self._exec.submit(self._task, q, token)
        except RuntimeError:  # pool already shut down: abandon cleanly
            self._release(q, token)

    def _task(self, q: Query, token: object) -> None:
        try:
            if self.startup_s:
                # interruptible provisioning: Event.wait returns True the
                # moment shutdown is signalled, so a stopping engine never
                # serves out queued startup sleeps (shutdown wall was
                # O(tasks x startup_s) with time.sleep here)
                if self.engine._stop.wait(self.startup_s):
                    return
            self._execute(q, token)
        except BaseException as err:  # pragma: no cover — _execute catches
            self.engine._fail(q, err)  # belt-and-braces: never swallow
        finally:
            self._release(q, token)


@dataclass
class LiveConfig:
    policy: Policy = Policy.AUTO
    sla_enabled: bool = True
    sla: SLAConfig = field(
        default_factory=lambda: SLAConfig(
            relaxed_deadline_s=10.0,
            poll_period_s=0.05,
            vm_overload_threshold=2,
            # live stages are milliseconds, so any remaining work is
            # worth a hop once spill/spill-back are enabled
            spill_min_remaining_s=0.0,
        )
    )
    #: executor registry: a list of PoolSpecs, one thread-backed pool
    #: each. None builds the legacy vm/cf live pair from the knobs below.
    pools: Optional[list[PoolSpec]] = None
    cf_startup_s: float = 0.3
    vm_price: float = 1.0  # $ per worker-second (multiplier base)
    cf_price_multiplier: float = 10.0
    # every live query runs this reduced shape (q.work is normalized at
    # submit — the legacy engine did the same implicitly)
    prompt_tokens: int = 32
    decode_tokens: int = 4
    #: decode chunk (= stage) size: the preemption/spill granularity
    decode_chunk_tokens: int = 2
    #: live calibration loop (core/calibration.py): fit each pool's
    #: cost model from its own measured stage walls and hot-swap the
    #: correction at stage boundaries, closing quote→measurement drift
    calibrate: bool = False
    calibration_alpha: float = 0.25  # EWMA weight of the newest stage
    calibration_min_samples: int = 8  # walls seen before the first swap
    #: JSON persistence: fitted state is loaded from here at startup and
    #: re-saved on every applied update (None keeps it in-memory)
    calibration_path: Optional[str] = None
    #: multi-query fusion: batch compatible pending queries (docs/fusion.md)
    fuse_queries: bool = False
    #: placement-time fusion across pools — live pools share the
    #: coordinator's CrossPoolFusionIndex, so compatible queries waiting
    #: on different pools merge into one batched jitted execution
    cross_pool_fusion: bool = False
    fuse_max: int = 8
    #: a RUNNING query must reach a stage boundary (heartbeat) this
    #: often or its placement is declared dead — the query is resumed by
    #: the convergence plane or failed with Query.error set, so a worker
    #: dying mid-stage can never hang drain(). None disables the reaper.
    stage_deadline_s: Optional[float] = 60.0
    #: convergence control plane (core/convergence.py): respawn dead
    #: reserved workers, decay their pool's calibration confidence, and
    #: resume lost in-flight queries from their DecodeCheckpoint
    convergence: bool = False
    #: checkpoint resumes allowed per query before the reaper fails it
    max_resumes: int = 1
    #: audit feed (core/events.py) recording placement / spill / fuse /
    #: death / replace / resume / drift interventions
    events: bool = False


class LiveEngine:
    """Thread-backed mirror of the simulated service: same ServiceLayer,
    same schedulers, same QueryCoordinator, same PoolSpec registry —
    driving real jitted models instead of a cost model."""

    #: lock contract (reprolint RL001 + repro.core.sanitize).
    _GUARDED_BY = {
        "done": "_lock",
        "failed": "_lock",
        "service": "_lock",
        "_ckpt": "_ckpt_mu",
        "_beats": "_beat_mu",
    }

    def __init__(self, cfg: LiveConfig):
        self.cfg = cfg
        self.models = _ModelPool(cfg.prompt_tokens, cfg.decode_tokens)
        self.done: list[Query] = []
        self.failed: list[Query] = []
        self._lock = threading.RLock()  # service layer + result sinks
        self._ckpt: dict[int, DecodeCheckpoint] = {}
        self._ckpt_mu = threading.Lock()
        # qid -> (Query, last stage-boundary time): the reaper's evidence
        self._beats: dict[int, tuple[Query, float]] = {}
        self._beat_mu = threading.Lock()
        self.events = EventFeed() if cfg.events else None
        self._t0 = time.monotonic()
        self._stop = threading.Event()
        specs = cfg.pools
        if specs is None:
            specs = default_live_pool_specs(
                cf_startup_s=cfg.cf_startup_s,
                cf_price_multiplier=cfg.cf_price_multiplier,
            )
        self.pools = [build_live_pool(spec, engine=self) for spec in specs]
        self.calibrator = None
        if cfg.calibrate:
            from .calibration import LiveCalibrator

            self.calibrator = LiveCalibrator(
                alpha=cfg.calibration_alpha,
                min_samples=cfg.calibration_min_samples,
                path=cfg.calibration_path,
            )
            for pool in self.pools:  # apply persisted fits before work
                self.calibrator.maybe_apply(pool)
        self.coordinator = QueryCoordinator(
            self.pools, policy=cfg.policy, cfg=cfg.sla,
            cross_pool_fusion=cfg.fuse_queries and cfg.cross_pool_fusion,
            fuse_max=cfg.fuse_max,
        )
        self.coordinator.wire_rehoming()
        self.coordinator.events = self.events
        for pool in self.pools:
            pool.events = self.events
        self.service = ServiceLayer(
            self.coordinator, cfg.sla, cfg.sla_enabled,
            fuse=cfg.fuse_queries, fuse_max=cfg.fuse_max,
        )
        #: live convergence (respawn + calibration decay + checkpoint
        #: resume) — created before the scheduler thread that steps it
        self.plane = ConvergencePlane(self) if cfg.convergence else None
        for pool in self.pools:  # consume only once rehoming is wired
            pool.start()
        self._sched_thread = threading.Thread(
            target=self._sched_loop, name="live-sched", daemon=True
        )
        self._sched_thread.start()

    # ------------------------------------------------------------------
    def now(self) -> float:
        return time.monotonic() - self._t0

    @property
    def vm_run_queue_len(self) -> int:  # legacy observability hook
        return self.coordinator.vm.run_queue_len

    def live_work(self, work: QueryWork) -> QueryWork:
        """Normalize a work descriptor to the reduced shape the live
        models actually run (every query shares one jit footprint)."""
        return replace(
            work,
            kind="serve",
            prompt_tokens=self.cfg.prompt_tokens,
            output_tokens=self.cfg.decode_tokens,
        )

    def price_menu(self, work: QueryWork):
        """Admission-time price menu quoted from the LIVE registry —
        per-pool Quote rows from the same pools queries execute on."""
        from .insights import price_menu

        return price_menu(
            self.live_work(work),
            pools=self.pools,
            relaxed_deadline_s=self.cfg.sla.relaxed_deadline_s,
        )

    # --- checkpoint store (host-shared across pools) -------------------
    def _save_ckpt(self, q: Query, ck: DecodeCheckpoint) -> None:
        with self._ckpt_mu:
            self._ckpt[q.qid] = ck

    def _load_ckpt(self, q: Query) -> DecodeCheckpoint:
        with self._ckpt_mu:
            ck = self._ckpt.get(q.qid)
        if ck is None:
            raise RuntimeError(
                f"no checkpoint for Q{q.qid} at stage {q.stage_cursor}"
            )
        return ck

    def _drop_ckpt(self, q: Query) -> None:
        with self._ckpt_mu:
            self._ckpt.pop(q.qid, None)

    def _has_ckpt(self, qid: int) -> bool:
        with self._ckpt_mu:
            return qid in self._ckpt

    # --- stage-boundary heartbeats (the reaper's evidence) -------------
    def _note_beat(self, q: Query) -> None:
        t_s = self.now()
        with self._beat_mu:
            self._beats[q.qid] = (q, t_s)

    def _clear_beat(self, q: Query) -> None:
        with self._beat_mu:
            self._beats.pop(q.qid, None)

    def _reap(self, now_s: float) -> None:
        """Fail or resume queries whose worker died mid-stage: a RUNNING
        query must make stage-boundary progress within
        ``stage_deadline_s`` or its placement is declared dead. Without
        this, a lost worker left the query in state "running" forever
        and ``drain()`` sat out its full timeout."""
        deadline_s = self.cfg.stage_deadline_s
        if deadline_s is None:
            return
        with self._beat_mu:
            stale = [
                q for q, t_s in self._beats.values()
                if q.state == "running" and now_s - t_s > deadline_s
            ]
        for q in stale:
            if self.plane is not None and self.plane.try_resume(q, now_s):
                continue
            self._fail(q, TimeoutError(
                f"stage deadline: no stage-boundary progress in "
                f"{deadline_s:.1f}s (worker died or wedged)"
            ))

    # --- result sinks (called from worker threads) ---------------------
    def _finish(self, q: Query) -> None:
        # a fused query completes as its members: times shared, billing
        # split by tokens with the exact-sum repair (same helper as the
        # simulator), so drain() counts each submitted query once
        with self._lock:
            if q.state == "failed":  # the reaper won this race
                return
            q.finish_time = self.now()
            q.state = "done"
            self.done.extend(unpack_fused(q))
        self._drop_ckpt(q)
        self._clear_beat(q)

    def _fail(self, q: Query, err: BaseException) -> None:
        with self._lock:
            if q.state in ("failed", "done"):  # double report / lost race
                return
            q.finish_time = self.now()
            q.state = "failed"
            q.error = f"{type(err).__name__}: {err}"
            self.failed.extend(unpack_fused(q))
        self._drop_ckpt(q)
        self._clear_beat(q)
        if self.events is not None:
            self.events.emit(
                "fail", q.finish_time, qid=q.qid, error=q.error
            )

    # ------------------------------------------------------------------
    def submit(self, q: Query) -> None:
        q.submit_time = self.now()
        q.work = self.live_work(q.work)
        with self._lock:
            self.service.submit(q, q.submit_time)

    def _sched_loop(self) -> None:
        while not self._stop.is_set():
            with self._lock:
                self.service.poll(self.now())
            now_s = self.now()
            if self.cfg.stage_deadline_s is not None:
                self._reap(now_s)
            if self.plane is not None:
                self.plane.step_live(now_s)
            time.sleep(self.cfg.sla.poll_period_s)

    def drain(self, n_expected: int, timeout: float = 120.0) -> list[Query]:
        """Block until n_expected queries have COMPLETED — done or
        failed — or the timeout passes. Failures count toward
        completion, so a raising query surfaces immediately instead of
        making the drain sit out its full timeout."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._lock:
                if len(self.done) + len(self.failed) >= n_expected:
                    break
            time.sleep(0.02)
        self.shutdown()
        with self._lock:
            out = list(self.done) + list(self.failed)
        if sanitize.enabled():
            # conservation + trace stitching over completed queries only
            # (failed ones may have partial traces mid-stage)
            sanitize.check_result([q for q in out if q.state == "done"])
        return out

    def shutdown(self) -> None:
        self._stop.set()
        for pool in self.pools:
            pool.stop()
        self._sched_thread.join(timeout=5.0)
        if self.calibrator is not None and self.calibrator.path is not None:
            self.calibrator.save(self.calibrator.path)
