"""LIVE execution backend for the SLA service: the same ServiceLayer /
schedulers / coordinator drive real jitted JAX work on this host.

The simulator (simulator.py) answers "what would this schedule cost on a
TPU fleet"; the live engine proves the scheduling layer is a real runtime,
not a model: queries run reduced-config models, the cost-efficient
"cluster" is a single worker thread (serialized, interference-free), and
the high-elastic "cluster" is an unbounded thread pool with a simulated
provisioning delay. Used by examples/serve_sla.py and tests/test_live.py.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..models.transformer import LM
from ..perf.hw import V5E
from .query import Query
from .sla import Policy, ServiceLevel, SLAConfig


class _ModelPool:
    """Jitted reduced models, shared by both clusters."""

    def __init__(self):
        self._models: dict[str, tuple[LM, dict]] = {}
        self._lock = threading.Lock()

    def get(self, arch: str):
        with self._lock:
            if arch not in self._models:
                cfg = get_config(arch, reduced=True)
                model = LM(cfg)
                params = model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
                self._models[arch] = (model, params)
            return self._models[arch]


@dataclass
class LiveConfig:
    policy: Policy = Policy.AUTO
    sla_enabled: bool = True
    sla: SLAConfig = field(
        default_factory=lambda: SLAConfig(
            relaxed_deadline_s=10.0, poll_period_s=0.05, vm_overload_threshold=2
        )
    )
    cf_startup_s: float = 0.3
    vm_price: float = 1.0  # $ per worker-second
    cf_price_multiplier: float = 10.0
    prompt_tokens: int = 32
    decode_tokens: int = 4


class LiveEngine:
    """Thread-backed mirror of the simulator's cluster pair."""

    def __init__(self, cfg: LiveConfig):
        self.cfg = cfg
        self.pool = _ModelPool()
        self.vm_queue: "queue.Queue[Optional[Query]]" = queue.Queue()
        self.cf_pool = ThreadPoolExecutor(max_workers=16)
        self.relaxed: list[Query] = []
        self.boe: list[Query] = []
        self.done: list[Query] = []
        self._lock = threading.Lock()
        self._vm_busy = 0
        self._t0 = time.monotonic()
        self._stop = threading.Event()
        self._vm_thread = threading.Thread(target=self._vm_loop, daemon=True)
        self._sched_thread = threading.Thread(target=self._sched_loop, daemon=True)
        self._vm_thread.start()
        self._sched_thread.start()

    # ------------------------------------------------------------------
    def now(self) -> float:
        return time.monotonic() - self._t0

    def _run_query(self, q: Query, price: float) -> None:
        model, params = self.pool.get(q.work.arch)
        cfg = model.cfg
        q.start_time = self.now()
        toks = jax.random.randint(
            jax.random.PRNGKey(q.qid),
            (max(1, q.work.batch), self.cfg.prompt_tokens),
            0,
            cfg.vocab_size,
        )
        kw = {}
        if cfg.is_encoder_decoder:
            kw["enc_embeds"] = jnp.zeros(
                (toks.shape[0], toks.shape[1], cfg.d_model), jnp.float32
            )
        if cfg.frontend == "vision_patches":
            kw["frontend_embeds"] = jnp.zeros(
                (toks.shape[0], cfg.frontend_tokens, cfg.d_model), jnp.float32
            )
        logits, cache = model.prefill(
            params, toks, kv_len=self.cfg.prompt_tokens + self.cfg.decode_tokens + 8,
            dtype=jnp.float32, **kw,
        )
        tok = jnp.argmax(logits, -1)[:, None]
        for _ in range(self.cfg.decode_tokens):
            logits, cache = model.decode_step(params, cache, tok, dtype=jnp.float32)
            tok = jnp.argmax(logits, -1)[:, None]
        jax.block_until_ready(tok)
        q.finish_time = self.now()
        q.chip_seconds = q.finish_time - q.start_time  # 1 "chip" worker
        q.cost = q.chip_seconds * price
        with self._lock:
            self.done.append(q)

    # ------------------------------------------------------------------
    def _vm_loop(self) -> None:
        while not self._stop.is_set():
            try:
                q = self.vm_queue.get(timeout=0.05)
            except queue.Empty:
                continue
            if q is None:
                break
            self._vm_busy += 1
            try:
                self._run_query(q, self.cfg.vm_price)
            finally:
                self._vm_busy -= 1
                self.vm_queue.task_done()

    @property
    def vm_run_queue_len(self) -> int:
        return self.vm_queue.qsize() + self._vm_busy

    def _route(self, q: Query) -> None:
        q.dequeue_time = self.now()
        overloaded = self.vm_run_queue_len >= self.cfg.sla.vm_overload_threshold
        sla = q.effective_sla
        if self.cfg.policy is Policy.FORCE:
            to_vm = sla in (ServiceLevel.RELAXED, ServiceLevel.BEST_EFFORT) or not overloaded
        else:
            to_vm = not overloaded
        if to_vm:
            q.cluster = "vm"
            self.vm_queue.put(q)
        else:
            q.cluster = "cf"

            def run_cf():
                time.sleep(self.cfg.cf_startup_s)  # provisioning latency
                self._run_query(q, self.cfg.vm_price * self.cfg.cf_price_multiplier)

            self.cf_pool.submit(run_cf)

    def _sched_loop(self) -> None:
        scfg = self.cfg.sla
        while not self._stop.is_set():
            now = self.now()
            with self._lock:
                # relaxed: overload-aware with deadline force-submit
                while self.relaxed:
                    head = self.relaxed[0]
                    near = now - head.submit_time >= scfg.relaxed_deadline_s * scfg.deadline_slack
                    can = self.vm_run_queue_len < scfg.vm_overload_threshold
                    if not (near or can):
                        break
                    self._route(self.relaxed.pop(0))
                # BoE: drain one when idle
                if self.boe and self.vm_run_queue_len <= scfg.boe_idle_threshold:
                    self._route(self.boe.pop(0))
            time.sleep(scfg.poll_period_s)

    # ------------------------------------------------------------------
    def submit(self, q: Query) -> None:
        q.submit_time = self.now()
        q.effective_sla = q.sla if self.cfg.sla_enabled else ServiceLevel.IMMEDIATE
        if q.effective_sla is ServiceLevel.IMMEDIATE:
            self._route(q)
        elif q.effective_sla is ServiceLevel.RELAXED:
            with self._lock:
                self.relaxed.append(q)
        else:
            with self._lock:
                self.boe.append(q)

    def drain(self, n_expected: int, timeout: float = 120.0) -> list[Query]:
        t0 = time.monotonic()
        while time.monotonic() - t0 < timeout:
            with self._lock:
                if len(self.done) >= n_expected:
                    break
            time.sleep(0.05)
        self.shutdown()
        return list(self.done)

    def shutdown(self) -> None:
        self._stop.set()
        self.vm_queue.put(None)
        self.cf_pool.shutdown(wait=True)
