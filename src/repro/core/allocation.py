"""Per-query chips-per-stage allocation from the calibrated cost model.

Kassing et al. ("Resource Allocation in Serverless Query Processing")
show per-query resource allocation is where a serverless engine wins or
loses money, and the paper's flexible-SLA menu only prices honestly when
each service level is quoted at *its own* cheapest allocation that still
meets the level's guarantee. This module makes slice width a per-query
decision instead of a per-pool constant:

``Allocator`` sweeps the latency/cost frontier of one (work shape, pool)
pair over a ``min_chips..max_chips`` grid of slice widths, planning each
width through the pool's own calibrated ``CostModel``, and picks per
service level:

  IMMEDIATE   — the cheapest width whose full-plan execution time meets
                ``imm_exec_target_s``; with no target (or none meets
                it), the latency-optimal point: IMMEDIATE buys wider
                slices than BEST_EFFORT for identical work.
  RELAXED     — the cheapest width meeting ``rel_exec_target_s``;
                otherwise it degrades to the cost-optimal point (the
                pending queue, not the slice, absorbs its deadline).
  BEST_EFFORT — the cost-optimal point, always.

The sweep is only meaningful on a cost model with a nonzero
``parallel_overhead``: the pure roofline is exactly linear in chips, so
chip-seconds — and therefore cost — are width-independent and every
width ties (the choice then falls to the deterministic tie-break: equal
cost resolves to the faster, then narrower width, so the degenerate
frontier collapses to "always widest" — wider is free).

Choices are memoized per (work shape, service level) and validated
against ``CalibrationTable.version`` exactly like the plan cache they
sit on, so a calibration hot swap re-runs the sweep on the very next
query. Pool-load dependence enters one layer up: the executors' static
quotes cache the chosen width's plan keyed by (version, load_epoch,
level) — see ``engine.ClusterExecutor._static_quote``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Optional

from .sla import ServiceLevel

if TYPE_CHECKING:  # pragma: no cover — import cycle guard
    from .cost_model import CostModel
    from .query import QueryWork


@dataclass(frozen=True)
class AllocationPoint:
    """One point on a (work shape, pool) latency/cost frontier."""

    chips: int
    exec_s: float  # full-plan execution time at this width
    chip_seconds: float  # billed chip-seconds (∝ cost at the pool price)


@dataclass(frozen=True)
class AllocationConfig:
    """Per-pool allocation bounds: the width grid the frontier sweep
    covers, plus optional per-level execution-time targets the chosen
    width must meet (``PoolSpec.allocation`` carries one of these)."""

    min_chips: int = 4
    max_chips: int = 64
    step_chips: int = 4
    #: cheapest width whose exec time meets this, else latency-optimal
    imm_exec_target_s: Optional[float] = None
    #: cheapest width meeting this, else the cost-optimal point
    rel_exec_target_s: Optional[float] = None

    def __post_init__(self):
        if self.min_chips < 1:
            raise ValueError(f"min_chips must be >= 1, got {self.min_chips}")
        if self.max_chips < self.min_chips:
            raise ValueError(
                f"max_chips ({self.max_chips}) < min_chips ({self.min_chips})"
            )
        if self.step_chips < 1:
            raise ValueError(f"step_chips must be >= 1, got {self.step_chips}")

    def widths(self) -> tuple[int, ...]:
        """The sweep grid: min..max by step, with max always included
        (a ragged last step must not silently drop the widest point —
        the latency-optimal pick usually lives there)."""
        ws = list(range(self.min_chips, self.max_chips + 1, self.step_chips))
        if ws[-1] != self.max_chips:
            ws.append(self.max_chips)
        return tuple(ws)


class Allocator:
    """Frontier sweep + per-level width choice for one pool's cost model.

    Attached to an executor as ``pool.allocator`` (build_pool does this
    when ``PoolSpec.allocation`` is set); the executor's ``_plan_chips``
    consults it, so quotes, spill thresholds, and execution all plan at
    the same chosen width through the one ``effective_chips`` accessor.
    """

    #: memo guard against unbounded work-shape variety (same discipline
    #: as the executors' static-quote cache)
    MEMO_MAX = 4096

    def __init__(self, cost_model: "CostModel", config: AllocationConfig):
        self.cost_model = cost_model
        self.config = config
        # (work shape, level) -> (plan version, chosen width)
        self._memo: dict[tuple, tuple[int, int]] = {}
        self.choose_hits = 0
        self.choose_misses = 0

    def frontier(self, work: "QueryWork") -> list[AllocationPoint]:
        """Plan the work at every grid width. Each width's plan lands in
        the cost model's LRU plan cache, so repeated sweeps over the
        same work shapes stay cached."""
        pts = []
        for w in self.config.widths():
            plan = self.cost_model.plan(work, w)
            pts.append(AllocationPoint(w, plan.exec_time, plan.chip_seconds))
        return pts

    def stats(self) -> dict:
        return {
            "hits": self.choose_hits,
            "misses": self.choose_misses,
            "size": len(self._memo),
        }

    def choose(self, work: "QueryWork", level: ServiceLevel) -> int:
        """The chosen width for (work, level) — memoized per work shape
        and validated against the calibration version, so a hot swap
        re-sweeps on the next call. Width is chosen from the FULL plan's
        execution time (cursor-independent): a preempted or spilled-back
        query resumes at the same width it started at."""
        key = (work.arch, work.kind, work.batch, work.prompt_tokens,
               work.output_tokens, work.train_steps, work.seq_len,
               int(level))
        ver = self.cost_model.plan_version()
        hit = self._memo.get(key)
        if hit is not None and hit[0] == ver:
            self.choose_hits += 1
            return hit[1]
        self.choose_misses += 1
        chips = self._pick(self.frontier(work), ServiceLevel(int(level)))
        if len(self._memo) > self.MEMO_MAX:
            self._memo.clear()
        self._memo[key] = (ver, chips)
        return chips

    def _pick(self, pts: list[AllocationPoint], level: ServiceLevel) -> int:
        # deterministic tie-breaks: cost picks prefer the narrower
        # width, latency picks the cheaper one, then narrower
        cheapest = min(pts, key=lambda p: (p.chip_seconds, p.exec_s, p.chips))
        if level is ServiceLevel.BEST_EFFORT:
            return cheapest.chips
        target = (
            self.config.imm_exec_target_s
            if level is ServiceLevel.IMMEDIATE
            else self.config.rel_exec_target_s
        )
        if target is not None:
            ok = [p for p in pts if p.exec_s <= target]
            if ok:
                return min(
                    ok, key=lambda p: (p.chip_seconds, p.exec_s, p.chips)
                ).chips
        if level is ServiceLevel.RELAXED:
            # no target, or none meets it: the relaxed pending queue
            # absorbs the deadline — degrade to the cost-optimal point
            return cheapest.chips
        # IMMEDIATE with no feasible target: latency-optimal
        return min(pts, key=lambda p: (p.exec_s, p.chip_seconds, p.chips)).chips
