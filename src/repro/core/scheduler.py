"""The paper's scheduling layer (§4.2 service levels, §4.3 coordinator).

Service layer -> {immediate path, relaxed pending queue, BoE pending queue}
-> schedulers poll -> query coordinator routes to the cost-efficient (VM)
or high-elastic (CF) cluster under the Force/Auto policy.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional

from .clusters import CostEfficientCluster, HighElasticCluster
from .query import Query, QueryWork
from .sla import Policy, ServiceLevel, SLAConfig


def fuse_queries(queries: list[Query], now: float) -> Query:
    """Merge same-(arch, prompt) queries into one batched query (the
    multi-query execution opportunity of paper §3.3). Weight streaming
    amortizes across the fused batch, so the fused plan's chip-seconds are
    strictly below the sum of the members' individual plans."""
    head = queries[0]
    if len(queries) == 1:
        return head
    merged = Query(
        work=QueryWork(
            arch=head.work.arch,
            kind=head.work.kind,
            batch=sum(q.work.batch for q in queries),
            prompt_tokens=head.work.prompt_tokens,
            output_tokens=max(q.work.output_tokens for q in queries),
        ),
        sla=head.sla,
        submit_time=min(q.submit_time for q in queries),
        source=head.source,
    )
    merged.members = queries  # type: ignore[attr-defined]
    for q in queries:
        q.dequeue_time = now
    return merged


def _fusable(head: Query, q: Query) -> bool:
    """Fusion safety: identical (arch, kind, prompt, output) only — a
    train query must never fuse with a serve query, and mismatched
    decode lengths would mis-bill the shorter members."""
    return (
        q.work.arch == head.work.arch
        and q.work.kind == head.work.kind
        and q.work.prompt_tokens == head.work.prompt_tokens
        and q.work.output_tokens == head.work.output_tokens
    )


def pop_fused(queue: deque, now: float, fuse: bool, fuse_max: int) -> Query:
    """Pop the queue head, fusing compatible waiting queries behind it.
    Shared by the relaxed and BoE schedulers so both apply the same
    matching rules. Only serve queries fuse (train steps don't batch)."""
    head = queue.popleft()
    if not fuse or head.work.kind != "serve":
        return head
    same = [q for q in list(queue) if _fusable(head, q)][: fuse_max - 1]
    for q in same:
        queue.remove(q)
    return fuse_queries([head] + same, now)


class QueryCoordinator:
    """Routes a dequeued query to a cluster (paper §4.3)."""

    def __init__(
        self,
        vm: CostEfficientCluster,
        cf: HighElasticCluster,
        policy: Policy,
        cfg: SLAConfig,
    ):
        self.vm = vm
        self.cf = cf
        self.policy = policy
        self.cfg = cfg

    @property
    def vm_overloaded(self) -> bool:
        return self.vm.run_queue_len >= self.cfg.vm_overload_threshold

    # ------------------------------------------------------------------
    # Beyond-paper: execution-time SLAs. The deterministic SOS cost model
    # makes admission-time latency quotes possible (paper §3.3 vision 1:
    # "it is easier to profile and control the performance and cost").
    # ------------------------------------------------------------------
    def estimate(self, q: Query) -> dict:
        """Latency/cost quote for both pools at the current load. Quotes
        cover only the REMAINING stages (q.stage_cursor onward), so a
        preempted or spill-candidate query is priced for what's left,
        not for work it already ran."""
        cm = self.vm.cost_model
        cur = q.stage_cursor
        vm_plan = cm.plan(q.work, self.vm.chips)
        vm_exec = vm_plan.remaining_time(cur)
        # POS: effective rate divides across running queries w/ interference
        k = self.vm.run_queue_len + 1
        vm_latency = vm_exec * k * (1.0 + self.vm.alpha * (k - 1))
        vm_cost = vm_plan.remaining_chip_seconds(cur) * self.vm.price_per_chip_s
        cf_plan = cm.plan(q.work, self.cf.slice_for(q))
        cf_latency = self.cf.startup_s + cf_plan.remaining_time(cur)
        cf_cost = cf_plan.remaining_chip_seconds(cur) * self.cf.price_per_chip_s
        return {
            "vm": {"latency_s": vm_latency, "cost": vm_cost},
            "cf": {"latency_s": cf_latency, "cost": cf_cost},
        }

    def should_spill(self, q: Query, now: float) -> bool:
        """Stage-boundary spill policy (SLAConfig.spill_enabled): move the
        remaining stages of a running VM query to the elastic cluster
        when its slice pool is overloaded — a waiting query AT LEAST AS
        urgent as `q` has no slice — and the remaining work is worth the
        elastic premium. A less-urgent waiter never displaces a runner
        (a deadline-distant RELAXED query must not push an IMMEDIATE
        query onto the 9-24x-priced pool), and BEST_EFFORT queries are
        never spilled — they are preempted instead."""
        if q.current_sla is ServiceLevel.BEST_EFFORT:
            return False
        displacing_waiter = any(
            w.current_sla is not ServiceLevel.BEST_EFFORT
            and w.current_sla <= q.current_sla
            for w in self.vm.waiting
        )
        if not displacing_waiter:
            return False
        plan = self.vm.cost_model.plan(q.work, self.vm.slice_chips)
        return plan.remaining_time(q.stage_cursor) >= self.cfg.spill_min_remaining_s

    def route(self, q: Query, now: float) -> str:
        sla = q.current_sla
        if self.policy is Policy.LATENCY_AWARE:
            est = self.estimate(q)
            target = q.latency_target_s
            ok = {
                pool: e for pool, e in est.items()
                if target is None or e["latency_s"] <= target
            } or est  # nothing meets the target: best effort, cheapest
            target_pool = min(ok, key=lambda p: ok[p]["cost"])
            (self.vm if target_pool == "vm" else self.cf).submit(q, now)
            return target_pool
        if self.policy is Policy.FORCE:
            # SLA directly decides the pool: relaxed/BoE are forced into
            # the cost-efficient cluster; immediate spills to the elastic
            # cluster only when the VM cluster is overloaded.
            if sla in (ServiceLevel.RELAXED, ServiceLevel.BEST_EFFORT):
                target = "vm"
            else:
                target = "cf" if self.vm_overloaded else "vm"
        else:  # AUTO: overload decides, regardless of service level
            target = "cf" if self.vm_overloaded else "vm"
        (self.vm if target == "vm" else self.cf).submit(q, now)
        return target


class RelaxedScheduler:
    """Polls the relaxed pending queue: dequeue when the cost-efficient
    cluster can execute, or when a query approaches its deadline."""

    def __init__(self, coordinator: QueryCoordinator, cfg: SLAConfig,
                 fuse: bool = False, fuse_max: int = 8):
        self.q: deque[Query] = deque()
        self.coordinator = coordinator
        self.cfg = cfg
        self.fuse = fuse
        self.fuse_max = fuse_max

    def enqueue(self, q: Query) -> None:
        self.q.append(q)

    def poll(self, now: float) -> list[Query]:
        out = []
        while self.q:
            head = self.q[0]
            deadline_near = (
                now - head.submit_time
                >= self.cfg.relaxed_deadline_s * self.cfg.deadline_slack
            )
            can_exec = not self.coordinator.vm_overloaded
            if not (can_exec or deadline_near):
                break
            q = pop_fused(self.q, now, self.fuse, self.fuse_max)
            q.dequeue_time = now
            self.coordinator.route(q, now)
            out.append(q)
        return out


class BoEScheduler:
    """Drains the BoE queue whenever the cost-efficient cluster is idle."""

    def __init__(self, coordinator: QueryCoordinator, cfg: SLAConfig,
                 fuse: bool = False, fuse_max: int = 8):
        self.q: deque[Query] = deque()
        self.coordinator = coordinator
        self.cfg = cfg
        self.fuse = fuse
        self.fuse_max = fuse_max

    def enqueue(self, q: Query) -> None:
        self.q.append(q)

    def poll(self, now: float) -> list[Query]:
        out = []
        while self.q and self.coordinator.vm.run_queue_len <= self.cfg.boe_idle_threshold:
            head = pop_fused(self.q, now, self.fuse, self.fuse_max)
            head.dequeue_time = now
            self.coordinator.route(head, now)
            out.append(head)
            # one dequeue per idle observation: re-check occupancy
        return out


class ServiceLayer:
    """Entry point (paper Fig. 4 left half): SLA-dispatches queries."""

    def __init__(
        self,
        coordinator: QueryCoordinator,
        cfg: SLAConfig,
        sla_enabled: bool = True,
        fuse: bool = False,
    ):
        self.coordinator = coordinator
        self.cfg = cfg
        self.sla_enabled = sla_enabled
        self.relaxed = RelaxedScheduler(coordinator, cfg, fuse=fuse)
        self.boe = BoEScheduler(coordinator, cfg, fuse=fuse)

    def submit(self, q: Query, now: float) -> None:
        # the paper's "w/o SLA" baseline rewrites every query to immediate
        # (reporting still groups by the SUBMITTED sla, as in Figs. 6-7)
        q.effective_sla = (
            q.sla if self.sla_enabled else ServiceLevel.IMMEDIATE
        )
        if q.effective_sla is ServiceLevel.IMMEDIATE:
            q.dequeue_time = now
            self.coordinator.route(q, now)
        elif q.effective_sla is ServiceLevel.RELAXED:
            self.relaxed.enqueue(q)
        else:
            self.boe.enqueue(q)

    def poll(self, now: float) -> None:
        self.relaxed.poll(now)
        self.boe.poll(now)

    @property
    def pending(self) -> int:
        return len(self.relaxed.q) + len(self.boe.q)
