"""The paper's scheduling layer (§4.2 service levels, §4.3 coordinator).

Service layer -> {immediate path, relaxed pending queue, BoE pending queue}
-> schedulers poll -> query coordinator places each query on one pool of
an N-pool executor registry, by per-pool remaining-stage quotes under the
Force/Auto/latency-aware policy. The registry generalizes the paper's
hardcoded vm/cf pair: "reserved" pools form the cost-efficient tier,
"elastic" pools the premium burst tier, and every placement decision —
routing, spill, spill-back — is made from the same quotes.
"""
from __future__ import annotations

from collections import deque
from typing import Iterable, Optional, Union

from .engine import ClusterExecutor
from .query import Query, QueryWork
from .sla import Policy, ServiceLevel, SLAConfig


def fuse_queries(queries: list[Query], now: float) -> Query:
    """Merge same-(arch, prompt) queries into one batched query (the
    multi-query execution opportunity of paper §3.3). Weight streaming
    amortizes across the fused batch, so the fused plan's chip-seconds are
    strictly below the sum of the members' individual plans."""
    head = queries[0]
    if len(queries) == 1:
        return head
    merged = Query(
        work=QueryWork(
            arch=head.work.arch,
            kind=head.work.kind,
            batch=sum(q.work.batch for q in queries),
            prompt_tokens=head.work.prompt_tokens,
            output_tokens=max(q.work.output_tokens for q in queries),
        ),
        sla=head.sla,
        submit_time=min(q.submit_time for q in queries),
        source=head.source,
    )
    merged.members = queries  # type: ignore[attr-defined]
    for q in queries:
        q.dequeue_time = now
    return merged


def _fusable(head: Query, q: Query) -> bool:
    """Fusion safety: identical (arch, kind, prompt, output) only — a
    train query must never fuse with a serve query, and mismatched
    decode lengths would mis-bill the shorter members."""
    return (
        q.work.arch == head.work.arch
        and q.work.kind == head.work.kind
        and q.work.prompt_tokens == head.work.prompt_tokens
        and q.work.output_tokens == head.work.output_tokens
    )


def pop_fused(queue: deque, now: float, fuse: bool, fuse_max: int) -> Query:
    """Pop the queue head, fusing compatible waiting queries behind it.
    Shared by the relaxed and BoE schedulers so both apply the same
    matching rules. Only serve queries fuse (train steps don't batch)."""
    head = queue.popleft()
    if not fuse or head.work.kind != "serve":
        return head
    same = [q for q in list(queue) if _fusable(head, q)][: fuse_max - 1]
    for q in same:
        queue.remove(q)
    return fuse_queries([head] + same, now)


class QueryCoordinator:
    """Places a dequeued query on one pool of the registry (paper §4.3,
    generalized): every decision reads per-pool remaining-stage quotes,
    not a hardcoded vm/cf branch.

    Accepts either a pool list or the legacy ``(vm, cf)`` pair. The
    first reserved pool is exposed as ``.vm`` and the first elastic pool
    as ``.cf`` for the two-pool system the paper describes.
    """

    def __init__(
        self,
        pools: Union[ClusterExecutor, Iterable[ClusterExecutor]],
        cf: Optional[ClusterExecutor] = None,
        policy: Policy = Policy.AUTO,
        cfg: Optional[SLAConfig] = None,
    ):
        if isinstance(pools, ClusterExecutor):
            pools = [pools] + ([cf] if cf is not None else [])
        elif cf is not None:
            raise TypeError("pass either a pool list or the (vm, cf) pair")
        self.pools: list[ClusterExecutor] = list(pools)
        if not self.pools:
            raise ValueError("registry needs at least one pool")
        names = [p.name for p in self.pools]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate pool names: {names}")
        self.by_name = {p.name: p for p in self.pools}
        self.policy = policy
        self.cfg = cfg or SLAConfig()
        self.reserved_pools = [
            p for p in self.pools if p.pool_kind == "reserved"
        ]
        self.elastic_pools = [p for p in self.pools if p.pool_kind == "elastic"]
        self.vm = self.reserved_pools[0] if self.reserved_pools else self.pools[0]
        self.cf = self.elastic_pools[0] if self.elastic_pools else None

    def pool_overloaded(self, pool: ClusterExecutor) -> bool:
        return pool.run_queue_len >= self.cfg.vm_overload_threshold

    @property
    def vm_overloaded(self) -> bool:
        """The legacy single-VM overload signal the schedulers poll:
        EVERY reserved pool is past the overload threshold. An
        all-elastic registry is never overloaded — burst capacity is
        unbounded, so holding relaxed queries back would only invert
        priority against BoE, which drains freely."""
        if not self.reserved_pools:
            return False
        return all(self.pool_overloaded(p) for p in self.reserved_pools)

    @property
    def reserved_min_queue_len(self) -> int:
        """Shortest run queue across the cost-efficient tier (the BoE
        drain signal; with one reserved pool: its run-queue length)."""
        if not self.reserved_pools:
            return 0
        return min(p.run_queue_len for p in self.reserved_pools)

    # ------------------------------------------------------------------
    # Beyond-paper: execution-time SLAs. The deterministic SOS cost model
    # makes admission-time latency quotes possible (paper §3.3 vision 1:
    # "it is easier to profile and control the performance and cost").
    # ------------------------------------------------------------------
    def estimate(self, q: Query, now: Optional[float] = None) -> dict:
        """Latency/cost quote for EVERY pool at the current load. Quotes
        cover only the REMAINING stages (q.stage_cursor onward), so a
        preempted or spill-candidate query is priced for what's left,
        not for work it already ran."""
        return {p.name: p.quote(q, now) for p in self.pools}

    def should_spill(
        self, q: Query, now: float, pool: Optional[ClusterExecutor] = None
    ) -> bool:
        """Stage-boundary spill policy (SLAConfig.spill_enabled): move the
        remaining stages of a running reserved-pool query to an elastic
        pool when its slice pool is overloaded — a waiting query AT LEAST
        AS urgent as `q` has no slice — and the remaining work is worth
        the elastic premium. A less-urgent waiter never displaces a
        runner (a deadline-distant RELAXED query must not push an
        IMMEDIATE query onto the 9-24x-priced pool), and BEST_EFFORT
        queries are never spilled — they are preempted instead."""
        pool = pool or self.vm
        if q.current_sla is ServiceLevel.BEST_EFFORT:
            return False
        # snapshot: live pools mutate `waiting` from worker threads while
        # this policy runs at another worker's stage boundary
        displacing_waiter = any(
            w.current_sla is not ServiceLevel.BEST_EFFORT
            and w.current_sla <= q.current_sla
            for w in list(pool.waiting)
        )
        if not displacing_waiter:
            return False
        plan = pool.cost_model.plan(q.work, pool.effective_chips(q))
        return plan.remaining_time(q.stage_cursor) >= self.cfg.spill_min_remaining_s

    def rehome(
        self, pool: ClusterExecutor, q: Query, now: float
    ) -> Optional[ClusterExecutor]:
        """Stage-boundary re-placement for `pool` (wired as pool.rehome).

        Reserved pool: spill — under overload, hand the remaining stages
        to the cheapest elastic quote. Elastic pool: spill-back — once a
        reserved pool has a free slice and its predicted backlog drain
        time is below the low watermark, a spilled query returns at its
        next stage boundary, making spill symmetric. Both moves require
        the remaining work to be worth the hop (spill_min_remaining_s),
        and the watermark hysteresis (spill needs a displaced waiter,
        spill-back an EMPTY queue plus low backlog) prevents ping-pong."""
        if pool.pool_kind == "reserved":
            if not self.cfg.spill_enabled or not self.elastic_pools:
                return None
            if not self.should_spill(q, now, pool):
                return None
            return min(self.elastic_pools, key=lambda p: p.quote_cost(q))
        # elastic pool: symmetric spill-back
        if not (self.cfg.spill_back_enabled and q.spilled):
            return None
        eligible = []
        for p in self.reserved_pools:
            if not p.has_capacity():
                continue
            if p.drain_time_s(now) > self.cfg.spill_back_low_backlog_s:
                continue
            plan = p.cost_model.plan(q.work, p.effective_chips(q))
            if plan.remaining_time(q.stage_cursor) < self.cfg.spill_min_remaining_s:
                continue  # the last chunk is not worth the hop
            eligible.append(p)
        if not eligible:
            return None
        # pick by quote, like every other placement decision: an
        # IMMEDIATE query returns to the fastest eligible pool, lower
        # levels to the cheapest — never registry order, which could
        # drop a latency-SLA query onto a 4x-slower pool
        if q.current_sla is ServiceLevel.IMMEDIATE:
            return min(eligible, key=lambda p: p.quote(q, now)["latency_s"])
        return min(eligible, key=lambda p: p.quote_cost(q))

    def wire_rehoming(self) -> None:
        """Install the stage-boundary re-placement hook on every pool the
        active SLAConfig makes eligible (reserved pools when spill is on,
        elastic pools when spill-back is on)."""
        for pool in self.pools:
            eligible = (
                self.cfg.spill_enabled
                if pool.pool_kind == "reserved"
                else self.cfg.spill_back_enabled
            )
            if eligible:
                pool.rehome = (
                    lambda q, now, _pool=pool: self.rehome(_pool, q, now)
                )

    def route(self, q: Query, now: float) -> str:
        sla = q.current_sla
        if self.policy is Policy.LATENCY_AWARE:
            est = self.estimate(q, now)
            target = q.latency_target_s
            ok = {
                name: e for name, e in est.items()
                if target is None or e["latency_s"] <= target
            } or est  # nothing meets the target: best effort, cheapest
            pool = self.by_name[min(ok, key=lambda n: ok[n]["cost"])]
        else:
            open_reserved = [
                p for p in self.reserved_pools if not self.pool_overloaded(p)
            ]
            if self.policy is Policy.FORCE and sla in (
                ServiceLevel.RELAXED,
                ServiceLevel.BEST_EFFORT,
            ):
                # SLA directly decides the tier: relaxed/BoE are forced
                # onto the cost-efficient tier even under overload
                candidates = open_reserved or self.reserved_pools
            else:
                # immediate (FORCE) and everything (AUTO): overflow to
                # the elastic tier only when the reserved tier is full
                candidates = (
                    open_reserved or self.elastic_pools or self.reserved_pools
                )
            candidates = candidates or self.pools  # all-elastic registry
            # quote only the candidate tier (a saturated pool's backlog
            # walk is pure waste when it is not a candidate anyway)
            if len(candidates) == 1:
                pool = candidates[0]
            elif sla is ServiceLevel.IMMEDIATE:
                pool = min(candidates, key=lambda p: p.quote(q, now)["latency_s"])
            else:
                pool = min(candidates, key=lambda p: p.quote_cost(q))
        pool.submit(q, now)
        return pool.name


class RelaxedScheduler:
    """Polls the relaxed pending queue: dequeue when the cost-efficient
    cluster can execute, or when a query approaches its deadline."""

    def __init__(self, coordinator: QueryCoordinator, cfg: SLAConfig,
                 fuse: bool = False, fuse_max: int = 8):
        self.q: deque[Query] = deque()
        self.coordinator = coordinator
        self.cfg = cfg
        self.fuse = fuse
        self.fuse_max = fuse_max

    def enqueue(self, q: Query) -> None:
        self.q.append(q)

    def poll(self, now: float) -> list[Query]:
        out = []
        while self.q:
            head = self.q[0]
            deadline_near = (
                now - head.submit_time
                >= self.cfg.relaxed_deadline_s * self.cfg.deadline_slack
            )
            can_exec = not self.coordinator.vm_overloaded
            if not (can_exec or deadline_near):
                break
            q = pop_fused(self.q, now, self.fuse, self.fuse_max)
            q.dequeue_time = now
            self.coordinator.route(q, now)
            out.append(q)
        return out


class BoEScheduler:
    """Drains the BoE queue whenever the cost-efficient cluster is idle."""

    def __init__(self, coordinator: QueryCoordinator, cfg: SLAConfig,
                 fuse: bool = False, fuse_max: int = 8):
        self.q: deque[Query] = deque()
        self.coordinator = coordinator
        self.cfg = cfg
        self.fuse = fuse
        self.fuse_max = fuse_max

    def enqueue(self, q: Query) -> None:
        self.q.append(q)

    def poll(self, now: float) -> list[Query]:
        out = []
        while self.q and self.coordinator.reserved_min_queue_len <= self.cfg.boe_idle_threshold:
            head = pop_fused(self.q, now, self.fuse, self.fuse_max)
            head.dequeue_time = now
            self.coordinator.route(head, now)
            out.append(head)
            # one dequeue per idle observation: re-check occupancy
        return out


class ServiceLayer:
    """Entry point (paper Fig. 4 left half): SLA-dispatches queries."""

    def __init__(
        self,
        coordinator: QueryCoordinator,
        cfg: SLAConfig,
        sla_enabled: bool = True,
        fuse: bool = False,
    ):
        self.coordinator = coordinator
        self.cfg = cfg
        self.sla_enabled = sla_enabled
        self.relaxed = RelaxedScheduler(coordinator, cfg, fuse=fuse)
        self.boe = BoEScheduler(coordinator, cfg, fuse=fuse)

    def submit(self, q: Query, now: float) -> None:
        # the paper's "w/o SLA" baseline rewrites every query to immediate
        # (reporting still groups by the SUBMITTED sla, as in Figs. 6-7)
        q.effective_sla = (
            q.sla if self.sla_enabled else ServiceLevel.IMMEDIATE
        )
        if q.effective_sla is ServiceLevel.IMMEDIATE:
            q.dequeue_time = now
            self.coordinator.route(q, now)
        elif q.effective_sla is ServiceLevel.RELAXED:
            self.relaxed.enqueue(q)
        else:
            self.boe.enqueue(q)

    def poll(self, now: float) -> None:
        self.relaxed.poll(now)
        self.boe.poll(now)

    @property
    def pending(self) -> int:
        return len(self.relaxed.q) + len(self.boe.q)
