"""The paper's scheduling layer (§4.2 service levels, §4.3 coordinator).

Service layer -> {immediate path, relaxed pending queue, BoE pending queue}
-> schedulers poll -> query coordinator places each query on one pool of
an N-pool executor registry, by per-pool remaining-stage quotes under the
Force/Auto/latency-aware policy. The registry generalizes the paper's
hardcoded vm/cf pair: "reserved" pools form the cost-efficient tier,
"elastic" pools the premium burst tier, and every placement decision —
routing, spill, spill-back — is made from the same quotes.

Multi-query fusion (paper §3.3) happens in two places, both indexed so a
fusable group is an O(1) lookup instead of a queue scan:

  * pending-queue fusion — ``PendingQueue`` buckets waiting queries by
    their fusion key, so ``pop_fused`` takes the head's group straight
    from its bucket (FIFO within the bucket) instead of copying and
    re-scanning the deque per pop;
  * cross-pool placement-time fusion — ``CrossPoolFusionIndex`` tracks
    every eligible WAITING query across ALL pools; when the coordinator
    routes a new query it pulls compatible waiters out of their pools
    (``pool.withdraw``) and places one merged query, so queries queued
    on *different* pools still share one batched execution. Fused
    billing splits by tokens at unpack (``unpack_fused``) with an
    exact-sum repair, through the same ``engine.account_stage``
    arithmetic as everything else.
"""
from __future__ import annotations

import math
import threading
from collections import deque
from typing import Iterable, Optional, Union

from . import sanitize
from .engine import ClusterExecutor
from .query import Query, QueryWork
from .sla import Policy, ServiceLevel, SLAConfig


def fusion_key(work: QueryWork) -> tuple:
    """Bucket key for fusion safety: identical (arch, kind, prompt,
    output) only — a train query must never fuse with a serve query, and
    mismatched decode lengths would mis-bill the shorter members."""
    return (work.arch, work.kind, work.prompt_tokens, work.output_tokens)


def fuse_queries(queries: list[Query], now: float) -> Query:
    """Merge same-(arch, prompt) queries into one batched query (the
    multi-query execution opportunity of paper §3.3). Weight streaming
    amortizes across the fused batch, so the fused plan's chip-seconds are
    strictly below the sum of the members' individual plans."""
    head = queries[0]
    if len(queries) == 1:
        return head
    merged = Query(
        work=QueryWork(
            arch=head.work.arch,
            kind=head.work.kind,
            batch=sum(q.work.batch for q in queries),
            prompt_tokens=head.work.prompt_tokens,
            output_tokens=max(q.work.output_tokens for q in queries),
        ),
        sla=head.sla,
        submit_time=min(q.submit_time for q in queries),
        source=head.source,
    )
    merged.members = queries
    merged.effective_sla = head.effective_sla
    # the batch must honor the most restrictive execution-time SLA of
    # its members (LATENCY_AWARE routing reads it)
    targets = [q.latency_target_s for q in queries
               if q.latency_target_s is not None]
    merged.latency_target_s = min(targets) if targets else None
    for q in queries:
        # members pulled out of a pool's waiting queue (cross-pool
        # fusion) already left the SLA pending queue — their pending
        # time is settled and must not be restamped
        if q.dequeue_time is None:
            q.dequeue_time = now
    return merged


def unpack_fused(q: Query) -> list[Query]:
    """Expand a finished fused query back into its members: times are
    shared, billed cost/chip-seconds split by each member's token share.
    The split is repaired to sum EXACTLY to the fused run's totals — the
    float residue of the share products is folded into the last member
    (explicitly, never silently left on member 0, which also carries
    the fused trace/counters) and the exact-sum invariant is asserted."""
    members = q.members
    if not members:
        return [q]
    tot = sum(m.work.total_tokens for m in members)
    for i, m in enumerate(members):
        share = m.work.total_tokens / max(tot, 1)
        m.start_time = q.start_time
        m.finish_time = q.finish_time
        m.cluster = q.cluster
        m.state = q.state
        m.error = q.error
        m.fused_with = len(members)
        m.chip_seconds = q.chip_seconds * share
        m.cost = q.cost * share
        if i == 0:  # the fused run's stage trace and engine counters
            m.stage_trace = q.stage_trace  # live on one member so
            m.retries = q.retries  # summaries stay exact
            m.preemptions = q.preemptions
            m.spilled = q.spilled
            m.spill_backs = q.spill_backs
    for attr, total in (("chip_seconds", q.chip_seconds), ("cost", q.cost)):
        _repair_exact_sum(members, attr, total)
        assert sum(getattr(m, attr) for m in members) == total, (
            f"fused {attr} split does not sum to the fused total "
            f"({total!r}) for Q{q.qid}"
        )
    return members


def _repair_exact_sum(members: list[Query], attr: str, total: float) -> None:
    """Adjust the LAST member so the members' left-to-right float sum
    equals `total` bit-for-bit. The last member is the only position
    whose value passes through a SINGLE rounding (the final addition):
    ``fl(prefix + x) == total`` holds for every x in an interval one
    ulp of `total` wide, which always contains representables (x is no
    larger than the total), so the algebraic solution ``total - prefix``
    plus at most a few one-ulp nudges lands the exact hit. Repairing
    any earlier position composes several roundings whose steps can
    jump PAST the total — that is how mixed-batch splits used to trip
    the caller's exactness assert. The residue is explicit, never
    silently parked on member 0 (with one member there is no residue)."""
    values = [getattr(m, attr) for m in members]
    if sum(values) == total:
        return
    prefix = sum(values[:-1])
    # Parity trap: when the last member dominates, x lives in the
    # total's own binade (ulp(x) == ulp(total)) and a prefix that is an
    # ODD multiple of ulp(total)/2 makes EVERY candidate sum land
    # exactly on a round-to-even tie — no representable x can produce
    # `total`. Escape by adding exactly one ulp OF THE PREFIX to the
    # second-to-last member: that single-rounding addition moves the
    # prefix by exactly one of its grid steps, flipping its parity.
    for _ in range(8):
        x = total - prefix
        for _ in range(8):
            s = prefix + x
            if s == total:
                setattr(members[-1], attr, x)
                return
            x = math.nextafter(x, math.inf if s < total else -math.inf)
        if len(members) < 2:
            break
        values[-2] += math.ulp(prefix)
        setattr(members[-2], attr, values[-2])
        prefix = sum(values[:-1])


class PendingQueue:
    """A scheduler pending queue: FIFO overall, with waiting queries
    bucketed by fusion key so a fused pop takes its group in O(group)
    instead of copying and scanning the whole deque (the old
    ``pop_fused``). Entries removed through a bucket leave a stale main-
    deque copy (and vice versa) that is skipped lazily, so every
    operation is amortized O(1). With ``fuse=False`` the bucket/stale
    bookkeeping is skipped entirely — stale bucket copies would
    otherwise accumulate forever, since only ``take_fusable`` consumes
    them."""

    __slots__ = ("_q", "_buckets", "_stale", "_n", "_fuse")

    def __init__(self, fuse: bool = True):
        self._q: deque[Query] = deque()
        self._buckets: dict[tuple, deque[Query]] = {}
        self._stale: dict[Query, int] = {}  # query -> stale copies left
        self._n = 0
        self._fuse = fuse

    def __len__(self) -> int:
        return self._n

    def __bool__(self) -> bool:
        return self._n > 0

    def __iter__(self):
        return (q for q in self._q if q not in self._stale)

    def __getitem__(self, i: int) -> Query:
        if i != 0:
            raise IndexError("PendingQueue only exposes its head")
        return self.head()

    def _consume_stale(self, q: Query) -> bool:
        c = self._stale.get(q)
        if not c:
            return False
        if c == 1:
            del self._stale[q]
        else:
            self._stale[q] = c - 1
        return True

    def append(self, q: Query) -> None:
        self._q.append(q)
        self._n += 1
        if self._fuse and q.work.kind == "serve":
            self._buckets.setdefault(fusion_key(q.work), deque()).append(q)

    def head(self) -> Query:
        while self._q and self._q[0] in self._stale:
            self._consume_stale(self._q.popleft())
        return self._q[0]

    def popleft(self) -> Query:
        q = self.head()
        self._q.popleft()
        self._n -= 1
        if self._fuse and q.work.kind == "serve":
            self._stale[q] = self._stale.get(q, 0) + 1  # bucket copy
        return q

    def take_fusable(self, head: Query, limit: int) -> list[Query]:
        """Up to `limit` queries fusable with `head`, in FIFO order —
        straight off the head's bucket, no queue scan."""
        key = fusion_key(head.work)
        bucket = self._buckets.get(key)
        if bucket is None:
            return []
        out: list[Query] = []
        while bucket and len(out) < limit:
            q = bucket.popleft()
            if self._consume_stale(q):
                continue  # head itself, or already popped via the deque
            out.append(q)
            self._n -= 1
            self._stale[q] = self._stale.get(q, 0) + 1  # main-deque copy
        if not bucket:
            del self._buckets[key]
        return out


def pop_fused(queue: PendingQueue, now: float, fuse: bool, fuse_max: int) -> Query:
    """Pop the queue head, fusing compatible waiting queries behind it.
    Shared by the relaxed and BoE schedulers so both apply the same
    matching rules. Only serve queries fuse (train steps don't batch)."""
    head = queue.popleft()
    if not fuse or head.work.kind != "serve":
        return head
    same = queue.take_fusable(head, fuse_max - 1)
    if not same:
        return head
    return fuse_queries([head] + same, now)


class CrossPoolFusionIndex:
    """Registry-wide fusion index (the ROADMAP cross-pool item): every
    eligible WAITING query — fresh, serve, not yet started — is indexed
    by fusion key the moment it enters ANY pool's waiting queue, and
    dropped the moment it leaves. The coordinator consults it at
    placement time, so compatible queries queued on different pools fuse
    into one batched execution instead of running separately.

    Thread-safe: live pools (core/live.py) mutate their waiting queues
    from worker threads and share this index with the coordinator."""

    #: lock contract (reprolint RL001 + repro.core.sanitize).
    _GUARDED_BY = {"_buckets": "_lock"}

    def __init__(self):
        self._lock = sanitize.ordered_lock(
            "CrossPoolFusionIndex._lock", threading.Lock()
        )
        # key -> {query: pool}; dict preserves insertion order, so FIFO
        # within a bucket holds across pools
        self._buckets: dict[tuple, dict[Query, ClusterExecutor]] = {}

    @staticmethod
    def _eligible(q: Query) -> bool:
        return (
            q.work.kind == "serve"
            and q.stage_cursor == 0
            and q.state == "pending"
            and q.members is None
        )

    def add(self, pool: ClusterExecutor, q: Query) -> None:
        if not self._eligible(q):
            return
        with self._lock:
            self._buckets.setdefault(fusion_key(q.work), {})[q] = pool

    def discard(self, q: Query) -> None:
        key = fusion_key(q.work)
        with self._lock:
            bucket = self._buckets.get(key)
            if bucket is not None and bucket.pop(q, None) is not None:
                if not bucket:
                    del self._buckets[key]

    def candidates(
        self, q: Query, limit: int
    ) -> list[tuple[Query, ClusterExecutor]]:
        """Fusable waiting mates for `q` (same key AND same service
        level — a BoE waiter must not ride an IMMEDIATE head's tier),
        FIFO, as (query, owning pool) snapshot pairs."""
        with self._lock:
            bucket = self._buckets.get(fusion_key(q.work))
            if not bucket:
                return []
            out = []
            for m, pool in bucket.items():
                if m is q or m.current_sla is not q.current_sla:
                    continue
                out.append((m, pool))
                if len(out) >= limit:
                    break
            return out


class QueryCoordinator:
    """Places a dequeued query on one pool of the registry (paper §4.3,
    generalized): every decision reads per-pool remaining-stage quotes,
    not a hardcoded vm/cf branch. Quotes are served from each pool's
    static-quote cache (engine.ClusterExecutor._static_quote), so the
    per-query all-pools loop re-plans only when a calibration version or
    pool load epoch changed.

    Accepts either a pool list or the legacy ``(vm, cf)`` pair. The
    first reserved pool is exposed as ``.vm`` and the first elastic pool
    as ``.cf`` for the two-pool system the paper describes.

    With ``cross_pool_fusion=True`` the coordinator maintains a
    ``CrossPoolFusionIndex`` over every pool's waiting queue and merges
    compatible waiters into each newly placed query (``fuse_max`` caps
    the batch, like the pending-queue fusion it extends).
    """

    def __init__(
        self,
        pools: Union[ClusterExecutor, Iterable[ClusterExecutor]],
        cf: Optional[ClusterExecutor] = None,
        policy: Policy = Policy.AUTO,
        cfg: Optional[SLAConfig] = None,
        cross_pool_fusion: bool = False,
        fuse_max: int = 8,
    ):
        if isinstance(pools, ClusterExecutor):
            pools = [pools] + ([cf] if cf is not None else [])
        elif cf is not None:
            raise TypeError("pass either a pool list or the (vm, cf) pair")
        self.pools: list[ClusterExecutor] = list(pools)
        if not self.pools:
            raise ValueError("registry needs at least one pool")
        names = [p.name for p in self.pools]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate pool names: {names}")
        self.by_name = {p.name: p for p in self.pools}
        self.policy = policy
        self.cfg = cfg or SLAConfig()
        self.fuse_max = fuse_max
        #: service levels eligible for placement-time fusion (see the
        #: route() gate for why RELAXED is not in the default set)
        self.cross_fuse_levels: tuple = (
            ServiceLevel.IMMEDIATE,
            ServiceLevel.BEST_EFFORT,
        )
        self.fusion: Optional[CrossPoolFusionIndex] = None
        if cross_pool_fusion:
            self.fusion = CrossPoolFusionIndex()
            for p in self.pools:
                p.wait_observer = self.fusion
        #: calibrated admission control (docs/allocation.md): quotes
        #: from a pool whose drift EWMA exceeds its table's bound are
        #: repriced at the measured speed, or the pool is dropped from
        #: the candidate set ("reject") when alternatives remain. Every
        #: intervention is counted into the run summary.
        self.drift_reprices = 0
        self.drift_rejects = 0
        #: audit feed (core/events.py) — attached by the simulator or
        #: live engine when event recording is on; None costs nothing
        self.events = None
        self._drift_on = any(
            getattr(p.cost_model.calibration, "drift_bound", None) is not None
            for p in self.pools
        )
        self.reserved_pools = [
            p for p in self.pools if p.pool_kind == "reserved"
        ]
        self.elastic_pools = [p for p in self.pools if p.pool_kind == "elastic"]
        self.vm = self.reserved_pools[0] if self.reserved_pools else self.pools[0]
        self.cf = self.elastic_pools[0] if self.elastic_pools else None

    def pool_overloaded(self, pool: ClusterExecutor) -> bool:
        return pool.run_queue_len >= self.cfg.vm_overload_threshold

    # ------------------------------------------------------------------
    # Calibrated admission control: the drift gate over quotes.
    # A pool's CalibrationTable tracks a log-EWMA of measured/predicted
    # stage walls (fed by LiveCalibrator.observe live, or the drift
    # stage observer the simulator wires); once it strays past the
    # table's drift_bound, this pool's quotes are known-stale and must
    # not be compared as-is against honest pools.
    # ------------------------------------------------------------------
    def refresh_drift_gate(self) -> None:
        """Re-arm the gate after tables were attached or swapped on a
        pool post-construction (the gate flag is precomputed so routing
        with no armed table pays zero per-query cost)."""
        self._drift_on = any(
            getattr(p.cost_model.calibration, "drift_bound", None) is not None
            for p in self.pools
        )

    def _drift_ratio(self, pool: ClusterExecutor) -> Optional[float]:
        """measured/predicted reprice factor when the pool's quotes are
        currently stale beyond its bound, else None."""
        t = pool.cost_model.calibration
        if t is None or not t.drift_exceeded():
            return None
        return t.drift_ratio()

    def _drift_rejected(self, pool: ClusterExecutor) -> bool:
        spec = getattr(pool, "spec", None)
        if spec is None or getattr(spec, "drift_action", "reprice") != "reject":
            return False
        t = pool.cost_model.calibration
        return t is not None and t.drift_exceeded()

    def quoted_latency(self, pool: ClusterExecutor, q: Query,
                       now: Optional[float]) -> float:
        """The pool's latency quote, drift-repriced when its gate trips
        (the drifted pool may still win — but at its measured speed)."""
        lat = pool.quote(q, now)["latency_s"]
        if self._drift_on:
            r = self._drift_ratio(pool)
            if r is not None:
                self.drift_reprices += 1
                lat *= r
        return lat

    def quoted_cost(self, pool: ClusterExecutor, q: Query) -> float:
        """The pool's cost quote, drift-repriced: a pool running slower
        than quoted also bills more chip-seconds than quoted."""
        c = pool.quote_cost(q)
        if self._drift_on:
            r = self._drift_ratio(pool)
            if r is not None:
                self.drift_reprices += 1
                c *= r
        return c

    def _drift_adjust(self, est: dict, q: Query, now: float) -> dict:
        """LATENCY_AWARE view of the drift gate: reprice drifted pools'
        estimates, drop "reject" pools while alternatives remain (a
        rejected pool that is the ONLY option is repriced instead —
        admission control reroutes, it never strands a query)."""
        out: dict = {}
        rejected: list[str] = []
        for name, e in est.items():
            p = self.by_name[name]
            if self._drift_rejected(p):
                rejected.append(name)
                continue
            r = self._drift_ratio(p)
            if r is not None:
                self.drift_reprices += 1
                if self.events is not None:
                    self.events.emit(
                        "drift_reprice", now, qid=q.qid, pool=name, ratio=r,
                    )
                e = {"latency_s": e["latency_s"] * r, "cost": e["cost"] * r}
            out[name] = e
        if out:
            self.drift_rejects += len(rejected)
            if rejected and self.events is not None:
                self.events.emit(
                    "drift_reject", now, qid=q.qid, pools=tuple(rejected),
                )
            return out
        for name in rejected:
            r = self._drift_ratio(self.by_name[name])
            e = est[name]
            if r is not None:
                self.drift_reprices += 1
                e = {"latency_s": e["latency_s"] * r, "cost": e["cost"] * r}
            out[name] = e
        return out

    @property
    def vm_overloaded(self) -> bool:
        """The legacy single-VM overload signal the schedulers poll:
        EVERY reserved pool is past the overload threshold. An
        all-elastic registry is never overloaded — burst capacity is
        unbounded, so holding relaxed queries back would only invert
        priority against BoE, which drains freely."""
        rp = self.reserved_pools
        if not rp:
            return False
        if len(rp) == 1:  # hot path: the paper's single-VM system
            return rp[0].run_queue_len >= self.cfg.vm_overload_threshold
        return all(self.pool_overloaded(p) for p in rp)

    @property
    def reserved_min_queue_len(self) -> int:
        """Shortest run queue across the cost-efficient tier (the BoE
        drain signal; with one reserved pool: its run-queue length)."""
        rp = self.reserved_pools
        if not rp:
            return 0
        if len(rp) == 1:
            return rp[0].run_queue_len
        return min(p.run_queue_len for p in rp)

    # ------------------------------------------------------------------
    # Beyond-paper: execution-time SLAs. The deterministic SOS cost model
    # makes admission-time latency quotes possible (paper §3.3 vision 1:
    # "it is easier to profile and control the performance and cost").
    # ------------------------------------------------------------------
    def estimate(self, q: Query, now: Optional[float] = None) -> dict:
        """Latency/cost quote for EVERY pool at the current load. Quotes
        cover only the REMAINING stages (q.stage_cursor onward), so a
        preempted or spill-candidate query is priced for what's left,
        not for work it already ran."""
        return {p.name: p.quote(q, now) for p in self.pools}

    def should_spill(
        self, q: Query, now: float, pool: Optional[ClusterExecutor] = None
    ) -> bool:
        """Stage-boundary spill policy (SLAConfig.spill_enabled): move the
        remaining stages of a running reserved-pool query to an elastic
        pool when its slice pool is overloaded — a waiting query AT LEAST
        AS urgent as `q` has no slice — and the remaining work is worth
        the elastic premium. A less-urgent waiter never displaces a
        runner (a deadline-distant RELAXED query must not push an
        IMMEDIATE query onto the 9-24x-priced pool), and BEST_EFFORT
        queries are never spilled — they are preempted instead."""
        pool = pool or self.vm
        if q.current_sla is ServiceLevel.BEST_EFFORT:
            return False
        # O(1) per-level waiting counts (live pools override with a
        # locked snapshot scan — their worker threads mutate `waiting`)
        if not pool.has_displacing_waiter(q):
            return False
        return pool.remaining_exec_s(q) >= self.cfg.spill_min_remaining_s

    def rehome(
        self, pool: ClusterExecutor, q: Query, now: float
    ) -> Optional[ClusterExecutor]:
        """Stage-boundary re-placement for `pool` (wired as pool.rehome).

        Reserved pool: spill — under overload, hand the remaining stages
        to the cheapest elastic quote. Elastic pool: spill-back — once a
        reserved pool has a free slice and its predicted backlog drain
        time is below the low watermark, a spilled query returns at its
        next stage boundary, making spill symmetric. Both moves require
        the remaining work to be worth the hop (spill_min_remaining_s),
        and the watermark hysteresis (spill needs a displaced waiter,
        spill-back an EMPTY queue plus low backlog) prevents ping-pong."""
        if pool.pool_kind == "reserved":
            if not self.cfg.spill_enabled or not self.elastic_pools:
                return None
            if not self.should_spill(q, now, pool):
                return None
            ep = self.elastic_pools
            if len(ep) == 1:  # common registry shape: skip the quote
                return ep[0]
            return min(ep, key=lambda p: p.quote_cost(q))
        # elastic pool: symmetric spill-back
        if not (self.cfg.spill_back_enabled and q.spilled):
            return None
        eligible = []
        for p in self.reserved_pools:
            if not p.has_capacity():
                continue
            if p.drain_time_s(now) > self.cfg.spill_back_low_backlog_s:
                continue
            if p.remaining_exec_s(q) < self.cfg.spill_min_remaining_s:
                continue  # the last chunk is not worth the hop
            eligible.append(p)
        if not eligible:
            return None
        # pick by quote, like every other placement decision: an
        # IMMEDIATE query returns to the fastest eligible pool, lower
        # levels to the cheapest — never registry order, which could
        # drop a latency-SLA query onto a 4x-slower pool
        if len(eligible) == 1:  # one home to return to: skip the quote
            return eligible[0]
        if q.current_sla is ServiceLevel.IMMEDIATE:
            return min(eligible, key=lambda p: p.quote(q, now)["latency_s"])
        return min(eligible, key=lambda p: p.quote_cost(q))

    def wire_rehoming(self) -> None:
        """Install the stage-boundary re-placement hook on every pool the
        active SLAConfig makes eligible (reserved pools when spill is on,
        elastic pools when spill-back is on)."""
        for pool in self.pools:
            eligible = (
                self.cfg.spill_enabled
                if pool.pool_kind == "reserved"
                else self.cfg.spill_back_enabled
            )
            if eligible:
                pool.rehome = (
                    lambda q, now, _pool=pool: self.rehome(_pool, q, now)
                )

    def _fuse_at_placement(self, q: Query, now: float) -> Query:
        """Cross-pool fusion: pull compatible waiters out of their
        pools and merge them into the query being placed; the merged
        batch then routes by the normal quote rules. A mate a pool no
        longer holds (a live worker grabbed it concurrently) is skipped
        — `withdraw` is the authoritative claim."""
        mates: list[Query] = []
        for m, pool in self.fusion.candidates(q, self.fuse_max - 1):
            if pool.withdraw(m):
                mates.append(m)
        if not mates:
            return q
        merged = fuse_queries([q] + mates, now)
        if self.events is not None:
            self.events.emit(
                "fuse", now, qid=merged.qid,
                members=tuple(m.qid for m in merged.members),
            )
        return merged

    def route(self, q: Query, now: float) -> str:
        if (
            self.fusion is not None
            and q.members is None
            and q.work.kind == "serve"
            and q.stage_cursor == 0
            # placement-time fusion targets the populations the pending
            # queues cannot batch: IMMEDIATE queries route instantly
            # (they never sit in a scheduler queue, so cross-pool
            # fusion is their ONLY batching path) and BEST_EFFORT work
            # is a pure cost play. RELAXED work is deliberately left to
            # the relaxed pending queue, which sees whole dashboard
            # rounds before placement — re-merging it here only coarsens
            # stage granularity (benchmarks/scale.py fusion rows).
            and q.current_sla in self.cross_fuse_levels
            # an IMMEDIATE arrival fuses only when a reserved slice is
            # free for it: the batch starts NOW and pulls its waiting
            # mates forward with it. When everything is busy the arrival
            # must not gamble its own latency on a batch that queues.
            and (
                q.current_sla is not ServiceLevel.IMMEDIATE
                or any(p.has_capacity() for p in self.reserved_pools)
            )
        ):
            q = self._fuse_at_placement(q, now)
        sla = q.current_sla
        if self.policy is Policy.LATENCY_AWARE:
            est = self.estimate(q, now)
            if self._drift_on:
                est = self._drift_adjust(est, q, now)
            target = q.latency_target_s
            ok = {
                name: e for name, e in est.items()
                if target is None or e["latency_s"] <= target
            } or est  # nothing meets the target: best effort, cheapest
            pool = self.by_name[min(ok, key=lambda n: ok[n]["cost"])]
        else:
            open_reserved = [
                p for p in self.reserved_pools if not self.pool_overloaded(p)
            ]
            if self.policy is Policy.FORCE and sla in (
                ServiceLevel.RELAXED,
                ServiceLevel.BEST_EFFORT,
            ):
                # SLA directly decides the tier: relaxed/BoE are forced
                # onto the cost-efficient tier even under overload
                candidates = open_reserved or self.reserved_pools
            else:
                # immediate (FORCE) and everything (AUTO): overflow to
                # the elastic tier only when the reserved tier is full
                candidates = (
                    open_reserved or self.elastic_pools or self.reserved_pools
                )
            candidates = candidates or self.pools  # all-elastic registry
            if self._drift_on and len(candidates) > 1:
                # admission control: route around "reject" pools whose
                # drift gate tripped, as long as an alternative remains
                kept = [p for p in candidates if not self._drift_rejected(p)]
                if kept and len(kept) != len(candidates):
                    self.drift_rejects += len(candidates) - len(kept)
                    if self.events is not None:
                        self.events.emit(
                            "drift_reject", now, qid=q.qid,
                            pools=tuple(
                                p.name for p in candidates if p not in kept
                            ),
                        )
                    candidates = kept
            # quote only the candidate tier (a saturated pool's backlog
            # walk is pure waste when it is not a candidate anyway)
            if len(candidates) == 1:
                pool = candidates[0]
            elif self._drift_on:
                if sla is ServiceLevel.IMMEDIATE:
                    pool = min(
                        candidates,
                        key=lambda p: self.quoted_latency(p, q, now),
                    )
                else:
                    pool = min(candidates, key=lambda p: self.quoted_cost(p, q))
            elif sla is ServiceLevel.IMMEDIATE:
                pool = min(candidates, key=lambda p: p.quote(q, now)["latency_s"])
            else:
                pool = min(candidates, key=lambda p: p.quote_cost(q))
        if self.events is not None:
            self.events.emit(
                "place", now, qid=q.qid, pool=pool.name,
                sla=sla.name, cursor=q.stage_cursor,
            )
        pool.submit(q, now)
        return pool.name


class RelaxedScheduler:
    """Polls the relaxed pending queue: dequeue when the cost-efficient
    cluster can execute, or when a query approaches its deadline."""

    def __init__(self, coordinator: QueryCoordinator, cfg: SLAConfig,
                 fuse: bool = False, fuse_max: int = 8):
        self.q = PendingQueue(fuse=fuse)
        self.coordinator = coordinator
        self.cfg = cfg
        self.fuse = fuse
        self.fuse_max = fuse_max

    def enqueue(self, q: Query) -> None:
        self.q.append(q)

    def poll(self, now: float) -> list[Query]:
        out = []
        while self.q:
            head = self.q.head()
            deadline_near = (
                now - head.submit_time
                >= self.cfg.relaxed_deadline_s * self.cfg.deadline_slack
            )
            can_exec = not self.coordinator.vm_overloaded
            if not (can_exec or deadline_near):
                break
            q = pop_fused(self.q, now, self.fuse, self.fuse_max)
            q.dequeue_time = now
            self.coordinator.route(q, now)
            out.append(q)
        return out


class BoEScheduler:
    """Drains the BoE queue whenever the cost-efficient cluster is idle."""

    def __init__(self, coordinator: QueryCoordinator, cfg: SLAConfig,
                 fuse: bool = False, fuse_max: int = 8):
        self.q = PendingQueue(fuse=fuse)
        self.coordinator = coordinator
        self.cfg = cfg
        self.fuse = fuse
        self.fuse_max = fuse_max

    def enqueue(self, q: Query) -> None:
        self.q.append(q)

    def poll(self, now: float) -> list[Query]:
        out = []
        while self.q and self.coordinator.reserved_min_queue_len <= self.cfg.boe_idle_threshold:
            head = pop_fused(self.q, now, self.fuse, self.fuse_max)
            head.dequeue_time = now
            self.coordinator.route(head, now)
            out.append(head)
            # one dequeue per idle observation: re-check occupancy
        return out


class ServiceLayer:
    """Entry point (paper Fig. 4 left half): SLA-dispatches queries."""

    def __init__(
        self,
        coordinator: QueryCoordinator,
        cfg: SLAConfig,
        sla_enabled: bool = True,
        fuse: bool = False,
        fuse_max: int = 8,
    ):
        self.coordinator = coordinator
        self.cfg = cfg
        self.sla_enabled = sla_enabled
        self.relaxed = RelaxedScheduler(coordinator, cfg, fuse=fuse,
                                        fuse_max=fuse_max)
        self.boe = BoEScheduler(coordinator, cfg, fuse=fuse,
                                fuse_max=fuse_max)

    def submit(self, q: Query, now: float) -> None:
        # the paper's "w/o SLA" baseline rewrites every query to immediate
        # (reporting still groups by the SUBMITTED sla, as in Figs. 6-7)
        q.effective_sla = (
            q.sla if self.sla_enabled else ServiceLevel.IMMEDIATE
        )
        if q.effective_sla is ServiceLevel.IMMEDIATE:
            q.dequeue_time = now
            self.coordinator.route(q, now)
        elif q.effective_sla is ServiceLevel.RELAXED:
            self.relaxed.enqueue(q)
        else:
            self.boe.enqueue(q)

    def poll(self, now: float) -> int:
        """Poll both pending queues; returns how many queries were
        dequeued and routed (the simulator skips its pool pass when an
        idle poll moved nothing)."""
        return len(self.relaxed.poll(now)) + len(self.boe.poll(now))

    @property
    def pending(self) -> int:
        return len(self.relaxed.q) + len(self.boe.q)
