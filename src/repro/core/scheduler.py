"""The paper's scheduling layer (§4.2 service levels, §4.3 coordinator).

Service layer -> {immediate path, relaxed pending queue, BoE pending queue}
-> schedulers poll -> query coordinator routes to the cost-efficient (VM)
or high-elastic (CF) cluster under the Force/Auto policy.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional

from .clusters import CostEfficientCluster, HighElasticCluster
from .query import Query, QueryWork
from .sla import Policy, ServiceLevel, SLAConfig


def fuse_queries(queries: list[Query], now: float) -> Query:
    """Merge same-(arch, prompt) queries into one batched query (the
    multi-query execution opportunity of paper §3.3). Weight streaming
    amortizes across the fused batch, so the fused plan's chip-seconds are
    strictly below the sum of the members' individual plans."""
    head = queries[0]
    if len(queries) == 1:
        return head
    merged = Query(
        work=QueryWork(
            arch=head.work.arch,
            kind=head.work.kind,
            batch=sum(q.work.batch for q in queries),
            prompt_tokens=head.work.prompt_tokens,
            output_tokens=max(q.work.output_tokens for q in queries),
        ),
        sla=head.sla,
        submit_time=min(q.submit_time for q in queries),
        source=head.source,
    )
    merged.members = queries  # type: ignore[attr-defined]
    for q in queries:
        q.dequeue_time = now
    return merged


class QueryCoordinator:
    """Routes a dequeued query to a cluster (paper §4.3)."""

    def __init__(
        self,
        vm: CostEfficientCluster,
        cf: HighElasticCluster,
        policy: Policy,
        cfg: SLAConfig,
    ):
        self.vm = vm
        self.cf = cf
        self.policy = policy
        self.cfg = cfg

    @property
    def vm_overloaded(self) -> bool:
        return self.vm.run_queue_len >= self.cfg.vm_overload_threshold

    # ------------------------------------------------------------------
    # Beyond-paper: execution-time SLAs. The deterministic SOS cost model
    # makes admission-time latency quotes possible (paper §3.3 vision 1:
    # "it is easier to profile and control the performance and cost").
    # ------------------------------------------------------------------
    def estimate(self, q: Query) -> dict:
        """Latency/cost quote for both pools at the current load."""
        cm = self.vm.cost_model
        vm_exec = cm.exec_time(q.work, self.vm.chips)
        # POS: effective rate divides across running queries w/ interference
        k = self.vm.run_queue_len + 1
        vm_latency = vm_exec * k * (1.0 + self.vm.alpha * (k - 1))
        vm_cost = cm.chip_seconds(q.work, self.vm.chips) * self.vm.price_per_chip_s
        cf_chips = self.cf.slice_for(q)
        cf_latency = self.cf.startup_s + cm.exec_time(q.work, cf_chips)
        cf_cost = cm.chip_seconds(q.work, cf_chips) * self.cf.price_per_chip_s
        return {
            "vm": {"latency_s": vm_latency, "cost": vm_cost},
            "cf": {"latency_s": cf_latency, "cost": cf_cost},
        }

    def route(self, q: Query, now: float) -> str:
        sla = q.effective_sla if q.effective_sla is not None else q.sla
        if self.policy is Policy.LATENCY_AWARE:
            est = self.estimate(q)
            target = q.latency_target_s
            ok = {
                pool: e for pool, e in est.items()
                if target is None or e["latency_s"] <= target
            } or est  # nothing meets the target: best effort, cheapest
            target_pool = min(ok, key=lambda p: ok[p]["cost"])
            (self.vm if target_pool == "vm" else self.cf).submit(q, now)
            return target_pool
        if self.policy is Policy.FORCE:
            # SLA directly decides the pool: relaxed/BoE are forced into
            # the cost-efficient cluster; immediate spills to the elastic
            # cluster only when the VM cluster is overloaded.
            if sla in (ServiceLevel.RELAXED, ServiceLevel.BEST_EFFORT):
                target = "vm"
            else:
                target = "cf" if self.vm_overloaded else "vm"
        else:  # AUTO: overload decides, regardless of service level
            target = "cf" if self.vm_overloaded else "vm"
        (self.vm if target == "vm" else self.cf).submit(q, now)
        return target


class RelaxedScheduler:
    """Polls the relaxed pending queue: dequeue when the cost-efficient
    cluster can execute, or when a query approaches its deadline."""

    def __init__(self, coordinator: QueryCoordinator, cfg: SLAConfig,
                 fuse: bool = False, fuse_max: int = 8):
        self.q: deque[Query] = deque()
        self.coordinator = coordinator
        self.cfg = cfg
        self.fuse = fuse
        self.fuse_max = fuse_max

    def enqueue(self, q: Query) -> None:
        self.q.append(q)

    def _pop_fused(self, now: float) -> Query:
        head = self.q.popleft()
        if not self.fuse:
            return head
        same = [
            q for q in list(self.q)
            if q.work.arch == head.work.arch
            and q.work.prompt_tokens == head.work.prompt_tokens
            and q.work.kind == head.work.kind
        ][: self.fuse_max - 1]
        for q in same:
            self.q.remove(q)
        return fuse_queries([head] + same, now)

    def poll(self, now: float) -> list[Query]:
        out = []
        while self.q:
            head = self.q[0]
            deadline_near = (
                now - head.submit_time
                >= self.cfg.relaxed_deadline_s * self.cfg.deadline_slack
            )
            can_exec = not self.coordinator.vm_overloaded
            if not (can_exec or deadline_near):
                break
            q = self._pop_fused(now)
            q.dequeue_time = now
            self.coordinator.route(q, now)
            out.append(q)
        return out


class BoEScheduler:
    """Drains the BoE queue whenever the cost-efficient cluster is idle."""

    def __init__(self, coordinator: QueryCoordinator, cfg: SLAConfig,
                 fuse: bool = False, fuse_max: int = 8):
        self.q: deque[Query] = deque()
        self.coordinator = coordinator
        self.cfg = cfg
        self.fuse = fuse
        self.fuse_max = fuse_max

    def enqueue(self, q: Query) -> None:
        self.q.append(q)

    def poll(self, now: float) -> list[Query]:
        out = []
        while self.q and self.coordinator.vm.run_queue_len <= self.cfg.boe_idle_threshold:
            head = self.q.popleft()
            if self.fuse:
                same = [
                    q for q in list(self.q)
                    if q.work.arch == head.work.arch
                    and q.work.prompt_tokens == head.work.prompt_tokens
                ][: self.fuse_max - 1]
                for q in same:
                    self.q.remove(q)
                head = fuse_queries([head] + same, now)
            head.dequeue_time = now
            self.coordinator.route(head, now)
            out.append(head)
            # one dequeue per idle observation: re-check occupancy
        return out


class ServiceLayer:
    """Entry point (paper Fig. 4 left half): SLA-dispatches queries."""

    def __init__(
        self,
        coordinator: QueryCoordinator,
        cfg: SLAConfig,
        sla_enabled: bool = True,
        fuse: bool = False,
    ):
        self.coordinator = coordinator
        self.cfg = cfg
        self.sla_enabled = sla_enabled
        self.relaxed = RelaxedScheduler(coordinator, cfg, fuse=fuse)
        self.boe = BoEScheduler(coordinator, cfg, fuse=fuse)

    def submit(self, q: Query, now: float) -> None:
        # the paper's "w/o SLA" baseline rewrites every query to immediate
        # (reporting still groups by the SUBMITTED sla, as in Figs. 6-7)
        q.effective_sla = (
            q.sla if self.sla_enabled else ServiceLevel.IMMEDIATE
        )
        if q.effective_sla is ServiceLevel.IMMEDIATE:
            q.dequeue_time = now
            self.coordinator.route(q, now)
        elif q.effective_sla is ServiceLevel.RELAXED:
            self.relaxed.enqueue(q)
        else:
            self.boe.enqueue(q)

    def poll(self, now: float) -> None:
        self.relaxed.poll(now)
        self.boe.poll(now)

    @property
    def pending(self) -> int:
        return len(self.relaxed.q) + len(self.boe.q)
