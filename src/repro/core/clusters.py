"""Cluster models: cost-efficient (reserved pod slice) vs high-elastic
(on-demand burst slices) — paper §4.3's spot-VM vs cloud-function pair,
instantiated for TPU (DESIGN.md §2).

Both clusters are ClusterExecutors (core/engine.py): a running query is a
cursor over its StagePlan, and completions come from one heap of
predicted per-stage finish times.

The cost-efficient cluster supports two execution modes:
  POS  — plan-oriented scaling (paper's Trino VM cluster): admitted
         queries share the whole slice under processor sharing with a
         concurrency interference penalty. Per-query times depend on what
         else is running — the nondeterminism the paper's §5.3 "lessons
         learned" complains about.
  SOS  — stage-oriented scaling: each query's stages run on an isolated
         fixed-size sub-slice with deterministic roofline times; queries
         wait when no slice is free. SOS is where stage boundaries become
         policy points: BEST_EFFORT runs can be preempted for a waiting
         IMMEDIATE query, and the coordinator may spill the remaining
         stages of a query to the elastic cluster under overload.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import numpy as np

from ..perf.hw import V5E, HwSpec
from .convergence import PoolConverger
from .cost_model import CostModel, Stage
from .engine import ClusterExecutor, _Run
from .query import Query
from .sla import ServiceLevel


@dataclass
class AutoscaleConfig:
    """Elastic scaling of the reserved slice (the paper notes spot VMs
    scale in minutes — modeled as a provisioning delay).

    Two triggers:
      run_queue — legacy PR-1 policy: scale-out when the running queue
                  stays above the high watermark, in below the low one.
      backlog   — scale from the stage heap's PREDICTED remaining
                  chip-seconds (ClusterExecutor.predicted_backlog_cs)
                  normalized to a drain time at current capacity. One
                  huge waiting query is a large backlog long before it
                  is a long run queue, so scale-out fires earlier and
                  provisioning latency overlaps the work that needs it.
    """

    enabled: bool = False
    min_chips: int = 4
    max_chips: int = 64
    step_chips: int = 4
    scale_delay_s: float = 180.0  # minutes-scale provisioning (paper §4.3)
    #: releasing capacity is fast even when acquiring it is slow; None
    #: falls back to scale_delay_s (the legacy symmetric behavior)
    scale_in_delay_s: Optional[float] = None
    trigger: str = "run_queue"  # run_queue | backlog
    high_watermark: int = 8  # run-queue length triggering scale-out
    low_watermark: int = 1
    backlog_high_s: float = 120.0  # predicted drain time triggering scale-out
    backlog_low_s: float = 10.0

    def __post_init__(self):
        if self.trigger not in ("run_queue", "backlog"):
            raise ValueError(
                f"unknown autoscale trigger {self.trigger!r} "
                "(expected 'run_queue' or 'backlog')"
            )


@dataclass
class FaultModel:
    """Stage-level failures and stragglers (simulated; SOS executors
    retry failed stages and speculatively duplicate stragglers). The
    engine samples outcomes PER STAGE, so a retry re-runs — and re-bills
    — only the failed stage, never the whole query."""

    failure_prob: float = 0.0  # per stage
    straggler_prob: float = 0.0  # per stage
    straggler_scale: float = 1.0  # Expo mean of extra relative time
    speculation: bool = True  # duplicate stragglers (cap the tail)
    speculation_cap: float = 0.3  # dup launched after 30% over estimate

    def stage_execution(
        self, base: float, chips: int, rng: np.random.Generator, q: Query
    ) -> tuple[float, float, int]:
        """Sample one stage run: (wall seconds, billed chip-seconds,
        retries). A failed stage is re-run once and the re-run is billed;
        a speculated straggler bills the duplicate's resources."""
        t = base
        billed = base * chips
        retries = 0
        if self.failure_prob and rng.random() < self.failure_prob:
            q.retries += 1
            retries = 1
            t += base  # re-run only this stage
            billed += base * chips  # the re-run is billed
        if self.straggler_prob and rng.random() < self.straggler_prob:
            tail = base * rng.exponential(self.straggler_scale)
            if self.speculation:
                tail = min(tail, base * self.speculation_cap)
                billed += base * chips  # the duplicate's resources
            t += tail
        return t, billed, retries

class CostEfficientCluster(ClusterExecutor):
    """Reserved slice: `chips` chips at reserved unit price."""

    name = "vm"

    def __init__(
        self,
        chips: int = 256,
        mode: str = "pos",  # pos | sos
        max_concurrent: int = 8,  # POS admission cap (Trino-style)
        interference_alpha: float = 0.3,
        sos_slice_chips: int = 32,
        cost_model: Optional[CostModel] = None,
        hw: HwSpec = V5E,
        fault: Optional[FaultModel] = None,
        rng: Optional[np.random.Generator] = None,
        autoscale: Optional[AutoscaleConfig] = None,
        preempt_best_effort: bool = False,
    ):
        super().__init__(
            cost_model=cost_model,
            fault=fault or FaultModel(),
            rng=rng,
            price_per_chip_s=hw.reserved_price / 3600.0,
        )
        self.chips = chips
        self.mode = mode
        self.max_concurrent = max_concurrent
        self.alpha = interference_alpha
        self.autoscale = autoscale or AutoscaleConfig()
        self._pending_scale: list[tuple[float, int]] = []  # (effective_at, chips)
        #: convergence plane (core/convergence.py): policies mutate the
        #: DESIRED capacity, the converger drives observed toward it —
        #: scale triggers, cron schedules, and death healing all flow
        #: through one `evaluate`/`heal` pair
        self.desired_chips = chips
        self.converger = PoolConverger()
        self.chip_seconds_provisioned = 0.0  # reserved-capacity accounting
        self._last_prov_t = 0.0
        self.slice_chips = sos_slice_chips
        #: SOS chips currently held by running queries — an integer
        #: counter (== len(running) * slice_chips for fixed slices,
        #: exactly), which is what lets admission price variable-width
        #: slices without an O(running) sum
        self._used_chips = 0
        self.hw = hw
        self.preempt_best_effort = preempt_best_effort
        self._shared_rates = mode == "pos"  # POS: processor sharing

    @property
    def chips(self) -> int:
        return self._chips

    @chips.setter
    def chips(self, value: int) -> None:
        """Capacity is a planning input: changing it invalidates the
        static-quote cache (load_epoch) and, for POS pools — which plan
        waiting queries at the full slice — the incremental backlog's
        waiting sums."""
        self._chips = value
        self.load_epoch += 1
        if getattr(self, "mode", "") == "pos" and self.waiting:
            self._bl_rebuild_wait()

    # --- POS processor-sharing dynamics ---
    def _eff_rate_per_query(self) -> float:
        """Aggregate chips each running query receives under PS with an
        interference penalty 1/(1 + alpha*(k-1))."""
        k = len(self.running)
        if k == 0:
            return float(self.chips)
        return (self.chips / k) / (1.0 + self.alpha * (k - 1))

    def accrue_provisioned(self, now: float) -> None:
        """Reserved-capacity accounting: chip-seconds the slice held
        provisioned up to `now`, whether used or idle ("idle capacity is
        paid for too"). Accrual is LAZY — capacity is piecewise-constant
        so the sum telescopes: `_apply_pending_scale` closes the open
        interval before every capacity change, and anything reading
        `chip_seconds_provisioned` (the benchmark report) must call this
        once more at its horizon end to close the tail interval."""
        if now > self._last_prov_t:
            self.chip_seconds_provisioned += self.chips * (now - self._last_prov_t)
            self._last_prov_t = now

    def _apply_pending_scale(self, now: float) -> bool:
        """Apply due capacity changes BEFORE admission (new capacity can
        admit this event's waiters); returns True when chips changed.
        Pending entries come from the converger only (autoscale policies
        or death healing), so no enabled-gate is needed here."""
        if not self._pending_scale:
            return False
        due = [c for t, c in self._pending_scale if t <= now]
        if not due:
            return False
        self.accrue_provisioned(now)  # close the interval at OLD chips
        changed = due[-1] != self.chips
        self.chips = due[-1]
        self._pending_scale = [
            (t, c) for t, c in self._pending_scale if t > now
        ]
        return changed

    def _schedule_autoscale(self, now: float) -> None:
        """Evaluate the scale policies AFTER admission, so `waiting`
        holds only queries that genuinely found no slice this event — an
        arriving query that a free slice admits immediately must not
        read as backlog pressure. The policy pass itself lives on the
        converger (core/convergence.py): the reactive watermark trigger
        is its default ``BacklogTriggerPolicy`` with float-identical
        math, and schedule/hook policies ride the same evaluation."""
        a = self.autoscale
        if not a.enabled:
            return
        target = self.converger.evaluate(self, now)
        if a.trigger == "backlog":
            self._as_next_eval = self._next_backlog_eval(now, a, target)

    def _next_backlog_eval(self, now: float, a: AutoscaleConfig,
                           target) -> float:
        """Earliest future time the backlog trigger's verdict can change
        WITHOUT a state change (every state change resets the cache to
        0): between events the drain signal only decays linearly, so the
        only passive transition is cold turning on when the running
        work's decay brings the backlog down to the low watermark."""
        if target is not None or self._pending_scale:
            return 0.0  # a scale is in flight: tick handles pending
        if self.waiting or self.chips <= a.min_chips or self._bl_future:
            return math.inf  # cold can't act; flips only at own events
        floor = self._bl_future_cs + self._bl_unstarted_cs + self._bl_wait_cs
        want = a.backlog_low_s * self.chips
        if floor >= want or self._bl_burn <= 0.0:
            return math.inf  # decay alone can never reach the watermark
        # max(tf_burn - t*burn, 0) + floor == want, solved for t (a hair
        # early: an early re-eval is harmless, a late one skips an event)
        return (self._bl_tf_burn - (want - floor)) / self._bl_burn - 1e-6

    # --- engine hooks -------------------------------------------------
    def _plan_chips(self, q: Query) -> int:
        if self.mode == "pos":
            return self.chips
        if self.allocator is not None:
            w = self.allocator.choose(q.work, q.current_sla)
            return max(1, min(w, self.chips))
        return self.slice_chips

    def _start_run(self, q: Query, now: float) -> _Run:
        run = super()._start_run(q, now)
        if self.mode == "sos":
            self._used_chips += run.chips
        return run

    def _bl_retire_run(self, run: _Run) -> None:
        if self.mode == "sos":
            self._used_chips -= run.chips
        super()._bl_retire_run(run)

    # --- placement interface ------------------------------------------
    def effective_capacity(self) -> int:
        """The chips a query admitted NOW can count on: current capacity
        capped by any already-scheduled scale-in. Admitting against the
        raw current chips in the window before a scale-in takes effect
        overcommits the post-scale slice — the run keeps its chips when
        the capacity change lands, so the pool would be over its new
        budget for the run's whole residence."""
        cap = self._chips
        for _, target in self._pending_scale:
            if target < cap:
                cap = target
        return cap

    def _admit_width(self) -> int:
        """The narrowest slice the next admission could need — what
        ``has_capacity`` (no concrete query in hand yet) prices."""
        if self.allocator is not None:
            return max(1, min(self.allocator.config.min_chips, self._chips))
        return self.slice_chips

    def has_capacity(self) -> bool:
        if self.waiting:
            return False
        if self.mode == "pos":
            return len(self.running) < self.max_concurrent
        return self._used_chips + self._admit_width() <= self.effective_capacity()

    def _run_remaining_cs(self, run: _Run, now) -> float:
        elapsed = 0.0 if now is None else max(now - run.last_update, 0.0)
        left = max(run.remaining - elapsed * run.rate, 0.0)
        if self.mode == "pos":
            return left  # POS work units ARE chip-seconds
        return left * run.chips  # SOS: wall-seconds on an isolated slice

    def _run_cs_factor(self, run: _Run) -> float:  # reprolint: disable=RL102 -- mode-dependent dimension: dimensionless in POS (work units ARE chip-seconds), chips in SOS (work units are wall-seconds)
        return 1.0 if self.mode == "pos" else float(run.chips)

    def drain_time_s(self, now=None) -> float:
        return self.predicted_backlog_cs(now) / max(self.chips, 1)

    @property
    def needs_tick(self) -> bool:
        return self.autoscale.enabled or self._chaos is not None

    def _chaos_step(self, now: float) -> None:
        """Apply every due injected worker death (core/chaos.py): close
        the provisioned-capacity interval, drop the dead chips — never
        below one admission slice, or a fixed-width waiter could never
        be admitted again — and let the converger schedule replacement
        capacity back to ``desired_chips`` through the normal
        provisioning delay (+ seeded backoff)."""
        ch = self._chaos
        while ch.next_death_s() <= now:
            t_death_s = ch.pop_death()
            # a POS pool shares all chips (no slice concept): one death
            # is one chip, floored at 1. An SOS pool loses a slice,
            # floored at one admission slice — below that a fixed-width
            # waiter could never be admitted again.
            unit = self.slice_chips if self.mode == "sos" else 1
            floor = min(unit, self.chips)
            loss = ch.death_chips or unit
            loss = min(loss, self.chips - floor)
            if loss > 0:
                self.accrue_provisioned(now)
                self.chips = self.chips - loss
                if self.events is not None:
                    self.events.emit(
                        "death", now, pool=self.name, chips_lost=loss,
                        at_s=t_death_s,
                    )
                self.converger.heal(self, now)
        self._chaos_next = ch.next_death_s()

    def tick(self, now: float) -> None:
        """Per-event bookkeeping when this pool has no completion due:
        apply due injected deaths, apply a due capacity change (it may
        admit waiters — full admission pass), heal death-induced
        capacity divergence, and re-evaluate the scale policies — the
        backlog trigger's drain-time signal decays continuously between
        this pool's own events, and schedule policies fire on their own
        clock. Run-queue state only changes at own events, so the
        run_queue trigger needs no tick. Amortized O(1): the trigger is
        only re-evaluated once `now` reaches ``_as_next_eval``, the
        pre-computed earliest time the linearly-decaying drain signal
        can change the verdict (any state change recomputes it)."""
        if self._chaos_next <= now:
            self._chaos_step(now)
        if self._pending_scale:
            if self._pending_scale[0][0] <= now:
                self._admit(now)
            return
        if self._chaos is not None and self.chips < self.desired_chips:
            self.converger.heal(self, now)
        a = self.autoscale
        if not a.enabled:
            return
        if (
            self.converger.next_fire_s <= now + 1e-9
            or (a.trigger == "backlog" and now + 1e-9 >= self._as_next_eval)
        ):
            self._schedule_autoscale(now)

    def tick_due(self, now: float) -> bool:
        if self._chaos_next <= now:
            return True
        if self._pending_scale:
            return self._pending_scale[0][0] <= now
        if self._chaos is not None and self.chips < self.desired_chips:
            return True
        a = self.autoscale
        if not a.enabled:
            return False
        if self.converger.next_fire_s <= now + 1e-9:
            return True
        return a.trigger == "backlog" and now + 1e-9 >= self._as_next_eval

    def next_tick_time(self) -> float:
        """Earliest future time `tick` could act — what the simulator's
        poll fast-forward skips to (engine.ClusterExecutor returns inf)."""
        if self._pending_scale:
            return self._pending_scale[0][0]
        if self._chaos is not None and self.chips < self.desired_chips:
            return 0.0  # un-healed death: act at the very next poll
        t_s = self._chaos_next
        a = self.autoscale
        if a.enabled:
            if self.converger.next_fire_s < t_s:
                t_s = self.converger.next_fire_s
            if a.trigger == "backlog" and self._as_next_eval < t_s:
                t_s = self._as_next_eval
        return t_s

    def quote(self, q: Query, now=None) -> dict:
        exec_s, _, cost = self._static_quote(q)
        if self.mode == "pos":
            # PS: joining k runners divides the slice and adds the
            # concurrency interference penalty
            k = self.run_queue_len + 1
            latency = exec_s * k * (1.0 + self.alpha * (k - 1))
        else:
            # SOS: deterministic slice time + predicted wait for a slice
            wait = 0.0 if self.has_capacity() else self.drain_time_s(now)
            latency = wait + exec_s
        return {"latency_s": latency, "cost": cost}

    def _run_rate(self, run: _Run) -> float:
        if self.mode == "pos":
            return self._eff_rate_per_query()
        return 1.0

    def _stage_work(self, stage: Stage, q: Query) -> tuple[float, float, int]:
        if self.mode == "pos":
            # PS tracks remaining WORK (chip-seconds); no fault sampling
            # in the interference model (matches the paper's Trino VM).
            return stage.chip_seconds, stage.chip_seconds, 0
        return self.fault.stage_execution(stage.time_s, stage.chips, self.rng, q)

    def _sync(self, now: float) -> None:
        if self.mode != "pos":
            return
        for run in self.running:
            run.remaining = max(
                run.remaining - run.rate * (now - run.last_update), 0.0
            )
            run.last_update = now

    def _rates_changed(self, now: float) -> None:
        if self.mode != "pos":
            return
        self._sync(now)
        rate = self._eff_rate_per_query()
        for run in self.running:
            run.rate = rate
            self._push(run, now)

    def _pop_waiting(self) -> Query:
        # SOS slice handoff: IMMEDIATE first, FIFO within a level (POS
        # admission pops FIFO directly in _admit) — O(1) from the
        # waiting queue's per-level lanes
        return self.waiting.pop_best()

    def _admit(self, now: float) -> None:
        # provisioned-capacity accrual is lazy (piecewise-constant chips
        # telescope): _apply_pending_scale closes intervals before any
        # capacity change, report paths close the tail — no need to
        # accrue on every admission
        scaling = self.autoscale.enabled
        # pending entries exist only when the converger scheduled one
        # (autoscale target or death healing) — apply either kind
        if self._pending_scale and self._apply_pending_scale(now):
            self._rates_changed(now)
        if self.mode == "pos":
            admitted = False
            while self.waiting and len(self.running) < self.max_concurrent:
                self._start_run(self.waiting.pop(0), now)
                admitted = True
            if admitted:
                self._rates_changed(now)
            if scaling:
                self._schedule_autoscale(now)
            return
        # SOS: isolated slices (fixed-size, or allocator-chosen width).
        # Admission prices the HEAD's slice against the effective
        # capacity — current chips capped by any pending scale-in — so a
        # query admitted just before a scale-in lands can no longer
        # overcommit the post-scale budget.
        if self.waiting:
            cap = self.effective_capacity()
            while self.waiting:
                width = self._plan_chips(self.waiting.peek_best())
                if self._used_chips + width > cap:
                    break
                self._start_run(self._pop_waiting(), now)
        if scaling:
            self._schedule_autoscale(now)
        # stage-boundary preemption: a waiting IMMEDIATE query may bump a
        # running BEST_EFFORT query at its next stage boundary; requests
        # are re-derived from the CURRENT waiting queue each admission so
        # a flag goes away when its IMMEDIATE found a slice elsewhere.
        if self.preempt_best_effort:
            self._rederive_preempt_flags()

    def _rederive_preempt_flags(self) -> None:
        """Match preempt flags to the IMMEDIATE waiter count. The
        O(running) re-derivation only runs when flags could change
        (IMMEDIATE waiter count != currently flagged runs) — the common
        no-preemption event is O(1). Called at every admission AND when
        fusion withdraws a waiter (the withdrawn IMMEDIATE must take
        its preempt request with it)."""
        n_imm = self.waiting.counts[int(ServiceLevel.IMMEDIATE)]
        if n_imm != len(self._flagged):
            flagged = [r for r in self.running if r.preempt_requested]
            for run in flagged[n_imm:]:  # stale: nobody waits for it
                run.preempt_requested = False
                self._flagged.discard(run)
            need = n_imm - min(len(flagged), n_imm)
            for run in self.running:
                if need <= 0:
                    break
                if (
                    not run.preempt_requested
                    and run.query.current_sla is ServiceLevel.BEST_EFFORT
                ):
                    run.preempt_requested = True
                    self._flagged.add(run)
                    need -= 1

    def _waiter_withdrawn(self, q: Query) -> None:
        if self.preempt_best_effort and self.mode == "sos":
            self._rederive_preempt_flags()

    def _continue_run(self, run: _Run, now: float) -> bool:
        if self.mode != "sos":
            return True
        q = run.query
        if run.preempt_requested:
            # stop at this boundary; chip-seconds already billed are kept
            run.preempt_requested = False
            q.preemptions += 1
            q.state = "preempted"
            self.waiting.append(q)  # resumes at stage_cursor on a free slice
            if self.events is not None:
                self.events.emit(
                    "preempt", now, qid=q.qid, pool=self.name,
                    cursor=q.stage_cursor,
                )
            return False
        # coordinator-owned re-placement (spill to an elastic pool)
        return super()._continue_run(run, now)


class HighElasticCluster(ClusterExecutor):
    """On-demand burst slices: unbounded, seconds-level provisioning,
    `elastic_price_multiplier`x unit price (paper's CF: 9-24x)."""

    name = "cf"
    pool_kind = "elastic"

    def __init__(
        self,
        cost_model: Optional[CostModel] = None,
        hw: HwSpec = V5E,
        startup_s: float = 2.0,
        min_chips: int = 4,
        max_chips: int = 64,
        tokens_per_chip: int = 262_144,
        fault: Optional[FaultModel] = None,
        rng: Optional[np.random.Generator] = None,
        price_multiplier: Optional[float] = None,
    ):
        mult = (
            price_multiplier
            if price_multiplier is not None
            else hw.elastic_price_multiplier
        )
        super().__init__(
            cost_model=cost_model,
            fault=fault or FaultModel(),
            rng=rng or np.random.default_rng(1),
            price_per_chip_s=hw.reserved_price * mult / 3600.0,
        )
        self.hw = hw
        self.startup_s = startup_s
        self.min_chips = min_chips
        self.max_chips = max_chips
        self.tokens_per_chip = tokens_per_chip

    def slice_for(self, q: Query) -> int:
        """Bigger queries get bigger slices (paper §5.2: CF dynamically
        allocates more resources to big queries)."""
        want = math.ceil(q.work.total_tokens / self.tokens_per_chip)
        return int(min(self.max_chips, max(self.min_chips, want)))

    def _plan_chips(self, q: Query) -> int:
        if self.allocator is not None:
            w = self.allocator.choose(q.work, q.current_sla)
            return int(min(self.max_chips, max(self.min_chips, w)))
        return self.slice_for(q)

    def _queue_delay_estimate(self, q: Query, now) -> float:
        return self.startup_s

    def _admit(self, now: float) -> None:
        # unbounded burst capacity: everything starts after provisioning
        while self.waiting:
            q = self.waiting.pop(0)
            self._start_run(q, now + self.startup_s)
