"""Cluster models: cost-efficient (reserved pod slice) vs high-elastic
(on-demand burst slices) — paper §4.3's spot-VM vs cloud-function pair,
instantiated for TPU (DESIGN.md §2).

The cost-efficient cluster supports two execution modes:
  POS  — plan-oriented scaling (paper's Trino VM cluster): admitted
         queries share the whole slice under processor sharing with a
         concurrency interference penalty. Per-query times depend on what
         else is running — the nondeterminism the paper's §5.3 "lessons
         learned" complains about.
  SOS  — stage-oriented scaling: each query's stages run on an isolated
         fixed-size sub-slice with deterministic roofline times; queries
         wait when no slice is free.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from ..perf.hw import V5E, HwSpec
from .cost_model import CostModel
from .query import Query


@dataclass
class AutoscaleConfig:
    """Elastic scaling of the reserved slice (the paper notes spot VMs
    scale in minutes — modeled as a provisioning delay). Scale-out when
    the running queue stays above the high watermark; scale-in when it
    falls below the low watermark."""

    enabled: bool = False
    min_chips: int = 4
    max_chips: int = 64
    step_chips: int = 4
    scale_delay_s: float = 180.0  # minutes-scale provisioning (paper §4.3)
    high_watermark: int = 8  # run-queue length triggering scale-out
    low_watermark: int = 1


@dataclass
class FaultModel:
    """Stage-level failures and stragglers (simulated; SOS executors
    retry failed stages and speculatively duplicate stragglers)."""

    failure_prob: float = 0.0  # per stage
    straggler_prob: float = 0.0  # per stage
    straggler_scale: float = 1.0  # Expo mean of extra relative time
    speculation: bool = True  # duplicate stragglers (cap the tail)
    speculation_cap: float = 0.3  # dup launched after 30% over estimate

    def stage_time(self, base: float, rng: np.random.Generator, q: Query) -> float:
        t = base
        if self.failure_prob and rng.random() < self.failure_prob:
            q.retries += 1
            t += base  # one retry of the whole stage
        if self.straggler_prob and rng.random() < self.straggler_prob:
            tail = base * rng.exponential(self.straggler_scale)
            if self.speculation:
                tail = min(tail, base * self.speculation_cap)
                q.chip_seconds += base  # the duplicate's resources
            t += tail
        return t


class _Running:
    __slots__ = ("query", "remaining", "last_update")

    def __init__(self, query: Query, remaining: float, now: float):
        self.query = query
        self.remaining = remaining  # chip-seconds of work left
        self.last_update = now


class CostEfficientCluster:
    """Reserved slice: `chips` chips at reserved unit price."""

    def __init__(
        self,
        chips: int = 256,
        mode: str = "pos",  # pos | sos
        max_concurrent: int = 8,  # POS admission cap (Trino-style)
        interference_alpha: float = 0.3,
        sos_slice_chips: int = 32,
        cost_model: Optional[CostModel] = None,
        hw: HwSpec = V5E,
        fault: Optional[FaultModel] = None,
        rng: Optional[np.random.Generator] = None,
        autoscale: Optional[AutoscaleConfig] = None,
    ):
        self.chips = chips
        self.mode = mode
        self.max_concurrent = max_concurrent
        self.alpha = interference_alpha
        self.autoscale = autoscale or AutoscaleConfig()
        self._pending_scale: list[tuple[float, int]] = []  # (effective_at, chips)
        self.chip_seconds_provisioned = 0.0  # reserved-capacity accounting
        self._last_prov_t = 0.0
        self.slice_chips = sos_slice_chips
        self.cost_model = cost_model or CostModel()
        self.hw = hw
        self.fault = fault or FaultModel()
        self.rng = rng or np.random.default_rng(0)
        self.running: list[_Running] = []
        self.waiting: list[Query] = []  # SOS: queries waiting for a slice
        self.price_per_chip_s = hw.reserved_price / 3600.0

    # --- the paper's "VM running queue" the coordinator watches ---
    @property
    def run_queue_len(self) -> int:
        return len(self.running) + len(self.waiting)

    @property
    def idle(self) -> bool:
        return self.run_queue_len == 0

    # --- POS processor-sharing dynamics ---
    def _eff_rate_per_query(self) -> float:
        """Aggregate chips each running query receives under PS with an
        interference penalty 1/(1 + alpha*(k-1))."""
        k = len(self.running)
        if k == 0:
            return float(self.chips)
        return (self.chips / k) / (1.0 + self.alpha * (k - 1))

    def _apply_autoscale(self, now: float) -> None:
        a = self.autoscale
        if not a.enabled:
            return
        # provisioned chip-seconds (idle capacity is paid for too)
        self.chip_seconds_provisioned += self.chips * (now - self._last_prov_t)
        self._last_prov_t = now
        # apply due capacity changes
        due = [c for t, c in self._pending_scale if t <= now]
        if due:
            self.chips = due[-1]
            self._pending_scale = [
                (t, c) for t, c in self._pending_scale if t > now
            ]
        target = None
        if self.run_queue_len >= a.high_watermark and self.chips < a.max_chips:
            target = min(a.max_chips, self.chips + a.step_chips)
        elif self.run_queue_len <= a.low_watermark and self.chips > a.min_chips:
            target = max(a.min_chips, self.chips - a.step_chips)
        if target is not None and not self._pending_scale:
            self._pending_scale.append((now + a.scale_delay_s, target))

    def _advance(self, now: float) -> None:
        self._apply_autoscale(now)
        rate = self._eff_rate_per_query()
        for r in self.running:
            r.remaining -= rate * (now - r.last_update)
            r.last_update = now

    def submit(self, q: Query, now: float) -> None:
        q.cluster = "vm"
        if self.mode == "pos":
            self.waiting.append(q)
            self._admit_pos(now)
        else:  # SOS: wait for a free fixed-size slice
            self.waiting.append(q)
            self._try_start_sos(now)

    def _admit_pos(self, now: float) -> None:
        self._advance(now)
        while self.waiting and len(self.running) < self.max_concurrent:
            q = self.waiting.pop(0)
            work_cs = self.cost_model.chip_seconds(q.work, self.chips)
            q.start_time = now
            q.chip_seconds += work_cs
            self.running.append(_Running(q, work_cs, now))

    def _try_start_sos(self, now: float) -> None:
        used = len(self.running) * self.slice_chips
        while self.waiting and used + self.slice_chips <= self.chips:
            q = self.waiting.pop(0)
            plan = self.cost_model.plan(q.work, self.slice_chips)
            t = sum(
                self.fault.stage_time(s.time_s, self.rng, q) for s in plan.stages
            )
            q.start_time = now
            q.chip_seconds += plan.chip_seconds
            r = _Running(q, t, now)  # SOS remaining is SECONDS (fixed rate 1)
            self.running.append(r)
            used += self.slice_chips

    def next_completion(self, now: float) -> Optional[float]:
        """Earliest absolute finish time among running queries."""
        if not self.running:
            return None
        if self.mode == "pos":
            rate = self._eff_rate_per_query()
            self._advance(now)
            return now + min(max(r.remaining, 0.0) / rate for r in self.running)
        return now + min(max(r.remaining - (now - r.last_update), 0.0)
                         for r in self.running)

    def collect_finished(self, now: float) -> list[Query]:
        done: list[Query] = []
        if self.mode == "pos":
            self._advance(now)
            eps = 1e-9
            still = []
            for r in self.running:
                if r.remaining <= eps:
                    r.query.finish_time = now
                    done.append(r.query)
                else:
                    still.append(r)
            self.running = still
            self._admit_pos(now)
        else:
            still = []
            for r in self.running:
                if (now - r.last_update) >= r.remaining - 1e-9:
                    r.query.finish_time = now
                    done.append(r.query)
                else:
                    still.append(r)
            self.running = still
            self._try_start_sos(now)
        for q in done:
            q.cost += q.chip_seconds * self.price_per_chip_s
        return done


class HighElasticCluster:
    """On-demand burst slices: unbounded, seconds-level provisioning,
    `elastic_price_multiplier`x unit price (paper's CF: 9-24x)."""

    def __init__(
        self,
        cost_model: Optional[CostModel] = None,
        hw: HwSpec = V5E,
        startup_s: float = 2.0,
        min_chips: int = 4,
        max_chips: int = 64,
        tokens_per_chip: int = 262_144,
        fault: Optional[FaultModel] = None,
        rng: Optional[np.random.Generator] = None,
        price_multiplier: Optional[float] = None,
    ):
        self.cost_model = cost_model or CostModel()
        self.hw = hw
        self.startup_s = startup_s
        mult = (
            price_multiplier
            if price_multiplier is not None
            else hw.elastic_price_multiplier
        )
        self.min_chips = min_chips
        self.max_chips = max_chips
        self.tokens_per_chip = tokens_per_chip
        self.fault = fault or FaultModel()
        self.rng = rng or np.random.default_rng(1)
        self.running: list[tuple[float, Query]] = []  # (finish_time, q)
        self.price_per_chip_s = hw.reserved_price * mult / 3600.0

    @property
    def run_queue_len(self) -> int:
        return len(self.running)

    def slice_for(self, q: Query) -> int:
        """Bigger queries get bigger slices (paper §5.2: CF dynamically
        allocates more resources to big queries)."""
        want = math.ceil(q.work.total_tokens / self.tokens_per_chip)
        return int(min(self.max_chips, max(self.min_chips, want)))

    def submit(self, q: Query, now: float) -> None:
        q.cluster = "cf"
        chips = self.slice_for(q)
        plan = self.cost_model.plan(q.work, chips)
        t = sum(self.fault.stage_time(s.time_s, self.rng, q) for s in plan.stages)
        q.start_time = now + self.startup_s
        q.chip_seconds += plan.chip_seconds
        finish = q.start_time + t
        q.cost += q.chip_seconds * self.price_per_chip_s
        self.running.append((finish, q))

    def next_completion(self, now: float) -> Optional[float]:
        if not self.running:
            return None
        return min(f for f, _ in self.running)

    def collect_finished(self, now: float) -> list[Query]:
        done = [q for f, q in self.running if f <= now + 1e-9]
        self.running = [(f, q) for f, q in self.running if f > now + 1e-9]
        for q in done:
            q.finish_time = now
        return done
