"""N-pool executor registry: pool specs and the builder that turns them
into ClusterExecutors.

The paper's flexible-SLA argument (and Kassing et al.'s allocation
study) is that the cost/latency frontier is traced by CHOOSING among
heterogeneous resource pools per query — a reserved slice, elastic burst
capacity, cheap-but-slow spot capacity — each with its own price, speed,
startup latency, and capacity model. ``PoolSpec`` captures exactly those
axes declaratively; ``build_pool`` instantiates the matching executor:

  kind="reserved" -> CostEfficientCluster (bounded POS/SOS slice pool,
                     optional autoscale)
  kind="elastic"  -> HighElasticCluster (unbounded burst slices with a
                     provisioning delay, premium unit price)

Pool heterogeneity enters the cost model as a ``speed_factor`` relative
to the hardware baseline: a 0.25x pool (CPU spot) runs every stage 4x
longer on the SAME plan structure, so a query's stage cursor stays valid
when its remaining stages hop pools (spill, spill-back) — only times and
bills are re-derived.

The default registry (``default_pool_specs``) is the paper's vm/cf pair
built from the legacy SimConfig knobs, so a registry of those two specs
reproduces the PR-1 simulator bit-for-bit, and a registry of size one
degenerates to a single-cluster system.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from ..perf.hw import V5E, HwSpec
from .allocation import AllocationConfig, Allocator
from .clusters import (
    AutoscaleConfig,
    CostEfficientCluster,
    FaultModel,
    HighElasticCluster,
)
from .cost_model import CostModel
from .engine import ClusterExecutor
from .sla import SLAConfig


@dataclass(frozen=True)
class PoolSpec:
    """Declarative description of one executor pool in the registry."""

    name: str
    kind: str = "reserved"  # reserved | elastic
    #: reserved: total slice capacity; elastic: max chips per burst slice
    chips: int = 64
    mode: str = "sos"  # reserved execution: pos | sos
    slice_chips: int = 16  # SOS isolated sub-slice size
    #: pool hardware speed relative to the hw baseline (0.25 = 4x slower)
    speed_factor: float = 1.0
    #: absolute $/chip-hour; None derives hw.reserved_price * multiplier
    price_per_chip_hour: Optional[float] = None
    price_multiplier: float = 1.0
    startup_s: float = 0.0  # provisioning delay (elastic pools)
    interference_alpha: float = 0.5  # POS processor-sharing penalty
    max_concurrent: int = 8  # POS admission cap
    min_chips: int = 4  # elastic: min chips per burst slice
    tokens_per_chip: int = 262_144  # elastic slice sizing
    autoscale: Optional[AutoscaleConfig] = None  # reserved pools only
    #: None follows SLAConfig.preempt_best_effort; a bool overrides it
    preempt_best_effort: Optional[bool] = None
    #: directory of dry-run JSONs recorded on THIS pool's hardware;
    #: build_pool fits the pool's speed_factor and per-(arch, kind)
    #: corrections from it (core/calibration.py), replacing the declared
    #: speed_factor constant with a measured one
    dryrun_dir: Optional[str] = None
    #: filter for a mixed dryrun_dir: only records whose "hw" field or
    #: filename carry this tag belong to this pool's hardware
    hw_tag: str = ""
    #: per-query chips-per-stage allocation bounds (core/allocation.py):
    #: when set, the pool's slice width becomes a per-(work, service
    #: level) decision swept over this grid instead of the fixed
    #: slice_chips / tokens_per_chip sizing. None keeps the legacy
    #: fixed-knob sizing bit-for-bit.
    allocation: Optional[AllocationConfig] = None
    #: coordination tax of wider slices: stage times scale by
    #: ``1 + parallel_overhead * (chips - 1)`` (CostModel). 0.0 keeps
    #: the pure — exactly chips-linear — roofline, under which every
    #: width costs the same chip-seconds and the frontier is degenerate.
    parallel_overhead: float = 0.0
    #: admission-control drift gate (CalibrationTable.drift_bound):
    #: when the pool's measured/predicted drift EWMA strays more than
    #: this relative bound, the coordinator stops trusting its quotes.
    #: None disables the gate for this pool.
    drift_bound: Optional[float] = None
    #: what a tripped gate does to this pool's quotes: "reprice" scales
    #: them to the measured speed; "reject" routes new queries to other
    #: candidate pools while any remain (falling back to reprice when
    #: this pool is the only option)
    drift_action: str = "reprice"

    def effective_price_per_chip_hour(self, hw: HwSpec = V5E) -> float:
        if self.price_per_chip_hour is not None:
            return self.price_per_chip_hour
        return hw.reserved_price * self.price_multiplier


def fit_spec_calibration(spec: PoolSpec, *, hw: HwSpec = V5E):
    """The one dryrun-fit resolution both backends share: a spec with
    ``dryrun_dir`` fits a CalibrationTable from that pool's hardware
    records (None otherwise), so simulated and live pools stay
    bit-identical by construction."""
    if not spec.dryrun_dir:
        return None
    from .calibration import fit_dryruns

    return fit_dryruns(spec.dryrun_dir, hw=hw, hw_tag=spec.hw_tag)


def build_pool(
    spec: PoolSpec,
    *,
    hw: HwSpec = V5E,
    use_calibration: bool = True,
    decode_chunk_tokens: int = 32,
    fault: Optional[FaultModel] = None,
    rng: Optional[np.random.Generator] = None,
    sla: Optional[SLAConfig] = None,
    calibration=None,
) -> ClusterExecutor:
    """Instantiate the executor a PoolSpec describes. All pools built for
    one simulation share `rng` so fault sampling stays deterministic for
    a given seed regardless of how queries hop between pools.

    Calibration: an explicit `calibration` table wins; otherwise a spec
    with `dryrun_dir` fits one from that pool's dry-run JSONs (offline
    per-pool calibration — the fitted speed_factor replaces the declared
    constant). An injected table applies regardless of
    `use_calibration`, which only gates the process-wide default."""
    sla = sla or SLAConfig()
    if spec.drift_action not in ("reprice", "reject"):
        raise ValueError(
            f"unknown drift_action {spec.drift_action!r} for {spec.name!r} "
            "(expected 'reprice' or 'reject')"
        )
    table = calibration
    if table is None:
        table = fit_spec_calibration(spec, hw=hw)
    if spec.drift_bound is not None:
        # the drift gate needs a table to hold its EWMA; arm the pool's
        # existing one (an injected table's own bound wins) or create
        # one that reproduces the pool's table-less stage times exactly
        # (the default dry-run loader when calibration is on, unit
        # factors when it is off)
        if table is None:
            from .calibration import CalibrationTable, _load_default_factor

            table = CalibrationTable(
                loader=_load_default_factor if use_calibration else None,
                source=f"drift-gate:{spec.name}",
                drift_bound=spec.drift_bound,
            )
        elif table.drift_bound is None:
            table.drift_bound = spec.drift_bound
    cm = CostModel(
        hw=hw,
        use_calibration=use_calibration,
        decode_chunk_tokens=decode_chunk_tokens,
        speed_factor=spec.speed_factor,
        calibration=table,
        parallel_overhead=spec.parallel_overhead,
    )
    if spec.kind == "elastic":
        pool: ClusterExecutor = HighElasticCluster(
            cost_model=cm,
            hw=hw,
            startup_s=spec.startup_s,
            min_chips=spec.min_chips,
            max_chips=spec.chips,
            tokens_per_chip=spec.tokens_per_chip,
            fault=fault,
            rng=rng,
        )
    elif spec.kind == "reserved":
        preempt = (
            sla.preempt_best_effort
            if spec.preempt_best_effort is None
            else spec.preempt_best_effort
        )
        pool = CostEfficientCluster(
            chips=spec.chips,
            mode=spec.mode,
            max_concurrent=spec.max_concurrent,
            interference_alpha=spec.interference_alpha,
            sos_slice_chips=spec.slice_chips,
            cost_model=cm,
            hw=hw,
            fault=fault,
            rng=rng,
            autoscale=spec.autoscale,
            preempt_best_effort=preempt,
        )
    else:
        raise ValueError(f"unknown pool kind {spec.kind!r} for {spec.name!r}")
    pool.name = spec.name
    pool.price_per_chip_s = spec.effective_price_per_chip_hour(hw) / 3600.0
    pool.spec = spec  # type: ignore[attr-defined]
    if spec.allocation is not None:
        pool.allocator = Allocator(cm, spec.allocation)
    return pool


def build_live_pool(spec: PoolSpec, *, engine) -> ClusterExecutor:
    """Live counterpart of `build_pool`: the same PoolSpec vocabulary
    instantiates thread-backed executors that run real jitted model work
    on this host (core/live.py):

      kind="reserved" -> LiveReservedPool (one serialized worker thread
                         per chip — the interference-free tier)
      kind="elastic"  -> LiveElasticPool (a task pool of up to `chips`
                         threads with a provisioning sleep of startup_s)

    `engine` is the owning LiveEngine (model pool, clock, checkpoint
    store, result sinks). Imported lazily: the live classes pull in jax
    and the model zoo, which the simulator never needs."""
    from .live import LiveElasticPool, LiveReservedPool

    if spec.kind == "elastic":
        return LiveElasticPool(spec, engine)
    if spec.kind == "reserved":
        return LiveReservedPool(spec, engine)
    raise ValueError(f"unknown pool kind {spec.kind!r} for {spec.name!r}")


def default_live_pool_specs(
    *,
    cf_startup_s: float = 0.3,
    cf_price_multiplier: float = 10.0,
) -> list[PoolSpec]:
    """The legacy live pair: one serialized cost-efficient worker thread
    and a 16-way elastic thread pool with a provisioning sleep — the
    pre-registry LiveEngine, now expressed as two PoolSpecs."""
    return [
        PoolSpec(name="vm", kind="reserved", chips=1),
        PoolSpec(
            name="cf",
            kind="elastic",
            chips=16,
            startup_s=cf_startup_s,
            price_multiplier=cf_price_multiplier,
        ),
    ]


def default_pool_specs(
    *,
    vm_chips: int = 4,
    vm_mode: str = "pos",
    interference_alpha: float = 0.5,
    sos_slice_chips: int = 32,
    cf_startup_s: float = 2.0,
    elastic_price_multiplier: float = 10.0,
    autoscale: Optional[AutoscaleConfig] = None,
) -> list[PoolSpec]:
    """The paper's two-pool system (reserved VM + elastic CF) as a
    registry — the SimConfig default, bit-for-bit the PR-1 simulator."""
    return [
        PoolSpec(
            name="vm",
            kind="reserved",
            chips=vm_chips,
            mode=vm_mode,
            slice_chips=sos_slice_chips,
            interference_alpha=interference_alpha,
            autoscale=autoscale,
        ),
        PoolSpec(
            name="cf",
            kind="elastic",
            chips=64,
            min_chips=4,
            startup_s=cf_startup_s,
            price_multiplier=elastic_price_multiplier,
        ),
    ]
