"""Service levels and SLA configuration (paper §3.1, §4.2).

Three enumerable service levels over query PENDING time:
  IMMEDIATE — starts executing immediately;
  RELAXED   — starts within `relaxed_deadline_s` (default 5 min);
  BEST_EFFORT — no pending-time guarantee (drained when the cost-efficient
                cluster is idle).
Guarantees are RELATIVE (paper §3.1): a lower level must consume cheaper
resources; no absolute latency promise is made.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field


class ServiceLevel(enum.IntEnum):
    IMMEDIATE = 0
    RELAXED = 1
    BEST_EFFORT = 2

    @property
    def short(self) -> str:
        return {0: "imm", 1: "rel", 2: "boe"}[int(self)]


class Policy(enum.Enum):
    """Query-coordinator routing policy (paper §4.3 + beyond-paper)."""

    FORCE = "force"  # SLA directly decides the resource pool
    AUTO = "auto"  # spill to the elastic pool only on overload
    # beyond-paper (§4.2 "we plan to implement SLAs regarding query
    # execution time"): admission-time latency quotes from the
    # deterministic SOS cost model pick the cheapest pool that meets the
    # query's latency target
    LATENCY_AWARE = "latency_aware"


@dataclass(frozen=True)
class SLAConfig:
    relaxed_deadline_s: float = 300.0  # paper: 5 minutes, configurable
    #: pending fraction at which the relaxed scheduler force-submits
    deadline_slack: float = 0.85
    #: scheduler poll period (the paper's schedulers "keep polling")
    poll_period_s: float = 1.0
    #: VM running-queue length at which the coordinator calls "overloaded"
    vm_overload_threshold: int = 8
    #: BoE drains only when the cost-efficient cluster is idle (length 0)
    boe_idle_threshold: int = 0
    # --- stage-level engine policy (core/engine.py; SOS mode only) ----
    #: an arriving IMMEDIATE query may bump a running BEST_EFFORT query
    #: at its next stage boundary (preempted work resumes at the next
    #: unfinished stage; chip-seconds already spent are kept and billed)
    preempt_best_effort: bool = False
    #: the coordinator may route the REMAINING stages of a VM query to
    #: the elastic cluster when its slice pool is overloaded mid-query
    #: (a waiting query at least as urgent has no slice)
    spill_enabled: bool = False
    #: only spill queries whose remaining stages are worth the elastic
    #: premium (seconds of remaining work on the VM slice)
    spill_min_remaining_s: float = 5.0
    #: symmetric spill: a spilled query returns to a reserved pool at its
    #: next stage boundary once that pool has a free slice and its
    #: predicted backlog drain time falls below this low watermark
    #: (seconds) — remaining stages bill at the reserved rate again
    spill_back_enabled: bool = False
    spill_back_low_backlog_s: float = 30.0
