"""Per-pool calibration: fit cost models from measurements, both ways.

The flexible-SLA menu (paper §3.3 vision 1) stands on a *deterministic,
accurate* cost model — admission prices and latency quotes are only as
honest as the stage-time predictions behind them. Kassing et al. and
Skyrise both show that per-resource-tier calibration against measured
execution is what makes a cost/latency frontier trustworthy. This module
closes the quote→measurement loop in both directions:

offline — ``fit_dryruns(dir)`` fits a pool's ``speed_factor`` and
    per-(arch, kind) correction factors from the dry-run JSONs recorded
    on that pool's hardware (``PoolSpec.dryrun_dir`` / ``hw_tag``),
    replacing the old module-global ``lru_cache`` over ``results/dryrun``
    with an explicit, invalidatable ``CalibrationTable``.

online — ``LiveCalibrator`` fits corrections from the pools' own
    measured ``stage_trace`` walls (an EWMA over predicted-vs-actual
    stage ratios in log space), persists them to JSON, and hot-swaps
    them into each pool's cost model at stage boundaries. Calibration
    scales stage *times*, never plan *structure*, so a mid-plan stage
    cursor stays valid across a hot swap — the same invariant that makes
    spill and preemption resume safe.

Fit model: ``measured = analytic(arch, kind) * factor(arch, kind) /
speed_factor``. Given per-record ratios r_i = measured_i / analytic_i,
the pool speed is the inverted geometric-mean ratio (one number for the
whole pool's hardware) and the per-(arch, kind) factors absorb what a
single speed cannot (attention vs SSM kernels, train vs serve).
"""
from __future__ import annotations

import argparse
import itertools
import json
import math
import os
import threading
from pathlib import Path
from typing import Callable, Iterable, Optional

from ..configs import get_config
from ..perf.hw import V5E, HwSpec
from . import cost_model as _cost_model
from .cost_model import CostModel, _analytic_step

# factors outside these bounds mean the record (or the analytic model) is
# broken — clamp rather than poison every quote with it
FACTOR_BOUNDS = (0.25, 20.0)
SPEED_BOUNDS = (1.0 / 64.0, 64.0)

# one global version sequence: ANY new or mutated table gets a version no
# cached plan has seen, so CostModel._plan_cache invalidation is a simple
# integer comparison even when the table object itself is swapped
_VERSION = itertools.count(1)


def _clamp(x: float, lo: float, hi: float) -> float:
    return max(lo, min(hi, x))


def _geomean(vals: Iterable[float]) -> float:
    logs = [math.log(v) for v in vals]
    return math.exp(sum(logs) / len(logs))


class CalibrationTable:
    """Explicit calibration state for one cost model: a fitted pool
    ``speed_factor`` (None keeps the declared constant) plus
    per-(arch, kind) correction factors. Every mutation bumps
    ``version``, which is what lets ``CostModel`` invalidate its plan
    cache — the old module-level ``lru_cache`` could never be updated
    after first use."""

    def __init__(
        self,
        factors: Optional[dict] = None,
        speed_factor: Optional[float] = None,
        source: str = "",
        loader: Optional[Callable[[str, str], float]] = None,
        drift_bound: Optional[float] = None,
        drift_alpha: float = 0.25,
        drift_min_samples: int = 4,
    ):
        self._factors: dict[tuple[str, str], float] = dict(factors or {})
        self.speed_factor = speed_factor
        self.source = source
        self._loader = loader
        self.version = next(_VERSION)
        #: admission-control drift gate: when the EWMA of measured /
        #: predicted stage walls strays more than this relative bound
        #: from 1.0, the coordinator stops quoting this pool's stale
        #: speed (reprice at measured speed, or reject — see
        #: scheduler.QueryCoordinator). None disables the gate.
        self.drift_bound = drift_bound
        self.drift_alpha = drift_alpha
        self.drift_min_samples = drift_min_samples
        self._drift_log = 0.0  # log-space EWMA of measured/predicted
        self._drift_n = 0

    # --- admission-control drift gate ---------------------------------
    def observe_drift(self, predicted_s: float, measured_s: float) -> None:
        """Feed one predicted-vs-measured stage wall into the drift
        EWMA. Deliberately NOT a version bump: drift gates ADMISSION
        (quotes get repriced or rejected), it does not rescale plans —
        plan caches stay valid, and only a real re-fit (``update`` /
        ``set_speed_factor``, e.g. LiveCalibrator.maybe_apply) moves
        the version."""
        if predicted_s <= 0 or measured_s <= 0:
            return
        lr = math.log(measured_s / predicted_s)
        if self._drift_n == 0:
            self._drift_log = lr
        else:
            a = self.drift_alpha
            self._drift_log = (1.0 - a) * self._drift_log + a * lr
        self._drift_n += 1

    def drift_ratio(self) -> Optional[float]:
        """EWMA of measured/predicted stage walls (None before the first
        observation). >1: the pool runs slower than quoted."""
        return math.exp(self._drift_log) if self._drift_n else None

    def drift_samples(self) -> int:
        return self._drift_n

    def drift_exceeded(self) -> bool:
        """Whether quotes from this table's pool are currently stale:
        the gate is armed (a bound is set and enough walls were seen)
        and the drift EWMA strays past the bound."""
        if self.drift_bound is None or self._drift_n < self.drift_min_samples:
            return False
        return abs(math.exp(self._drift_log) - 1.0) > self.drift_bound

    def reset_drift(self) -> None:
        """Forget the drift EWMA (a re-fit just landed: the new speed is
        the measured one, so the old residuals no longer apply)."""
        self._drift_log = 0.0
        self._drift_n = 0

    def factor(self, arch: str, kind: str) -> float:
        """Correction factor for one (arch, kind). A miss asks the
        loader (the default table reads results/dryrun lazily) and
        caches the answer — a deterministic fill, not a mutation, so the
        version does not move."""
        key = (arch, kind)
        f = self._factors.get(key)
        if f is None:
            f = self._loader(arch, kind) if self._loader is not None else 1.0
            self._factors[key] = f
        return f

    # --- mutations (each bumps version -> plan caches invalidate) -----
    def set_factor(self, arch: str, kind: str, value: float) -> None:
        self._factors[(arch, kind)] = _clamp(value, *FACTOR_BOUNDS)
        self.version = next(_VERSION)

    def set_speed_factor(self, value: Optional[float]) -> None:
        self.speed_factor = (
            None if value is None else _clamp(value, *SPEED_BOUNDS)
        )
        self.version = next(_VERSION)

    def update(
        self,
        factors: Optional[dict] = None,
        speed_factor: Optional[float] = None,
    ) -> None:
        """Batch mutation: one version bump for any number of changes."""
        for (arch, kind), v in (factors or {}).items():
            self._factors[(arch, kind)] = _clamp(v, *FACTOR_BOUNDS)
        if speed_factor is not None:
            self.speed_factor = _clamp(speed_factor, *SPEED_BOUNDS)
        self.version = next(_VERSION)

    def invalidate(self) -> None:
        """Drop every cached/learned factor and bump the version: the
        next lookup re-reads the source (dry-run JSONs may have been
        re-recorded)."""
        self._factors.clear()
        self.version = next(_VERSION)

    # --- persistence ---------------------------------------------------
    def as_dict(self) -> dict:
        out = {
            "speed_factor": self.speed_factor,
            "factors": {
                f"{arch}/{kind}": round(v, 6)
                for (arch, kind), v in sorted(self._factors.items())
            },
            "source": self.source,
        }
        # drift-gate config only when armed: ungated tables keep the
        # legacy payload byte-identical
        if self.drift_bound is not None:
            out["drift_bound"] = self.drift_bound
            out["drift_alpha"] = self.drift_alpha
            out["drift_min_samples"] = self.drift_min_samples
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "CalibrationTable":
        factors = {}
        for key, v in (d.get("factors") or {}).items():
            arch, _, kind = key.partition("/")
            factors[(arch, kind)] = float(v)
        return cls(
            factors=factors,
            speed_factor=d.get("speed_factor"),
            source=d.get("source", ""),
            drift_bound=d.get("drift_bound"),
            drift_alpha=float(d.get("drift_alpha", 0.25)),
            drift_min_samples=int(d.get("drift_min_samples", 4)),
        )

    def save(self, path) -> None:
        Path(path).write_text(json.dumps(self.as_dict(), indent=1,
                                         sort_keys=True) + "\n")

    @classmethod
    def load(cls, path) -> "CalibrationTable":
        return cls.from_dict(json.loads(Path(path).read_text()))


# ---------------------------------------------------------------------------
# the default table: lazy results/dryrun semantics of the old lru_cache,
# now invalidatable
# ---------------------------------------------------------------------------

# canonical dry-run cells the legacy calibration read (dryrun.py output)
_KIND_SHAPE = {"serve": "prefill_32k", "train": "train_4k"}
_SHAPE_TOKENS = {"prefill_32k": 32 * 32768, "train_4k": 256 * 4096}

_default: Optional[CalibrationTable] = None


def _load_default_factor(arch: str, kind: str) -> float:
    """HLO-derived step time / analytic step time, from the canonical
    dry-run record in ``results/dryrun`` (the legacy behavior)."""
    shape = _KIND_SHAPE.get(kind)
    if shape is None:
        return 1.0
    path = _cost_model.RESULTS / f"{arch}__{shape}__16x16.json"
    if not path.exists():
        return 1.0
    try:
        rec = json.loads(path.read_text())
        terms = rec["roofline"]["terms"]
        cfg = get_config(arch)
        an = _analytic_step(cfg, _SHAPE_TOKENS[shape], kind,
                            chips=rec["chips"])
        return _clamp(terms["step_s"] / an, *FACTOR_BOUNDS) if an else 1.0
    except (OSError, json.JSONDecodeError, KeyError, TypeError, ValueError,
            ZeroDivisionError):
        # malformed/partial dry-run record: the declared constant stands
        return 1.0


def default_table() -> CalibrationTable:
    """The process-wide table backing ``CostModel(use_calibration=True)``
    when no table is injected — same semantics as the old global
    ``lru_cache``, but explicitly invalidatable."""
    global _default
    if _default is None:
        _default = CalibrationTable(
            source=str(_cost_model.RESULTS), loader=_load_default_factor
        )
    return _default


def invalidate_default_calibration() -> None:
    """Drop the default table's cached factors; every CostModel using it
    re-plans on its next call (dry-run records changed on disk)."""
    if _default is not None:
        _default.invalidate()


# ---------------------------------------------------------------------------
# offline fit: dry-run JSONs -> (speed_factor, per-(arch, kind) factors)
# ---------------------------------------------------------------------------

def _parse_dryrun_record(rec: dict) -> Optional[tuple]:
    """(arch, kind, chips, tokens, step_s) from one dry-run JSON, or
    None when the record is unusable (skipped/errored cells)."""
    if rec.get("status") not in (None, "ok"):
        return None
    try:
        arch = rec["arch"]
        chips = int(rec["chips"])
        step_s = float(rec["roofline"]["terms"]["step_s"])
    except (KeyError, TypeError, ValueError):
        return None
    shape = rec.get("shape", "")
    kind = rec.get("kind") or ("train" if "train" in shape else "serve")
    tokens = rec.get("tokens") or _SHAPE_TOKENS.get(shape)
    if tokens is None or step_s <= 0 or chips <= 0:
        return None
    return arch, kind, chips, int(tokens), step_s


def _record_matches_hw(rec: dict, fname: str, hw_tag: str) -> bool:
    """Match the record's "hw" field exactly, or the tag as a whole
    "__"-delimited filename segment (dryrun.py names are
    arch__shape__mesh[__variant].json) — substring matching would let
    hw_tag="v5" swallow both v5e and v5p records."""
    if not hw_tag:
        return True
    if rec.get("hw") == hw_tag:
        return True
    stem = fname[:-5] if fname.endswith(".json") else fname
    return hw_tag in stem.split("__")


def fit_dryruns(
    dryrun_dir,
    *,
    hw: HwSpec = V5E,
    hw_tag: str = "",
) -> CalibrationTable:
    """Fit one pool's calibration from the dry-run JSONs recorded on its
    hardware. ``hw_tag`` filters a mixed directory to the records whose
    ``"hw"`` field (or filename) carries the tag.

    speed_factor = 1 / geomean(measured_i / analytic_i)   over all records
    factor(a, k) = geomean(ratio over that (arch, kind)) * speed_factor

    so a uniformly-4x-slow pool fits speed 0.25 with every factor at 1.0,
    and per-(arch, kind) residuals absorb what one speed cannot."""
    dryrun_dir = Path(dryrun_dir)
    ratios: dict[tuple[str, str], list[float]] = {}
    n_records = 0
    # files silently dropped used to be invisible (the RL004 bug shape:
    # a fit quietly computed from fewer records than the caller shipped);
    # now every skip is named with its reason in the table's source
    skipped: list[str] = []
    for p in sorted(dryrun_dir.glob("*.json")):
        try:
            rec = json.loads(p.read_text())
        except (OSError, json.JSONDecodeError) as err:
            skipped.append(f"{p.name}: unreadable ({type(err).__name__})")
            continue
        if not _record_matches_hw(rec, p.name, hw_tag):
            continue  # intentional filter, not a skip worth surfacing
        parsed = _parse_dryrun_record(rec)
        if parsed is None:
            skipped.append(f"{p.name}: unrecognized record shape")
            continue
        arch, kind, chips, tokens, step_s = parsed
        try:
            cfg = get_config(arch)
        except KeyError:
            skipped.append(f"{p.name}: unknown arch {arch!r}")
            continue
        an = _analytic_step(cfg, tokens, kind, chips=chips, hw=hw)
        if an <= 0:
            skipped.append(f"{p.name}: non-positive analytic step")
            continue
        ratios.setdefault((arch, kind), []).append(step_s / an)
        n_records += 1
    if not ratios:
        raise ValueError(
            f"no usable dry-run records in {dryrun_dir}"
            + (f" matching hw_tag={hw_tag!r}" if hw_tag else "")
        )
    speed = _clamp(
        1.0 / _geomean([r for rs in ratios.values() for r in rs]),
        *SPEED_BOUNDS,
    )
    factors = {
        key: _clamp(_geomean(rs) * speed, *FACTOR_BOUNDS)
        for key, rs in ratios.items()
    }
    table = CalibrationTable(
        factors=factors,
        speed_factor=speed,
        source=f"dryrun:{dryrun_dir}"
        + (f"#{hw_tag}" if hw_tag else "")
        + f" ({n_records} records)"
        + (f" [skipped {len(skipped)}: " + "; ".join(skipped) + "]"
           if skipped else ""),
    )
    return table


# ---------------------------------------------------------------------------
# online fit: measured stage walls -> per-pool speed correction (EWMA)
# ---------------------------------------------------------------------------

def _fitted_speed(st: dict) -> float:
    """The one fit expression every read-out shares: the speed the
    pool's DECLARED constant should have been, given the EWMA of
    measured/predicted ratios recorded against that declared speed."""
    return _clamp(st["declared"] / math.exp(st["log_ratio"]), *SPEED_BOUNDS)


class LiveCalibrator:
    """Closes quote→measurement drift from the pools' own measured stage
    walls. Per pool it keeps a log-space EWMA of the ratio

        r = measured stage wall / reference prediction

    where the *reference* is a frozen copy of the pool's cost model at
    its DECLARED speed — predictions for the ratio never chase the
    corrections, so the fit is a stable fixed point:

        fitted speed_factor = declared speed_factor / ewma(r)

    ``maybe_apply`` hot-swaps the fitted speed into the pool's cost
    model at a stage boundary (a `CalibrationTable` version bump, so
    plan caches invalidate but plan structure — and therefore every
    mid-plan stage cursor — is untouched) and persists the state to
    ``path`` when one is configured."""

    #: relative speed change below which a hot swap is skipped (avoids
    #: re-planning every pool on sub-permille EWMA wiggle)
    APPLY_EPSILON = 1e-3

    #: lock contract (reprolint RL001 + repro.core.sanitize).
    _GUARDED_BY = {
        "_state": "_mu",
        "_tables": "_mu",
        "_refs": "_mu",
    }

    def __init__(
        self,
        alpha: float = 0.25,
        min_samples: int = 8,
        path=None,
    ):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = alpha
        self.min_samples = min_samples
        self.path = Path(path) if path is not None else None
        self._mu = threading.Lock()
        # pool name -> {"log_ratio": EWMA, "n": samples, "declared": speed}
        self._state: dict[str, dict] = {}
        self._tables: dict[str, CalibrationTable] = {}
        self._refs: dict[str, CostModel] = {}
        self._save_mu = threading.Lock()  # serializes persistence writes
        if self.path is not None and self.path.exists():
            self.load(self.path)

    # --- observation ---------------------------------------------------
    def _ref_model(self, pool) -> CostModel:
        """Frozen declared-speed model the ratios are measured against.
        It carries the pool's offline-fitted per-(arch, kind) factors
        (speed excluded), so the EWMA measures only the residual SPEED
        error beyond the offline fit and the two compose cleanly."""
        with self._mu:
            cm = self._refs.get(pool.name)
            # rebuild on a declared-speed change: the frozen reference
            # must always reflect the CURRENT spec, like the EWMA state
            if cm is None or cm.speed_factor != pool.cost_model.speed_factor:
                src = pool.cost_model
                base = src.calibration
                ref_table = (
                    CalibrationTable(factors=dict(base._factors))
                    if base is not None
                    else None
                )
                cm = CostModel(
                    hw=src.hw,
                    use_calibration=False,
                    decode_chunk_tokens=src.decode_chunk_tokens,
                    speed_factor=src.speed_factor,
                    calibration=ref_table,
                )
                self._refs[pool.name] = cm
            return cm

    def observe(self, pool, work, index: int, chips: int,
                wall_s: float) -> None:
        """Record one measured stage wall: stage ``index`` of ``work``'s
        plan ran on ``pool`` in ``wall_s`` seconds on a ``chips`` slice."""
        plan = self._ref_model(pool).plan(work, chips)
        if not 0 <= index < len(plan.stages):
            return
        predicted = plan.stages[index].time_s
        if predicted <= 0 or wall_s <= 0:
            return
        lr = math.log(wall_s / predicted)
        # admission-control drift: the pool's ACTIVE table (when its
        # gate is armed) also sees the wall, measured against the
        # CURRENT model — the one quotes are made from — not the frozen
        # declared reference the speed fit uses
        table = pool.cost_model.calibration
        if table is not None and table.drift_bound is not None:
            cur = pool.cost_model.plan(work, chips)
            cur_pred = cur.stages[index].time_s
            if cur_pred > 0:
                table.observe_drift(cur_pred, wall_s)
        declared = pool.cost_model.speed_factor
        with self._mu:
            st = self._state.get(pool.name)
            if st is None or st["declared"] != declared:
                # first wall, or the pool's DECLARED speed changed since
                # the state was persisted: old ratios were measured
                # against a different reference and would mis-fit —
                # start the EWMA over
                self._state[pool.name] = {
                    "log_ratio": lr, "n": 1, "declared": declared,
                }
                return
            alpha = self.alpha
            if st.get("decayed"):
                # post-replacement re-learn (``decay``): weight fresh
                # walls like the running average of a near-empty window
                # (2/(n+2) is the EWMA whose effective memory is the n
                # samples seen since the decay), so a replaced worker's
                # true speed dominates within a handful of stages; the
                # boost expires once confidence is back at min_samples.
                alpha = max(alpha, 2.0 / (st["n"] + 2.0))
                if st["n"] + 1 >= self.min_samples:
                    st.pop("decayed")
            st["log_ratio"] = (
                (1.0 - alpha) * st["log_ratio"] + alpha * lr
            )
            st["n"] += 1

    def decay(self, pool_name: str) -> bool:
        """Reduce the pool's calibration confidence after a worker
        replacement (core/convergence.py): the replacement host inherits
        the pool EWMA as its prior — the fitted speed stays applied, so
        quotes never snap back to the declared speed — but ``n`` drops
        to 1, re-arming ``maybe_apply``'s min_samples gate and boosting
        ``observe``'s effective alpha until the replacement has re-earned
        the confidence. Returns False when the pool has no state yet."""
        with self._mu:
            st = self._state.get(pool_name)
            if st is None:
                return False
            st["n"] = 1
            st["decayed"] = True
            return True

    def observe_query(self, pool, q) -> None:
        """Convenience: feed every stage of a finished query's trace that
        ran on `pool` (offline analysis of simulated traces)."""
        for e in q.stage_trace:
            if e.cluster == pool.name:
                self.observe(pool, q.work, e.index, e.chips,
                             e.finish - e.start)

    # --- read-outs -----------------------------------------------------
    def ratio(self, pool_name: str) -> Optional[float]:
        """Current EWMA of measured/predicted for the pool (None before
        the first observation)."""
        with self._mu:
            st = self._state.get(pool_name)
            return math.exp(st["log_ratio"]) if st else None

    def samples(self, pool_name: str) -> int:
        with self._mu:
            st = self._state.get(pool_name)
            return st["n"] if st else 0

    def drift_ratio(self, pool) -> Optional[float]:
        """The pool's admission-control drift EWMA (measured/predicted
        against its ACTIVE table), None when the pool carries no table
        or the gate has seen no walls — the per-pool drift bound itself
        lives on the table (``CalibrationTable.drift_bound``)."""
        table = pool.cost_model.calibration
        return table.drift_ratio() if table is not None else None

    def drift_exceeded(self, pool) -> bool:
        table = pool.cost_model.calibration
        return table.drift_exceeded() if table is not None else False

    def fitted_speed_factor(self, pool) -> Optional[float]:
        """Fit against the declared speed the ratios were MEASURED
        under — persisted state may predate a spec change."""
        with self._mu:
            st = self._state.get(pool.name)
            return _fitted_speed(st) if st is not None else None

    # --- the hot swap --------------------------------------------------
    def maybe_apply(self, pool) -> bool:
        """Stage-boundary hot-swap: once ``min_samples`` walls have been
        seen, install/refresh the fitted speed on the pool's cost model.
        Returns True when the model changed. One critical section per
        call: concurrent workers of the same pool must agree on a single
        table, or the pool's cost model could hold an orphan the later
        updates never reach."""
        with self._mu:
            st = self._state.get(pool.name)
            if st is None or st["n"] < self.min_samples:
                return False
            if st["declared"] != pool.cost_model.speed_factor:
                # persisted fit against a since-changed declared spec:
                # don't apply; observe() restarts the EWMA on new walls
                return False
            fitted = _fitted_speed(st)
            table = self._tables.get(pool.name)
            if table is None:
                # seed from the pool's current (offline-fitted) table so
                # the hot swap refines its speed WITHOUT dropping the
                # per-(arch, kind) factors the dry-runs measured. The
                # fitted speed is set BEFORE install: a concurrent
                # plan() between install and a later speed update would
                # otherwise quote at the raw declared constant.
                base = pool.cost_model.calibration
                table = self._tables[pool.name] = CalibrationTable(
                    factors=dict(base._factors) if base is not None else None,
                    speed_factor=fitted,
                    source=f"live:{pool.name}"
                    + (f" over [{base.source}]"
                       if base is not None and base.source else ""),
                    # the drift gate survives the table swap: the live
                    # table inherits the base's admission-control config
                    drift_bound=base.drift_bound if base is not None else None,
                    drift_alpha=base.drift_alpha if base is not None else 0.25,
                    drift_min_samples=(
                        base.drift_min_samples if base is not None else 4
                    ),
                )
                pool.cost_model.set_calibration(table)
            else:
                current = table.speed_factor
                if current is not None and abs(fitted - current) <= (
                    self.APPLY_EPSILON * current
                ):
                    return False
                table.set_speed_factor(fitted)
            # the re-fit just moved quotes to the measured speed — the
            # old drift residuals no longer describe them
            table.reset_drift()
        if self.path is not None:
            self.save(self.path)
        return True

    def table(self, pool_name: str) -> Optional[CalibrationTable]:
        with self._mu:
            return self._tables.get(pool_name)

    # --- persistence ---------------------------------------------------
    def as_dict(self) -> dict:
        with self._mu:
            return {
                "alpha": self.alpha,
                "min_samples": self.min_samples,
                "pools": {
                    name: {
                        "log_ratio": st["log_ratio"],
                        "ratio": round(math.exp(st["log_ratio"]), 6),
                        "n": st["n"],
                        "declared_speed_factor": st["declared"],
                        "fitted_speed_factor": round(_fitted_speed(st), 6),
                    }
                    for name, st in sorted(self._state.items())
                },
            }

    def save(self, path) -> None:
        """Atomic persistence: every pool's worker threads save on an
        applied update, so write-to-temp + rename — a torn or
        interleaved in-place write would crash the next startup's
        load() with invalid JSON."""
        payload = json.dumps(self.as_dict(), indent=1, sort_keys=True) + "\n"
        path = Path(path)
        with self._save_mu:
            tmp = path.with_name(path.name + ".tmp")
            tmp.write_text(payload)
            os.replace(tmp, path)

    def load(self, path) -> None:
        d = json.loads(Path(path).read_text())
        with self._mu:
            for name, st in (d.get("pools") or {}).items():
                self._state[name] = {
                    "log_ratio": float(st["log_ratio"]),
                    "n": int(st["n"]),
                    "declared": float(st.get("declared_speed_factor", 1.0)),
                }


# ---------------------------------------------------------------------------
# drift probe: a measured live run for benchmarks/tests
# ---------------------------------------------------------------------------

def measure_live_speed_drift(
    declared_speed: float,
    *,
    n_queries: int = 12,
    decode_tokens: int = 64,
    decode_chunk_tokens: int = 8,
    alpha: float = 0.2,
    min_samples: int = 10,
):
    """Run a 1-pool LiveEngine with the calibration loop on and record
    the loop's ONLINE decode-wall drift: at each stage boundary,
    ``(samples seen, work, index, wall_s, pred_now)`` where ``pred_now``
    is from the model in effect while the stage ran (observation
    happens before that boundary's hot swap). DECODE walls only: one
    pool speed cannot fit prefill and decode simultaneously (the
    analytic prefill:decode ratio differs from the live engine's — the
    per-(arch, kind) factor axis exists for that), so speed-drift
    claims ride the homogeneous stage type. Returns ``(engine, walls)``
    with the engine already shut down. Shared by
    benchmarks/calibration.py and tests/test_live.py."""
    from .live import LiveConfig, LiveEngine
    from .pools import PoolSpec
    from .query import Query, QueryWork
    from .sla import ServiceLevel, SLAConfig

    eng = LiveEngine(LiveConfig(
        pools=[PoolSpec(name="vm", kind="reserved", chips=1,
                        speed_factor=declared_speed)],
        sla=SLAConfig(relaxed_deadline_s=10.0, poll_period_s=0.02,
                      vm_overload_threshold=1_000),
        decode_tokens=decode_tokens,
        decode_chunk_tokens=decode_chunk_tokens,
        calibrate=True, calibration_alpha=alpha,
        calibration_min_samples=min_samples,
    ))
    walls: list[tuple] = []
    orig_observe = eng.calibrator.observe

    def observing(pool, work, index, chips, wall_s):
        if wall_s > 0 and index > 0:
            pred = pool.cost_model.plan(work, chips).stages[index].time_s
            walls.append((eng.calibrator.samples(pool.name), work, index,
                          wall_s, pred))
        orig_observe(pool, work, index, chips, wall_s)

    eng.calibrator.observe = observing
    for _ in range(n_queries):
        eng.submit(Query(work=QueryWork(), sla=ServiceLevel.IMMEDIATE,
                         submit_time=0.0))
    done = [q for q in eng.drain(n_queries, timeout=120)
            if q.state == "done"]
    if len(done) != n_queries:
        raise RuntimeError(
            f"drift probe: only {len(done)}/{n_queries} queries finished"
        )
    return eng, walls


# ---------------------------------------------------------------------------
# CLI (the CI calibration-smoke entry point)
# ---------------------------------------------------------------------------

def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="Fit a pool calibration table from dry-run JSONs."
    )
    ap.add_argument("--fit", required=True, metavar="DIR",
                    help="directory of dry-run JSONs to fit")
    ap.add_argument("--hw-tag", default="",
                    help="only fit records whose hw field/filename match")
    ap.add_argument("--out", default=None, metavar="JSON",
                    help="write the fitted table here")
    ap.add_argument("--check", action="store_true",
                    help="fail unless the fit produced a usable table")
    args = ap.parse_args(argv)
    table = fit_dryruns(args.fit, hw_tag=args.hw_tag)
    print(json.dumps(table.as_dict(), indent=1, sort_keys=True))
    if args.out:
        table.save(args.out)
    if args.check:  # explicit raises: a gate must survive python -O
        d = table.as_dict()
        if not d["factors"]:
            raise SystemExit("fit produced no (arch, kind) factors")
        if not d["speed_factor"] or d["speed_factor"] <= 0:
            raise SystemExit("fit produced no usable speed_factor")
        print(f"calibration-smoke OK: {len(d['factors'])} factors, "
              f"speed_factor={d['speed_factor']:.4f}")


if __name__ == "__main__":
    main()
