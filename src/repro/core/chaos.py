"""Fault-injection harness: worker deaths, provisioning stalls, and
persistent slow hosts — seeded, deterministic, usable in both the
simulator and the live engine (ISSUE: the convergence plane is only
credible if a 10%-death day degrades gracefully instead of stranding
queries).

Three layers, all derived from one ``ChaosConfig`` seed:

  * ``ChaosFaultModel`` extends ``clusters.FaultModel`` from per-stage
    faults to PERSISTENT slow hosts: a seeded subset of virtual host
    slots runs every stage ``slow_factor`` slower. Wall time and billed
    chip-seconds scale together, so chip-second conservation holds
    under ``REPRO_SANITIZE=1`` by construction.
  * ``PoolChaos`` is a pool's death/stall schedule for the SIMULATOR:
    pre-drawn death times knock ``death_chips`` off the pool's capacity
    (``CostEfficientCluster._chaos_step``), and seeded provisioning
    failures stretch every scheduled capacity change through the
    converger's exponential backoff (core/convergence.py).
  * ``LiveChaos`` injects worker deaths into the LIVE engine by raising
    ``WorkerDeath`` (a BaseException — it sails past the stage loop's
    ``except Exception`` barrier exactly like a real thread death) from
    a seeded (qid, stage) hash, each site at most once so a resumed
    stage isn't re-killed forever.

Replay contract: same config + same seed => same deaths, same stalls,
same slow hosts, bit-for-bit — benchmarks/chaos.py runs the day twice
and compares event-feed fingerprints (core/events.py).
"""
from __future__ import annotations

import hashlib
import threading
from dataclasses import dataclass, field, replace

import numpy as np

from .clusters import FaultModel


def _pool_seed(seed: int, name: str) -> np.random.SeedSequence:
    """Stable per-pool seeding: the pool NAME is folded in through a
    sha256 (never ``hash()`` — it is salted per process), so a pool's
    chaos schedule survives registry reordering and process restarts."""
    digest = hashlib.sha256(name.encode()).digest()
    return np.random.SeedSequence(
        [seed, int.from_bytes(digest[:8], "big")]
    )


@dataclass
class ChaosConfig:
    """One knob set for a fault-injected day. All draws derive from
    ``seed`` — two runs with equal configs are bit-identical."""

    seed: int = 0
    #: worker deaths per TARGETED pool over the horizon (uniform times)
    n_deaths: int = 0
    #: chips lost per death; 0 = the pool's slice size
    death_chips: int = 0
    #: pools that see deaths; empty = every reserved pool
    death_pools: tuple = ()
    horizon_s: float = 86_400.0
    #: per-attempt probability that provisioning a capacity change
    #: stalls and must be retried (geometric, capped at max_stalls)
    stall_prob: float = 0.0
    max_stalls: int = 4
    backoff_base_s: float = 30.0
    backoff_cap_s: float = 600.0
    #: persistent slow hosts: this fraction of n_hosts virtual host
    #: slots runs every stage slow_factor x slower
    slow_host_frac: float = 0.0
    slow_factor: float = 1.0
    n_hosts: int = 16
    #: LIVE engine only: per-(qid, stage) probability a worker thread
    #: dies mid-stage (raised as WorkerDeath, once per site)
    live_death_prob: float = 0.0


@dataclass
class ChaosFaultModel(FaultModel):
    """FaultModel + persistent slow hosts. A query lands on virtual
    host slot ``qid % n_hosts``; slow slots stretch the base stage time
    BEFORE the inherited fault/straggler sampling, so retries and
    speculation price the slow host's reality, and billed chip-seconds
    stay proportional to wall time (conservation-exact)."""

    slow_hosts: frozenset = field(default_factory=frozenset)
    slow_factor: float = 1.0
    n_hosts: int = 16

    def stage_execution(self, base, chips, rng, q):
        if self.slow_hosts and (q.qid % self.n_hosts) in self.slow_hosts:
            base = base * self.slow_factor
        return super().stage_execution(base, chips, rng, q)


class PoolChaos:
    """One pool's pre-drawn death/stall schedule (simulator side).
    Single-threaded like the pool it belongs to — no lock."""

    __slots__ = (
        "death_times_s", "_di", "death_chips", "stall_prob", "max_stalls",
        "backoff_base_s", "backoff_cap_s", "_rng",
    )

    def __init__(self, cfg: ChaosConfig, name: str):
        rng = np.random.default_rng(_pool_seed(cfg.seed, name))
        self.death_times_s = sorted(
            float(t_s)
            for t_s in rng.uniform(0.0, cfg.horizon_s, size=cfg.n_deaths)
        )
        self._di = 0
        self.death_chips = cfg.death_chips
        self.stall_prob = cfg.stall_prob
        self.max_stalls = cfg.max_stalls
        self.backoff_base_s = cfg.backoff_base_s
        self.backoff_cap_s = cfg.backoff_cap_s
        self._rng = rng

    def next_death_s(self) -> float:
        if self._di < len(self.death_times_s):
            return self.death_times_s[self._di]
        return float("inf")

    def pop_death(self) -> float:
        t_s = self.death_times_s[self._di]
        self._di += 1
        return t_s

    def draw_provision_failures(self) -> int:
        """Seeded stall count for ONE provisioning attempt chain."""
        k = 0
        while k < self.max_stalls and self._rng.random() < self.stall_prob:
            k += 1
        return k

    def backoff_s(self, k: int) -> float:
        return min(self.backoff_cap_s, self.backoff_base_s * (2.0 ** k))


def wire_sim_chaos(pools, cfg: ChaosConfig) -> None:
    """Attach the harness to a simulator pool registry: a ``PoolChaos``
    schedule on every targeted reserved pool, and the slow-host fault
    wrapper on every pool (slow hosts are a fleet property). Must run
    before the simulation loop starts — ``needs_tick`` is read once."""
    slow_hosts = frozenset()
    if cfg.slow_host_frac > 0.0 and cfg.slow_factor != 1.0:
        rng = np.random.default_rng(_pool_seed(cfg.seed, "__slow_hosts__"))
        n_slow = int(round(cfg.slow_host_frac * cfg.n_hosts))
        slow_hosts = frozenset(
            int(i) for i in rng.choice(cfg.n_hosts, size=n_slow,
                                       replace=False)
        )
    for pool in pools:
        base = pool.fault or FaultModel()
        if slow_hosts:
            pool.fault = ChaosFaultModel(
                failure_prob=base.failure_prob,
                straggler_prob=base.straggler_prob,
                straggler_scale=base.straggler_scale,
                speculation=base.speculation,
                speculation_cap=base.speculation_cap,
                slow_hosts=slow_hosts,
                slow_factor=cfg.slow_factor,
                n_hosts=cfg.n_hosts,
            )
        if pool.pool_kind != "reserved" or not hasattr(pool, "_chaos"):
            continue
        if cfg.death_pools and pool.name not in cfg.death_pools:
            # stalls still apply wherever provisioning happens
            pool._chaos = PoolChaos(replace(cfg, n_deaths=0), pool.name)
        else:
            pool._chaos = PoolChaos(cfg, pool.name)
        pool._chaos_next = pool._chaos.next_death_s()


# ---------------------------------------------------------------------------
# live-engine fault injection
# ---------------------------------------------------------------------------

class WorkerDeath(BaseException):
    """Injected live worker death. A BaseException ON PURPOSE: it must
    blow through ``LiveExecutor._execute``'s ``except Exception`` fault
    barrier and kill the worker thread the way a real host loss would —
    the convergence plane's heartbeat reaper and thread respawn are the
    only things allowed to recover from it."""


class LiveChaos:
    """Seeded mid-stage worker deaths for the LIVE engine. The kill
    decision hashes (seed, qid, stage) so concurrent workers agree with
    any interleaving; each site fires at most once so the plane's
    checkpoint resume of the same stage survives."""

    #: lock contract (reprolint RL001 + repro.core.sanitize): the
    #: fired-site registry is touched from every worker thread.
    _GUARDED_BY = {
        "_fired": "_mu",
    }

    __slots__ = ("cfg", "_mu", "_fired")

    def __init__(self, cfg: ChaosConfig):
        self.cfg = cfg
        self._mu = threading.Lock()
        self._fired: dict = {}  # (qid, stage) -> True once killed

    def should_kill(self, qid: int, stage: int) -> bool:
        p = self.cfg.live_death_prob
        if p <= 0.0:
            return False
        digest = hashlib.sha256(
            f"{self.cfg.seed}:{qid}:{stage}".encode()
        ).digest()
        if int.from_bytes(digest[:8], "big") / 2.0 ** 64 >= p:
            return False
        key = (qid, stage)
        with self._mu:
            if key in self._fired:
                return False
            self._fired[key] = True
        return True


def install_live_chaos(engine, cfg: ChaosConfig) -> LiveChaos:
    """Wrap every live pool's ``_run_stage_work`` with seeded worker
    deaths. Returns the harness (its ``_fired`` map doubles as the
    injected-death ledger for assertions)."""
    chaos = LiveChaos(cfg)

    def _wrap(pool):
        orig = pool._run_stage_work

        def wrapped(lm, q, _orig=orig, _chaos=chaos):
            if _chaos.should_kill(q.qid, q.stage_cursor):
                raise WorkerDeath(
                    f"injected worker death: Q{q.qid} "
                    f"stage {q.stage_cursor}"
                )
            _orig(lm, q)

        pool._run_stage_work = wrapped

    for pool in engine.pools:
        _wrap(pool)
    return chaos
