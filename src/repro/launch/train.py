"""Fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
        --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt --ckpt-every 10

Production behaviors demonstrated here (and tested in tests/test_fault.py):
  * periodic async sharded checkpoints (params + optimizer + data stream);
  * crash/restart recovery: on startup the driver resumes from the latest
    checkpoint, including the data-stream cursor (exact-once batches);
  * simulated failure injection (--fail-at) to exercise the recovery path;
  * elastic restore onto a different mesh (see tests).
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import numpy as np

from ..checkpoint.store import CheckpointStore
from ..configs import get_config
from ..data.batches import TokenStream
from ..models.transformer import LM
from ..optim.adamw import OptConfig
from ..parallel.sharding import TRAIN_RULES, sharding_ctx, tree_shardings
from ..training import step as training_step


class SimulatedFailure(RuntimeError):
    pass


def train(
    arch: str = "qwen2-0.5b",
    *,
    reduced: bool = True,
    steps: int = 50,
    batch: int = 8,
    seq: int = 64,
    ckpt_dir: str = "/tmp/repro_ckpt",
    ckpt_every: int = 10,
    fail_at: int = -1,
    seed: int = 0,
    mesh=None,
    microbatches: int = 1,
    log_every: int = 10,
    opt: OptConfig | None = None,
) -> dict:
    cfg = get_config(arch, reduced=reduced)
    model = LM(cfg)
    opt_cfg = opt or OptConfig(warmup_steps=10, total_steps=max(steps, 10))
    step_fn = training_step.make_train_step(
        model, opt_cfg, microbatches=microbatches, remat=None
    )
    store = CheckpointStore(ckpt_dir)
    stream = TokenStream(cfg, batch, seq, seed=seed)

    shardings = None
    if mesh is not None:
        shardings = {
            "state": tree_shardings(
                training_step.state_axes(model),
                training_step.state_specs(model),
                TRAIN_RULES,
                mesh,
            )
        }

    # --- restore or init ---
    start = store.latest_step()
    if start is not None:
        template = jax.tree.map(
            lambda s: np.zeros(s.shape, s.dtype), training_step.state_specs(model)
        )
        state, extra = store.restore(
            start, template, shardings["state"] if shardings else None
        )
        stream.seek(extra["stream"])
        print(f"[train] resumed from step {start}")
    else:
        state = training_step.init_state(model, jax.random.PRNGKey(seed))
        start = 0

    jit_kw = {"donate_argnums": (0,)}
    if shardings is not None:
        jit_kw["in_shardings"] = (shardings["state"], None)
    jitted = jax.jit(step_fn, **jit_kw)

    losses = []
    t0 = time.perf_counter()
    ctx = sharding_ctx(mesh, TRAIN_RULES) if mesh is not None else None
    for i in range(start, steps):
        if i == fail_at:
            store.wait()
            raise SimulatedFailure(f"injected failure at step {i}")
        batch_data = stream.next()
        if ctx is not None:
            with ctx:
                state, metrics = jitted(state, batch_data)
        else:
            state, metrics = jitted(state, batch_data)
        loss = float(metrics["loss"])
        losses.append(loss)
        if (i + 1) % log_every == 0:
            print(
                f"[train] step {i+1}/{steps} loss={loss:.4f}"
                f" gnorm={float(metrics['grad_norm']):.3f}"
                f" ({(time.perf_counter()-t0)/max(1,i+1-start):.2f}s/step)"
            )
        if (i + 1) % ckpt_every == 0 or (i + 1) == steps:
            store.save(
                i + 1, state, extra={"stream": stream.state()}, async_=True
            )
    store.wait()
    return {"final_loss": losses[-1] if losses else None, "losses": losses,
            "state": state, "steps_run": len(losses)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, default=-1)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = train(
        args.arch, reduced=args.reduced, steps=args.steps, batch=args.batch,
        seq=args.seq, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        fail_at=args.fail_at, microbatches=args.microbatches, seed=args.seed,
    )
    print(f"[train] done: final_loss={out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
