"""Serving driver: continuous-batching decode loop with SLA-aware admission.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --requests 8

The engine mirrors production LLM serving: a fixed decode batch of slots,
prefill on admission (slot fill), one decode step advances every active
slot, finished requests free their slot. Requests carry the paper's
service levels; admission order is IMMEDIATE > RELAXED (deadline-aware) >
BEST_EFFORT, i.e. the flexible-SLA queues of core/ applied at the
slot-admission level — the SOS view of serving: every decode step is a
fixed-shape stage task.
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models.transformer import LM
from ..core.sla import ServiceLevel


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    sla: ServiceLevel = ServiceLevel.IMMEDIATE
    submit_t: float = 0.0
    out_tokens: list = field(default_factory=list)
    start_t: Optional[float] = None
    finish_t: Optional[float] = None


class ServeEngine:
    def __init__(self, arch: str, *, reduced: bool = True, slots: int = 4,
                 max_len: int = 128, relaxed_deadline_s: float = 5.0,
                 seed: int = 0):
        self.cfg = get_config(arch, reduced=reduced)
        self.model = LM(self.cfg)
        self.params = self.model.init(jax.random.PRNGKey(seed), dtype=jnp.float32)
        self.slots = slots
        self.max_len = max_len
        self.relaxed_deadline_s = relaxed_deadline_s
        self.cache = self.model.init_cache(slots, max_len, dtype=jnp.float32)
        self.active: list[Optional[Request]] = [None] * slots
        self.queues = {lvl: [] for lvl in ServiceLevel}
        self._decode = jax.jit(
            lambda p, c, t: self.model.decode_step(p, c, t, dtype=jnp.float32)
        )
        self.t0 = time.monotonic()

    def now(self) -> float:
        return time.monotonic() - self.t0

    def submit(self, req: Request) -> None:
        req.submit_t = self.now()
        self.queues[req.sla].append(req)

    def _next_request(self) -> Optional[Request]:
        if self.queues[ServiceLevel.IMMEDIATE]:
            return self.queues[ServiceLevel.IMMEDIATE].pop(0)
        rel = self.queues[ServiceLevel.RELAXED]
        if rel:
            # deadline-aware: pull when near the pending limit, or when
            # there is no immediate pressure (which is the case here)
            return rel.pop(0)
        if self.queues[ServiceLevel.BEST_EFFORT]:
            # BoE fills slots only when everything else is drained
            return self.queues[ServiceLevel.BEST_EFFORT].pop(0)
        return None

    def _admit(self, slot: int, req: Request) -> None:
        """Prefill the request into the slot's cache rows."""
        req.start_t = self.now()
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        logits, cache1 = self.model.prefill(
            self.params, toks, kv_len=self.max_len, dtype=jnp.float32
        )

        # write slot rows: cache leaves carry the batch axis at different
        # ranks (stacked layer caches vs top-level lengths)
        def write(big, small):
            # small has B=1 at the same axis where big has B=self.slots
            baxis = None
            for ax in range(big.ndim):
                if big.shape[ax] == self.slots and small.shape[ax] == 1:
                    baxis = ax
                    break
            if baxis is None:
                return big
            idx = [slice(None)] * big.ndim
            idx[baxis] = slice(slot, slot + 1)
            return big.at[tuple(idx)].set(small)

        self.cache = jax.tree.map(write, self.cache, cache1)
        self.active[slot] = req
        req.out_tokens.append(int(jnp.argmax(logits[0])))

    def step(self) -> None:
        # fill free slots
        for s in range(self.slots):
            if self.active[s] is None:
                req = self._next_request()
                if req is None:
                    break
                self._admit(s, req)
        if not any(self.active):
            return
        toks = jnp.asarray(
            [
                (r.out_tokens[-1] if r and r.out_tokens else 0)
                for r in self.active
            ],
            jnp.int32,
        )[:, None]
        logits, self.cache = self._decode(self.params, self.cache, toks)
        nxt = jnp.argmax(logits, axis=-1)
        for s, r in enumerate(self.active):
            if r is None:
                continue
            r.out_tokens.append(int(nxt[s]))
            if len(r.out_tokens) >= r.max_new:
                r.finish_t = self.now()
                self.active[s] = None

    def run(self, requests: list[Request], max_steps: int = 1000) -> list[Request]:
        for r in requests:
            self.submit(r)
        done: list[Request] = []
        for _ in range(max_steps):
            self.step()
            done = [r for r in requests if r.finish_t is not None]
            if len(done) == len(requests):
                break
        return requests


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()
    eng = ServeEngine(args.arch, slots=args.slots)
    rng = np.random.default_rng(0)
    levels = [ServiceLevel.IMMEDIATE, ServiceLevel.RELAXED, ServiceLevel.BEST_EFFORT]
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(0, eng.cfg.vocab_size, size=12),
            max_new=args.new_tokens,
            sla=levels[i % 3],
        )
        for i in range(args.requests)
    ]
    t0 = time.perf_counter()
    eng.run(reqs)
    for r in reqs:
        lat = (r.finish_t or 0) - r.submit_t
        print(
            f"req {r.rid} sla={r.sla.short} latency={lat:6.2f}s"
            f" tokens={len(r.out_tokens)} first={r.out_tokens[:4]}"
        )
    print(f"[serve] {len(reqs)} requests in {time.perf_counter()-t0:.1f}s")


if __name__ == "__main__":
    main()
