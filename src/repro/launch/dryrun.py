import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the
# device count on first init). Only the dry-run forces 512 host devices;
# tests and benchmarks see the real single CPU device.

"""Multi-pod dry-run: lower + compile EVERY (architecture × input shape)
on the production meshes and record memory / cost / collective analyses.

  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k --multi-pod

Per cell this produces results/dryrun/<arch>__<shape>__<mesh>[__variant].json:
  - memory_analysis of the FULL (scan-rolled) program: per-device bytes,
    proves the cell fits 16 GiB HBM chips;
  - collective schedule of the full program;
  - roofline terms from depth-differencing: two UNROLLED programs at 1 and
    2 super-layers give exact per-layer FLOPs/bytes/collective-bytes
    (cost_analysis counts a rolled `while` body once — verified — so the
    rolled program cannot be used for per-step totals).
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from ..configs import ARCHS, cells, get_config, get_shape, runnable
from ..perf.hlo import collective_summary
from ..perf.hw import V5E, roofline_terms
from .mesh import make_production_mesh
from .programs import build_program

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _mem_stats(compiled) -> dict:
    ma = compiled.memory_analysis()
    if ma is None:
        return {}
    keys = [
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ]
    return {k: int(getattr(ma, k, 0) or 0) for k in keys}


def _cost(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }


def _compile_cell(arch, shape, mesh, *, depth_supers=None, unroll=False, **kw):
    prog = build_program(arch, shape, mesh, depth_supers=depth_supers, unroll=unroll, **kw)
    with mesh:
        lowered = prog.lower()
        compiled = lowered.compile()
    return prog, compiled


def run_cell(arch: str, shape: str, *, multi_pod: bool, variant: str = "baseline",
             skip_diff: bool = False, **build_kw) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    cell = get_shape(shape)
    cfg = get_config(arch)
    rec: dict = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_name,
        "chips": chips,
        "variant": variant,
        "kind": cell.kind,
    }
    ok, why = runnable(arch, cell)
    if not ok:
        rec["status"] = "skipped"
        rec["skip_reason"] = why
        return rec

    t0 = time.perf_counter()
    # 1) FULL program: sharding-coherence proof + memory + collective schedule
    prog, compiled = _compile_cell(arch, shape, mesh, variant=variant, **build_kw)
    rec["full"] = {
        "memory": _mem_stats(compiled),
        "cost_analysis_rolled": _cost(compiled),
        "collectives": collective_summary(compiled.as_text(), chips),
        "compile_s": round(time.perf_counter() - t0, 1),
    }
    hbm = rec["full"]["memory"]
    per_dev = sum(
        hbm.get(k, 0)
        for k in ("argument_size_in_bytes", "output_size_in_bytes", "temp_size_in_bytes")
    ) - hbm.get("alias_size_in_bytes", 0)
    rec["full"]["per_device_bytes_estimate"] = per_dev
    rec["full"]["fits_hbm"] = bool(per_dev <= V5E.hbm_bytes)

    if skip_diff:
        rec["status"] = "ok"
        return rec

    # 2) depth differencing with unrolled scans: accurate per-step totals.
    # microbatches=1 here: totals are scheduling-invariant, and a rolled
    # microbatch loop would be counted once by cost_analysis.
    t1 = time.perf_counter()
    _, c1 = _compile_cell(arch, shape, mesh, depth_supers=1, unroll=True,
                          variant=variant, microbatches=1, **build_kw)
    _, c2 = _compile_cell(arch, shape, mesh, depth_supers=2, unroll=True,
                          variant=variant, microbatches=1, **build_kw)
    model = prog.model
    n_super = model.n_super
    f1, f2 = _cost(c1), _cost(c2)
    w1 = collective_summary(c1.as_text(), chips)["total_wire_bytes_per_chip"]
    w2 = collective_summary(c2.as_text(), chips)["total_wire_bytes_per_chip"]
    per_super = {
        "flops": f2["flops"] - f1["flops"],
        "bytes": f2["bytes_accessed"] - f1["bytes_accessed"],
        "wire": w2 - w1,
    }
    residual = {
        "flops": f1["flops"] - per_super["flops"],
        "bytes": f1["bytes_accessed"] - per_super["bytes"],
        "wire": w1 - per_super["wire"],
    }
    # ALL quantities below are PER-CHIP: cost_analysis reports the
    # post-SPMD per-device program, and collective_summary converts to
    # per-chip wire bytes.
    total = {
        "flops_per_chip": residual["flops"] + n_super * per_super["flops"],
        "bytes_per_chip": residual["bytes"] + n_super * per_super["bytes"],
        "wire_per_chip": residual["wire"] + n_super * per_super["wire"],
    }
    terms = roofline_terms(
        total["flops_per_chip"], total["bytes_per_chip"], total["wire_per_chip"], chips
    )
    # usefulness ratio: MODEL_FLOPS / (chips * per-chip HLO flops), with
    # MODEL_FLOPS = 6*N_active*tokens (train, fwd+bwd) or 2*N_active*tokens
    # (inference). Catches remat recompute and replication waste.
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    factor = 6 if cell.kind == "train" else 2
    model_flops = factor * cfg.active_params() * tokens
    hlo_flops_global = chips * total["flops_per_chip"]
    rec["roofline"] = {
        "per_super": per_super,
        "residual": residual,
        "total": total,
        "terms": terms,
        "model_flops": model_flops,
        "hlo_flops_global": hlo_flops_global,
        "useful_ratio": model_flops / hlo_flops_global if hlo_flops_global else 0.0,
        "diff_compile_s": round(time.perf_counter() - t1, 1),
    }
    rec["status"] = "ok"
    return rec


def out_path(arch: str, shape: str, mesh_name: str, variant: str) -> Path:
    v = "" if variant == "baseline" else f"__{variant}"
    return RESULTS / f"{arch}__{shape}__{mesh_name}{v}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--skip-diff", action="store_true")
    args = ap.parse_args()

    RESULTS.mkdir(parents=True, exist_ok=True)
    if args.all:
        todo = list(cells())
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, get_shape(args.shape))]
    meshes = [True, False] if (args.both_meshes or args.all) else [args.multi_pod]

    failures = 0
    for arch, cell in todo:
        for mp in meshes:
            mesh_name = "2x16x16" if mp else "16x16"
            path = out_path(arch, cell.name, mesh_name, args.variant)
            if path.exists() and not args.force:
                print(f"cached   {path.name}")
                continue
            t0 = time.perf_counter()
            try:
                rec = run_cell(
                    arch, cell.name, multi_pod=mp, variant=args.variant,
                    skip_diff=args.skip_diff,
                )
            except Exception as e:  # noqa: BLE001 - record and continue
                rec = {
                    "arch": arch, "shape": cell.name, "mesh": mesh_name,
                    "variant": args.variant, "status": "error",
                    "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:],
                }
                failures += 1
            path.write_text(json.dumps(rec, indent=1))
            status = rec.get("status")
            extra = ""
            if status == "ok" and "roofline" in rec:
                t = rec["roofline"]["terms"]
                extra = (
                    f" step={t['step_s']*1e3:.2f}ms bottleneck={t['bottleneck']}"
                    f" useful={rec['roofline']['useful_ratio']:.2f}"
                )
            print(
                f"{status:8s} {arch} {cell.name} {mesh_name}"
                f" ({time.perf_counter()-t0:.0f}s){extra}",
                flush=True,
            )
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
