"""Cell program builder: (arch × shape × mesh) -> jittable step + shardings.

Single source of truth used by the multi-pod dry-run, the roofline
analysis, the benchmarks, and the SLA cost model. A "variant" selects the
sharding/remat strategy so the §Perf hillclimb can A/B strategies without
touching model code.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..configs import get_config, get_shape
from ..data.batches import batch_axes, prefill_specs, train_specs
from ..models.config import ModelConfig, ShapeCell
from ..models.transformer import LM
from ..optim.adamw import OptConfig
from ..parallel.sharding import (
    Rules,
    rules_for,
    sharding_ctx,
    tree_shardings,
)
from ..training import step as training_step

BF16 = jnp.bfloat16
F32 = jnp.float32


@dataclass
class CellProgram:
    arch: str
    cell: ShapeCell
    kind: str  # train | prefill | decode
    fn: Callable
    in_specs: tuple
    in_shardings: tuple
    donate_argnums: tuple
    mesh: jax.sharding.Mesh
    rules: Rules
    cfg: ModelConfig
    model: LM
    meta: dict = field(default_factory=dict)

    def jitted(self):
        return jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            donate_argnums=self.donate_argnums,
        )

    def lower(self):
        return self.jitted().lower(*self.in_specs)


def _scaled_cfg(cfg: ModelConfig, depth_supers: Optional[int], period: int, n_super: int):
    """Scale depth to `depth_supers` super-layers (roofline differencing)."""
    if depth_supers is None:
        return cfg
    kw = {"num_layers": period * depth_supers}
    if cfg.is_encoder_decoder:
        enc_per_super = max(1, cfg.num_encoder_layers // n_super)
        kw["num_encoder_layers"] = enc_per_super * depth_supers
    return cfg.replace(**kw)


def _data_shards(mesh: jax.sharding.Mesh, rules: Rules) -> int:
    ax = rules.get("batch")
    if ax is None:
        return 1
    axes = ax if isinstance(ax, tuple) else (ax,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def default_microbatches(cfg: ModelConfig, cell: ShapeCell, mesh, rules) -> int:
    """Smallest power-of-two microbatch count keeping per-device remat
    residuals (L x B_local x S x D x 2B) under ~2 GiB. Capped so each
    microbatch still spans every data shard."""
    shards = _data_shards(mesh, rules)
    local_b = max(1, cell.global_batch // shards)
    resid = cfg.num_layers * local_b * cell.seq_len * cfg.d_model * 2
    mb, cap = 1, max(1, cell.global_batch // shards)
    while resid / mb > 2 * 2**30 and mb < cap:
        mb *= 2
    return mb


#: named §Perf variants -> build_program overrides
def _serve_fsdp_rules(kind: str, multi_pod: bool) -> Rules:
    r = dict(rules_for(kind, multi_pod=multi_pod))
    r["fsdp"] = "data"  # ZeRO-style weight sharding for big-model serving
    return r


def _kvseq_rules(kind: str, multi_pod: bool) -> Rules:
    r = dict(rules_for(kind, multi_pod=multi_pod))
    # flash-decode: KV sequence sharded over "model"; kv_heads/head_dim
    # replicated -> no q-vs-kv layout mismatch, softmax stats all-reduce
    # is (B,H,1)-tiny
    r["kv_seq"] = "model"
    r["kv_heads"] = None
    r["head_dim"] = None
    r["kv_param_hd"] = None
    return r


def _long_tp_rules(kind: str, multi_pod: bool) -> Rules:
    r = dict(rules_for(kind, multi_pod=multi_pod))
    r["fsdp"] = None  # weights TP-only: no per-token ZeRO gathers
    return r


def _cshard_rules(kind: str, multi_pod: bool) -> Rules:
    r = dict(rules_for(kind, multi_pod=multi_pod))
    r["capacity"] = "model"
    r["moe_ff"] = None
    return r


VARIANTS: dict[str, dict] = {
    "remat_dots": {"remat": "dots"},
    "remat_none": {"remat": None},
    # shard MoE expert compute on capacity rows; expert weights replicate
    # over model (still FSDP over data) -> no row-parallel all-reduce
    "moe_cshard": {"rules_fn": _cshard_rules},
    "moe_cshard_dots": {"rules_fn": _cshard_rules, "remat": "dots"},
    "dots_mb2": {"remat": "dots", "microbatches": 2},
    "dots_mb4": {"remat": "dots", "microbatches": 4},
    # save only all-reduced sublayer outputs (tagged "coll_out")
    "remat_coll": {"remat": "coll"},
    "coll_mb16": {"remat": "coll", "microbatches": 16},
    "serve_fsdp": {"rules_fn": _serve_fsdp_rules},
    "long_tp": {"rules_fn": _long_tp_rules},
    # int8 KV cache: halves decode's dominant HBM stream
    "kv_int8": {"kv_quant": True},
    # sequence-sharded KV decode (flash-decode over the model axis)
    "decode_kvseq": {"rules_fn": _kvseq_rules},
    "decode_kvseq_int8": {"rules_fn": _kvseq_rules, "kv_quant": True},
    # big-model prefill: ZeRO weights + sequential batch chunks
    # (pmb=2 keeps each chunk's batch >= the 16-way data axis)
    "big_serve": {"rules_fn": _serve_fsdp_rules, "prefill_microbatches": 2},
}


def build_program(
    arch: str,
    shape: str,
    mesh: jax.sharding.Mesh,
    *,
    reduced: bool = False,
    depth_supers: Optional[int] = None,
    unroll: bool = False,
    variant: str = "baseline",
    microbatches: Optional[int] = None,
    remat: Optional[str] = "full",
    rules_override: Optional[Rules] = None,
    prefill_microbatches: int = 1,
    kv_quant: bool = False,
) -> CellProgram:
    if variant in VARIANTS:
        for k, v in VARIANTS[variant].items():
            if k == "remat":
                remat = v
            elif k == "microbatches" and microbatches is None:
                # explicit caller values win (the roofline differencing
                # passes microbatches=1: totals are schedule-invariant)
                microbatches = v
            elif k == "prefill_microbatches":
                prefill_microbatches = v
            elif k == "kv_quant":
                kv_quant = v
            elif k == "rules":
                rules_override = v
    cell = get_shape(shape)
    cfg0 = get_config(arch, reduced=reduced)
    probe = LM(cfg0)  # for period/n_super before scaling
    cfg = _scaled_cfg(cfg0, depth_supers, probe.period, probe.n_super)
    model = LM(cfg, scan_unroll=unroll, kv_quant=kv_quant)

    multi_pod = "pod" in mesh.axis_names
    rule_kind = "long" if cell.name == "long_500k" else cell.kind
    if variant in VARIANTS and "rules_fn" in VARIANTS[variant]:
        rules_override = VARIANTS[variant]["rules_fn"](rule_kind, multi_pod)
    rules = rules_override or rules_for(rule_kind, multi_pod=multi_pod)
    meta = {"variant": variant, "multi_pod": multi_pod, "rule_kind": rule_kind}

    if cell.kind == "train":
        st_specs = training_step.state_specs(model)
        st_axes = training_step.state_axes(model)
        st_sh = tree_shardings(st_axes, st_specs, rules, mesh)
        b_specs = train_specs(cfg, cell, dtype=BF16)
        b_ax = batch_axes(cfg, "train")
        b_sh = {
            k: tree_shardings(b_ax[k], v, rules, mesh) for k, v in b_specs.items()
        }
        opt_cfg = OptConfig()
        if microbatches is None:
            microbatches = default_microbatches(cfg, cell, mesh, rules)
        meta["microbatches"] = microbatches
        step_fn = training_step.make_train_step(
            model, opt_cfg, microbatches=microbatches, remat=remat
        )

        def fn(state, batch):
            with sharding_ctx(mesh, rules):
                return step_fn(state, batch)

        return CellProgram(
            arch, cell, "train", fn,
            in_specs=(st_specs, b_specs),
            in_shardings=(st_sh, b_sh),
            donate_argnums=(0,),
            mesh=mesh, rules=rules, cfg=cfg, model=model, meta=meta,
        )

    # --- serving ---
    p_specs = model.param_shapes(BF16)
    p_ax = model.param_axes()
    p_sh = tree_shardings(p_ax, p_specs, rules, mesh)

    if cell.kind == "prefill":
        b_specs = prefill_specs(cfg, cell, dtype=BF16)
        b_ax = batch_axes(cfg, "prefill")
        b_sh = {
            k: tree_shardings(b_ax[k], v, rules, mesh) for k, v in b_specs.items()
        }
        pmb = prefill_microbatches
        meta["prefill_microbatches"] = pmb

        def _prefill_one(params, batch):
            return model.prefill(
                params,
                batch["tokens"],
                frontend_embeds=batch.get("patch_embeds"),
                enc_embeds=batch.get("enc_embeds"),
            )

        def fn(params, batch):
            with sharding_ctx(mesh, rules):
                if pmb <= 1:
                    return _prefill_one(params, batch)
                # sequential batch chunks bound the S=32k activation
                # live-set (EXPERIMENTS.md SPerf B4). Chunk results are
                # written in place into the full cache/logits with
                # dynamic_update_slice (a lax.map + transpose merge was
                # measured at 91.6 GiB of stacked/copied caches).
                B = cell.global_batch
                Bc = B // pmb
                full_spec = model.cache_spec(
                    B, cell.seq_len, dtype=BF16,
                    enc_len=cell.seq_len if cfg.is_encoder_decoder else None,
                )
                ax = model.cache_axes(full_spec)
                full_cache = jax.tree.map(
                    lambda sd: jnp.full(sd.shape, -1, sd.dtype)
                    if sd.dtype == jnp.int32
                    else jnp.zeros(sd.shape, sd.dtype),
                    full_spec,
                )
                full_logits = jnp.zeros((B, cfg.vocab_size), F32)

                def body(i, carry):
                    logits_acc, cache_acc = carry
                    chunk = jax.tree.map(
                        lambda x: jax.lax.dynamic_slice_in_dim(
                            x, i * Bc, Bc, axis=0
                        ),
                        batch,
                    )
                    lg, cc = _prefill_one(params, chunk)
                    logits_acc = jax.lax.dynamic_update_slice_in_dim(
                        logits_acc, lg.astype(F32), i * Bc, axis=0
                    )

                    def put(axes, big, small):
                        bpos = list(axes).index("batch")
                        return jax.lax.dynamic_update_slice_in_dim(
                            big, small, i * Bc, axis=bpos
                        )

                    cache_acc = jax.tree.map(
                        put, ax, cache_acc, cc,
                        is_leaf=lambda a: isinstance(a, tuple),
                    )
                    return logits_acc, cache_acc

                logits, cache = jax.lax.fori_loop(
                    0, pmb, body, (full_logits, full_cache)
                )
                return logits, cache

        return CellProgram(
            arch, cell, "prefill", fn,
            in_specs=(p_specs, b_specs),
            in_shardings=(p_sh, b_sh),
            donate_argnums=(),
            mesh=mesh, rules=rules, cfg=cfg, model=model, meta=meta,
        )

    # decode: one new token against a kv_len context
    B = cell.global_batch
    c_specs = model.cache_spec(
        B, cell.seq_len, dtype=BF16,
        enc_len=cell.seq_len if cfg.is_encoder_decoder else None,
    )
    c_ax = model.cache_axes(c_specs)
    c_sh = tree_shardings(c_ax, c_specs, rules, mesh)
    t_spec = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    t_sh = tree_shardings(("batch", "seq"), t_spec, rules, mesh)

    def fn(params, cache, tokens):
        with sharding_ctx(mesh, rules):
            return model.decode_step(params, cache, tokens)

    return CellProgram(
        arch, cell, "decode", fn,
        in_specs=(p_specs, c_specs, t_spec),
        in_shardings=(p_sh, c_sh, t_sh),
        donate_argnums=(1,),
        mesh=mesh, rules=rules, cfg=cfg, model=model, meta=meta,
    )
