"""Multi-host bootstrap for real TPU pods.

This container lowers against faked devices; on a real v5e pod slice each
host runs THIS same entry point and jax.distributed coordinates them:

    # on every host of the slice (GKE/QR give the env automatically):
    python -m repro.launch.multihost --steps 1000 --arch mixtral-8x7b \
        --coordinator ${MEGASCALE_COORDINATOR_ADDRESS:-$HOST0:1234}

What carries over from the dry-run unchanged:
  * make_production_mesh() — jax.make_mesh uses all globally-visible
    devices; the (pod, data, model) axes map onto the real slice topology;
  * the cell programs (launch/programs.py) — in_shardings are global, so
    jit compiles the identical SPMD module the dry-run validated;
  * per-host data loading — TokenStream(host_index=process_index,
    host_count=process_count) feeds each host its batch shard, and
    jax.make_array_from_process_local_data assembles the global arrays;
  * checkpointing — every host writes its addressable shards; restore is
    elastic across pod counts (checkpoint/store.py).

Failure handling on real fleets: the driver loop is the same
checkpoint/restart pattern tests/test_fault.py exercises — a failed host
brings the slice down, the scheduler restarts all hosts, and training
resumes from the last snapshot (including the data cursor). Straggler
mitigation within a step is XLA's (collectives are synchronous); across
steps, the async checkpointer keeps the critical path clean.
"""
from __future__ import annotations

import argparse
import os


def initialize(coordinator: str | None = None, num_processes: int | None = None,
               process_id: int | None = None) -> dict:
    """jax.distributed.initialize with env fallbacks; returns topology."""
    import jax

    kw = {}
    if coordinator:
        kw["coordinator_address"] = coordinator
    if num_processes is not None:
        kw["num_processes"] = num_processes
    if process_id is not None:
        kw["process_id"] = process_id
    if kw or os.environ.get("COORDINATOR_ADDRESS"):
        jax.distributed.initialize(**kw)
    return {
        "process_index": jax.process_index(),
        "process_count": jax.process_count(),
        "local_devices": jax.local_device_count(),
        "global_devices": jax.device_count(),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--coordinator", default=os.environ.get("COORDINATOR_ADDRESS"))
    ap.add_argument("--num-processes", type=int, default=None)
    ap.add_argument("--process-id", type=int, default=None)
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default="gs://BUCKET/ckpt")
    args = ap.parse_args()

    topo = initialize(args.coordinator, args.num_processes, args.process_id)
    print(f"[multihost] topology: {topo}")

    import jax

    from ..configs import get_config
    from .mesh import make_production_mesh

    multi_pod = jax.device_count() > 256
    mesh = make_production_mesh(multi_pod=multi_pod)
    print(f"[multihost] mesh {dict(mesh.shape)} on {jax.device_count()} chips")

    # the rest is the dry-run-validated program, now against real devices
    from .programs import build_program

    prog = build_program(args.arch, "train_4k", mesh, variant="remat_coll")
    with mesh:
        compiled = prog.lower().compile()
    print("[multihost] compiled:", compiled.memory_analysis())
    print("[multihost] ready — wire into launch/train.py's driver loop "
          "with TokenStream(host_index=%d, host_count=%d)"
          % (topo["process_index"], topo["process_count"]))


if __name__ == "__main__":
    main()
