"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (device count is locked on first jax init, and
only dryrun.py is allowed to force 512 host devices).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 chips per pod ("data","model"); 2 pods adds a leading "pod"
    axis. v5e pod slice = 256 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_local_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (CPU tests)."""
    return jax.make_mesh(
        (data, model),
        ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
