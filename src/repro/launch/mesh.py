"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (device count is locked on first jax init, and
only dryrun.py is allowed to force 512 host devices).
"""
from __future__ import annotations

import jax


def mesh_axis_kwargs(n_axes: int) -> dict:
    """`axis_types=Auto` where supported; older jax predates AxisType
    (explicit-sharding era) and already treats every axis as auto."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 chips per pod ("data","model"); 2 pods adds a leading "pod"
    axis. v5e pod slice = 256 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **mesh_axis_kwargs(len(axes)))


def make_local_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (CPU tests)."""
    return jax.make_mesh(
        (data, model), ("data", "model"), **mesh_axis_kwargs(2)
    )
