from .hlo import collective_summary, parse_collectives
from .hw import V5E, HwSpec, roofline_terms
