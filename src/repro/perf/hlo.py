"""Parse collective traffic out of post-SPMD compiled HLO text.

``compiled.cost_analysis()`` has no collective-bytes entry, so the roofline
collective term comes from here: every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute instruction is matched,
its per-partition result shape and replica-group size are parsed, and
converted to per-chip wire bytes with ring formulas:

  all-reduce      2 (N-1)/N * bytes      (reduce-scatter + all-gather phases)
  all-gather      (N-1)/N   * result     (result is the gathered shape)
  reduce-scatter  (N-1)     * result     (operand = N * result)
  all-to-all      (N-1)/N   * bytes
  collective-permute       1 * bytes
"""
from __future__ import annotations

import re
from dataclasses import dataclass

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<rtype>\([^)]*\)|[a-z0-9_]+\[[^\]]*\](?:\{[^}]*\})?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<start>-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


@dataclass
class Collective:
    op: str
    bytes_out: float  # per-partition result bytes
    group_size: int
    wire_bytes: float  # per-chip wire bytes


def _shape_bytes(type_str: str) -> float:
    """Sum byte sizes of all array shapes in a (possibly tuple) type."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _last_shape_bytes(type_str: str) -> float:
    """Bytes of the last array shape (the destination buffer of -start ops)."""
    matches = _SHAPE_RE.findall(type_str)
    if not matches:
        return 0.0
    dt, dims = matches[-1]
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip() != ""]
        return max(len(ids), 1)
    return total_devices


def _wire_bytes(op: str, out_bytes: float, n: int) -> float:
    if n <= 1:
        return 0.0
    if op == "all-reduce":
        return 2 * (n - 1) / n * out_bytes
    if op == "all-gather":
        return (n - 1) / n * out_bytes
    if op == "reduce-scatter":
        return (n - 1) * out_bytes
    if op == "all-to-all":
        return (n - 1) / n * out_bytes
    if op == "collective-permute":
        return out_bytes
    return 0.0


def parse_collectives(hlo_text: str, total_devices: int) -> list[Collective]:
    out = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        rtype = m.group("rtype")
        if m.group("start"):
            b = _last_shape_bytes(rtype)
        else:
            b = _shape_bytes(rtype)
        n = _group_size(line, total_devices)
        out.append(Collective(op, b, n, _wire_bytes(op, b, n)))
    return out


def collective_summary(hlo_text: str, total_devices: int) -> dict:
    colls = parse_collectives(hlo_text, total_devices)
    by_op: dict[str, dict] = {}
    for c in colls:
        d = by_op.setdefault(c.op, {"count": 0, "wire_bytes": 0.0, "out_bytes": 0.0})
        d["count"] += 1
        d["wire_bytes"] += c.wire_bytes
        d["out_bytes"] += c.bytes_out
    return {
        "total_wire_bytes_per_chip": sum(c.wire_bytes for c in colls),
        "count": len(colls),
        "by_op": by_op,
    }
