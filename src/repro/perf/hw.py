"""TPU v5e hardware model (the TARGET; this container only lowers)."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class HwSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12  # FLOP/s per chip
    hbm_bandwidth: float = 819e9  # B/s per chip
    ici_link_bandwidth: float = 50e9  # B/s per link
    hbm_bytes: int = 16 * 1024**3  # 16 GiB per chip
    vmem_bytes: int = 128 * 1024**2  # ~128 MiB VMEM
    # pricing for the SLA cost model (core/billing.py); unit: $/chip-hour.
    # Ratio mirrors the paper's spot-VM vs cloud-function gap (9-24x, §4.3).
    reserved_price: float = 1.2
    elastic_price_multiplier: float = 10.0


V5E = HwSpec()


def roofline_terms(
    flops_per_chip: float,
    hbm_bytes_per_chip: float,
    wire_bytes_per_chip: float,
    chips: int,
    hw: HwSpec = V5E,
) -> dict:
    """The three roofline terms in seconds.

    All inputs are PER-CHIP: ``compiled.cost_analysis()`` reports the
    post-SPMD per-device program (verified empirically), and the HLO
    collective parser converts to per-chip wire bytes. Equivalent to the
    global formulation HLO_FLOPs_global / (chips * peak) with
    HLO_FLOPs_global = chips * per-chip.
    """
    compute = flops_per_chip / hw.peak_flops_bf16
    memory = hbm_bytes_per_chip / hw.hbm_bandwidth
    collective = wire_bytes_per_chip / hw.ici_link_bandwidth
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    terms["step_s"] = max(compute, memory, collective)
    terms["bottleneck"] = max(
        ("compute_s", compute), ("memory_s", memory), ("collective_s", collective),
        key=lambda kv: kv[1],
    )[0].replace("_s", "")
    return terms
