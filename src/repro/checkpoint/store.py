"""Sharded checkpointing with elastic restore — no orbax dependency.

Layout (one directory per step):
  step_000123/
    MANIFEST.json        # tree structure, shapes, dtypes, shard table
    <leaf-key>.npz       # zstd-compressed npy shards (one file per leaf
                         #  per host in multi-host; single host here)

Properties the fault-tolerant driver relies on:
  * atomic publish: written to step_xxx.tmp, fsync'd, renamed;
  * elastic restore: leaves are stored UNSHARDED logically (host gathers
    its addressable shards); restore re-shards onto any mesh whose axes
    divide the leaf dims — a 512-chip checkpoint restores onto 256 chips
    and vice versa;
  * async save: the device->host copy happens synchronously (cheap), the
    compress+write runs on a background thread so training continues.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

try:  # optional: zstd when available, zlib otherwise (codec recorded
    import zstandard  # in the manifest so mixed environments interop)
except ModuleNotFoundError:
    zstandard = None

_SEP = "/"


def _flatten(tree) -> dict[str, Any]:
    flat = {}

    def rec(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                rec(prefix + [str(k)], v)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(prefix + [str(i)], v)
        else:
            flat[_SEP.join(prefix)] = node

    rec([], tree)
    return flat


def _unflatten(flat: dict[str, Any], template) -> Any:
    def rec(prefix, node):
        if isinstance(node, dict):
            return {k: rec(prefix + [str(k)], v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            vals = [rec(prefix + [str(i)], v) for i, v in enumerate(node)]
            return type(node)(vals)
        return flat[_SEP.join(prefix)]

    return rec([], template)


class CheckpointStore:
    def __init__(self, root: str | Path, keep: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pending: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> Path:
        return self.root / f"step_{step:08d}"

    def steps(self) -> list[int]:
        out = []
        for p in self.root.glob("step_*"):
            if p.is_dir() and (p / "MANIFEST.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # ------------------------------------------------------------------
    def save(self, step: int, tree, extra: Optional[dict] = None,
             async_: bool = False) -> None:
        """Snapshot `tree` (pytree of jax/np arrays) at `step`."""
        flat = _flatten(tree)
        # device -> host synchronously (so donated buffers can proceed)
        host = {k: np.asarray(v) for k, v in flat.items()}

        def write():
            tmp = self.root / f"step_{step:08d}.tmp"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            if zstandard is not None:
                codec, compress = "zstd", zstandard.ZstdCompressor(level=3).compress
            else:
                codec, compress = "zlib", (lambda b: zlib.compress(b, 6))
            manifest = {
                "step": step, "extra": extra or {}, "codec": codec, "leaves": {}
            }
            for i, (key, arr) in enumerate(sorted(host.items())):
                fn = f"leaf_{i:05d}.npz"
                manifest["leaves"][key] = {
                    "file": fn,
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                }
                raw = arr.tobytes()
                (tmp / fn).write_bytes(compress(raw))
            (tmp / "MANIFEST.json").write_text(json.dumps(manifest))
            final = self._step_dir(step)
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        if async_:
            self.wait()
            self._pending = threading.Thread(target=write, daemon=True)
            self._pending.start()
        else:
            write()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(
        self,
        step: Optional[int],
        template,
        shardings=None,
    ):
        """Restore into the structure of `template`; if `shardings` is a
        matching pytree of NamedShardings, leaves are placed sharded
        (elastic: any mesh whose axes divide the dims)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = self._step_dir(step)
        manifest = json.loads((d / "MANIFEST.json").read_text())
        codec = manifest.get("codec", "zstd")  # pre-codec manifests: zstd
        if codec == "zstd":
            if zstandard is None:
                raise RuntimeError(
                    f"checkpoint {d} is zstd-compressed but the 'zstandard' "
                    "package is not installed; `pip install zstandard` to "
                    "read it (new checkpoints fall back to zlib)"
                )
            decompress = zstandard.ZstdDecompressor().decompress
        else:
            decompress = zlib.decompress
        flat = {}
        for key, meta in manifest["leaves"].items():
            raw = decompress((d / meta["file"]).read_bytes())
            arr = np.frombuffer(raw, dtype=np.dtype(meta["dtype"]))
            flat[key] = arr.reshape(meta["shape"]).copy()
        tree = _unflatten(flat, template)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(jnp.asarray(x), s), tree, shardings
            )
        return tree, manifest["extra"]
