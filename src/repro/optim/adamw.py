"""AdamW + cosine schedule + global-norm clipping, in pure JAX.

No optax dependency: the optimizer state is a plain pytree {m, v} shaped
like the params, so the checkpointer and the FSDP sharding rules apply to
it unchanged (optimizer state shards exactly like its parameter).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(F32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, F32)
    return {"m": jax.tree.map(zeros, params), "v": jax.tree.map(zeros, params)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(F32))) for g in jax.tree.leaves(tree))
    )


def update(cfg: OptConfig, params, grads, opt_state, step: jax.Array):
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    lr = schedule(cfg, step)
    t = (step + 1).astype(F32)
    bc1 = 1 - cfg.b1**t
    bc2 = 1 - cfg.b2**t

    def upd(p, g, m, v):
        g = g.astype(F32)
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(F32)
        return (p.astype(F32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}, {"grad_norm": gnorm, "lr": lr}
