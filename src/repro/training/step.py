"""Training step factory: grad-accumulation microbatching, remat, AdamW.

The returned step is a pure function (state, batch) -> (state, metrics)
suitable for jit with donated state. Gradient reduction across the
data/pod axes is induced by the param shardings (XLA emits reduce-scatter
for FSDP-sharded params, all-reduce for replicated ones) — no explicit
collectives needed under pjit.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..models.transformer import LM
from ..optim import adamw

F32 = jnp.float32


def init_state(model: LM, key: jax.Array) -> dict:
    params = model.init(key, dtype=F32)
    return {
        "params": params,
        "opt": adamw.init(params),
        "step": jnp.zeros((), jnp.int32),
    }


def state_axes(model: LM) -> dict:
    pax = model.param_axes()
    return {
        "params": pax,
        "opt": {"m": pax, "v": pax},
        "step": (),
    }


def state_specs(model: LM) -> dict:
    ps = model.param_shapes(F32)
    return {
        "params": ps,
        "opt": {"m": ps, "v": ps},
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def make_train_step(
    model: LM,
    opt_cfg: adamw.OptConfig,
    *,
    microbatches: int = 1,
    remat: Optional[str] = "full",
    compute_dtype=jnp.bfloat16,
):
    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch, remat=remat, dtype=compute_dtype)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc(carry, mb):
                g_acc, l_acc, a_acc = carry
                (loss, metrics), grads = grad_fn(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, grads)
                return (g_acc, l_acc + loss, a_acc + metrics["aux"]), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
            (grads, loss, aux), _ = jax.lax.scan(
                acc, (g0, jnp.zeros((), F32), jnp.zeros((), F32)), micro
            )
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            metrics = {"ce": loss, "aux": aux / microbatches}

        new_params, new_opt, opt_metrics = adamw.update(
            opt_cfg, params, grads, state["opt"], state["step"]
        )
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
        }
        return new_state, {"loss": loss, **metrics, **opt_metrics}

    return train_step
