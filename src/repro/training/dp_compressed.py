"""Data-parallel training with int8 error-feedback gradient reduction.

shard_map over the "data" axis: params replicated, batch sharded, each
worker computes local grads, the cross-worker mean is transmitted int8
(parallel/compress.py). Used (a) as a distributed-optimization option in
the training driver, (b) as the §Perf "compressed-DP" dry-run variant
whose compiled HLO shows s8 all-gathers replacing f32 all-reduces.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..models.transformer import LM
from ..optim import adamw

F32 = jnp.float32


def init_state(model: LM, key) -> dict:
    params = model.init(key, dtype=F32)
    return {
        "params": params,
        "opt": adamw.init(params),
        "err": jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def make_dp_train_step(
    model: LM,
    opt_cfg: adamw.OptConfig,
    mesh: jax.sharding.Mesh,
    *,
    axis: str = "data",
    compress: bool = True,
    remat: Optional[str] = None,
):
    """Returns (state, batch) -> (state, metrics); batch sharded on `axis`."""
    from ..parallel.compress import tree_ef_allreduce_mean

    def local_loss(params, batch):
        loss, _ = model.loss(params, batch, remat=remat)
        return loss

    def shard_body(state, batch):
        params = state["params"]
        loss, grads = jax.value_and_grad(local_loss)(params, batch)
        loss = jax.lax.pmean(loss, axis)
        if compress:
            grads, new_err = tree_ef_allreduce_mean(grads, state["err"], axis)
        else:
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, axis), grads)
            new_err = state["err"]
        new_params, new_opt, om = adamw.update(
            opt_cfg, params, grads, state["opt"], state["step"]
        )
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "err": new_err,
            "step": state["step"] + 1,
        }
        return new_state, {"loss": loss, **om}

    def step(state, batch):
        rep = P()
        bspec = P(axis)
        in_specs = (
            jax.tree.map(lambda _: rep, state),
            jax.tree.map(lambda _: bspec, batch),
        )
        out_specs = (
            jax.tree.map(lambda _: rep, state),
            {"loss": rep, "grad_norm": rep, "lr": rep},
        )
        if hasattr(jax, "shard_map"):
            sm = jax.shard_map(
                shard_body, mesh=mesh, in_specs=in_specs,
                out_specs=out_specs, check_vma=False,
            )
        else:  # pre-0.5 jax: experimental spelling, check_rep kwarg
            from jax.experimental.shard_map import shard_map as _shard_map

            sm = _shard_map(
                shard_body, mesh=mesh, in_specs=in_specs,
                out_specs=out_specs, check_rep=False,
            )
        return sm(state, batch)

    return step
