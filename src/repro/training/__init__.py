from . import step
