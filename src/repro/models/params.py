"""Declarative parameters: one declaration drives init, logical axes, and
shape inspection (for dry-run ShapeDtypeStructs) without duplication.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamDecl:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis names, len == len(shape)
    init: str = "normal"  # normal | zeros | ones | constant
    fan_in: Optional[int] = None  # scale = 1/sqrt(fan_in); default shape[0]
    constant: float = 0.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


DeclTree = dict  # nested dict[str, ParamDecl | DeclTree]


def _is_decl(x) -> bool:
    return isinstance(x, ParamDecl)


def init_param(key: jax.Array, d: ParamDecl, dtype) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "constant":
        return jnp.full(d.shape, d.constant, dtype)
    fan_in = d.fan_in if d.fan_in is not None else (d.shape[0] if d.shape else 1)
    scale = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, d.shape, jnp.float32) * scale).astype(dtype)


def init_tree(key: jax.Array, decls: DeclTree, dtype) -> dict:
    """Initialize a params pytree from a declaration tree."""
    leaves, treedef = jax.tree.flatten(decls, is_leaf=_is_decl)
    keys = jax.random.split(key, len(leaves))
    inited = [init_param(k, d, dtype) for k, d in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, inited)


def axes_tree(decls: DeclTree) -> dict:
    return jax.tree.map(lambda d: d.axes, decls, is_leaf=_is_decl)


def shape_tree(decls: DeclTree, dtype) -> dict:
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), decls, is_leaf=_is_decl
    )


def stacked(decls: DeclTree, n: int) -> DeclTree:
    """Add a leading layer axis (logical name "layers" -> replicated)."""

    def one(d: ParamDecl) -> ParamDecl:
        return ParamDecl(
            (n,) + d.shape, ("layers",) + d.axes, d.init, d.fan_in, d.constant
        )

    return jax.tree.map(one, decls, is_leaf=_is_decl)


def init_stacked(key: jax.Array, decls: DeclTree, n: int, dtype) -> dict:
    """Init n stacked copies with independent keys (vmapped)."""
    keys = jax.random.split(key, n)

    def init_one(k):
        return init_tree(k, decls, dtype)

    return jax.vmap(init_one)(keys)


def count_params(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))
