"""Build model objects from configs."""
from __future__ import annotations

from .config import ModelConfig
from .transformer import LM


def build_model(cfg: ModelConfig, impl: str = "jnp") -> LM:
    return LM(cfg, impl=impl)
