"""Model/shape configuration for all assigned architectures.

Every architecture in the assignment is expressed as a ``ModelConfig``.
Configs are frozen dataclasses so they hash and can key compilation caches.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) cell from the assignment matrix."""

    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


@dataclass(frozen=True)
class ModelConfig:
    """Unified architecture description covering all 10 assigned families."""

    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention variants ---
    qkv_bias: bool = False
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    sliding_window: Optional[int] = None  # SWA width (mixtral; gemma2 local)
    # per-layer attention pattern, tiled over depth: "l"=local(sliding), "g"=global
    local_global_pattern: Optional[str] = None
    rope_theta: float = 10_000.0
    rope_interleaved: bool = True  # interleaved pairs are TP-shardable on head_dim
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    act: str = "silu"  # silu | gelu
    scale_embeddings: bool = False  # gemma-style sqrt(d_model) scaling
    post_block_norms: bool = False  # gemma2 sandwich norms
    attn_out_scale: Optional[float] = None

    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- SSM (mamba2 / jamba mamba layers) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    conv_width: int = 4

    # --- hybrid (jamba) ---
    # period description: attention at index attn_every-1 within each period
    hybrid_period: int = 0  # 0 => not hybrid
    hybrid_attn_index: int = 4  # position of the attention layer inside a period
    hybrid_moe_stride: int = 2  # MoE FFN every Nth layer inside a period

    # --- encoder-decoder (seamless) ---
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0

    # --- modality frontend stubs ([audio]/[vlm]) ---
    frontend: Optional[str] = None  # "audio_frames" | "vision_patches"
    frontend_tokens: int = 0  # positions supplied as precomputed embeddings

    dtype: str = "bfloat16"

    # ----- derived -----
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def is_hybrid(self) -> bool:
        return self.hybrid_period > 0

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """True when long-context decode state is bounded (<< seq_len)."""
        if self.family in ("ssm", "hybrid"):
            return True
        # SWA everywhere bounds the KV cache at the window size.
        return self.sliding_window is not None and self.local_global_pattern is None

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer mixer kind for the full depth ("attn" | "mamba")."""
        if not self.is_hybrid:
            kind = "mamba" if self.family == "ssm" else "attn"
            return tuple(kind for _ in range(self.num_layers))
        kinds = []
        for i in range(self.num_layers):
            kinds.append("attn" if (i % self.hybrid_period) == self.hybrid_attn_index else "mamba")
        return tuple(kinds)

    def ffn_kinds(self) -> Tuple[str, ...]:
        """Per-layer FFN kind ("moe" | "mlp")."""
        if not self.is_moe:
            return tuple("mlp" for _ in range(self.num_layers))
        if self.is_hybrid:
            return tuple(
                "moe" if (i % self.hybrid_moe_stride) == 1 else "mlp"
                for i in range(self.num_layers)
            )
        return tuple("moe" for _ in range(self.num_layers))

    def window_pattern(self) -> Tuple[Optional[int], ...]:
        """Per-layer sliding window (None = full attention)."""
        out = []
        for i in range(self.num_layers):
            if self.local_global_pattern:
                c = self.local_global_pattern[i % len(self.local_global_pattern)]
                out.append(self.sliding_window if c == "l" else None)
            else:
                out.append(self.sliding_window)
        return tuple(out)

    def num_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        h, k, hd = self.num_heads, self.num_kv_heads, self.head_dim
        attn = d * h * hd + 2 * d * k * hd + h * hd * d
        if self.qkv_bias:
            attn += (h + 2 * k) * hd
        mlp = 3 * d * ff
        moe = self.num_experts * 3 * d * ff + d * self.num_experts if self.is_moe else 0
        if self.ssm_state:
            di, g, ns = self.d_inner, 1, self.ssm_state
            nh = self.ssm_heads
            conv_ch = di + 2 * g * ns
            mamba = (
                d * (2 * di + 2 * g * ns + nh)  # in_proj
                + conv_ch * self.conv_width
                + 2 * nh  # A_log, D
                + di  # gated norm
                + di * d  # out_proj
            )
        else:
            mamba = 0
        total = 0
        for lk, fk in zip(self.layer_kinds(), self.ffn_kinds()):
            total += attn if lk == "attn" else mamba
            total += moe if fk == "moe" else mlp
            total += 2 * d  # norms
        if self.is_encoder_decoder:
            # encoder blocks: self-attn + mlp; decoder adds cross-attn per block
            total += self.num_encoder_layers * (attn + mlp + 2 * d)
            total += self.num_layers * (attn + d)  # cross-attn + norm
        total += v * d  # embed
        if not self.tie_embeddings:
            total += v * d
        total += d  # final norm
        return total

    def active_params(self) -> int:
        """Active parameters per token (MoE uses top_k of num_experts)."""
        if not self.is_moe:
            return self.num_params()
        d, ff = self.d_model, self.d_ff
        dead_experts = (self.num_experts - self.top_k) * 3 * d * ff
        n_moe_layers = sum(1 for k in self.ffn_kinds() if k == "moe")
        return self.num_params() - n_moe_layers * dead_experts

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
