"""Mamba2 state-space duality (SSD) — chunked reference + decode recurrence.

Implements the SSD algorithm from "Transformers are SSMs" (arXiv:2405.21060):
the sequence is split into chunks; within a chunk the recurrence is computed
as a masked, decay-weighted attention-like quadratic form; chunk states are
carried by a scan. A Pallas TPU kernel (kernels/ssd_scan.py) implements the
same chunking with VMEM tiles; this jnp version is its oracle and the
lowering path for the CPU dry-run.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..parallel.sharding import shard
from .config import ModelConfig
from .params import ParamDecl

F32 = jnp.float32


def ssd_chunked(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) positive step sizes
    A: jax.Array,  # (H,) negative continuous-time decay
    B_: jax.Array,  # (B, S, H, N) input matrix (already head-expanded)
    C_: jax.Array,  # (B, S, H, N) output matrix (already head-expanded)
    chunk: int,
    h0: Optional[jax.Array] = None,  # (B, H, P, N) initial state
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y, final_state): y (B,S,H,P), state (B,H,P,N)."""
    Bsz, S, H, P = x.shape
    N = B_.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc, Q = S // chunk, chunk

    # chunk-serial scan (the Pallas kernel's schedule, in jnp): only ONE
    # chunk's (B,Q,Q,H) quadratic tensors are live at a time — the fully
    # vectorized form materialized (B,nc,Q,Q,H) f32 several times over
    # (~17 GiB/device on jamba prefill_32k; see EXPERIMENTS.md §Perf B2)
    xr = jnp.moveaxis(x.reshape(Bsz, nc, Q, H, P), 1, 0).astype(F32)
    dtr = jnp.moveaxis(dt.reshape(Bsz, nc, Q, H), 1, 0).astype(F32)
    Br = jnp.moveaxis(B_.reshape(Bsz, nc, Q, H, N), 1, 0).astype(F32)
    Cr = jnp.moveaxis(C_.reshape(Bsz, nc, Q, H, N), 1, 0).astype(F32)
    Af = A.astype(F32)
    tri = jnp.tril(jnp.ones((Q, Q), bool))[None, :, :, None]

    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), F32)

    def step(h, inp):
        x_c, dt_c, B_c, C_c = inp  # (B,Q,H,*)
        dtA = dt_c * Af  # (B,Q,H), negative
        cs = jnp.cumsum(dtA, axis=1)  # inclusive
        # intra: L[q,k] = exp(cs_q - cs_k), q >= k
        diff = cs[:, :, None, :] - cs[:, None, :, :]  # (B,Q,K,H)
        L = jnp.where(tri, jnp.exp(diff), 0.0)
        scores = jnp.einsum("bqhn,bkhn->bqkh", C_c, B_c)
        M = scores * L * dt_c[:, None, :, :]
        y = jnp.einsum("bqkh,bkhp->bqhp", M, x_c)
        # inter: contribution of the carried state
        y += jnp.einsum("bqhn,bhpn->bqhp", C_c * jnp.exp(cs)[..., None], h)
        # chunk summary -> next state
        cs_last = cs[:, -1:, :]
        w = jnp.exp(cs_last - cs) * dt_c  # (B,Q,H)
        state_c = jnp.einsum("bqh,bqhp,bqhn->bhpn", w, x_c, B_c)
        h_next = jnp.exp(cs_last[:, 0, :])[:, :, None, None] * h + state_c
        return h_next, y

    h_final, ys = jax.lax.scan(
        jax.checkpoint(step, prevent_cse=False), h0, (xr, dtr, Br, Cr)
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, S, H, P)
    return y.astype(x.dtype), h_final


def ssd_decode_step(
    h: jax.Array,  # (B, H, P, N)
    x: jax.Array,  # (B, H, P)
    dt: jax.Array,  # (B, H)
    A: jax.Array,  # (H,)
    B_: jax.Array,  # (B, H, N)
    C_: jax.Array,  # (B, H, N)
) -> Tuple[jax.Array, jax.Array]:
    """One-token recurrence. Returns (y (B,H,P), new state)."""
    hf = h.astype(F32)
    dA = jnp.exp(dt.astype(F32) * A.astype(F32))  # (B,H)
    upd = dt.astype(F32)[:, :, None, None] * jnp.einsum(
        "bhp,bhn->bhpn", x.astype(F32), B_.astype(F32)
    )
    h_new = dA[:, :, None, None] * hf + upd
    y = jnp.einsum("bhpn,bhn->bhp", h_new, C_.astype(F32))
    return y.astype(x.dtype), h_new


# ---------------------------------------------------------------------------
# Mamba2 mixer layer (projections + conv + SSD + gated norm)
# ---------------------------------------------------------------------------

def mamba_decl(cfg: ModelConfig) -> dict:
    d, di, ns = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh, W = cfg.ssm_heads, cfg.conv_width
    conv_ch = di + 2 * ns  # x, B, C channels (single group)
    return {
        "in_proj": ParamDecl((d, 2 * di + 2 * ns + nh), ("fsdp", "ssm_inner"), fan_in=d),
        "conv_w": ParamDecl((W, conv_ch), (None, "conv_ch"), fan_in=W),
        "conv_b": ParamDecl((conv_ch,), ("conv_ch",), init="zeros"),
        "A_log": ParamDecl((nh,), ("ssm_heads",), init="zeros"),  # A = -1
        "D": ParamDecl((nh,), ("ssm_heads",), init="ones"),
        "dt_bias": ParamDecl((nh,), ("ssm_heads",), init="zeros"),
        "norm_w": ParamDecl((di,), ("ssm_inner",), init="ones"),
        "out_proj": ParamDecl((di, d), ("ssm_inner", "fsdp"), fan_in=di),
    }


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. u: (B,S,C), w: (W,C)."""
    W = w.shape[0]
    up = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    S = u.shape[1]
    out = sum(up[:, i : i + S, :] * w[i][None, None, :] for i in range(W))
    return out + b[None, None, :]


def _split_proj(zxbcdt: jax.Array, cfg: ModelConfig):
    di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xs = zxbcdt[..., di : 2 * di]
    B_ = zxbcdt[..., 2 * di : 2 * di + ns]
    C_ = zxbcdt[..., 2 * di + ns : 2 * di + 2 * ns]
    dt = zxbcdt[..., 2 * di + 2 * ns :]
    return z, xs, B_, C_, dt


def mamba_apply(
    p: dict,
    x: jax.Array,  # (B, S, D)
    *,
    cfg: ModelConfig,
    cache: Optional[dict] = None,  # {"ssm": (B,H,P,N), "conv": (B,W-1,conv_ch)}
    want_cache: bool = False,
    impl: str = "jnp",
):
    """Mamba2 mixer. Prefill/train when cache is None or want_cache;
    single-step decode when cache holds state and S == 1."""
    Bsz, S, D = x.shape
    dt_ = x.dtype
    di, ns, nh, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    W = cfg.conv_width

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(dt_))
    zxbcdt = shard(zxbcdt, "batch", "seq", "ssm_inner")
    z, xs, B_, C_, dtr = _split_proj(zxbcdt, cfg)
    conv_in = jnp.concatenate([xs, B_, C_], axis=-1)  # (B,S,conv_ch)

    decode = cache is not None and "ssm" in cache and S == 1
    if decode:
        full = jnp.concatenate([cache["conv"].astype(dt_), conv_in], axis=1)
        conv_out = jnp.einsum(
            "bwc,wc->bc", full.astype(F32), p["conv_w"].astype(F32)
        ) + p["conv_b"].astype(F32)
        conv_out = conv_out[:, None, :].astype(dt_)
        new_conv = full[:, 1:, :]
    else:
        conv_out = _causal_conv(conv_in, p["conv_w"].astype(dt_), p["conv_b"].astype(dt_))
        new_conv = conv_in[:, -(W - 1) :, :] if want_cache else None
    conv_out = jax.nn.silu(conv_out)

    xs_c = conv_out[..., :di].reshape(Bsz, S, nh, P)
    B_c = conv_out[..., di : di + ns]  # (B,S,N) single group
    C_c = conv_out[..., di + ns :]
    Bh = jnp.broadcast_to(B_c[:, :, None, :], (Bsz, S, nh, ns))
    Ch = jnp.broadcast_to(C_c[:, :, None, :], (Bsz, S, nh, ns))
    dt_act = jax.nn.softplus(dtr.astype(F32) + p["dt_bias"].astype(F32))  # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(F32))  # (H,)

    if decode:
        h0 = cache["ssm"]
        y1, h_new = ssd_decode_step(
            h0, xs_c[:, 0], dt_act[:, 0], A, Bh[:, 0], Ch[:, 0]
        )
        y = y1[:, None]  # (B,1,H,P)
        new_cache = {"ssm": h_new, "conv": new_conv}
    else:
        h0 = cache["ssm"] if (cache is not None and "ssm" in cache) else None
        chunk = min(cfg.ssm_chunk, S)
        pad = (-S) % chunk
        if pad:
            # right-pad with dt=0: exp(0)=1 leaves the state untouched and
            # padded outputs are dropped below
            xp = jnp.pad(xs_c, ((0, 0), (0, pad), (0, 0), (0, 0)))
            Bp = jnp.pad(Bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            Cp = jnp.pad(Ch, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dtp = jnp.pad(dt_act, ((0, 0), (0, pad), (0, 0)))
        else:
            xp, Bp, Cp, dtp = xs_c, Bh, Ch, dt_act
        y, h_new = ssd_chunked(xp, dtp, A, Bp, Cp, chunk, h0=h0)
        if pad:
            y = y[:, :S]
        new_cache = {"ssm": h_new, "conv": new_conv} if want_cache else None

    y = y + xs_c * p["D"].astype(dt_)[None, None, :, None]
    y = y.reshape(Bsz, S, di)
    # gated RMSNorm (mamba2's norm-before-gate variant)
    yf = y.astype(F32) * jax.nn.silu(z.astype(F32))
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    yn = (p["norm_w"].astype(F32) * yf * jax.lax.rsqrt(var + cfg.norm_eps)).astype(dt_)
    out = jnp.einsum("bse,ed->bsd", yn, p["out_proj"].astype(dt_))
    return shard(out, "batch", "seq", "embed"), new_cache


def mamba_cache_decl(cfg: ModelConfig, batch: int, dtype) -> dict:
    """ShapeDtypeStructs for one layer's mamba cache."""
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "ssm": jax.ShapeDtypeStruct(
            (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), F32
        ),
        "conv": jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, conv_ch), dtype),
    }
