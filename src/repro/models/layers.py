"""Core neural layers shared by all 10 architectures.

All functions are pure; parameters come from declarative ``ParamDecl``
trees (see params.py). Activations are annotated with logical sharding
axes via ``parallel.sharding.shard`` so the same model code lowers on a
single CPU device (no-op) and on the 512-chip production mesh.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from jax.ad_checkpoint import checkpoint_name

from ..parallel.sharding import shard
from .config import ModelConfig
from .params import ParamDecl


def _coll_out(x):
    """Tag row-parallel (all-reduced) outputs so the "coll" remat policy
    can save exactly these and avoid re-running forward collectives in
    the backward pass (see EXPERIMENTS.md SPerf, mixtral train)."""
    return checkpoint_name(x, "coll_out")

F32 = jnp.float32

# Pluggable scaled-dot-product-attention implementations. kernels/ops.py
# registers "pallas" on import; "jnp" is the oracle/default.
SDPA_IMPL: dict = {}


# ---------------------------------------------------------------------------
# Norms / activations / rope
# ---------------------------------------------------------------------------

def rms_norm(w: jax.Array, x: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(F32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (w.astype(F32) * xf * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def activate(x: jax.Array, act: str) -> jax.Array:
    return jax.nn.gelu(x) if act == "gelu" else jax.nn.silu(x)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> jax.Array:
    """(.., hd/2) rotation angles for given absolute positions."""
    freq = theta ** (-jnp.arange(0, head_dim // 2, dtype=F32) / (head_dim // 2))
    return positions.astype(F32)[..., None] * freq  # (..., hd/2)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float, interleaved: bool) -> jax.Array:
    """Rotary embedding. x: (B, S, N, hd); positions: (B, S).

    Interleaved pairing (2i, 2i+1) keeps rotation pairs local under
    head_dim tensor-parallel sharding (shards hold even-sized contiguous
    chunks >= 2), unlike the rotate-half formulation.
    """
    B, S, N, hd = x.shape
    ang = rope_angles(positions, hd, theta)[:, :, None, :]  # (B,S,1,hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    xf = x.astype(F32)
    if interleaved:
        x1 = xf[..., 0::2]
        x2 = xf[..., 1::2]
        r1 = x1 * cos - x2 * sin
        r2 = x1 * sin + x2 * cos
        out = jnp.stack([r1, r2], axis=-1).reshape(B, S, N, hd)
    else:
        half = hd // 2
        x1, x2 = xf[..., :half], xf[..., half:]
        out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attn_decl(cfg: ModelConfig, cross: bool = False) -> dict:
    d, h, k, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    decl = {
        "wq": ParamDecl((d, h, hd), ("fsdp", "heads", "q_param_hd"), fan_in=d),
        "wk": ParamDecl((d, k, hd), ("fsdp", "kv_heads", "kv_param_hd"), fan_in=d),
        "wv": ParamDecl((d, k, hd), ("fsdp", "kv_heads", "kv_param_hd"), fan_in=d),
        "wo": ParamDecl((h, hd, d), ("heads", "head_dim", "fsdp"), fan_in=h * hd),
    }
    if cfg.qkv_bias and not cross:
        decl["bq"] = ParamDecl((h, hd), ("heads", "head_dim"), init="zeros")
        decl["bk"] = ParamDecl((k, hd), ("kv_heads", "head_dim"), init="zeros")
        decl["bv"] = ParamDecl((k, hd), ("kv_heads", "head_dim"), init="zeros")
    return decl


def causal_window_mask(
    q_pos: jax.Array,  # (B, Sq) absolute positions of queries
    k_pos: jax.Array,  # (B, Sk) absolute positions of keys (-1 = empty slot)
    window: jax.Array | int | None,  # traced or static; <=0 / None = global
    causal: bool = True,
) -> jax.Array:
    d = q_pos[:, :, None] - k_pos[:, None, :]  # (B, Sq, Sk)
    ok = k_pos[:, None, :] >= 0
    if causal:
        ok &= d >= 0
    if window is not None:
        w = jnp.asarray(window, jnp.int32)
        ok &= (w <= 0) | (d < w)
    return ok


def _sdpa_dense(q, k, v, q_pos, k_pos, window, causal, cap) -> jax.Array:
    """Materialized-scores attention: (B,Sq,H,hd) x (B,Sk,K,hd)."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k, preferred_element_type=F32)
    scores = scores / math.sqrt(hd)
    scores = softcap(scores, cap)
    mask = causal_window_mask(q_pos, k_pos, window, causal)
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out.reshape(B, Sq, H, hd)


#: chunk the query axis when the full score tensor would exceed this many
#: elements per (batch, head) pair — the jnp analogue of flash attention.
_CHUNK_BUDGET = 1 << 20
_CHUNK_MIN_SQ = 1024


def _sdpa_jnp(q, k, v, q_pos, k_pos, window, causal, cap) -> jax.Array:
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    if Sq < _CHUNK_MIN_SQ or Sq * Sk <= _CHUNK_BUDGET:
        return _sdpa_dense(q, k, v, q_pos, k_pos, window, causal, cap)
    chunk = max(128, _CHUNK_BUDGET // Sk)
    while Sq % chunk:
        chunk //= 2
    nq = Sq // chunk
    qr = jnp.moveaxis(q.reshape(B, nq, chunk, H, hd), 1, 0)  # (nq,B,c,H,hd)
    pr = jnp.moveaxis(q_pos.reshape(B, nq, chunk), 1, 0)  # (nq,B,c)

    def body(_, inp):
        qc, pc = inp
        # checkpoint: recompute this chunk's scores in backward instead of
        # stashing (nq, B, H, chunk, Sk) residuals == the full score matrix
        return None, _sdpa_dense(qc, k, v, pc, k_pos, window, causal, cap)

    _, outs = jax.lax.scan(jax.checkpoint(body, prevent_cse=False), None, (qr, pr))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, hd)


SDPA_IMPL["jnp"] = _sdpa_jnp


def sdpa(q, k, v, *, q_pos, k_pos, window, causal, cap, impl: str = "jnp"):
    return SDPA_IMPL.get(impl, _sdpa_jnp)(q, k, v, q_pos, k_pos, window, causal, cap)


def quantize_kv(t: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-(slot, head) symmetric int8 over head_dim. t: (B,S,K,hd)."""
    amax = jnp.max(jnp.abs(t.astype(F32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(t.astype(F32) / scale), -127, 127).astype(jnp.int8)
    return q, scale[..., 0].astype(F32)  # (B,S,K,hd) s8, (B,S,K) f32


def dequantize_kv(q: jax.Array, scale: jax.Array, dt) -> jax.Array:
    return (q.astype(F32) * scale[..., None]).astype(dt)


def attention(
    p: dict,
    x: jax.Array,  # (B, S, D)
    *,
    cfg: ModelConfig,
    positions: jax.Array,  # (B, S)
    window: jax.Array | int | None = None,
    cache: Optional[dict] = None,  # {"k","v","pos_ids"} per-layer slices
    lengths: Optional[jax.Array] = None,  # (B,) current lengths (decode)
    kv_override: Optional[tuple] = None,  # cross-attn: (k, v, k_pos) precomputed
    causal: bool = True,
    use_rope: bool = True,
    impl: str = "jnp",
    kv_quant: bool = False,
):
    """Unified attention for train/prefill/decode/cross.

    Returns (out, new_cache). new_cache is None unless a cache was given
    or prefill requested one via cache={} sentinel. With kv_quant the
    cache stores int8 K/V (+ per-slot-head f32 scales): memory-bound
    decode reads half the bytes; dequantization fuses into the sdpa
    loads (EXPERIMENTS.md §Perf D).
    """
    B, S, D = x.shape
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
    q = shard(q, "batch", "seq", "act_heads", "act_head_dim")

    if kv_override is not None:
        k, v, k_pos = kv_override
        new_cache = None
    else:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
        if "bk" in p:
            k = k + p["bk"].astype(dt)
            v = v + p["bv"].astype(dt)
        if use_rope:
            k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_interleaved)
        k = shard(k, "batch", "seq", "act_kv_heads", "act_head_dim")
        v = shard(v, "batch", "seq", "act_kv_heads", "act_head_dim")
        if cache is not None and ("k" in cache or "k_q" in cache):
            # decode: write the S new entries (S==1) into ring/linear slots
            quant = "k_q" in cache
            Smax = (cache["k_q"] if quant else cache["k"]).shape[1]
            slot = (lengths[:, None] + jnp.arange(S)[None, :]) % Smax  # (B,S)
            oh = jax.nn.one_hot(slot, Smax, dtype=F32)  # (B,S,Smax)
            wrote = oh.sum(1) > 0  # (B, Smax) bool
            written = jnp.einsum(
                "bsm,bs->bm", oh.astype(jnp.int32), positions.astype(jnp.int32)
            )
            pos_ids = jnp.where(wrote, written, cache["pos_ids"])
            if quant:
                kq, ks = quantize_kv(k)
                vq, vs = quantize_kv(v)
                sel = wrote[:, :, None, None]
                # S == 1 on this path: broadcast the new entry to all slots
                # and select only the written one
                ck = jnp.where(sel, kq[:, 0:1], cache["k_q"])
                cv = jnp.where(sel, vq[:, 0:1], cache["v_q"])
                cks = jnp.where(wrote[:, :, None], ks[:, 0:1], cache["k_s"])
                cvs = jnp.where(wrote[:, :, None], vs[:, 0:1], cache["v_s"])
                new_cache = {"k_q": ck, "v_q": cv, "k_s": cks, "v_s": cvs,
                             "pos_ids": pos_ids}
                k = dequantize_kv(ck, cks, dt)
                v = dequantize_kv(cv, cvs, dt)
                k_pos = pos_ids
            else:
                ohd = oh.astype(dt)
                ck = cache["k"] * (1 - ohd.sum(1)[:, :, None, None])
                cv = cache["v"] * (1 - ohd.sum(1)[:, :, None, None])
                ck = ck + jnp.einsum("bsm,bshk->bmhk", ohd, k)
                cv = cv + jnp.einsum("bsm,bshk->bmhk", ohd, v)
                new_cache = {"k": ck, "v": cv, "pos_ids": pos_ids}
                k, v, k_pos = ck, cv, pos_ids
        elif cache is not None:
            # prefill requested a cache: keys are their own slots
            if kv_quant:
                kq, ks = quantize_kv(k)
                vq, vs = quantize_kv(v)
                new_cache = {"k_q": kq, "v_q": vq, "k_s": ks, "v_s": vs,
                             "pos_ids": positions}
                # serve exactly what decode will read (quantized)
                k = dequantize_kv(kq, ks, dt)
                v = dequantize_kv(vq, vs, dt)
            else:
                new_cache = {"k": k, "v": v, "pos_ids": positions}
            k_pos = positions
        else:
            new_cache = None
            k_pos = positions

    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_interleaved)
    out = sdpa(
        q, k, v,
        q_pos=positions, k_pos=k_pos, window=window, causal=causal,
        cap=cfg.attn_logit_softcap, impl=impl,
    )
    if cfg.attn_out_scale is not None:
        out = out * cfg.attn_out_scale
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    y = _coll_out(shard(y, "batch", "seq", "embed"))
    return y, new_cache


# ---------------------------------------------------------------------------
# MLP / MoE
# ---------------------------------------------------------------------------

def mlp_decl(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "wi": ParamDecl((d, f), ("fsdp", "ff"), fan_in=d),
        "wg": ParamDecl((d, f), ("fsdp", "ff"), fan_in=d),
        "wo": ParamDecl((f, d), ("ff", "fsdp"), fan_in=f),
    }


def mlp_apply(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = x.dtype
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(dt))
    g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(dt))
    h = activate(g, cfg.act) * h
    h = shard(h, "batch", "seq", "ff")
    y = jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(dt))
    return _coll_out(shard(y, "batch", "seq", "embed"))


def moe_decl(cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    # Axis priority: experts claim "model" when divisible (EP, e.g. 16
    # experts on a 16-way axis); otherwise the fallback lets "ff" claim it
    # (TP-MoE, e.g. mixtral's 8 experts on a 16-way axis). See sharding.py.
    return {
        "router": ParamDecl((d, e), ("fsdp", None), fan_in=d),
        "wi": ParamDecl((e, d, f), ("experts", "fsdp", "moe_ff"), fan_in=d),
        "wg": ParamDecl((e, d, f), ("experts", "fsdp", "moe_ff"), fan_in=d),
        "wo": ParamDecl((e, f, d), ("experts", "moe_ff", "fsdp"), fan_in=f),
    }


def moe_capacity(tokens: int, k: int, e: int, cf: float) -> int:
    c = int(math.ceil(tokens * k * cf / e))
    return max(8, -(-c // 8) * 8)  # round up to 8 lanes


def moe_apply(p: dict, x: jax.Array, cfg: ModelConfig):
    """Token-choice top-k MoE with GROUP-LOCAL sort-based dispatch.

    Routing groups are batch rows, so dispatch gathers/scatters stay inside
    the data shard (no global token all-gather; the only cross-device
    traffic is the expert-parallel all-to-all induced by resharding the
    (group, expert, capacity, d) tensor from batch- to expert-sharded).
    A naive globally-flattened dispatch was measured at ~8 TB/chip of
    all-gather on mixtral train_4k — see EXPERIMENTS.md §Perf.

    Returns (y, aux_loss). Dropless up to capacity_factor per group.
    """
    B, S, D = x.shape
    if S == 1 and B <= 16 and cfg.num_experts % 16 != 0:
        # tiny-batch decode: gather ONLY the top-k experts' weights.
        # The capacity path streams every expert's weights per step -
        # measured 3.5x excess HBM traffic on mixtral long_500k decode
        # (EXPERIMENTS.md SPerf C2). Gated to archs whose experts cannot
        # shard the 16-way model axis (mixtral: E=8 -> weights local);
        # for EP-sharded experts (jamba/phi3.5: E=16) the gather crosses
        # devices and was measured 3.6x SLOWER than capacity dispatch.
        return _moe_gathered(p, x, cfg)
    if S == 1:  # decode: one group over the (small) batch
        y, aux = _moe_grouped(p, x.reshape(1, B, D), cfg)
        return y.reshape(B, S, D), aux
    y, aux = _moe_grouped(p, x, cfg)
    return y, aux


def _moe_gathered(p: dict, x: jax.Array, cfg: ModelConfig):
    """Dropless per-token expert-weight gather; exact for any batch, used
    when weight streaming (not compute) dominates. x: (B, 1, D)."""
    B, S, D = x.shape
    dt = x.dtype
    K = cfg.top_k
    xf = x[:, 0]  # (B, D)
    logits = jnp.einsum("bd,de->be", xf.astype(F32), p["router"].astype(F32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, K)  # (B, K)
    gate = (gate / jnp.sum(gate, axis=-1, keepdims=True)).astype(dt)
    wi = jnp.take(p["wi"], eidx, axis=0).astype(dt)  # (B, K, D, F)
    wg = jnp.take(p["wg"], eidx, axis=0).astype(dt)
    wo = jnp.take(p["wo"], eidx, axis=0).astype(dt)  # (B, K, F, D)
    h = jnp.einsum("bd,bkdf->bkf", xf, wi)
    g = jnp.einsum("bd,bkdf->bkf", xf, wg)
    h = activate(g, cfg.act) * h
    y = jnp.einsum("bkf,bkfd->bd", h * gate[..., None], wo)
    aux = jnp.zeros((), F32)  # no aux loss on the decode path
    return y[:, None, :], aux


def _moe_grouped(p: dict, xg: jax.Array, cfg: ModelConfig):
    """xg: (G, T, D) — G routing groups of T tokens each."""
    G, T, D = xg.shape
    dt = xg.dtype
    E, K = cfg.num_experts, cfg.top_k
    C = moe_capacity(T, K, E, cfg.capacity_factor)

    logits = jnp.einsum("gtd,de->gte", xg.astype(F32), p["router"].astype(F32))
    probs = jax.nn.softmax(logits, axis=-1)  # (G, T, E)
    gate, eidx = jax.lax.top_k(probs, K)  # (G, T, K)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    flat_e = eidx.reshape(G, T * K)
    order = jnp.argsort(flat_e, axis=-1)  # (G, T*K) slot ids sorted by expert
    counts = jnp.sum(jax.nn.one_hot(flat_e, E, dtype=jnp.int32), axis=1)  # (G, E)
    starts = jnp.cumsum(counts, axis=-1) - counts  # exclusive (G, E)
    pos = starts[:, :, None] + jnp.arange(C, dtype=jnp.int32)[None, None, :]
    valid = jnp.arange(C, dtype=jnp.int32)[None, None, :] < counts[:, :, None]
    slot = jnp.take_along_axis(
        order, jnp.minimum(pos, T * K - 1).reshape(G, E * C), axis=-1
    )  # (G, E*C)
    token = slot // K

    xe = jnp.take_along_axis(xg, token[..., None], axis=1)  # (G, E*C, D)
    xe = xe.reshape(G, E, C, D) * valid[..., None].astype(dt)
    xe = shard(xe, "batch", "experts", "capacity", "embed")
    h = jnp.einsum("gecd,edf->gecf", xe, p["wi"].astype(dt))
    g_ = jnp.einsum("gecd,edf->gecf", xe, p["wg"].astype(dt))
    h = activate(g_, cfg.act) * h
    h = shard(h, "batch", "experts", "capacity", "moe_ff")
    ye = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(dt))  # (G, E, C, D)
    ye = _coll_out(ye)  # direct output of the row-parallel partial-sum einsum

    gate_gc = jnp.take_along_axis(gate.reshape(G, T * K), slot, axis=-1)
    gate_gc = jnp.where(valid.reshape(G, E * C), gate_gc, 0.0)
    contrib = ye.reshape(G, E * C, D) * gate_gc[..., None].astype(dt)

    def scatter_row(tok, c):  # (E*C,), (E*C, D)
        return jnp.zeros((T, D), dt).at[tok].add(c)

    y = jax.vmap(scatter_row)(token, contrib)  # (G, T, D)
    y = _coll_out(shard(y, "batch", "seq", "embed"))

    # load-balancing aux loss (Switch/Mixtral formulation), averaged over groups
    me = jnp.mean(probs, axis=1)  # (G, E)
    assign = counts.astype(F32) / (T * K)  # (G, E)
    aux = E * jnp.mean(jnp.sum(me * assign, axis=-1))
    return y, aux
