from .config import SHAPES, ModelConfig, ShapeCell
from .registry import build_model
from .transformer import LM
