"""Unified LM covering all 10 assigned architectures.

Depth is organized as ``n_super`` super-layers of ``period`` sublayers and
scanned with ``jax.lax.scan`` so the HLO contains each distinct sublayer
body exactly once (keeps multi-pod compiles tractable). Uniform archs have
period == 1; gemma2's local/global alternation gives period == 2; jamba's
mamba/attention 7:1 interleave with alternating dense/MoE FFNs gives
period == 8. Encoder-decoder (seamless) adds an encoder stack and
cross-attention to every decoder sublayer.

Cache layout (decode-ready):
  {"lengths": (B,), "blocks": <stacked per-super self caches>,
   "cross": <stacked cross-KV, enc-dec only>}
Cross-KV is read-only during decode, so it rides through the layer scan as
`xs` (never re-emitted as `ys`) — XLA does not copy it per step.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..parallel.sharding import shard
from .config import ModelConfig
from .layers import (
    attention,
    attn_decl,
    mlp_apply,
    mlp_decl,
    moe_apply,
    moe_decl,
    rms_norm,
    softcap,
)
from .params import ParamDecl, axes_tree, init_tree, shape_tree, stacked
from .ssd import mamba_apply, mamba_cache_decl, mamba_decl

F32 = jnp.float32


def ce_loss(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean next-token cross-entropy; logits (B,S,V) f32, targets (B,S)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


#: sequence-chunk the LM-head + CE when S exceeds this: the full (B,S,V)
#: logits tensor (and its gradient) never materializes in HBM.
_CE_CHUNK = 512


def chunked_ce(head_fn, x: jax.Array, targets: jax.Array) -> jax.Array:
    """CE over head_fn(x-chunk) with rematerialized chunks. x: (B,S,D)."""
    B, S, D = x.shape
    if S <= 2 * _CE_CHUNK:
        return ce_loss(head_fn(x), targets)
    c = _CE_CHUNK
    while S % c:
        c //= 2
    nc = S // c
    xr = jnp.moveaxis(x.reshape(B, nc, c, D), 1, 0)  # (nc,B,c,D)
    tr = jnp.moveaxis(targets.reshape(B, nc, c), 1, 0)

    def body(acc, inp):
        xc, tc = inp
        logits = head_fn(xc)  # (B,c,V) f32
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(logz - gold), None

    acc, _ = jax.lax.scan(
        jax.checkpoint(body, prevent_cse=False), jnp.zeros((), F32), (xr, tr)
    )
    return acc / (B * S)


class LM:
    """Decoder-only / hybrid / enc-dec language model."""

    def __init__(self, cfg: ModelConfig, impl: str = "jnp", scan_unroll: bool = False,
                 kv_quant: bool = False):
        self.cfg = cfg
        self.impl = impl
        self.kv_quant = kv_quant  # int8 KV cache (serving)
        # unroll=True inlines every layer into the HLO: used by the
        # roofline differencing builds (perf/), where collectives inside a
        # rolled `while` body would be counted once regardless of depth
        self.scan_unroll = scan_unroll
        if cfg.is_hybrid:
            self.period = cfg.hybrid_period
        elif cfg.local_global_pattern:
            self.period = len(cfg.local_global_pattern)
        else:
            self.period = 1
        assert cfg.num_layers % self.period == 0, (cfg.num_layers, self.period)
        self.n_super = cfg.num_layers // self.period
        self.kinds = cfg.layer_kinds()[: self.period]
        self.ffns = cfg.ffn_kinds()[: self.period]
        self.windows = cfg.window_pattern()[: self.period]
        self.has_ffn = cfg.d_ff > 0

    # ------------------------------------------------------------------
    # Declarations
    # ------------------------------------------------------------------
    def _sub_decl(self, i: int, cross: bool) -> dict:
        cfg = self.cfg
        d = {"ln1": ParamDecl((cfg.d_model,), ("embed",), init="ones")}
        if self.kinds[i] == "attn":
            d["attn"] = attn_decl(cfg)
        else:
            d["mamba"] = mamba_decl(cfg)
        if cfg.post_block_norms:
            d["ln1p"] = ParamDecl((cfg.d_model,), ("embed",), init="ones")
        if cross and self.kinds[i] == "attn":
            d["ln_x"] = ParamDecl((cfg.d_model,), ("embed",), init="ones")
            d["cross"] = attn_decl(cfg, cross=True)
        if self.has_ffn:
            d["ln2"] = ParamDecl((cfg.d_model,), ("embed",), init="ones")
            if self.ffns[i] == "moe":
                d["moe"] = moe_decl(cfg)
            else:
                d["mlp"] = mlp_decl(cfg)
            if cfg.post_block_norms:
                d["ln2p"] = ParamDecl((cfg.d_model,), ("embed",), init="ones")
        return d

    def decls(self) -> dict:
        cfg = self.cfg
        per = {
            f"sub{i}": self._sub_decl(i, cross=cfg.is_encoder_decoder)
            for i in range(self.period)
        }
        tree = {
            "embed": ParamDecl(
                (cfg.vocab_size, cfg.d_model), ("vocab", "fsdp"), fan_in=cfg.d_model
            ),
            "blocks": stacked(per, self.n_super),
            "final_norm": ParamDecl((cfg.d_model,), ("embed",), init="ones"),
        }
        if not cfg.tie_embeddings:
            tree["lm_head"] = ParamDecl(
                (cfg.d_model, cfg.vocab_size), ("fsdp", "vocab"), fan_in=cfg.d_model
            )
        if cfg.is_encoder_decoder:
            enc_sub = {
                "ln1": ParamDecl((cfg.d_model,), ("embed",), init="ones"),
                "attn": attn_decl(cfg),
                "ln2": ParamDecl((cfg.d_model,), ("embed",), init="ones"),
                "mlp": mlp_decl(cfg),
            }
            tree["enc_blocks"] = stacked({"sub0": enc_sub}, cfg.num_encoder_layers)
            tree["enc_final_norm"] = ParamDecl((cfg.d_model,), ("embed",), init="ones")
        return tree

    def init(self, key: jax.Array, dtype=F32) -> dict:
        return init_tree(key, self.decls(), dtype)

    def param_axes(self) -> dict:
        return axes_tree(self.decls())

    def param_shapes(self, dtype=F32) -> dict:
        return shape_tree(self.decls(), dtype)

    # ------------------------------------------------------------------
    # Sublayer body
    # ------------------------------------------------------------------
    def _sub_apply(
        self,
        p: dict,
        i: int,
        x: jax.Array,
        *,
        positions: jax.Array,
        cache: Optional[dict],
        lengths: Optional[jax.Array],
        want_cache: bool,
        enc_out: Optional[jax.Array],
        cross_kv: Optional[dict],
    ):
        cfg = self.cfg
        h = rms_norm(p["ln1"], x, cfg.norm_eps)
        new_cache: dict = {}
        if self.kinds[i] == "attn":
            if cache is not None:
                c_in = cache["attn"]
            elif want_cache:
                c_in = {}
            else:
                c_in = None
            mix, nc = attention(
                p["attn"],
                h,
                cfg=cfg,
                positions=positions,
                window=self.windows[i],
                cache=c_in,
                lengths=lengths,
                impl=self.impl,
                kv_quant=self.kv_quant,
            )
            if nc is not None:
                new_cache["attn"] = nc
        else:
            c_in = cache["mamba"] if cache is not None else None
            mix, nc = mamba_apply(
                p["mamba"], h, cfg=cfg, cache=c_in, want_cache=want_cache, impl=self.impl
            )
            if nc is not None:
                new_cache["mamba"] = nc
        if cfg.post_block_norms:
            mix = rms_norm(p["ln1p"], mix, cfg.norm_eps)
        x = x + mix

        if "cross" in p and (enc_out is not None or cross_kv is not None):
            h = rms_norm(p["ln_x"], x, cfg.norm_eps)
            if cross_kv is not None:
                kv = (cross_kv["k"], cross_kv["v"], cross_kv["pos_ids"])
            else:
                dt = h.dtype
                ek = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wk"].astype(dt))
                ev = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wv"].astype(dt))
                epos = jnp.broadcast_to(
                    jnp.arange(enc_out.shape[1], dtype=jnp.int32)[None],
                    enc_out.shape[:2],
                )
                if want_cache:
                    new_cache["cross"] = {"k": ek, "v": ev, "pos_ids": epos}
                kv = (ek, ev, epos)
            cx, _ = attention(
                p["cross"],
                h,
                cfg=cfg,
                positions=positions,
                kv_override=kv,
                causal=False,
                use_rope=False,
                impl=self.impl,
            )
            x = x + cx

        aux = jnp.zeros((), F32)
        if self.has_ffn:
            h = rms_norm(p["ln2"], x, cfg.norm_eps)
            if self.ffns[i] == "moe":
                f, aux = moe_apply(p["moe"], h, cfg)
            else:
                f = mlp_apply(p["mlp"], h, cfg)
            if cfg.post_block_norms:
                f = rms_norm(p["ln2p"], f, cfg.norm_eps)
            x = x + f
        return x, new_cache, aux

    # ------------------------------------------------------------------
    # Layer scan
    # ------------------------------------------------------------------
    def _scan_blocks(
        self,
        params: dict,
        x: jax.Array,
        *,
        positions: jax.Array,
        cache: Optional[dict] = None,  # stacked self caches (decode)
        cross: Optional[dict] = None,  # stacked cross-KV (decode, read-only)
        lengths: Optional[jax.Array] = None,
        want_cache: bool = False,
        enc_out: Optional[jax.Array] = None,
        remat: Optional[str] = None,
    ):
        has_cache, has_cross = cache is not None, cross is not None

        def body(carry, xs):
            xc = carry
            p_super, cache_s, cross_s = xs
            caches, auxes = {}, []
            for i in range(self.period):
                sub_cache = cache_s.get(f"sub{i}") if has_cache else None
                sub_cross = cross_s.get(f"sub{i}") if has_cross else None
                xc, nc, aux = self._sub_apply(
                    p_super[f"sub{i}"],
                    i,
                    xc,
                    positions=positions,
                    cache=sub_cache,
                    lengths=lengths,
                    want_cache=want_cache,
                    enc_out=enc_out,
                    cross_kv=sub_cross,
                )
                caches[f"sub{i}"] = nc
                auxes.append(aux)
            return xc, (caches, sum(auxes))

        if remat == "full":
            body = jax.checkpoint(body, prevent_cse=False)
        elif remat == "dots":
            body = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
                prevent_cse=False,
            )
        elif remat == "coll":
            # save only the all-reduced sublayer outputs: backward never
            # re-runs forward collectives, residual memory stays ~(B,S,D)
            body = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.save_only_these_names("coll_out"),
                prevent_cse=False,
            )
        xs = (params["blocks"], cache if has_cache else {}, cross if has_cross else {})
        x, (new_caches, auxes) = jax.lax.scan(body, x, xs, unroll=self.scan_unroll)
        return x, new_caches, jnp.sum(auxes)

    # ------------------------------------------------------------------
    # Embedding / head / encoder
    # ------------------------------------------------------------------
    def embed(self, params, tokens, frontend_embeds=None, dtype=jnp.bfloat16):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
        if cfg.scale_embeddings:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), dtype)
        if frontend_embeds is not None:
            x = jnp.concatenate([frontend_embeds.astype(dtype), x], axis=1)
        return shard(x, "batch", "seq", "embed")

    def head(self, params, x) -> jax.Array:
        cfg = self.cfg
        w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum(
            "bsd,dv->bsv", x, w.astype(x.dtype), preferred_element_type=F32
        )
        logits = softcap(logits, cfg.final_logit_softcap)
        return shard(logits, "batch", "seq", "vocab")

    def encode(self, params, enc_embeds, remat=None):
        """Encoder stack over precomputed frame embeddings (audio stub)."""
        cfg = self.cfg
        x = shard(enc_embeds, "batch", "seq", "embed")
        positions = jnp.broadcast_to(
            jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2]
        )

        def body(carry, p_super):
            h = carry
            p = p_super["sub0"]
            a = rms_norm(p["ln1"], h, cfg.norm_eps)
            mix, _ = attention(
                p["attn"], a, cfg=cfg, positions=positions, causal=False, impl=self.impl
            )
            h = h + mix
            f = mlp_apply(p["mlp"], rms_norm(p["ln2"], h, cfg.norm_eps), cfg)
            return h + f, None

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["enc_blocks"], unroll=self.scan_unroll)
        return rms_norm(params["enc_final_norm"], x, cfg.norm_eps)

    # ------------------------------------------------------------------
    # Public steps
    # ------------------------------------------------------------------
    def forward(self, params, tokens, *, frontend_embeds=None, enc_embeds=None,
                remat=None, dtype=jnp.bfloat16):
        """Teacher-forced forward; returns (logits, moe_aux)."""
        x, aux = self.hidden(
            params, tokens, frontend_embeds=frontend_embeds,
            enc_embeds=enc_embeds, remat=remat, dtype=dtype,
        )
        return self.head(params, x), aux

    def hidden(self, params, tokens, *, frontend_embeds=None, enc_embeds=None,
               remat=None, dtype=jnp.bfloat16):
        """Embed -> blocks -> final norm; returns (x, moe_aux)."""
        cfg = self.cfg
        x = self.embed(params, tokens, frontend_embeds, dtype)
        positions = jnp.broadcast_to(
            jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2]
        )
        enc_out = None
        if cfg.is_encoder_decoder:
            assert enc_embeds is not None, "enc-dec model requires enc_embeds"
            enc_out = self.encode(params, enc_embeds.astype(dtype), remat=remat)
        x, _, aux = self._scan_blocks(
            params, x, positions=positions, enc_out=enc_out, remat=remat
        )
        return rms_norm(params["final_norm"], x, cfg.norm_eps), aux

    def loss(self, params, batch, *, remat=None, dtype=jnp.bfloat16):
        """batch: tokens (B,S), targets (B,S) [+ patch_embeds / enc_embeds]."""
        cfg = self.cfg
        x, aux = self.hidden(
            params,
            batch["tokens"],
            frontend_embeds=batch.get("patch_embeds"),
            enc_embeds=batch.get("enc_embeds"),
            remat=remat,
            dtype=dtype,
        )
        if cfg.frontend == "vision_patches" and cfg.frontend_tokens:
            x = x[:, cfg.frontend_tokens :, :]
        ce = chunked_ce(lambda xc: self.head(params, xc), x, batch["targets"])
        total = ce + cfg.router_aux_weight * aux
        return total, {"ce": ce, "aux": aux}

    # --- serving ---
    def _attn_cache_len(self, kv_len: int, window: Optional[int]) -> int:
        if window and 0 < window <= kv_len:
            return window  # ring buffer
        return kv_len + 128  # headroom so full-attn decode never wraps

    def cache_spec(self, batch: int, kv_len: int, dtype=jnp.bfloat16,
                   enc_len: Optional[int] = None) -> dict:
        """ShapeDtypeStructs for a decode-ready cache at context kv_len."""
        cfg = self.cfg
        K, hd = cfg.num_kv_heads, cfg.head_dim
        per, per_cross = {}, {}
        for i in range(self.period):
            sub = {}
            if self.kinds[i] == "attn":
                smax = self._attn_cache_len(kv_len, self.windows[i])
                if self.kv_quant:
                    sub["attn"] = {
                        "k_q": jax.ShapeDtypeStruct((batch, smax, K, hd), jnp.int8),
                        "v_q": jax.ShapeDtypeStruct((batch, smax, K, hd), jnp.int8),
                        "k_s": jax.ShapeDtypeStruct((batch, smax, K), F32),
                        "v_s": jax.ShapeDtypeStruct((batch, smax, K), F32),
                        "pos_ids": jax.ShapeDtypeStruct((batch, smax), jnp.int32),
                    }
                else:
                    sub["attn"] = {
                        "k": jax.ShapeDtypeStruct((batch, smax, K, hd), dtype),
                        "v": jax.ShapeDtypeStruct((batch, smax, K, hd), dtype),
                        "pos_ids": jax.ShapeDtypeStruct((batch, smax), jnp.int32),
                    }
                if cfg.is_encoder_decoder:
                    senc = enc_len or kv_len
                    per_cross[f"sub{i}"] = {
                        "k": jax.ShapeDtypeStruct((batch, senc, K, hd), dtype),
                        "v": jax.ShapeDtypeStruct((batch, senc, K, hd), dtype),
                        "pos_ids": jax.ShapeDtypeStruct((batch, senc), jnp.int32),
                    }
            else:
                sub["mamba"] = mamba_cache_decl(cfg, batch, dtype)
            per[f"sub{i}"] = sub

        def stack(sd):
            return jax.ShapeDtypeStruct((self.n_super,) + sd.shape, sd.dtype)

        out = {
            "lengths": jax.ShapeDtypeStruct((batch,), jnp.int32),
            "blocks": jax.tree.map(stack, per),
        }
        if cfg.is_encoder_decoder:
            out["cross"] = jax.tree.map(stack, per_cross)
        return out

    def cache_axes(self, cache_spec: dict) -> dict:
        """Logical sharding axes for every cache leaf (by leaf name)."""

        def one(path, leaf):
            names = [getattr(k, "key", str(k)) for k in path]
            stacked_ = "blocks" in names or "cross" in names
            lead = ("layers",) if stacked_ else ()
            name = names[-1]
            if name == "lengths":
                return ("batch",)
            if name in ("k", "v", "k_q", "v_q"):
                return lead + ("batch", "kv_seq", "kv_heads", "head_dim")
            if name in ("k_s", "v_s"):
                return lead + ("batch", "kv_seq", "kv_heads")
            if name == "pos_ids":
                return lead + ("batch", "kv_seq")
            if name == "ssm":
                return lead + ("batch", "ssm_heads", None, None)
            if name == "conv":
                return lead + ("batch", None, "conv_ch")
            raise ValueError(f"unknown cache leaf {names}")

        return jax.tree_util.tree_map_with_path(one, cache_spec)

    def init_cache(self, batch: int, kv_len: int, dtype=jnp.bfloat16,
                   enc_len: Optional[int] = None) -> dict:
        spec = self.cache_spec(batch, kv_len, dtype, enc_len)

        def zero(sd):
            if sd.dtype == jnp.int32:
                return jnp.full(sd.shape, -1, jnp.int32)
            return jnp.zeros(sd.shape, sd.dtype)

        cache = jax.tree.map(zero, spec)
        cache["lengths"] = jnp.zeros((batch,), jnp.int32)
        return cache

    def prefill(self, params, tokens, *, kv_len: Optional[int] = None,
                frontend_embeds=None, enc_embeds=None, dtype=jnp.bfloat16):
        """Process a full prompt; returns (last_logits, decode-ready cache)."""
        cfg = self.cfg
        x = self.embed(params, tokens, frontend_embeds, dtype)
        B, S = x.shape[:2]
        kv_len = kv_len or S
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        enc_out = None
        if cfg.is_encoder_decoder:
            enc_out = self.encode(params, enc_embeds.astype(dtype))
        x, caches, _ = self._scan_blocks(
            params, x, positions=positions, want_cache=True, enc_out=enc_out
        )
        x = rms_norm(params["final_norm"], x, cfg.norm_eps)
        logits = self.head(params, x[:, -1:, :])[:, 0]
        cache = self._finalize_prefill_cache(caches, B, S, kv_len)
        return logits, cache

    def _finalize_prefill_cache(self, caches, B, S, kv_len):
        """Pad/ring-place prefill K/V into the decode-cache layout."""

        def place(path, leaf):
            names = [getattr(k, "key", str(k)) for k in path]
            if "mamba" in names or "cross" in names:
                return leaf
            sub_i = int([n for n in names if n.startswith("sub")][0][3:])
            smax = self._attn_cache_len(kv_len, self.windows[sub_i])
            is_pos = names[-1] == "pos_ids"
            # leaf: (n_super, B, S, ...)
            if smax >= S:
                pad = [(0, 0)] * leaf.ndim
                pad[2] = (0, smax - S)
                return jnp.pad(leaf, pad, constant_values=-1 if is_pos else 0)
            # ring: contiguous prefill keeps the last smax positions at
            # slots p % smax
            idx = jnp.arange(S - smax, S) % smax
            kept = leaf[:, :, S - smax :]
            out = jnp.full(
                leaf.shape[:2] + (smax,) + leaf.shape[3:],
                -1 if is_pos else 0,
                leaf.dtype,
            )
            return out.at[:, :, idx].set(kept)

        blocks = jax.tree_util.tree_map_with_path(place, caches)
        out = {"lengths": jnp.full((B,), S, jnp.int32), "blocks": blocks}
        if self.cfg.is_encoder_decoder:
            cross = {}
            for sk, sub in blocks.items():
                if "cross" in sub:
                    cross[sk] = sub.pop("cross")
            out["cross"] = cross
        return out

    def decode_step(self, params, cache, tokens, dtype=jnp.bfloat16):
        """One decode step for every sequence. tokens: (B, S_new).

        Returns (logits (B, V) for the last position, new cache)."""
        cfg = self.cfg
        lengths = cache["lengths"]
        x = self.embed(params, tokens, None, dtype)
        positions = lengths[:, None] + jnp.arange(tokens.shape[1], dtype=jnp.int32)[None]
        x, new_blocks, _ = self._scan_blocks(
            params,
            x,
            positions=positions,
            cache=cache["blocks"],
            cross=cache.get("cross"),
            lengths=lengths,
            want_cache=False,
        )
        x = rms_norm(params["final_norm"], x, cfg.norm_eps)
        logits = self.head(params, x)[:, -1]
        new_cache = {"lengths": lengths + tokens.shape[1], "blocks": new_blocks}
        if "cross" in cache:
            new_cache["cross"] = cache["cross"]
        return logits, new_cache
