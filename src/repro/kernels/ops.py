"""Jitted public wrappers around the Pallas kernels.

* On TPU the kernels run compiled (interpret=False); on this CPU
  container they run in interpret mode — same kernel body, Python
  evaluation — which is how tests validate them.
* ``sdpa_flash`` registers itself as the "pallas" SDPA implementation in
  models/layers.py, so any model can switch its attention inner loop to
  the kernel with ``LM(cfg, impl="pallas")``.
* Training differentiability: flash_attention gets a custom_vjp whose
  backward rematerializes through the jnp oracle (exact same math). The
  dedicated TPU backward kernel is future work; serving (the paper's
  workload) only needs forward.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..models import layers as _layers
from .decode_attention import decode_attention
from .flash_attention import flash_attention
from .ref import decode_attention_ref, flash_attention_ref, ssd_scan_ref
from .ssd_scan import ssd_scan

ON_TPU = any(d.platform == "tpu" for d in jax.devices())
INTERPRET = not ON_TPU


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention_diff(q, k, v, causal=True, window=0, softcap=0.0):
    return flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap, interpret=INTERPRET
    )


def _fa_fwd(q, k, v, causal, window, softcap):
    out = flash_attention_diff(q, k, v, causal, window, softcap)
    return out, (q, k, v)


def _fa_bwd(causal, window, softcap, res, g):
    q, k, v = res

    def ref(q, k, v):
        return flash_attention_ref(
            q, k, v, causal=causal, window=window, softcap=softcap
        )

    _, vjp = jax.vjp(ref, q, k, v)
    return vjp(g)


flash_attention_diff.defvjp(_fa_fwd, _fa_bwd)


def sdpa_flash(q, k, v, q_pos, k_pos, window, causal, cap):
    """models/layers.py SDPA_IMPL["pallas"] adapter.

    Contiguous-position fast paths use the kernels; ragged cases (ring
    caches mid-wrap, cross-attention against cached positions) fall back
    to the oracle.
    """
    B, Sq, H, hd = q.shape
    win = int(window) if isinstance(window, int) and window else 0
    capf = float(cap) if cap else 0.0
    if Sq == 1 and k.shape[1] % 128 == 0:
        lengths = q_pos[:, 0]
        return decode_attention(
            q[:, 0], k, v, k_pos, lengths,
            window=win, softcap=capf, interpret=INTERPRET,
        )[:, None]
    if Sq % 128 == 0 and k.shape[1] % 128 == 0 and Sq == k.shape[1]:
        return flash_attention_diff(q, k, v, causal, win, capf)
    return _layers._sdpa_jnp(q, k, v, q_pos, k_pos, window, causal, cap)


_layers.SDPA_IMPL["pallas"] = sdpa_flash

__all__ = [
    "flash_attention",
    "flash_attention_diff",
    "decode_attention",
    "ssd_scan",
    "sdpa_flash",
    "flash_attention_ref",
    "decode_attention_ref",
    "ssd_scan_ref",
]
